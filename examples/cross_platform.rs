//! Cross-platform knowledge transfer (§6.2): synthesize on CUDA,
//! reuse the correct CUDA program as a reference when targeting every
//! *other* registered platform.
//!
//! Demonstrates the paper's second contribution — a reference
//! implementation from one architecture substantially improves
//! generation quality for a different hardware target — and the open
//! platform API: the target list below is the registry, not a
//! hardcoded pair, so a newly registered accelerator shows up here
//! with zero changes.
//!
//! ```bash
//! cargo run --release --example cross_platform
//! ```

use kforge::agents::persona::by_name;
use kforge::coordinator::{run_campaign, ExperimentConfig};
use kforge::metrics;
use kforge::workloads::refcorpus::RefCorpus;
use kforge::workloads::{Level, Suite};

fn main() -> anyhow::Result<()> {
    let suite = Suite::sample(20); // 20 problems per level
    let persona = by_name("claude-opus-4").unwrap();

    // 1. build the CUDA reference corpus (first correct program per task)
    println!("building CUDA reference corpus...");
    let corpus = RefCorpus::build(&suite, 6, 0xC0DE);
    println!(
        "corpus coverage: {:.0}% of {} problems\n",
        corpus.coverage(&suite) * 100.0,
        suite.len()
    );

    // 2. every registered platform where a CUDA reference acts as
    //    cross-architecture transfer: baseline vs +reference
    for platform in kforge::platform::registry().platforms() {
        if !platform.reference_transfer() {
            continue; // the reference's home platform
        }
        let mut cfg = ExperimentConfig::iterative(platform.clone(), vec![persona]);
        cfg.name = format!("xplat_{}_baseline", platform.name());
        cfg.iterations = 1; // single-shot, as in Table 4
        let baseline = run_campaign(&suite, None, &cfg);

        let mut cfg_ref = cfg.clone();
        cfg_ref.name = format!("xplat_{}_cudaref", platform.name());
        cfg_ref.use_reference = true;
        let with_ref = run_campaign(&suite, Some(&corpus), &cfg_ref);

        println!(
            "single-shot correctness on {} ({}):",
            platform.name(),
            persona.name
        );
        println!("{:<10} {:>10} {:>16}", "level", "baseline", "+CUDA reference");
        for level in Level::ALL {
            let b = metrics::correctness_rate(&baseline.outcomes(persona.name, level));
            let r = metrics::correctness_rate(&with_ref.outcomes(persona.name, level));
            println!("{:<10} {b:>10.2} {r:>16.2}", level.name());
        }
        println!();
    }
    println!(
        "the CUDA reference transfers fusion/vectorization decisions across\n\
         platforms — \"some implementation patterns are language-agnostic and,\n\
         to some extent, hardware-agnostic\" (§6.2)."
    );
    Ok(())
}
