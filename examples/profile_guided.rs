//! Profile-guided optimization (§3.2, §5.2, §6.3): each platform's
//! registered profiler frontend turns the raw profile into its native
//! artifact (nsys CSV tables, Xcode screenshots, rocprof trace JSON),
//! interprets it into the Evidence IR, and the analysis agent ranks a
//! recommendation from the evidence alone — with the capture fidelity
//! surfaced as confidence.
//!
//! ```bash
//! cargo run --release --example profile_guided                     # all platforms
//! cargo run --release --example profile_guided -- --platform rocm # one platform
//! cargo run --release --example profile_guided -- --list          # names, one per line
//! ```

use kforge::agents::analysis::AnalysisAgent;
use kforge::perfsim::{lower, simulate};
use kforge::platform::PlatformRef;
use kforge::profiler::Profile;
use kforge::sched::Schedule;
use kforge::util::rng::Pcg;
use kforge::workloads::Suite;

fn run_platform(platform: &PlatformRef) -> anyhow::Result<()> {
    let suite = Suite::full();
    let problem = suite.get("l3_squeezenet_fire").unwrap();
    let naive = Schedule::naive();
    let mut rng = Pcg::seed(7);

    let spec = platform.spec();
    let plan = lower::lower(&problem.perf_graph, &naive);
    let sim = simulate(spec, &plan, &mut rng, 100, 10);
    let profile = Profile::from_sim(&problem.id, spec.name, &sim);

    let frontend = platform.profiler_frontend();
    println!(
        "========= {}: {} frontend ({:?}, {}) =========\n",
        spec.name,
        frontend.name(),
        frontend.kind(),
        if frontend.lossless() { "recommendation-grade" } else { "lossy capture" },
    );
    let artifact = frontend.capture(&profile);
    for part in &artifact.parts {
        println!("--- part {:?} ---\n{}", part.name, part.content);
    }

    let evidence = frontend.interpret(&artifact)?;
    println!(
        "evidence: {} kernels, total {:.1} us, launch fraction {:.2}, fidelity score {:.3}",
        evidence.n_kernels(),
        evidence.total_us.or(f64::NAN),
        evidence.launch_fraction().or(f64::NAN),
        evidence.fidelity_score()
    );

    let agent = AnalysisAgent::new(platform.clone());
    let advice = agent.advise_from_evidence(&evidence, &naive);
    println!(
        "analysis agent recommendation: {:?} (confidence {:.3})",
        advice.recommendation, advice.confidence
    );
    println!(
        "recommendation text fed to the generation agent:\n  {}\n",
        advice.recommendation.text()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let registry = kforge::platform::registry();
    if args.iter().any(|a| a == "--list") {
        for p in registry.platforms() {
            println!("{}", p.name());
        }
        return Ok(());
    }
    let only = match args.iter().position(|a| a == "--platform") {
        Some(i) => {
            let name = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("--platform requires a name (try --list)"))?;
            Some(kforge::platform::by_name(name)?)
        }
        None => None,
    };
    match only {
        Some(platform) => run_platform(&platform)?,
        None => {
            for platform in registry.platforms() {
                run_platform(platform)?;
            }
        }
    }
    Ok(())
}
