//! Profile-guided optimization (§3.2, §5.2, §6.3): the analysis agent
//! turns raw profiling artifacts into one recommendation per iteration.
//!
//! Shows both profiler frontends on the same workload:
//! - CUDA: nsys-style CSV reports (programmatic), and
//! - Metal: Xcode-style rendered screenshots that the agent must
//!   screen-scrape (the paper automated Xcode with cliclick).
//!
//! ```bash
//! cargo run --release --example profile_guided
//! ```

use kforge::agents::analysis::AnalysisAgent;
use kforge::perfsim::{lower, simulate};
use kforge::platform::{cuda, metal, PlatformKind};
use kforge::profiler::{nsys, xcode, Profile};
use kforge::sched::Schedule;
use kforge::util::rng::Pcg;
use kforge::workloads::Suite;

fn main() -> anyhow::Result<()> {
    let suite = Suite::full();
    let problem = suite.get("l3_squeezenet_fire").unwrap();
    let naive = Schedule::naive();
    let mut rng = Pcg::seed(7);

    // ---- CUDA: programmatic CSV path -----------------------------------
    let h100 = cuda::h100();
    let plan = lower::lower(&problem.perf_graph, &naive);
    let sim = simulate(&h100, &plan, &mut rng, 100, 10);
    let profile = Profile::from_sim(&problem.id, h100.name, &sim);
    println!("================ CUDA: nsys stats CSV reports ================\n");
    println!("{}", nsys::full_report(&profile));
    let agent = AnalysisAgent::new(PlatformKind::Cuda);
    println!(
        "analysis agent recommendation: {:?}\n",
        agent.recommend_cuda(&profile, &naive)
    );

    // ---- Metal: GUI screenshot path -------------------------------------
    let m4 = metal::m4_max();
    let mplan = lower::lower(&problem.perf_graph, &naive);
    let msim = simulate(&m4, &mplan, &mut rng, 100, 10);
    let mprofile = Profile::from_sim(&problem.id, m4.name, &msim);
    println!("============ Metal: Xcode Instruments screenshots ============\n");
    for screen in xcode::capture_screens(&mprofile) {
        println!("{screen}");
    }
    let magent = AnalysisAgent::new(PlatformKind::Metal);
    let rec = magent.recommend_metal(&xcode::capture_screens(&mprofile), &naive);
    println!("analysis agent recommendation (from screenshots): {rec:?}");
    println!("\nrecommendation text fed to the generation agent:\n  {}", rec.text());
    Ok(())
}
