//! Profile-guided optimization (§3.2, §5.2, §6.3): the analysis agent
//! turns raw profiling artifacts into one recommendation per iteration.
//!
//! Shows both profiler frontends on the same workload:
//! - CUDA: nsys-style CSV reports (programmatic), and
//! - Metal: Xcode-style rendered screenshots that the agent must
//!   screen-scrape (the paper automated Xcode with cliclick).
//!
//! ```bash
//! cargo run --release --example profile_guided
//! ```

use kforge::agents::analysis::AnalysisAgent;
use kforge::perfsim::{lower, simulate};
use kforge::platform::ProfilerAccess;
use kforge::profiler::{nsys, xcode, Profile};
use kforge::sched::Schedule;
use kforge::util::rng::Pcg;
use kforge::workloads::Suite;

fn main() -> anyhow::Result<()> {
    let suite = Suite::full();
    let problem = suite.get("l3_squeezenet_fire").unwrap();
    let naive = Schedule::naive();
    let mut rng = Pcg::seed(7);

    // every registered platform, through whichever profiler frontend it
    // actually exposes (programmatic CSV vs GUI screenshots)
    for platform in kforge::platform::registry().platforms() {
        let spec = platform.spec();
        let plan = lower::lower(&problem.perf_graph, &naive);
        let sim = simulate(spec, &plan, &mut rng, 100, 10);
        let profile = Profile::from_sim(&problem.id, spec.name, &sim);
        let agent = AnalysisAgent::new(platform.clone());
        let rec = match spec.profiler {
            ProfilerAccess::ProgrammaticCsv => {
                println!(
                    "========= {}: programmatic CSV reports ({} path) =========\n",
                    spec.name,
                    platform.language()
                );
                println!("{}", nsys::full_report(&profile));
                agent.recommend_from_profile(&profile, &naive)
            }
            ProfilerAccess::GuiScreenshot => {
                println!(
                    "========= {}: GUI screenshots (screen-scraped) =========\n",
                    spec.name
                );
                let screens = xcode::capture_screens(&profile);
                for screen in &screens {
                    println!("{screen}");
                }
                agent.recommend_from_screens(&screens, &naive)
            }
        };
        println!("analysis agent recommendation: {rec:?}");
        println!("recommendation text fed to the generation agent:\n  {}\n", rec.text());
    }
    Ok(())
}
