//! Quickstart: synthesize, verify and optimize one kernel end-to-end.
//!
//! Runs the full KForge loop (generation agent → verification →
//! performance-analysis agent → refinement) for one KernelBench-KIR
//! problem on the simulated H100, printing every execution state and
//! the final speedup over PyTorch-eager.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use kforge::agents::analysis::AnalysisAgent;
use kforge::agents::persona::by_name;
use kforge::agents::GenerationAgent;
use kforge::baseline::eager;
use kforge::profiler::Profile;
use kforge::util::rng::Pcg;
use kforge::verify::{self, ExecState};
use kforge::workloads::Suite;

fn main() -> anyhow::Result<()> {
    let suite = Suite::full();
    let problem = suite.get("l2_gemm_bias_swish_0").expect("problem exists");
    let platform = kforge::platform::by_name("cuda")?;
    let spec = platform.spec().clone();
    let persona = by_name("openai-gpt-5").unwrap();
    let agent = GenerationAgent::new(persona, platform.clone());
    let analyst = AnalysisAgent::new(platform);
    let mut rng = Pcg::seed(2024);

    println!("== problem ==\n{}", problem.eval_graph.render());
    let baseline = eager::measure(&problem.perf_graph, &spec, &mut rng);
    println!("eager baseline: {:.3} ms\n", baseline.measured_s * 1e3);

    let mut current = None;
    let mut last_error: Option<String> = None;
    let mut last_rec = None;
    let mut best: Option<f64> = None;
    for iter in 0..5 {
        let candidate = match (&current, &last_error) {
            (None, _) => agent.synthesize(problem, None, &mut rng),
            (Some(prev), Some(err)) => agent.refine(problem, prev, Some(err), None, &mut rng),
            (Some(prev), None) => agent.refine(problem, prev, None, last_rec.as_ref(), &mut rng),
        };
        let out = verify::verify(&spec, problem, candidate.as_ref(), &mut rng);
        println!("iteration {iter}: {}", out.state.label());
        match out.state {
            ExecState::Correct => {
                let sim = out.sim.unwrap();
                let speedup = baseline.measured_s / sim.measured_s;
                println!(
                    "  candidate: {:.3} ms ({speedup:.2}x vs eager), {} kernel launch(es)",
                    sim.measured_s * 1e3,
                    sim.timeline.len()
                );
                if best.map(|b| sim.measured_s < b).unwrap_or(true) {
                    best = Some(sim.measured_s);
                }
                let profile = Profile::from_sim(&problem.id, spec.name, &sim);
                let rec = analyst.recommend(&profile, &candidate.as_ref().unwrap().schedule);
                println!("  analysis agent: {rec:?}");
                last_rec = Some(rec);
                last_error = None;
            }
            ref failed => {
                println!("  error: {}", failed.error_text().unwrap_or("?"));
                last_error = failed.error_text().map(String::from);
            }
        }
        if candidate.is_some() {
            current = candidate;
        }
    }
    if let Some(b) = best {
        println!(
            "\nfinal: best candidate {:.3} ms — {:.2}x over eager",
            b * 1e3,
            baseline.measured_s / b
        );
        println!("\n== final program ==\n{}", current.unwrap().source_listing);
    } else {
        println!("\nno correct candidate found in 5 iterations");
    }
    Ok(())
}
