//! End-to-end driver: the full three-layer stack on real workloads.
//!
//! Loads the AOT artifacts produced by the Python L1/L2 layers
//! (Pallas kernels inside JAX workloads, lowered to HLO text), compiles
//! them once on the PJRT CPU client, then:
//!
//! 1. **numerically validates** every schedule variant against its
//!    reference variant (real execution, real numerics — the same
//!    check the verification pipeline performs in simulation);
//! 2. **serves batched requests** round-robin across workloads through
//!    the `kforge::serve::Service` front end (admission control +
//!    typed outcomes), reporting latency percentiles and throughput;
//! 3. **times variant pairs** (naive vs tuned) with the paper's
//!    100-run/10-warmup protocol and reports real speedups.
//!
//! Requires `make artifacts` to have produced `artifacts/`.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use kforge::runtime::{PjrtRuntime, Registry};
use kforge::serve::{AdmissionPolicy, Outcome, Priority, Service, Ticket};
use kforge::util::stats;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let registry = Registry::load(&dir)?;
    let rt = PjrtRuntime::new(registry)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {}\n", rt.registry().entries.len());

    // ---- 1. numerics: every variant vs its reference --------------------
    println!("== variant validation (real PJRT numerics) ==");
    let mut validated = 0;
    let mut failed = 0;
    let workloads = rt.registry().workloads();
    for w in &workloads {
        let batches: Vec<usize> = {
            let mut b: Vec<usize> = rt
                .registry()
                .entries
                .iter()
                .filter(|e| &e.workload == w)
                .map(|e| e.batch)
                .collect();
            b.sort();
            b.dedup();
            b
        };
        for batch in batches {
            let Some(reference) = rt.registry().reference(w, batch) else {
                continue;
            };
            let ref_key = reference.key.clone();
            let inputs = rt.seeded_inputs(&ref_key, 42)?;
            let want = rt.execute(&ref_key, &inputs)?;
            let variant_keys: Vec<String> = rt
                .registry()
                .variants(w, batch)
                .iter()
                .filter(|e| !e.is_reference)
                .map(|e| e.key.clone())
                .collect();
            for key in variant_keys {
                let got = rt.execute(&key, &inputs)?;
                let ok = got.len() == want.len()
                    && got
                        .iter()
                        .zip(&want)
                        .all(|(g, w)| g.allclose(w, 5e-3, 5e-4));
                if ok {
                    validated += 1;
                } else {
                    failed += 1;
                    let d = got[0].max_abs_diff(&want[0]);
                    println!("  MISMATCH {key}: max |diff| = {d}");
                }
            }
        }
    }
    println!("  {validated} variants match their reference, {failed} mismatches\n");
    assert_eq!(failed, 0, "variant numerics must match");

    // ---- 2. serving loop -------------------------------------------------
    println!("== serving 128 batched requests (round-robin, Service front end) ==");
    // serve the reference variants (the tuned Pallas variants run under
    // interpret mode on CPU — structurally validated above, but their
    // wallclock is not representative; see the note at the end)
    let keys: Vec<String> = rt
        .registry()
        .entries
        .iter()
        .filter(|e| e.is_reference)
        .map(|e| e.key.clone())
        .collect();
    anyhow::ensure!(!keys.is_empty(), "no reference artifacts in the registry");
    // capacity covers every submission: the example demonstrates the
    // request lifecycle, not load-shedding (kforge serve --synthetic
    // exercises that)
    let svc: Service<usize, f64> = Service::new(AdmissionPolicy::new(128));
    let tickets: Vec<Ticket<f64>> =
        (0..128usize).map(|i| svc.submit(Priority::Interactive, None, i)).collect();
    svc.close();
    let t0 = std::time::Instant::now();
    // the PJRT executable cache is not Sync, so drain on this thread
    svc.drain_inline(|&i| {
        let key = &keys[i % keys.len()];
        let inputs = rt.seeded_inputs(key, i as u64)?;
        let t = std::time::Instant::now();
        rt.execute(key, &inputs)?;
        Ok(t.elapsed().as_secs_f64())
    });
    let total = t0.elapsed().as_secs_f64();
    println!("  {}", svc.stats_line());
    let mut latencies = Vec::new();
    for t in tickets {
        match t.wait() {
            (Outcome::Completed { .. }, Some(s)) => latencies.push(s),
            (outcome, _) => anyhow::bail!("request resolved {}", outcome.label()),
        }
    }
    let s = stats::summarize(&latencies);
    println!(
        "  throughput: {:.1} req/s   latency ms p50={:.2} p95={:.2} p99={:.2}",
        128.0 / total,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.p99 * 1e3
    );
    println!("  compiled executables cached: {}\n", rt.cache_len());

    // ---- 3. real variant timings (paper protocol) -------------------------
    println!("== naive vs tuned (real wallclock, 100 runs / 10 warmup) ==");
    println!("{:<34} {:>12} {:>12} {:>9}", "workload", "naive ms", "tuned ms", "speedup");
    for (w, naive_v, tuned_v) in [
        ("swish", "naive", "ept8"),
        ("gemm_bias_relu", "naive", "fused"),
        ("reduction_chain", "naive", "reduced"),
        ("mlp_block", "naive", "fused"),
    ] {
        let batches: Vec<usize> = rt
            .registry()
            .entries
            .iter()
            .filter(|e| e.workload == w)
            .map(|e| e.batch)
            .collect();
        let Some(&batch) = batches.first() else { continue };
        let naive_key = format!("{w}__{naive_v}__b{batch}");
        let tuned_key = format!("{w}__{tuned_v}__b{batch}");
        if rt.registry().get(&naive_key).is_none() || rt.registry().get(&tuned_key).is_none() {
            continue;
        }
        let inputs = rt.seeded_inputs(&naive_key, 1)?;
        let naive_t = stats::timed_mean(&rt.bench(&naive_key, &inputs, 10, 100)?, 0);
        let tuned_t = stats::timed_mean(&rt.bench(&tuned_key, &inputs, 10, 100)?, 0);
        println!(
            "{:<34} {:>12.3} {:>12.3} {:>8.2}x",
            format!("{w} (b{batch})"),
            naive_t * 1e3,
            tuned_t * 1e3,
            naive_t / tuned_t
        );
    }
    println!("\n(NOTE: interpret-mode Pallas on CPU — structure is validated here;\n TPU performance is estimated analytically in DESIGN.md §Hardware adaptation.)");
    Ok(())
}
