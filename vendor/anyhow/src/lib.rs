//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! workspace vendors the small surface kforge actually uses:
//!
//! - [`Error`]: a message plus an optional context chain;
//! - [`Result<T>`] defaulting the error type;
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! - `anyhow!` / `bail!` macros (format-string forms);
//! - `Display` prints the outermost message, `{:#}` prints the chain
//!   (`outer: inner: root`), `Debug` prints a `Caused by:` listing —
//!   matching real anyhow closely enough for logs and tests.
//!
//! Swap back to the real crate by replacing the `[dependencies]` path
//! entry; no source changes needed.

use std::fmt;

/// An error message with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> String {
        self.msg.clone()
    }

    /// Iterate the chain from the outermost message to the root cause.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(src) = &cur.source {
            cur = src;
        }
        &cur.msg
    }
}

/// Iterator over an [`Error`]'s context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(&cur.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = &self.source;
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes this blanket conversion coherent (the reflexive
// `From<Error> for Error` comes from core's identity impl instead).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // flatten the std source chain into our context chain
        let mut msgs = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut e = Error::msg(it.next().expect("at least one message"));
        for m in it {
            e = e.context(m);
        }
        e
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false (the real
/// crate's two forms: bare condition, or condition + format string).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_is_outermost_alternate_is_chain() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "middle", "root"]);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert!(format!("{e:#}").contains("file missing"));

        let o: Option<u32> = None;
        let e2 = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e2.to_string(), "missing key");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn ensure_both_forms() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x != 0);
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(0).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        let e = f(0).unwrap_err();
        assert_eq!(e.to_string(), "zero is not allowed (got 0)");
        let e2 = anyhow!("plain");
        assert_eq!(e2.to_string(), "plain");
    }
}
