"""L2 workload graphs: every variant of every workload must agree with
its reference variant numerically (variants differ only in schedule)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model

RNG = np.random.default_rng(1)


def make_inputs(specs):
    return [jnp.asarray(RNG.normal(size=s.shape, scale=0.5).astype(np.float32)) for s in specs]


ALL_CASES = [
    (name, vname)
    for name, (variants, _, ref_variant) in sorted(model.WORKLOADS.items())
    for vname in sorted(variants)
    if vname != ref_variant
]


@pytest.mark.parametrize("name,vname", ALL_CASES, ids=[f"{n}:{v}" for n, v in ALL_CASES])
def test_variant_matches_reference(name, vname):
    variants, spec_fn, ref_variant = model.WORKLOADS[name]
    specs = spec_fn(4)
    inputs = make_inputs(specs)
    want = variants[ref_variant](*inputs)
    got = variants[vname](*inputs)
    assert len(got) == len(want)
    # fast-math variants (swish ept8) run with a looser tolerance, as the
    # paper trades precision for speed via fast::exp (§7.2).
    # fast-math variants (swish ept8) and deep tuned blocks accumulate in a
    # different order than the oracle; tolerances reflect that, not bugs.
    rtol, atol = (3e-3, 5e-4) if vname in ("ept8", "tuned") else (2e-4, 2e-4)
    for g, w in zip(got, want):
        assert g.shape == w.shape
        assert_allclose(np.asarray(g), np.asarray(w), rtol=rtol, atol=atol)


@pytest.mark.parametrize("name", sorted(model.WORKLOADS))
def test_specs_scale_with_batch(name):
    _, spec_fn, _ = model.WORKLOADS[name]
    s4, s8 = spec_fn(4), spec_fn(8)
    assert len(s4) == len(s8)
    assert all(a.dtype == b.dtype for a, b in zip(s4, s8))


def test_reference_variant_exists():
    for name, (variants, _, ref_variant) in model.WORKLOADS.items():
        assert ref_variant in variants, name


def test_reduction_chain_collapse_exact():
    """§7.4: the algebraic identity behind the graph reduction.

    sum over axis-1 of (xW + b) is a scalar per row; max/mean/lse over a
    singleton axis are identity, so the chain equals x @ W.sum(1) + b.sum().
    """
    specs = model.specs_reduction(4)
    x, w, b = make_inputs(specs)
    (full,) = model.reduction_chain_naive(x, w, b)
    (reduced,) = model.reduction_chain_reduced(x, w, b)
    assert_allclose(np.asarray(full), np.asarray(reduced), rtol=1e-3, atol=1e-3)


def test_lower_to_hlo_text_smoke():
    variants, spec_fn, _ = model.WORKLOADS["swish"]
    text = model.lower_to_hlo_text(variants["ept1"], spec_fn(1))
    assert "HloModule" in text
    assert len(text) > 200
