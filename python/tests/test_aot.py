"""AOT pipeline: manifest integrity + artifact round-trip (text parse)."""

from __future__ import annotations

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, only=["swish", "reduction_chain"], batches={"swish": [2], "reduction_chain": [2]})
    return out, manifest


def test_manifest_written(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["version"] == 1


def test_all_artifacts_exist_and_are_hlo(built):
    out, manifest = built
    assert manifest["entries"], "no artifacts lowered"
    for e in manifest["entries"]:
        p = os.path.join(out, e["path"])
        assert os.path.exists(p), e["key"]
        text = open(p).read()
        assert text.startswith("HloModule"), e["key"]


def test_every_workload_has_one_reference(built):
    _, manifest = built
    per = {}
    for e in manifest["entries"]:
        k = (e["workload"], e["batch"])
        per.setdefault(k, []).append(e["is_reference"])
    for k, flags in per.items():
        assert sum(flags) == 1, k


def test_keys_unique_and_well_formed(built):
    _, manifest = built
    keys = [e["key"] for e in manifest["entries"]]
    assert len(keys) == len(set(keys))
    for e in manifest["entries"]:
        assert e["key"] == f"{e['workload']}__{e['variant']}__b{e['batch']}"
        assert all("shape" in s and "dtype" in s for s in e["inputs"])


def test_only_filter_respected(built):
    _, manifest = built
    assert {e["workload"] for e in manifest["entries"]} == {"swish", "reduction_chain"}
