"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including ragged, non-block-multiple sizes)
and schedule points; assert_allclose against ref.py is the core signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention as attn_k
from compile.kernels import conv as conv_k
from compile.kernels import elementwise as ew_k
from compile.kernels import layernorm as ln_k
from compile.kernels import matmul as mm_k
from compile.kernels import ref
from compile.kernels import softmax as sm_k

RNG = np.random.default_rng(0)


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape, scale=scale).astype(np.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 128, 128)])
    def test_block_schedules(self, bm, bn, bk):
        x, y = randn(48, 40), randn(40, 56)
        assert_allclose(mm_k.matmul(x, y, bm=bm, bn=bn, bk=bk), ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_square(self):
        x, y = randn(64, 64), randn(64, 64)
        assert_allclose(mm_k.matmul(x, y), ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            mm_k.matmul(randn(4, 5), randn(6, 4))

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        blk=st.sampled_from([8, 16, 32]),
    )
    def test_ragged_shapes(self, m, k, n, blk):
        x, y = randn(m, k), randn(k, n)
        got = mm_k.matmul(x, y, bm=blk, bn=blk, bk=blk)
        assert got.shape == (m, n)
        assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("act", ["relu", "swish", "gelu", "none"])
    def test_fused_epilogue(self, act):
        x, w, b = randn(40, 48), randn(48, 24), randn(24)
        got = mm_k.matmul_bias_act(x, w, b, act=act, bm=16, bn=16, bk=16)
        assert_allclose(got, ref.bias_act(ref.matmul(x, w), b, act), rtol=1e-4, atol=1e-4)

    def test_fused_bad_bias_raises(self):
        with pytest.raises(ValueError):
            mm_k.matmul_bias_act(randn(8, 8), randn(8, 8), randn(4))

    def test_matvec_reduction(self):
        """§7.4: reduced graph equals the full chain's collapsed form."""
        x, w, b = randn(16, 32), randn(32, 64), randn(64)
        got = mm_k.matvec(x, jnp.sum(w, axis=1), jnp.sum(b), bm=8, bk=8)
        want = ref.matmul(x, jnp.sum(w, axis=1).reshape(-1, 1)) + jnp.sum(b)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# elementwise / swish (§7.2)
# ---------------------------------------------------------------------------

class TestElementwise:
    @pytest.mark.parametrize("ept", [1, 2, 4, 8])
    def test_swish_ept(self, ept):
        x = randn(3, 1000)
        assert_allclose(ew_k.swish(x, ept=ept), ref.swish(x), rtol=1e-5, atol=1e-6)

    def test_swish_fast_math_close_but_loose(self):
        x = randn(4096)
        got = ew_k.swish(x, ept=8, fast_math=True)
        assert_allclose(got, ref.swish(x), rtol=2e-3, atol=2e-4)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 5000), ept=st.sampled_from([1, 4, 8]))
    def test_ragged_lengths(self, n, ept):
        x = randn(n)
        got = ew_k.swish(x, ept=ept)
        assert got.shape == (n,)
        assert_allclose(got, ref.swish(x), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize(
        "ops", [("relu",), ("swish", "relu"), ("square", "add1", "sigmoid"), ("gelu",)]
    )
    def test_chains(self, ops):
        x = randn(777)
        want = x
        for op in ops:
            want = {
                "relu": ref.relu,
                "swish": ref.swish,
                "sigmoid": ref.sigmoid,
                "gelu": ref.gelu,
                "square": lambda v: v * v,
                "add1": lambda v: v + 1.0,
            }[op](want)
        assert_allclose(ew_k.elementwise_chain(x, ops=ops), want, rtol=1e-5, atol=1e-5)

    def test_bad_ept_raises(self):
        with pytest.raises(ValueError):
            ew_k.elementwise_chain(randn(8), ept=0)

    def test_bias_act_2d(self):
        x, b = randn(20, 48), randn(48)
        got = ew_k.bias_act_2d(x, b, op="swish", rows_per_step=8)
        assert_allclose(got, ref.bias_act(x, b, "swish"), rtol=1e-5, atol=1e-5)

    def test_fast_exp_accuracy(self):
        x = jnp.linspace(-20.0, 20.0, 4001)
        got = ew_k._fast_exp(x)
        want = jnp.exp(x)
        rel = np.abs(np.asarray(got - want)) / np.maximum(np.asarray(want), 1e-30)
        # fast-math by design: ~1e-3 max relative error is the §7.2 trade-off
        assert rel.max() < 2e-3


# ---------------------------------------------------------------------------
# softmax (online)
# ---------------------------------------------------------------------------

class TestSoftmax:
    @pytest.mark.parametrize("shape", [(8, 128), (5, 100), (1, 7), (33, 257)])
    def test_shapes(self, shape):
        x = randn(*shape, scale=3.0)
        assert_allclose(sm_k.softmax(x), ref.softmax(x), rtol=1e-5, atol=1e-6)

    def test_rows_sum_to_one(self):
        x = randn(17, 200, scale=5.0)
        s = np.asarray(sm_k.softmax(x)).sum(axis=-1)
        assert_allclose(s, np.ones(17), rtol=1e-5)

    def test_extreme_values_stable(self):
        x = jnp.array([[1e4, 1e4 - 1.0, 0.0, -1e4]], dtype=jnp.float32)
        got = np.asarray(sm_k.softmax(x))
        assert np.isfinite(got).all()
        assert_allclose(got, np.asarray(ref.softmax(x)), rtol=1e-5, atol=1e-7)

    def test_1d_rejected(self):
        with pytest.raises(ValueError):
            sm_k.softmax(randn(16))

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 40), n=st.integers(1, 300), bc=st.sampled_from([16, 64, 128]))
    def test_ragged(self, m, n, bc):
        x = randn(m, n, scale=2.0)
        assert_allclose(sm_k.softmax(x, br=8, bc=bc), ref.softmax(x), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

class TestLayernorm:
    @pytest.mark.parametrize("m,n,br", [(16, 64, 8), (7, 33, 4), (100, 512, 16)])
    def test_shapes(self, m, n, br):
        x, g, b = randn(m, n), randn(n), randn(n)
        assert_allclose(
            ln_k.layernorm(x, g, b, br=br), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-5
        )

    def test_normalization_property(self):
        x = randn(10, 256, scale=4.0)
        g, b = jnp.ones(256), jnp.zeros(256)
        out = np.asarray(ln_k.layernorm(x, g, b))
        assert_allclose(out.mean(axis=-1), np.zeros(10), atol=1e-5)
        assert_allclose(out.std(axis=-1), np.ones(10), rtol=1e-3)

    def test_mismatched_gamma_raises(self):
        with pytest.raises(ValueError):
            ln_k.layernorm(randn(4, 8), randn(7), randn(8))

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 50), n=st.integers(2, 200))
    def test_ragged(self, m, n):
        x, g, b = randn(m, n), randn(n), randn(n)
        assert_allclose(ln_k.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention (flash)
# ---------------------------------------------------------------------------

class TestAttention:
    @pytest.mark.parametrize("s,d,bq,bk", [(64, 32, 16, 32), (128, 64, 16, 64), (32, 16, 8, 8)])
    def test_block_multiple(self, s, d, bq, bk):
        q, k, v = randn(s, d), randn(s, d), randn(s, d)
        got = attn_k.attention(q, k, v, bq=bq, bk=bk)
        assert_allclose(got, ref.attention(q, k, v), rtol=1e-4, atol=1e-5)

    def test_ragged_seq(self):
        q, k, v = randn(50, 32), randn(50, 32), randn(50, 32)
        got = attn_k.attention(q, k, v, bq=16, bk=16)
        assert_allclose(got, ref.attention(q, k, v), rtol=1e-4, atol=1e-5)

    def test_mismatched_kv_raises(self):
        with pytest.raises(ValueError):
            attn_k.attention(randn(8, 4), randn(9, 4), randn(8, 4))

    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(4, 80), d=st.sampled_from([8, 16, 32]))
    def test_ragged_property(self, s, d):
        q, k, v = randn(s, d), randn(s, d), randn(s, d)
        got = attn_k.attention(q, k, v, bq=16, bk=16)
        assert_allclose(got, ref.attention(q, k, v), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# conv
# ---------------------------------------------------------------------------

class TestConv:
    @pytest.mark.parametrize(
        "n,c,h,w,o,kh,stride,padding",
        [(2, 3, 8, 8, 4, 3, 1, 1), (1, 8, 14, 14, 16, 1, 1, 0), (2, 4, 9, 9, 8, 3, 2, 1)],
    )
    def test_vs_lax_conv(self, n, c, h, w, o, kh, stride, padding):
        x, wt = randn(n, c, h, w), randn(o, c, kh, kh)
        got = conv_k.conv2d_im2col(x, wt, stride=stride, padding=padding, bm=16, bn=16, bk=16)
        assert_allclose(got, ref.conv2d(x, wt, stride=stride, padding=padding), rtol=1e-4, atol=1e-4)

    def test_conv1x1_equals_conv(self):
        x, wt = randn(2, 8, 7, 7), randn(16, 8, 1, 1)
        got = conv_k.conv1x1(x, wt, bm=16, bn=16, bk=16)
        assert_allclose(got, ref.conv2d(x, wt), rtol=1e-4, atol=1e-4)

    def test_channel_mismatch_raises(self):
        with pytest.raises(ValueError):
            conv_k.conv2d_im2col(randn(1, 3, 8, 8), randn(4, 5, 3, 3))

    def test_im2col_oracle(self):
        """im2col patches reassemble to the lax conv result via plain matmul."""
        x, wt = randn(2, 3, 6, 6), randn(5, 3, 3, 3)
        cols = ref.im2col(x, 3, 3, padding=1)
        out = cols @ wt.reshape(5, -1).T
        out = out.reshape(2, 6, 6, 5).transpose(0, 3, 1, 2)
        assert_allclose(out, ref.conv2d(x, wt, padding=1), rtol=1e-4, atol=1e-4)
