"""L2: JAX workload graphs, mirroring the KernelBench subset that the
rust coordinator executes for real through PJRT.

Each *workload* is a pure jax function built from the L1 Pallas kernels;
each carries named *variants* — points in the synthesis schedule space
(naive / fused / tuned) — so the coordinator can load the artifact that
matches a synthesized program's schedule and time the real execution.

Variant naming convention: ``<workload>__<variant>__b<batch>``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import conv as conv_k
from .kernels import elementwise as ew_k
from .kernels import layernorm as ln_k
from .kernels import matmul as mm_k
from .kernels import ref
from .kernels import softmax as sm_k


# ---------------------------------------------------------------------------
# Level-1-style workloads (single primitives)
# ---------------------------------------------------------------------------

def swish_naive(x):
    """Unfused swish: the eager-mode analog (sigmoid then multiply)."""
    return (x * jax.nn.sigmoid(x),)


def swish_ept1(x):
    return (ew_k.swish(x, ept=1),)


def swish_ept8(x):
    """§7.2 winning schedule: 8 elements per thread + fast-math exp."""
    return (ew_k.swish(x, ept=8, fast_math=True),)


def matmul_naive(x, y):
    return (ref.matmul(x, y),)


def matmul_tiled_64(x, y):
    return (mm_k.matmul(x, y, bm=64, bn=64, bk=64),)


def matmul_tiled_128(x, y):
    return (mm_k.matmul(x, y, bm=128, bn=128, bk=128),)


def softmax_naive(x):
    return (ref.softmax(x),)


def softmax_online(x):
    return (sm_k.softmax(x, br=8, bc=128),)


def layernorm_tuned(x, g, b):
    return (ln_k.layernorm(x, g, b, br=8),)


def layernorm_naive(x, g, b):
    return (ref.layernorm(x, g, b),)


# ---------------------------------------------------------------------------
# Level-2-style workloads (fusable sequences)
# ---------------------------------------------------------------------------

def gemm_bias_relu_naive(x, w, b):
    """Three separate ops — three HBM round trips."""
    y = ref.matmul(x, w)
    y = y + b
    return (jnp.maximum(y, 0.0),)


def gemm_bias_relu_fused(x, w, b):
    """Single fused kernel with epilogue."""
    return (mm_k.matmul_bias_act(x, w, b, act="relu", bm=64, bn=64, bk=64),)


def gemm_bias_swish_fused(x, w, b):
    return (mm_k.matmul_bias_act(x, w, b, act="swish", bm=64, bn=64, bk=64),)


def mlp_block_naive(x, w1, b1, w2, b2):
    h = jnp.maximum(ref.matmul(x, w1) + b1, 0.0)
    return (ref.matmul(h, w2) + b2,)


def mlp_block_fused(x, w1, b1, w2, b2):
    h = mm_k.matmul_bias_act(x, w1, b1, act="relu", bm=64, bn=64, bk=64)
    return (mm_k.matmul_bias_act(h, w2, b2, act="none", bm=64, bn=64, bk=64),)


def reduction_chain_naive(x, w, b):
    """§7.4 L2-problem-12 analog: linear → sum → max → mean → lse → lse."""
    y = ref.matmul(x, w) + b  # [m, n]
    y = jnp.sum(y, axis=1, keepdims=True)
    y = jnp.max(y, axis=1, keepdims=True)
    y = jnp.mean(y, axis=1, keepdims=True)
    y = jax.nn.logsumexp(y, axis=1, keepdims=True)
    y = jax.nn.logsumexp(y, axis=1, keepdims=True)
    return (y,)


def reduction_chain_reduced(x, w, b):
    """The model-discovered reduction: collapses to x @ W.sum(1) + b.sum()."""
    w_sum = jnp.sum(w, axis=1)
    b_sum = jnp.sum(b)
    return (mm_k.matvec(x, w_sum, b_sum, bm=64, bk=64),)


# ---------------------------------------------------------------------------
# Level-3-style workloads (architectures)
# ---------------------------------------------------------------------------

def fire_module_naive(x, ws, bs, we1, be1, we3, be3):
    """SqueezeNet Fire (§7.1): squeeze 1x1 → expand 1x1 ‖ expand 3x3, eager."""
    s = jax.nn.relu(ref.conv2d(x, ws) + bs[None, :, None, None])
    e1 = jax.nn.relu(ref.conv2d(s, we1) + be1[None, :, None, None])
    e3 = jax.nn.relu(ref.conv2d(s, we3, padding=1) + be3[None, :, None, None])
    return (jnp.concatenate([e1, e3], axis=1),)


def fire_module_tuned(x, ws, bs, we1, be1, we3, be3):
    """Fire with Pallas im2col-GEMM convs (fused bias+relu epilogues)."""

    def conv_bias_relu(inp, w, b, padding=0):
        out = conv_k.conv2d_im2col(inp, w, padding=padding, bm=64, bn=64, bk=64)
        return jax.nn.relu(out + b[None, :, None, None])

    s = conv_bias_relu(x, ws, bs)
    e1 = conv_bias_relu(s, we1, be1)
    e3 = conv_bias_relu(s, we3, be3, padding=1)
    return (jnp.concatenate([e1, e3], axis=1),)


def attention_block_naive(q, k, v):
    """MinGPT-style single-head attention, materialized logits."""
    return (ref.attention(q, k, v),)


def attention_block_flash(q, k, v):
    """Fused FlashAttention-style kernel."""
    return (attn_k.attention(q, k, v, bq=16, bk=64),)


def transformer_block_naive(x, wq, wk, wv, wo, g1, b1, w1, bb1, w2, bb2, g2, b2):
    """One MinGPT block: LN → attn → residual → LN → MLP → residual."""
    h = ref.layernorm(x, g1, b1)
    q, k, v = ref.matmul(h, wq), ref.matmul(h, wk), ref.matmul(h, wv)
    a = ref.attention(q, k, v)
    x = x + ref.matmul(a, wo)
    h = ref.layernorm(x, g2, b2)
    h = ref.gelu(ref.matmul(h, w1) + bb1)
    return (x + ref.matmul(h, w2) + bb2,)


def transformer_block_tuned(x, wq, wk, wv, wo, g1, b1, w1, bb1, w2, bb2, g2, b2):
    """Same block with Pallas kernels: fused LN, flash attention, fused GEMM."""
    h = ln_k.layernorm(x, g1, b1, br=8)
    q, k, v = (
        mm_k.matmul(h, wq, bm=64, bn=64, bk=64),
        mm_k.matmul(h, wk, bm=64, bn=64, bk=64),
        mm_k.matmul(h, wv, bm=64, bn=64, bk=64),
    )
    a = attn_k.attention(q, k, v, bq=16, bk=64)
    x = x + mm_k.matmul(a, wo, bm=64, bn=64, bk=64)
    h = ln_k.layernorm(x, g2, b2, br=8)
    h = mm_k.matmul_bias_act(h, w1, bb1, act="gelu", bm=64, bn=64, bk=64)
    return (x + mm_k.matmul_bias_act(h, w2, bb2, act="none", bm=64, bn=64, bk=64),)


# ---------------------------------------------------------------------------
# Backward passes (§9 future work: "program synthesis for both forward
# and backward passes").  Each *_grad workload returns the gradients of
# a scalar loss (sum of outputs) w.r.t. every differentiable input, so
# training-style artifacts flow through the same AOT → PJRT path.
# ---------------------------------------------------------------------------

def _grad_of(fn, argnums):
    def loss(*args):
        (out,) = fn(*args)
        return jnp.sum(out * out)

    def wrapped(*args):
        return tuple(jax.grad(loss, argnums=argnums)(*args))

    return wrapped


# Pallas interpret-mode kernels do not support reverse-mode AD, so the
# tuned variants carry custom VJPs — the same pattern real fused kernels
# use (FlashAttention ships a hand-written backward).  The backward
# passes themselves call the Pallas matmul kernel where a dense
# contraction appears, so gradients also exercise the L1 layer.

@jax.custom_vjp
def _swish_ept8_cv(x):
    return ew_k.swish(x, ept=8, fast_math=True)


def _swish_fwd(x):
    return _swish_ept8_cv(x), x


def _swish_bwd(x, g):
    s = jax.nn.sigmoid(x)
    return (g * (s + x * s * (1.0 - s)),)


_swish_ept8_cv.defvjp(_swish_fwd, _swish_bwd)


@jax.custom_vjp
def _gemm_bias_relu_cv(x, w, b):
    return mm_k.matmul_bias_act(x, w, b, act="relu", bm=64, bn=64, bk=64)


def _gbr_fwd_w(x, w, b):
    # keep w for dx; keep x for dw; keep y for the relu mask
    y = _gemm_bias_relu_cv(x, w, b)
    return y, (x, w, y)


def _gbr_bwd(res, g):
    x, w, y = res
    mask = (y > 0.0).astype(g.dtype)
    gm = g * mask
    # dense contractions run through the Pallas tiled matmul
    dx = mm_k.matmul(gm, w.T, bm=64, bn=64, bk=64)
    dw = mm_k.matmul(x.T, gm, bm=64, bn=64, bk=64)
    db = jnp.sum(gm, axis=0)
    return (dx, dw, db)


_gemm_bias_relu_cv.defvjp(_gbr_fwd_w, _gbr_bwd)


def swish_grad_naive(x):
    return _grad_of(swish_naive, (0,))(x)


def swish_grad_ept8(x):
    def fused(v):
        return (_swish_ept8_cv(v),)

    return _grad_of(fused, (0,))(x)


def gemm_bias_relu_grad_naive(x, w, b):
    return _grad_of(gemm_bias_relu_naive, (1, 2))(x, w, b)


def gemm_bias_relu_grad_fused(x, w, b):
    def fused(xx, ww, bb):
        return (_gemm_bias_relu_cv(xx, ww, bb),)

    return _grad_of(fused, (1, 2))(x, w, b)


# ---------------------------------------------------------------------------
# Workload registry: name -> (fn, input-spec builder)
# ---------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def specs_swish(batch: int):
    return [_f32(batch, 16384)]


def specs_matmul(batch: int):
    return [_f32(batch * 8, 256), _f32(256, 256)]


def specs_softmax(batch: int):
    return [_f32(batch * 8, 512)]


def specs_layernorm(batch: int):
    return [_f32(batch * 8, 512), _f32(512), _f32(512)]


def specs_gemm_bias(batch: int):
    return [_f32(batch * 8, 256), _f32(256, 256), _f32(256)]


def specs_mlp(batch: int):
    return [_f32(batch * 8, 256), _f32(256, 512), _f32(512), _f32(512, 256), _f32(256)]


def specs_reduction(batch: int):
    return [_f32(batch, 512), _f32(512, 1024), _f32(1024)]


def specs_fire(batch: int):
    # SqueezeNet fire2 geometry (scaled): 32ch 28x28 in, squeeze 8, expand 2x16
    return [
        _f32(batch, 32, 28, 28),
        _f32(8, 32, 1, 1), _f32(8),
        _f32(16, 8, 1, 1), _f32(16),
        _f32(16, 8, 3, 3), _f32(16),
    ]


def specs_attention(batch: int):
    del batch
    return [_f32(128, 64), _f32(128, 64), _f32(128, 64)]


def specs_transformer(batch: int):
    del batch
    s, d, f = 64, 128, 512
    return [
        _f32(s, d),
        _f32(d, d), _f32(d, d), _f32(d, d), _f32(d, d),
        _f32(d), _f32(d),
        _f32(d, f), _f32(f), _f32(f, d), _f32(d),
        _f32(d), _f32(d),
    ]


# name -> (variant -> fn, spec builder, reference variant name)
WORKLOADS: dict[str, tuple[dict[str, Callable], Callable, str]] = {
    "swish": (
        {"naive": swish_naive, "ept1": swish_ept1, "ept8": swish_ept8},
        specs_swish,
        "naive",
    ),
    "matmul": (
        {"naive": matmul_naive, "tiled64": matmul_tiled_64, "tiled128": matmul_tiled_128},
        specs_matmul,
        "naive",
    ),
    "softmax": (
        {"naive": softmax_naive, "online": softmax_online},
        specs_softmax,
        "naive",
    ),
    "layernorm": (
        {"naive": layernorm_naive, "tuned": layernorm_tuned},
        specs_layernorm,
        "naive",
    ),
    "gemm_bias_relu": (
        {"naive": gemm_bias_relu_naive, "fused": gemm_bias_relu_fused},
        specs_gemm_bias,
        "naive",
    ),
    "mlp_block": (
        {"naive": mlp_block_naive, "fused": mlp_block_fused},
        specs_mlp,
        "naive",
    ),
    "reduction_chain": (
        {"naive": reduction_chain_naive, "reduced": reduction_chain_reduced},
        specs_reduction,
        "naive",
    ),
    "fire_module": (
        {"naive": fire_module_naive, "tuned": fire_module_tuned},
        specs_fire,
        "naive",
    ),
    "attention_block": (
        {"naive": attention_block_naive, "flash": attention_block_flash},
        specs_attention,
        "naive",
    ),
    "transformer_block": (
        {"naive": transformer_block_naive, "tuned": transformer_block_tuned},
        specs_transformer,
        "naive",
    ),
    # backward passes (§9): gradients flow through the Pallas kernels'
    # interpret-mode VJPs and lower to the same artifact format
    "swish_grad": (
        {"naive": swish_grad_naive, "ept8": swish_grad_ept8},
        specs_swish,
        "naive",
    ),
    "gemm_bias_relu_grad": (
        {"naive": gemm_bias_relu_grad_naive, "fused": gemm_bias_relu_grad_fused},
        specs_gemm_bias,
        "naive",
    ),
}

# Batch sizes lowered per workload (Table 6 sweeps fire_module over all).
DEFAULT_BATCHES: dict[str, list[int]] = {name: [16] for name in WORKLOADS}
DEFAULT_BATCHES["fire_module"] = [8, 16, 32]
DEFAULT_BATCHES["swish"] = [16, 64]


def lower_to_hlo_text(fn: Callable, specs: list) -> str:
    """Lower a jitted workload to HLO *text* (the interchange format the
    xla 0.1.6 crate's xla_extension 0.5.1 can parse — serialized protos
    from jax>=0.5 carry 64-bit ids it rejects)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
