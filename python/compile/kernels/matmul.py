"""L1 Pallas kernel: tiled matmul with a configurable block schedule.

This is the MXU-facing hot spot of the stack.  The schedule point
``(bm, bn, bk)`` is the Pallas analog of the paper's CUDA threadblock
tiling: each grid step owns one ``bm×bn`` output tile resident in VMEM
and marches over the K dimension in ``bk`` slabs (the HBM↔VMEM schedule
the paper expresses with threadblocks is expressed here with BlockSpec).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered to plain HLO ops and numerics are
validated through the interpret path (see DESIGN.md §Hardware adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: accumulate x[i,k] @ y[k,j] into o[i,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Tiled matmul ``x @ y`` with block schedule (bm, bn, bk).

    Inputs of arbitrary (m, k) × (k, n) shape; internally padded to block
    multiples (zero padding is exact for matmul) and sliced back.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    n = y.shape[1]
    bm_, bn_, bk_ = min(bm, m) or 1, min(bn, n) or 1, min(bk, k) or 1
    xp = _pad_to(x, bm_, bk_)
    yp = _pad_to(y, bk_, bn_)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def _matmul_bias_act_kernel(x_ref, y_ref, b_ref, o_ref, *, nk: int, act: str):
    """Matmul with fused epilogue: bias add + activation on the last K step.

    Fusing the epilogue is the Pallas analog of the paper's dominant CUDA
    optimization (kernel fusion): the output tile is written to HBM once,
    already activated, instead of being round-tripped per epilogue op.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        if act == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif act == "swish":
            acc = acc * (1.0 / (1.0 + jnp.exp(-acc)))
        elif act == "gelu":
            c = 0.7978845608028654  # sqrt(2/pi)
            acc = 0.5 * acc * (1.0 + jnp.tanh(c * (acc + 0.044715 * acc**3)))
        elif act != "none":
            raise ValueError(f"unknown activation {act!r}")
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "act"))
def matmul_bias_act(
    x: jax.Array,
    y: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """Fused ``act(x @ y + b)`` — the L2 GEMM+epilogue building block."""
    m, k = x.shape
    n = y.shape[1]
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    bm_, bn_, bk_ = min(bm, m) or 1, min(bn, n) or 1, min(bk, k) or 1
    xp = _pad_to(x, bm_, bk_)
    yp = _pad_to(y, bk_, bn_)
    bp = jnp.pad(b, (0, (-n) % bn_)).reshape(1, -1)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    nk = kp // bk_
    grid = (mp // bm_, np_ // bn_, nk)
    kern = functools.partial(_matmul_bias_act_kernel, nk=nk, act=act)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp, bp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def matvec(x: jax.Array, w_sum: jax.Array, b_sum: jax.Array, *, bm: int = 128, bk: int = 128) -> jax.Array:
    """GEMV for the §7.4 graph-reduction case study.

    The paper's L2-problem-12 chain (linear → sum → max → mean → lse → lse)
    collapses to ``x @ W.sum(0) + bias.sum()``: a matrix-*vector* product.
    Expressed as a (m,k)×(k,1) tiled matmul so it reuses the MXU path.
    """
    out = matmul(x, w_sum.reshape(-1, 1), bm=bm, bn=1, bk=bk)
    return out + b_sum
