"""L1 Pallas kernel: LayerNorm over the last axis, row-blocked schedule.

Each grid step normalizes a block of rows resident in VMEM: mean and
variance are computed on-chip and the scaled/shifted result is written
back in the same pass — one HBM round trip per row instead of the four
an unfused mean/var/normalize/affine sequence pays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("br", "eps"))
def layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    br: int = 8,
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm over the last axis of [m, n] with ``br`` rows per step."""
    if x.ndim != 2:
        raise ValueError(f"layernorm kernel expects 2-D input, got {x.shape}")
    m, n = x.shape
    if gamma.shape != (n,) or beta.shape != (n,):
        raise ValueError("gamma/beta must match the last axis")
    br_ = min(br, m)
    pad = (-m) % br_
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    kern = functools.partial(_layernorm_kernel, eps=eps)
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // br_,),
        in_specs=[
            pl.BlockSpec((br_, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br_, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, gamma.reshape(1, -1), beta.reshape(1, -1))
    return out[:m]
