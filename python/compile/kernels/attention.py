"""L1 Pallas kernel: FlashAttention-style fused attention.

The §1 motivation of the paper: online softmax + tiled attention, with
the KV sequence streamed through VMEM in chunks while a block of query
rows stays resident.  Running max ``m``, denominator ``l`` and output
accumulator ``acc`` are rescaled per chunk — the logits matrix is never
materialized in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, nk: int, scale: float):
    bq = q_ref.shape[0]
    d = q_ref.shape[1]       # may include the +1 masking dim
    dv = v_ref.shape[1]      # plain head dim
    q = q_ref[...] * scale

    def body(c, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[...], (c * bk, 0), (bk, d))
        v = jax.lax.dynamic_slice(v_ref[...], (c * bk, 0), (bk, dv))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l, acc

    m0 = jnp.full((bq, 1), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    a0 = jnp.zeros((bq, dv), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bq: int = 16,
    bk: int = 64,
) -> jax.Array:
    """Fused attention over [s, d] q/k/v.  bq query rows per grid step,
    KV streamed in bk chunks.  Ragged s padded with -inf-masked keys."""
    s, d = q.shape
    if k.shape != (s, d) or v.shape != (s, d):
        raise ValueError("q, k, v must share [s, d]")
    scale = 1.0 / float(d) ** 0.5
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    pq = (-s) % bq_
    pk = (-s) % bk_
    qp = jnp.pad(q, ((0, pq), (0, 0)))
    # Pad keys so padded logits are -inf -> zero weight.  Padding K with a
    # huge negative constant on a fresh row only works via the logits, so
    # instead pad K/V with zeros and mask by padding Q rows only; for keys
    # we append rows whose dot with any q is 0 and then subtract inf mask:
    kp = jnp.pad(k, ((0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, pk), (0, 0)))
    if pk:
        # Mask padded keys by forcing their logits to -inf: append a bias
        # column trick is unavailable in the simple kernel, so instead we
        # fold the mask into K by scaling Q against a sentinel: simplest
        # exact approach — compute with padded keys then renormalize is
        # wrong; we instead require the caller shape or do mask via V=0
        # and logit = 0 which *does* perturb softmax.  So: pad keys with
        # -1e30 in an extra feature dim paired with +1 in q.
        ones = jnp.concatenate([jnp.ones((s, 1), q.dtype), jnp.zeros((pq, 1), q.dtype)])
        neg = jnp.concatenate(
            [jnp.zeros((s, 1), q.dtype), jnp.full((pk, 1), -1e30 * float(d) ** 0.5, q.dtype)]
        )
        qp = jnp.concatenate([qp, ones], axis=1)
        kp = jnp.concatenate([kp, neg], axis=1)
        scale_adj = scale  # extra dim contributes 0 or -1e30 pre-scale
    else:
        scale_adj = scale
    sp = qp.shape[0]
    dp = qp.shape[1]
    nk = kp.shape[0] // bk_
    kern = functools.partial(_flash_kernel, bk=bk_, nk=nk, scale=scale_adj)
    out = pl.pallas_call(
        kern,
        grid=(sp // bq_,),
        in_specs=[
            pl.BlockSpec((bq_, dp), lambda i: (i, 0)),
            pl.BlockSpec((kp.shape[0], dp), lambda i: (0, 0)),
            pl.BlockSpec((vp.shape[0], v.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq_, v.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, v.shape[1]), q.dtype),
        interpret=True,
    )(qp, kp, vp)
    return out[:s]
