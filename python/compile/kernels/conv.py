"""L1 kernel: conv2d lowered to im2col + the tiled Pallas matmul.

The classic GPU lowering (cuDNN's implicit GEMM) expressed explicitly:
unfold input patches, hit the MXU with one large matmul, fold back.
The unfold runs in plain jnp (gather-heavy, XLA fuses it); the FLOP-dense
contraction is the Pallas matmul kernel so the whole conv inherits its
(bm, bn, bk) schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import matmul as mm
from . import ref


@functools.partial(jax.jit, static_argnames=("stride", "padding", "bm", "bn", "bk"))
def conv2d_im2col(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """NCHW x OIHW -> NCHW conv2d via im2col + Pallas matmul."""
    n, c, h, wd = x.shape
    o, ci, kh, kw = w.shape
    if ci != c:
        raise ValueError(f"channel mismatch: input {c}, weight {ci}")
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw) // stride + 1
    cols = ref.im2col(x, kh, kw, stride=stride, padding=padding)  # [N*OH*OW, C*KH*KW]
    wmat = w.reshape(o, c * kh * kw).T  # [C*KH*KW, O]
    out = mm.matmul(cols, wmat, bm=bm, bn=bn, bk=bk)  # [N*OH*OW, O]
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def conv1x1(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Pointwise conv as a pure matmul — the Fire-module squeeze path."""
    n, c, h, wd = x.shape
    o = w.shape[0]
    xm = x.transpose(0, 2, 3, 1).reshape(n * h * wd, c)
    out = mm.matmul(xm, w.reshape(o, c).T, bm=bm, bn=bn, bk=bk)
    return out.reshape(n, h, wd, o).transpose(0, 3, 1, 2)
