"""L1 Pallas kernels: elementwise ops with an elements-per-thread schedule.

Reproduces the §7.2 Swish case study on the TPU-style substrate.  The
paper's winning Metal kernel processed 8 elements per thread to raise
arithmetic intensity and cut launch overhead; the Pallas analog is the
*block length* each grid step owns: ``ept`` scales the block from the
base lane width, so ``ept=8`` moves 8× more elements per grid step
through VMEM with a single bounds check per block (the padded tail).

``fast_math=True`` models the paper's ``fast::exp`` intrinsic with a
cheaper exp approximation — numerically looser, structurally faster.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Base lane width: one "thread"'s natural vector unit. ept multiplies it.
_BASE = 128


def _fast_exp(x):
    """exp via 2**(x*log2e) with a rational refinement — models fast::exp.

    Cheaper-pipeline stand-in: exact enough for sigmoid (|rel err| ~1e-4)
    but intentionally not bit-identical to jnp.exp.
    """
    log2e = 1.4426950408889634
    y = x * log2e
    n = jnp.floor(y)
    f = y - n
    # 2**f on [0,1) via a degree-4 minimax-ish polynomial.
    p = 1.0 + f * (0.6931471805599453 + f * (0.2401596780245081
        + f * (0.0558015897034194 + f * 0.0089893400833312)))
    return jnp.exp2(n) * p


def _act(acc, op: str, fast_math: bool):
    exp = _fast_exp if fast_math else jnp.exp
    if op == "swish":
        return acc * (1.0 / (1.0 + exp(-acc)))
    if op == "sigmoid":
        return 1.0 / (1.0 + exp(-acc))
    if op == "relu":
        return jnp.maximum(acc, 0.0)
    if op == "gelu":
        c = 0.7978845608028654
        return 0.5 * acc * (1.0 + jnp.tanh(c * (acc + 0.044715 * acc**3)))
    if op == "square":
        return acc * acc
    if op == "add1":
        return acc + 1.0
    raise ValueError(f"unknown elementwise op {op!r}")


def _chain_kernel(x_ref, o_ref, *, ops: tuple, fast_math: bool):
    """Apply the whole op chain to the resident block — one HBM round trip."""
    acc = x_ref[...]
    for op in ops:
        acc = _act(acc, op, fast_math)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("ops", "ept", "fast_math"))
def elementwise_chain(
    x: jax.Array,
    *,
    ops: tuple = ("swish",),
    ept: int = 1,
    fast_math: bool = False,
) -> jax.Array:
    """Fused elementwise chain over a tensor of any shape.

    ``ept`` — elements-per-thread factor (block = ept * 128 lanes).
    ``ops`` — tuple of op names applied in order inside one kernel.
    """
    if ept < 1:
        raise ValueError("ept must be >= 1")
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    blk = _BASE * ept
    pad = (-n) % blk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = (flat.shape[0] // blk,)
    kern = functools.partial(_chain_kernel, ops=tuple(ops), fast_math=fast_math)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(flat)
    return out[:n].reshape(shape)


def swish(x: jax.Array, *, ept: int = 1, fast_math: bool = False) -> jax.Array:
    """§7.2 Swish kernel.  ept=8 + fast_math is the paper's winning point."""
    return elementwise_chain(x, ops=("swish",), ept=ept, fast_math=fast_math)


def _bias_act_kernel(x_ref, b_ref, o_ref, *, op: str, fast_math: bool):
    o_ref[...] = _act(x_ref[...] + b_ref[...], op, fast_math)


@functools.partial(jax.jit, static_argnames=("op", "rows_per_step", "fast_math"))
def bias_act_2d(
    x: jax.Array,
    b: jax.Array,
    *,
    op: str = "relu",
    rows_per_step: int = 8,
    fast_math: bool = False,
) -> jax.Array:
    """Fused bias+activation over [m, n] with a row-blocked schedule."""
    m, n = x.shape
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    r = min(rows_per_step, m)
    pad = (-m) % r
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    kern = functools.partial(_bias_act_kernel, op=op, fast_math=fast_math)
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // r,),
        in_specs=[
            pl.BlockSpec((r, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, b.reshape(1, -1))
    return out[:m]
