"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the ground truth the pytest suite checks each kernel against
(``assert_allclose``).  They intentionally use the most direct jnp
formulation — no tiling, no tricks — so that a mismatch always indicts
the kernel, not the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain dense matmul, f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def swish(x: jax.Array) -> jax.Array:
    """Swish / SiLU: x * sigmoid(x)  (paper §7.2)."""
    return x * jax.nn.sigmoid(x)


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximated GELU (matches the kernel's formulation)."""
    return (
        0.5
        * x
        * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (x + 0.044715 * x**3)))
    )


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def sigmoid(x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x)


def bias_act(x: jax.Array, b: jax.Array, act: str) -> jax.Array:
    """Fused bias-add + activation oracle."""
    y = x + b
    if act == "relu":
        return relu(y)
    if act == "swish":
        return swish(y)
    if act == "gelu":
        return gelu(y)
    if act == "none":
        return y
    raise ValueError(f"unknown activation {act!r}")


def softmax(x: jax.Array) -> jax.Array:
    """Numerically stable softmax along the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q: jax.Array, k: jax.Array, v: jax.Array, scale: float | None = None) -> jax.Array:
    """Single-head scaled dot-product attention.  q,k,v: [s, d]."""
    if scale is None:
        scale = 1.0 / float(q.shape[-1]) ** 0.5
    logits = jnp.matmul(q, k.T) * scale
    return jnp.matmul(softmax(logits), v)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0) -> jax.Array:
    """NCHW conv2d with OIHW weights, via lax.conv (oracle)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, padding: int = 0) -> jax.Array:
    """Unfold NCHW input into [N*OH*OW, C*KH*KW] patches (oracle for the
    im2col transform feeding the matmul kernel)."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                xp[:, :, i : i + stride * oh : stride, j : j + stride * ow : stride]
            )
    # [KH*KW, N, C, OH, OW] -> [N, OH, OW, C, KH*KW] -> [N*OH*OW, C*KH*KW]
    st = jnp.stack(patches, axis=0)
    st = st.transpose(1, 3, 4, 2, 0)
    return st.reshape(n * oh * ow, c * kh * kw)


def swish_chain(x: jax.Array, n: int = 1) -> jax.Array:
    """n successive swish applications (fused-chain oracle)."""
    for _ in range(n):
        x = swish(x)
    return x
