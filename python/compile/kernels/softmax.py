"""L1 Pallas kernel: online softmax (Milakov & Gimelshein 2018).

The paper's FlashAttention discussion (§1) rests on the online-softmax
trick: a single pass over the row maintains a running maximum ``m`` and
a running rescaled denominator ``d`` so the row never needs to be
materialized twice.  Each grid step owns a block of rows in VMEM and
streams the columns in ``bc``-wide chunks with a ``fori_loop`` — the
same schedule FlashAttention expresses with warps, expressed here with
in-kernel chunking over the resident block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _online_softmax_kernel(x_ref, o_ref, *, bc: int, nc: int):
    """Rows resident; stream columns in nc chunks of width bc."""
    rows = x_ref.shape[0]

    def body(c, carry):
        m, d = carry
        chunk = jax.lax.dynamic_slice(x_ref[...], (0, c * bc), (rows, bc))
        m_new = jnp.maximum(m, jnp.max(chunk, axis=-1, keepdims=True))
        d = d * jnp.exp(m - m_new) + jnp.sum(jnp.exp(chunk - m_new), axis=-1, keepdims=True)
        return m_new, d

    m0 = jnp.full((rows, 1), -jnp.inf, dtype=x_ref.dtype)
    d0 = jnp.zeros((rows, 1), dtype=x_ref.dtype)
    m, d = jax.lax.fori_loop(0, nc, body, (m0, d0))
    o_ref[...] = jnp.exp(x_ref[...] - m) / d


@functools.partial(jax.jit, static_argnames=("br", "bc"))
def softmax(x: jax.Array, *, br: int = 8, bc: int = 128) -> jax.Array:
    """Online softmax along the last axis of a 2-D array [m, n].

    ``br`` rows per grid step; columns streamed in ``bc`` chunks.  Ragged
    n is padded with -inf (exact: exp(-inf)=0 contributes nothing).
    """
    if x.ndim != 2:
        raise ValueError(f"softmax kernel expects 2-D input, got {x.shape}")
    m, n = x.shape
    br_ = min(br, m)
    bc_ = min(bc, n)
    pr = (-m) % br_
    pc = (-n) % bc_
    xp = jnp.pad(x, ((0, pr), (0, pc)), constant_values=-jnp.inf)
    nc = xp.shape[1] // bc_
    kern = functools.partial(_online_softmax_kernel, bc=bc_, nc=nc)
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // br_,),
        in_specs=[pl.BlockSpec((br_, xp.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br_, xp.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:m, :n]
