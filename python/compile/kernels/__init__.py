"""L1 Pallas kernels (build-time only; lowered AOT into artifacts/).

Every kernel takes a *schedule* (block sizes, elements-per-thread,
fast-math) mirroring the synthesis space the rust coordinator searches.
"""

from . import attention, conv, elementwise, layernorm, matmul, ref, softmax  # noqa: F401
