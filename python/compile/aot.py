"""AOT driver: lower every (workload, variant, batch) to HLO text.

Writes ``artifacts/<workload>__<variant>__b<batch>.hlo.txt`` plus a
``manifest.json`` the rust runtime's registry consumes (artifact path,
input shapes/dtypes, output arity, which variant is the reference).

Run once at build time (``make artifacts``); python never appears on the
rust request path.  Interchange is HLO *text*, not ``.serialize()`` —
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit ids).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

from . import model


def _spec_json(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def build(out_dir: str, only: list[str] | None = None, batches: dict | None = None) -> dict:
    """Lower the registry and return the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    batches = batches or model.DEFAULT_BATCHES
    for name, (variants, spec_fn, ref_variant) in sorted(model.WORKLOADS.items()):
        if only and name not in only:
            continue
        for batch in batches.get(name, [16]):
            specs = spec_fn(batch)
            for vname, fn in sorted(variants.items()):
                key = f"{name}__{vname}__b{batch}"
                path = os.path.join(out_dir, f"{key}.hlo.txt")
                text = model.lower_to_hlo_text(fn, specs)
                with open(path, "w") as f:
                    f.write(text)
                entries.append(
                    {
                        "key": key,
                        "workload": name,
                        "variant": vname,
                        "batch": batch,
                        "path": os.path.basename(path),
                        "inputs": [_spec_json(s) for s in specs],
                        "is_reference": vname == ref_variant,
                        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                    }
                )
                print(f"  lowered {key}: {len(text)} chars", file=sys.stderr)
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--only", nargs="*", default=None, help="limit to workloads")
    args = ap.parse_args()
    manifest = build(args.out, only=args.only)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
