//! Property tests (hand-rolled, seeded — proptest is unavailable
//! offline).  Each property sweeps many random cases from a seeded
//! generator and asserts an invariant.

use kforge::kir::graph::{Graph, GraphBuilder};
use kforge::kir::interp::eval;
use kforge::kir::op::{BinaryKind, ReduceKind, UnaryKind};
use kforge::kir::rewrite::{algebraic, constant_fold, cse, dce};
use kforge::kir::validate::validate;
use kforge::metrics::{self, TaskOutcome};
use kforge::sched::{legal, Schedule};
use kforge::tensor::{Shape, Tensor};
use kforge::util::rng::Pcg;

/// Generate a random small elementwise/matmul/reduce graph.
fn random_graph(rng: &mut Pcg) -> Graph {
    let mut b = GraphBuilder::new("prop");
    let m = rng.range_i64(2, 6) as usize;
    let k = rng.range_i64(2, 6) as usize;
    let x = b.input(Shape::of(&[m, k]));
    let mut frontier = vec![x];
    let n_ops = rng.range_i64(2, 8) as usize;
    for _ in 0..n_ops {
        let pick = *rng.choose(&frontier);
        let shape = {
            // look up current shape via a temp finish? builder tracks nodes;
            // use the node shape through a cheap rebuild trick:
            // store shapes alongside frontier instead
            pick
        };
        let _ = shape;
        let choice = rng.below(4);
        let id = match choice {
            0 => {
                let kind = *rng.choose(&UnaryKind::ALL);
                b.unary(kind, pick)
            }
            1 => b.binary(*rng.choose(&[BinaryKind::Add, BinaryKind::Mul, BinaryKind::Max]), pick, pick),
            2 => {
                let kind = *rng.choose(&[ReduceKind::Sum, ReduceKind::Max, ReduceKind::Mean]);
                b.reduce(kind, rng.below(2) as usize, pick)
            }
            _ => {
                let w = b.input(Shape::of(&[k, rng.range_i64(2, 5) as usize]));
                // matmul only valid from rank-2 [., k] nodes; x qualifies
                b.matmul(x, w)
            }
        };
        frontier.push(id);
    }
    let out = *frontier.last().unwrap();
    b.finish(vec![out])
}

fn rand_inputs(g: &Graph, rng: &mut Pcg) -> Vec<Tensor> {
    g.input_shapes
        .iter()
        .map(|s| Tensor::randn(s.clone(), rng, 0.7))
        .collect()
}

#[test]
fn prop_rewrites_preserve_semantics() {
    // cse/dce/constant_fold/algebraic all preserve outputs on random graphs
    let mut rng = Pcg::seed(0xFACADE);
    for case in 0..120 {
        let g = random_graph(&mut rng);
        validate(&g).unwrap();
        let ins = rand_inputs(&g, &mut rng);
        let Ok(want) = eval(&g, &ins) else { continue };
        if want[0].data.iter().any(|v| !v.is_finite()) {
            continue; // exp overflow etc. — not a rewrite question
        }
        for (name, rewritten) in [
            ("cse", cse::eliminate(&g)),
            ("dce", dce(&g)),
            ("fold", constant_fold::fold(&g)),
            ("algebraic", algebraic::reduce_matmul_chains(&g)),
        ] {
            validate(&rewritten).unwrap_or_else(|e| panic!("case {case} {name}: invalid: {e}"));
            let got = eval(&rewritten, &ins).unwrap();
            assert_eq!(got.len(), want.len());
            for (gt, wt) in got.iter().zip(&want) {
                assert!(
                    gt.allclose(wt, 1e-3, 1e-3),
                    "case {case} {name}: outputs diverge\n{}\nvs\n{}",
                    g.render(),
                    rewritten.render()
                );
            }
        }
    }
}

#[test]
fn prop_rewrites_never_grow_flops() {
    let mut rng = Pcg::seed(0xBEEF);
    for _ in 0..100 {
        let g = random_graph(&mut rng);
        let base = cse::eliminate(&g).total_flops();
        let reduced = algebraic::reduce_matmul_chains(&cse::eliminate(&g)).total_flops();
        assert!(reduced <= base * 1.001, "algebraic grew flops: {base} -> {reduced}");
    }
}

#[test]
fn prop_fast_p_monotone_and_bounded() {
    let mut rng = Pcg::seed(0xF00D);
    for _ in 0..200 {
        let n = rng.range_i64(1, 40) as usize;
        let outcomes: Vec<TaskOutcome> = (0..n)
            .map(|_| {
                if rng.chance(0.6) {
                    TaskOutcome::correct(rng.range_f64(0.05, 4.0))
                } else {
                    TaskOutcome::incorrect()
                }
            })
            .collect();
        let mut prev = f64::INFINITY;
        for p in [0.0, 0.5, 1.0, 1.5, 2.0, 3.0] {
            let f = metrics::fast_p(&outcomes, p);
            assert!((0.0..=1.0).contains(&f));
            assert!(f <= prev + 1e-12, "fast_p not monotone at {p}");
            prev = f;
        }
        assert!(metrics::fast_p(&outcomes, 0.0) <= metrics::correctness_rate(&outcomes) + 1e-12);
    }
}

#[test]
fn prop_schedule_sampling_always_improvable_to_legal() {
    // any sampled schedule, after repair toward the platform expert,
    // passes legality on that platform — for every registered platform
    let platforms = kforge::platform::registry().platforms();
    let mut rng = Pcg::seed(0x5EED);
    for _ in 0..300 {
        let skill = rng.uniform();
        let mut s = Schedule::sample(&mut rng, skill);
        for platform in platforms {
            let spec = platform.spec();
            let e = Schedule::expert_for(spec);
            s.tile = e.tile;
            s.threadgroup = e.threadgroup;
            s.ept = s.ept.clamp(1, 8).next_power_of_two();
            s.vec_width = s.vec_width.clamp(1, 4).next_power_of_two();
            legal::check(&s, spec).unwrap();
        }
    }
}

#[test]
fn prop_profile_screenshot_roundtrip_bounded_loss() {
    // render → scrape loses at most printing precision on any profile
    use kforge::kir::op::Op;
    use kforge::perfsim::{lower, simulate};
    use kforge::profiler::{parse, xcode, Profile};
    let spec = kforge::platform::metal::m4_max();
    let mut rng = Pcg::seed(0xD15C);
    for case in 0..40 {
        let mut b = GraphBuilder::new("p");
        let n = rng.range_i64(16, 64) as usize * 2;
        let x = b.input(Shape::of(&[n, n]));
        let w = b.input(Shape::of(&[n, n]));
        let m = b.matmul(x, w);
        let sm = b.push(Op::Softmax { input: m });
        let g = b.finish(vec![sm]);
        let skill = rng.uniform();
        let sched = Schedule::sample(&mut rng, skill);
        let plan = lower::lower(&g, &sched);
        let sim = simulate(&spec, &plan, &mut rng, 10, 2);
        let profile = Profile::from_sim("p", spec.name, &sim);
        let scraped = parse::scrape(&xcode::capture_screens(&profile)).unwrap();
        assert_eq!(scraped.dispatches, profile.kernels.len(), "case {case}");
        let rel = (scraped.gpu_time_us - profile.total_us).abs() / profile.total_us.max(1e-9);
        assert!(rel < 0.06, "case {case}: gpu time loss {rel}");
    }
}

#[test]
fn prop_frontends_agree_on_dominant_bottleneck() {
    // every frontend — lossless nsys/rocprof and the scraped xcode
    // screens — identifies the same hottest kernel on random profiles
    // (modulo the 20-char name column), whenever the top-2 gap exceeds
    // the coarsest frontend's rounding resolution
    use kforge::profiler::nsys::NsysFrontend;
    use kforge::profiler::rocprof::RocprofFrontend;
    use kforge::profiler::xcode::XcodeFrontend;
    use kforge::profiler::{KernelRecord, Profile, ProfilerFrontend};
    let names = [
        "matmul_0",
        "softmax_1",
        "layernorm_with_a_fused_bias_epilogue_2",
        "conv_3",
        "swish_4",
        "attention_projection_packed_qkv_5",
    ];
    let mut rng = Pcg::seed(0xB0771E);
    let mut checked = 0;
    for case in 0..80 {
        let n_kernels = rng.range_i64(2, 6) as usize;
        let mut kernels = Vec::new();
        let mut total = 0.0;
        let mut launch = 0.0;
        for i in 0..n_kernels {
            let time = rng.range_f64(1.0, 100.0);
            let gap = rng.range_f64(0.5, 10.0);
            total += time + gap;
            launch += gap;
            kernels.push(KernelRecord {
                name: names[i].to_string(),
                time_us: time,
                pct_of_total: 0.0, // filled below once total is known
                gap_before_us: gap,
                mm_utilization: rng.uniform(),
                mem_utilization: rng.uniform(),
                occupancy: rng.uniform(),
                compute_bound: rng.chance(0.5),
            });
        }
        let busy = (total - launch) / total;
        for k in &mut kernels {
            k.pct_of_total = 100.0 * k.time_us / total;
        }
        let profile = Profile {
            workload: "prop".into(),
            platform: "Prop GPU".into(),
            kernels,
            total_us: total,
            launch_overhead_us: launch,
            busy_fraction: busy,
            total_flops: 1e9,
            total_bytes: 1e6,
        };
        // skip near-ties: below the screenshot's 0.1us print resolution
        // no frontend is obliged to order the top two consistently
        let mut times: Vec<f64> = profile.kernels.iter().map(|k| k.time_us).collect();
        times.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if times[0] - times[1] < 0.3 {
            continue;
        }
        checked += 1;
        let truth = profile
            .kernels
            .iter()
            .max_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
            .unwrap();
        for frontend in [
            &NsysFrontend as &dyn ProfilerFrontend,
            &RocprofFrontend,
            &XcodeFrontend,
        ] {
            let ev = frontend
                .evidence(&profile)
                .unwrap_or_else(|e| panic!("case {case} {}: {e:#}", frontend.name()));
            let hot = ev.hottest().unwrap_or_else(|| panic!("{}: no hottest", frontend.name()));
            // scraped names are clipped to the GUI column width; a
            // lossless frontend must match exactly
            let clipped: String = truth.name.chars().take(20).collect();
            assert!(
                hot.name == truth.name || hot.name == clipped,
                "case {case} {}: hottest {:?} != true hottest {:?}",
                frontend.name(),
                hot.name,
                truth.name
            );
        }
    }
    assert!(checked >= 40, "only {checked} informative cases");
}

#[test]
fn prop_search_candidates_legal_on_every_registered_platform() {
    // ISSUE 5 acceptance: every candidate any registered strategy
    // emits passes legal::check on the spec it searched — swept over
    // every (platform, strategy) pair on curated problems
    use kforge::search::{strategies, Budget, CostOracle};
    let suite = kforge::workloads::Suite::sample(2);
    for platform in kforge::platform::registry().platforms() {
        let spec = platform.spec();
        for strategy in strategies() {
            for p in suite.problems.iter().filter(|p| p.supported_on(spec)).take(3) {
                let oracle = CostOracle::new(spec, &p.perf_graph);
                let mut budget = Budget::new(120, 2);
                let mut rng = Pcg::seed(0x5EA7C4);
                let out = strategy.search(&oracle, &mut budget, &mut rng);
                assert!(!out.visited.is_empty(), "{}/{}", platform.name(), strategy.name());
                assert!(out.visited.len() <= 120, "{}/{} overdrew the budget", platform.name(), strategy.name());
                for s in &out.visited {
                    legal::check(s, spec).unwrap_or_else(|e| {
                        panic!(
                            "{}/{} on {}: illegal candidate {}: {e}",
                            platform.name(),
                            strategy.name(),
                            p.id,
                            s.canon()
                        )
                    });
                }
                assert!(out.best.cost_s.is_finite());
                assert_eq!(out.best.schedule, out.frontier[0].schedule);
            }
        }
    }
}

#[test]
fn prop_tuned_schedule_never_prices_above_naive() {
    // the curated-suite acceptance invariant behind `kforge tune`'s
    // nonzero exit: tuned <= naive on 100% of problems, per platform
    // and per strategy
    use kforge::search::{strategies, tune_problem, TuneConfig};
    let suite = kforge::workloads::Suite::sample(2);
    for platform in kforge::platform::registry().platforms() {
        for strategy in strategies() {
            let mut cfg = TuneConfig::new(platform.clone());
            cfg.strategy = strategy.clone();
            cfg.budget = 96;
            for p in suite.problems.iter().filter(|p| p.supported_on(platform.spec())).take(3) {
                let r = tune_problem(&cfg, p);
                assert!(
                    r.tuned_s <= r.naive_s,
                    "{}/{} on {}: tuned {} > naive {}",
                    platform.name(),
                    strategy.name(),
                    p.id,
                    r.tuned_s,
                    r.naive_s
                );
                legal::check(&r.schedule, platform.spec()).unwrap();
            }
        }
    }
}

#[test]
fn prop_verification_deterministic_across_runs() {
    use kforge::agents::GenerationAgent;
    let suite = kforge::workloads::Suite::sample(4);
    let spec = kforge::platform::cuda::h100();
    let persona = kforge::agents::persona::by_name("deepseek-r1").unwrap();
    let agent = GenerationAgent::new(persona, kforge::platform::by_name("cuda").unwrap());
    for p in suite.problems.iter() {
        let mut r1 = Pcg::seed(42);
        let mut r2 = Pcg::seed(42);
        let a = agent.synthesize(p, None, &mut r1);
        let b = agent.synthesize(p, None, &mut r2);
        match (a, b) {
            (Some(pa), Some(pb)) => {
                let mut v1 = Pcg::seed(7);
                let mut v2 = Pcg::seed(7);
                let oa = kforge::verify::verify(&spec, p, Some(&pa), &mut v1);
                let ob = kforge::verify::verify(&spec, p, Some(&pb), &mut v2);
                assert_eq!(oa.state.label(), ob.state.label());
            }
            (None, None) => {}
            _ => panic!("generation determinism violated"),
        }
    }
}

#[test]
fn prop_suite_eval_graphs_all_finite() {
    // every problem's reference evaluation yields finite outputs on its
    // seeded inputs (guards tolerances in the verifier)
    let suite = kforge::workloads::Suite::full();
    for p in suite.problems.iter() {
        let ins = p.eval_inputs(0xC0FFEE);
        let out = eval(&p.eval_graph, &ins).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        for (i, t) in out.iter().enumerate() {
            assert!(
                t.data.iter().all(|v| v.is_finite()),
                "{} output {i} has non-finite values",
                p.id
            );
        }
    }
}
