//! Property tests (hand-rolled, seeded — proptest is unavailable
//! offline).  Each property sweeps many random cases from a seeded
//! generator and asserts an invariant.

use kforge::kir::graph::{Graph, GraphBuilder};
use kforge::kir::interp::eval;
use kforge::kir::op::{BinaryKind, ReduceKind, UnaryKind};
use kforge::kir::rewrite::{algebraic, constant_fold, cse, dce};
use kforge::kir::validate::validate;
use kforge::metrics::{self, TaskOutcome};
use kforge::sched::{legal, Schedule};
use kforge::tensor::{Shape, Tensor};
use kforge::util::rng::Pcg;

/// Generate a random small elementwise/matmul/reduce graph.
fn random_graph(rng: &mut Pcg) -> Graph {
    let mut b = GraphBuilder::new("prop");
    let m = rng.range_i64(2, 6) as usize;
    let k = rng.range_i64(2, 6) as usize;
    let x = b.input(Shape::of(&[m, k]));
    let mut frontier = vec![x];
    let n_ops = rng.range_i64(2, 8) as usize;
    for _ in 0..n_ops {
        let pick = *rng.choose(&frontier);
        let shape = {
            // look up current shape via a temp finish? builder tracks nodes;
            // use the node shape through a cheap rebuild trick:
            // store shapes alongside frontier instead
            pick
        };
        let _ = shape;
        let choice = rng.below(4);
        let id = match choice {
            0 => {
                let kind = *rng.choose(&UnaryKind::ALL);
                b.unary(kind, pick)
            }
            1 => b.binary(*rng.choose(&[BinaryKind::Add, BinaryKind::Mul, BinaryKind::Max]), pick, pick),
            2 => {
                let kind = *rng.choose(&[ReduceKind::Sum, ReduceKind::Max, ReduceKind::Mean]);
                b.reduce(kind, rng.below(2) as usize, pick)
            }
            _ => {
                let w = b.input(Shape::of(&[k, rng.range_i64(2, 5) as usize]));
                // matmul only valid from rank-2 [., k] nodes; x qualifies
                b.matmul(x, w)
            }
        };
        frontier.push(id);
    }
    let out = *frontier.last().unwrap();
    b.finish(vec![out])
}

fn rand_inputs(g: &Graph, rng: &mut Pcg) -> Vec<Tensor> {
    g.input_shapes
        .iter()
        .map(|s| Tensor::randn(s.clone(), rng, 0.7))
        .collect()
}

#[test]
fn prop_rewrites_preserve_semantics() {
    // cse/dce/constant_fold/algebraic all preserve outputs on random graphs
    let mut rng = Pcg::seed(0xFACADE);
    for case in 0..120 {
        let g = random_graph(&mut rng);
        validate(&g).unwrap();
        let ins = rand_inputs(&g, &mut rng);
        let Ok(want) = eval(&g, &ins) else { continue };
        if want[0].data.iter().any(|v| !v.is_finite()) {
            continue; // exp overflow etc. — not a rewrite question
        }
        for (name, rewritten) in [
            ("cse", cse::eliminate(&g)),
            ("dce", dce(&g)),
            ("fold", constant_fold::fold(&g)),
            ("algebraic", algebraic::reduce_matmul_chains(&g)),
        ] {
            validate(&rewritten).unwrap_or_else(|e| panic!("case {case} {name}: invalid: {e}"));
            let got = eval(&rewritten, &ins).unwrap();
            assert_eq!(got.len(), want.len());
            for (gt, wt) in got.iter().zip(&want) {
                assert!(
                    gt.allclose(wt, 1e-3, 1e-3),
                    "case {case} {name}: outputs diverge\n{}\nvs\n{}",
                    g.render(),
                    rewritten.render()
                );
            }
        }
    }
}

#[test]
fn prop_rewrites_never_grow_flops() {
    let mut rng = Pcg::seed(0xBEEF);
    for _ in 0..100 {
        let g = random_graph(&mut rng);
        let base = cse::eliminate(&g).total_flops();
        let reduced = algebraic::reduce_matmul_chains(&cse::eliminate(&g)).total_flops();
        assert!(reduced <= base * 1.001, "algebraic grew flops: {base} -> {reduced}");
    }
}

#[test]
fn prop_fast_p_monotone_and_bounded() {
    let mut rng = Pcg::seed(0xF00D);
    for _ in 0..200 {
        let n = rng.range_i64(1, 40) as usize;
        let outcomes: Vec<TaskOutcome> = (0..n)
            .map(|_| {
                if rng.chance(0.6) {
                    TaskOutcome::correct(rng.range_f64(0.05, 4.0))
                } else {
                    TaskOutcome::incorrect()
                }
            })
            .collect();
        let mut prev = f64::INFINITY;
        for p in [0.0, 0.5, 1.0, 1.5, 2.0, 3.0] {
            let f = metrics::fast_p(&outcomes, p);
            assert!((0.0..=1.0).contains(&f));
            assert!(f <= prev + 1e-12, "fast_p not monotone at {p}");
            prev = f;
        }
        assert!(metrics::fast_p(&outcomes, 0.0) <= metrics::correctness_rate(&outcomes) + 1e-12);
    }
}

#[test]
fn prop_schedule_sampling_always_improvable_to_legal() {
    // any sampled schedule, after repair toward the platform expert,
    // passes legality on that platform — for every registered platform
    let platforms = kforge::platform::registry().platforms();
    let mut rng = Pcg::seed(0x5EED);
    for _ in 0..300 {
        let skill = rng.uniform();
        let mut s = Schedule::sample(&mut rng, skill);
        for platform in platforms {
            let spec = platform.spec();
            let e = Schedule::expert_for(spec);
            s.tile = e.tile;
            s.threadgroup = e.threadgroup;
            s.ept = s.ept.clamp(1, 8).next_power_of_two();
            s.vec_width = s.vec_width.clamp(1, 4).next_power_of_two();
            legal::check(&s, spec).unwrap();
        }
    }
}

#[test]
fn prop_profile_screenshot_roundtrip_bounded_loss() {
    // render → scrape loses at most printing precision on any profile
    use kforge::kir::op::Op;
    use kforge::perfsim::{lower, simulate};
    use kforge::profiler::{parse, xcode, Profile};
    let spec = kforge::platform::metal::m4_max();
    let mut rng = Pcg::seed(0xD15C);
    for case in 0..40 {
        let mut b = GraphBuilder::new("p");
        let n = rng.range_i64(16, 64) as usize * 2;
        let x = b.input(Shape::of(&[n, n]));
        let w = b.input(Shape::of(&[n, n]));
        let m = b.matmul(x, w);
        let sm = b.push(Op::Softmax { input: m });
        let g = b.finish(vec![sm]);
        let skill = rng.uniform();
        let sched = Schedule::sample(&mut rng, skill);
        let plan = lower::lower(&g, &sched);
        let sim = simulate(&spec, &plan, &mut rng, 10, 2);
        let profile = Profile::from_sim("p", spec.name, &sim);
        let scraped = parse::scrape(&xcode::capture_screens(&profile)).unwrap();
        assert_eq!(scraped.dispatches, profile.kernels.len(), "case {case}");
        let rel = (scraped.gpu_time_us - profile.total_us).abs() / profile.total_us.max(1e-9);
        assert!(rel < 0.06, "case {case}: gpu time loss {rel}");
    }
}

#[test]
fn prop_frontends_agree_on_dominant_bottleneck() {
    // every frontend — lossless nsys/rocprof and the scraped xcode
    // screens — identifies the same hottest kernel on random profiles
    // (modulo the 20-char name column), whenever the top-2 gap exceeds
    // the coarsest frontend's rounding resolution
    use kforge::profiler::nsys::NsysFrontend;
    use kforge::profiler::rocprof::RocprofFrontend;
    use kforge::profiler::xcode::XcodeFrontend;
    use kforge::profiler::{KernelRecord, Profile, ProfilerFrontend};
    let names = [
        "matmul_0",
        "softmax_1",
        "layernorm_with_a_fused_bias_epilogue_2",
        "conv_3",
        "swish_4",
        "attention_projection_packed_qkv_5",
    ];
    let mut rng = Pcg::seed(0xB0771E);
    let mut checked = 0;
    for case in 0..80 {
        let n_kernels = rng.range_i64(2, 6) as usize;
        let mut kernels = Vec::new();
        let mut total = 0.0;
        let mut launch = 0.0;
        for i in 0..n_kernels {
            let time = rng.range_f64(1.0, 100.0);
            let gap = rng.range_f64(0.5, 10.0);
            total += time + gap;
            launch += gap;
            kernels.push(KernelRecord {
                name: names[i].to_string(),
                time_us: time,
                pct_of_total: 0.0, // filled below once total is known
                gap_before_us: gap,
                mm_utilization: rng.uniform(),
                mem_utilization: rng.uniform(),
                occupancy: rng.uniform(),
                compute_bound: rng.chance(0.5),
            });
        }
        let busy = (total - launch) / total;
        for k in &mut kernels {
            k.pct_of_total = 100.0 * k.time_us / total;
        }
        let profile = Profile {
            workload: "prop".into(),
            platform: "Prop GPU".into(),
            kernels,
            total_us: total,
            launch_overhead_us: launch,
            busy_fraction: busy,
            total_flops: 1e9,
            total_bytes: 1e6,
        };
        // skip near-ties: below the screenshot's 0.1us print resolution
        // no frontend is obliged to order the top two consistently
        let mut times: Vec<f64> = profile.kernels.iter().map(|k| k.time_us).collect();
        times.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if times[0] - times[1] < 0.3 {
            continue;
        }
        checked += 1;
        let truth = profile
            .kernels
            .iter()
            .max_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
            .unwrap();
        for frontend in [
            &NsysFrontend as &dyn ProfilerFrontend,
            &RocprofFrontend,
            &XcodeFrontend,
        ] {
            let ev = frontend
                .evidence(&profile)
                .unwrap_or_else(|e| panic!("case {case} {}: {e:#}", frontend.name()));
            let hot = ev.hottest().unwrap_or_else(|| panic!("{}: no hottest", frontend.name()));
            // scraped names are clipped to the GUI column width; a
            // lossless frontend must match exactly
            let clipped: String = truth.name.chars().take(20).collect();
            assert!(
                hot.name == truth.name || hot.name == clipped,
                "case {case} {}: hottest {:?} != true hottest {:?}",
                frontend.name(),
                hot.name,
                truth.name
            );
        }
    }
    assert!(checked >= 40, "only {checked} informative cases");
}

#[test]
fn prop_search_candidates_legal_on_every_registered_platform() {
    // ISSUE 5 acceptance: every candidate any registered strategy
    // emits passes legal::check on the spec it searched — swept over
    // every (platform, strategy) pair on curated problems
    use kforge::search::{strategies, Budget, CostOracle};
    let suite = kforge::workloads::Suite::sample(2);
    for platform in kforge::platform::registry().platforms() {
        let spec = platform.spec();
        for strategy in strategies() {
            for p in suite.problems.iter().filter(|p| p.supported_on(spec)).take(3) {
                let oracle = CostOracle::new(spec, &p.perf_graph);
                let mut budget = Budget::new(120, 2);
                let mut rng = Pcg::seed(0x5EA7C4);
                let out = strategy.search(&oracle, &mut budget, &mut rng);
                assert!(!out.visited.is_empty(), "{}/{}", platform.name(), strategy.name());
                assert!(out.visited.len() <= 120, "{}/{} overdrew the budget", platform.name(), strategy.name());
                for s in &out.visited {
                    legal::check(s, spec).unwrap_or_else(|e| {
                        panic!(
                            "{}/{} on {}: illegal candidate {}: {e}",
                            platform.name(),
                            strategy.name(),
                            p.id,
                            s.canon()
                        )
                    });
                }
                assert!(out.best.cost_s.is_finite());
                assert_eq!(out.best.schedule, out.frontier[0].schedule);
            }
        }
    }
}

#[test]
fn prop_tuned_schedule_never_prices_above_naive() {
    // the curated-suite acceptance invariant behind `kforge tune`'s
    // nonzero exit: tuned <= naive on 100% of problems, per platform
    // and per strategy
    use kforge::search::{strategies, tune_problem, TuneConfig};
    let suite = kforge::workloads::Suite::sample(2);
    for platform in kforge::platform::registry().platforms() {
        for strategy in strategies() {
            let mut cfg = TuneConfig::new(platform.clone());
            cfg.strategy = strategy.clone();
            cfg.budget = 96;
            for p in suite.problems.iter().filter(|p| p.supported_on(platform.spec())).take(3) {
                let r = tune_problem(&cfg, p);
                assert!(
                    r.tuned_s <= r.naive_s,
                    "{}/{} on {}: tuned {} > naive {}",
                    platform.name(),
                    strategy.name(),
                    p.id,
                    r.tuned_s,
                    r.naive_s
                );
                legal::check(&r.schedule, platform.spec()).unwrap();
            }
        }
    }
}

#[test]
fn prop_verification_deterministic_across_runs() {
    use kforge::agents::GenerationAgent;
    let suite = kforge::workloads::Suite::sample(4);
    let spec = kforge::platform::cuda::h100();
    let persona = kforge::agents::persona::by_name("deepseek-r1").unwrap();
    let agent = GenerationAgent::new(persona, kforge::platform::by_name("cuda").unwrap());
    for p in suite.problems.iter() {
        let mut r1 = Pcg::seed(42);
        let mut r2 = Pcg::seed(42);
        let a = agent.synthesize(p, None, &mut r1);
        let b = agent.synthesize(p, None, &mut r2);
        match (a, b) {
            (Some(pa), Some(pb)) => {
                let mut v1 = Pcg::seed(7);
                let mut v2 = Pcg::seed(7);
                let oa = kforge::verify::verify(&spec, p, Some(&pa), &mut v1);
                let ob = kforge::verify::verify(&spec, p, Some(&pb), &mut v2);
                assert_eq!(oa.state.label(), ob.state.label());
            }
            (None, None) => {}
            _ => panic!("generation determinism violated"),
        }
    }
}

// ---------------------------------------------------------------------------
// GraphPatch / incremental-rewrite properties
// ---------------------------------------------------------------------------

#[test]
fn prop_patch_on_validated_graph_yields_validated_graph() {
    use kforge::kir::fuzz;
    use kforge::kir::op::Op;
    use kforge::kir::patch::GraphPatch;
    for seed in 0..300u64 {
        let g = fuzz::graph(seed);
        validate(&g).unwrap();
        // every pass's staged patch applies into a validated graph
        let (a, _) = cse::patch(&g).apply().unwrap_or_else(|e| panic!("seed {seed} cse: {e}"));
        validate(&a).unwrap_or_else(|e| panic!("seed {seed} cse output: {e}"));
        let (b, _) =
            constant_fold::patch(&g).apply().unwrap_or_else(|e| panic!("seed {seed} fold: {e}"));
        validate(&b).unwrap_or_else(|e| panic!("seed {seed} fold output: {e}"));
        if let Some(p) = algebraic::next_patch(&g) {
            let (c, _) = p.apply().unwrap_or_else(|e| panic!("seed {seed} algebraic: {e}"));
            validate(&c).unwrap_or_else(|e| panic!("seed {seed} algebraic output: {e}"));
        }
        // a hand-staged patch too: add a relu over a seeded node and
        // rewire output 0 at it
        let mut rng = Pcg::seed(seed ^ 0xA11CE);
        let target = rng.below(g.nodes.len() as u32) as usize;
        let mut p = GraphPatch::new(&g);
        p.prune();
        let added = p.add(Op::Unary { kind: UnaryKind::Relu, input: target }).unwrap();
        p.rewire_output(0, added).unwrap();
        let (d, dirty) = p.apply().unwrap_or_else(|e| panic!("seed {seed} staged: {e}"));
        validate(&d).unwrap_or_else(|e| panic!("seed {seed} staged output: {e}"));
        assert!(dirty.count() > 0, "seed {seed}: edit produced an empty dirty set");
    }
}

#[test]
fn prop_empty_patch_is_identity() {
    use kforge::kir::fuzz;
    use kforge::kir::patch::GraphPatch;
    for seed in 0..300u64 {
        let g = fuzz::graph(seed);
        let (out, dirty) = GraphPatch::new(&g).apply().unwrap();
        assert_eq!(out, g, "seed {seed}: empty patch changed the graph");
        assert_eq!(
            out.render(),
            g.render(),
            "seed {seed}: empty-patch serialization not bit-identical"
        );
        assert_eq!(dirty.count(), 0, "seed {seed}: empty patch dirtied nodes");
        assert_eq!(dirty.len(), g.nodes.len());
        for (i, m) in dirty.old_to_new.iter().enumerate() {
            assert_eq!(*m, Some(i), "seed {seed}: id map not identity at {i}");
        }
    }
}

#[test]
fn prop_conflicting_patch_edits_name_both_node_ids() {
    use kforge::kir::fuzz;
    use kforge::kir::op::Op;
    use kforge::kir::patch::GraphPatch;
    let mut checked = 0;
    for seed in 0..150u64 {
        let g = fuzz::graph(seed);
        // a non-input node with a same-shaped operand → redirectable
        let Some((id, o)) = g.nodes.iter().enumerate().find_map(|(id, n)| {
            if matches!(n.op, Op::Input { .. }) {
                return None;
            }
            n.op
                .operands()
                .into_iter()
                .find(|&o| g.nodes[o].shape == n.shape)
                .map(|o| (id, o))
        }) else {
            continue;
        };
        checked += 1;
        let mut p = GraphPatch::new(&g);
        p.redirect(id, o).unwrap();
        let err = p.replace(id, g.nodes[id].op.clone()).unwrap_err().to_string();
        assert!(
            err.contains(&format!("%{id}")) && err.contains(&format!("%{o}")),
            "seed {seed}: conflict error must name both ids (%{id}, %{o}): {err}"
        );
    }
    assert!(checked >= 30, "only {checked} conflict cases exercised");
}

#[test]
fn prop_reprice_bit_identical_to_full_relowering() {
    // oracle incrementality: re-pricing a patched schedule from the
    // dirty region returns the same bits as pricing the patched graph
    // from scratch — per registered platform, ≥200 fuzz seeds each,
    // under both the eager (depth 0) and expert (depth MAX) schedules
    use kforge::kir::fuzz;
    use kforge::search::{price, reprice, CostOracle};
    for platform in kforge::platform::registry().platforms() {
        let spec = platform.spec();
        let schedules = [Schedule::naive(), Schedule::expert_for(spec)];
        let mut reused_total = 0usize;
        for seed in 0..200u64 {
            let g = fuzz::graph(seed);
            // alternate patch sources: prune+redirect (cse) and
            // replace/add-bearing (constant_fold) patches
            let (g2, dirty) = if seed % 2 == 0 {
                cse::patch(&g).apply().unwrap()
            } else {
                constant_fold::patch(&g).apply().unwrap()
            };
            for s in &schedules {
                let prev = price(spec, &g, s);
                let inc = reprice(spec, s, &prev, &g2, &dirty);
                let full = CostOracle::new(spec, &g2).cost(s);
                assert_eq!(
                    inc.cost_s.to_bits(),
                    full.to_bits(),
                    "{} seed {seed} {}: incremental reprice diverged from full cost",
                    platform.name(),
                    s.canon()
                );
                reused_total += inc.reused_kernels;
            }
        }
        assert!(
            reused_total > 0,
            "{}: dirty-region re-pricing never reused a kernel — incrementality is dead code",
            platform.name()
        );
    }
}

#[test]
fn prop_tune_bit_identical_across_workers_and_store_temperature() {
    use kforge::search::{tune_suite_with, TuneConfig};
    use kforge::store::Store;
    let suite = kforge::workloads::Suite::sample(1);
    let platform = kforge::platform::by_name("cuda").unwrap();
    let mut per_worker = Vec::new();
    for workers in [1usize, 4, 16] {
        let mut cfg = TuneConfig::new(platform.clone());
        cfg.budget = 64;
        cfg.workers = workers;
        let store = Store::memory();
        let cold = tune_suite_with(&store, &cfg, &suite);
        let warm = tune_suite_with(&store, &cfg, &suite);
        assert!(warm.cache.hits > 0, "workers={workers}: warm run never hit the store");
        for (c, w) in cold.outcomes.iter().zip(&warm.outcomes) {
            assert_eq!(c.problem_id, w.problem_id);
            assert_eq!(
                c.tuned_s.to_bits(),
                w.tuned_s.to_bits(),
                "warm/cold drift on {} at workers={workers}",
                c.problem_id
            );
            assert_eq!(c.schedule, w.schedule);
        }
        per_worker.push(cold);
    }
    for r in &per_worker[1..] {
        assert_eq!(per_worker[0].outcomes.len(), r.outcomes.len());
        for (a, b) in per_worker[0].outcomes.iter().zip(&r.outcomes) {
            assert_eq!(a.problem_id, b.problem_id);
            assert_eq!(
                a.tuned_s.to_bits(),
                b.tuned_s.to_bits(),
                "worker-count drift on {}",
                a.problem_id
            );
            assert_eq!(a.schedule, b.schedule);
        }
    }
}

#[test]
fn prop_patch_shrink_matches_wholesale_on_pinned_seeds() {
    use kforge::kir::fuzz;
    use kforge::kir::op::Op;
    let has_matmul =
        |g: &Graph| g.nodes.iter().any(|n| matches!(n.op, Op::Matmul { .. }));
    let mut pinned = 0;
    for seed in 0..120u64 {
        let g = fuzz::graph(seed);
        if !has_matmul(&g) {
            continue;
        }
        pinned += 1;
        let (min_p, stats) = fuzz::shrink_with_stats(&g, &has_matmul);
        let min_w = fuzz::shrink_wholesale(&g, &has_matmul);
        assert_eq!(min_p, min_w, "seed {seed}: patch shrink repro differs from wholesale");
        assert!(min_p.len() <= min_w.len(), "seed {seed}: patch repro larger");
        assert!(has_matmul(&min_p), "seed {seed}: shrink lost the failure");
        validate(&min_p).unwrap();
        assert!(stats.accepted <= stats.attempts, "seed {seed}");
    }
    assert!(pinned >= 20, "only {pinned} matmul-bearing seeds in range");
}

#[test]
fn prop_shrink_large_dead_chain_stays_near_linear() {
    use kforge::kir::fuzz;
    use kforge::kir::op::Op;
    // a tiny matmul cone plus a 5,000-node unary side chain, both
    // exported: output narrowing must drop the chain with one accepted
    // candidate and must never materialize the dead chain into any
    // candidate (the clone-per-candidate shrinker rebuilt all ~5,004
    // nodes per attempt)
    let mut b = GraphBuilder::new("big");
    let x = b.input(Shape::of(&[4, 5]));
    let w = b.input(Shape::of(&[5, 6]));
    let mm = b.matmul(x, w);
    let t = b.input(Shape::of(&[8]));
    let mut chain = t;
    for _ in 0..5000 {
        chain = b.unary(UnaryKind::Relu, chain);
    }
    let g = b.finish(vec![mm, chain]);
    assert!(g.len() > 5000);
    let has_matmul =
        |g: &Graph| g.nodes.iter().any(|n| matches!(n.op, Op::Matmul { .. }));
    let (min, stats) = fuzz::shrink_with_stats(&g, &has_matmul);
    assert!(has_matmul(&min), "shrink lost the failure");
    assert!(min.len() <= 4, "repro not minimal: {} nodes", min.len());
    assert!(stats.attempts < 100, "shrink needed {} attempts", stats.attempts);
    assert!(
        stats.materialized_nodes < 1000,
        "shrink materialized {} nodes — candidates are re-cloning the dead chain",
        stats.materialized_nodes
    );
}

#[test]
fn prop_suite_eval_graphs_all_finite() {
    // every problem's reference evaluation yields finite outputs on its
    // seeded inputs (guards tolerances in the verifier)
    let suite = kforge::workloads::Suite::full();
    for p in suite.problems.iter() {
        let ins = p.eval_inputs(0xC0FFEE);
        let out = eval(&p.eval_graph, &ins).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        for (i, t) in out.iter().enumerate() {
            assert!(
                t.data.iter().all(|v| v.is_finite()),
                "{} output {i} has non-finite values",
                p.id
            );
        }
    }
}
