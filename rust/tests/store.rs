//! Result-store integration: the ISSUE 4 acceptance properties.
//!
//! - warm-vs-cold bit identity: a campaign answered from the disk
//!   store is indistinguishable (every `TaskResult` field, f64s by bit
//!   pattern) from a cold run — the property that makes a warm
//!   `kforge conformance` render byte-identical to a cold one;
//! - corrupted/truncated cache entries degrade to misses;
//! - `--resume` after a simulated mid-campaign kill (truncated journal
//!   tail, wiped object store) completes with no duplicated or missing
//!   jobs, bit-identical to an uninterrupted campaign.

use kforge::agents::persona::by_name;
use kforge::coordinator::{run_campaign_with, BaselineKind, CampaignResult, ExperimentConfig};
use kforge::store::Store;
use kforge::workloads::Suite;
use std::path::PathBuf;

fn cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        platform: kforge::platform::by_name("cuda").unwrap(),
        personas: vec![by_name("openai-gpt-5").unwrap(), by_name("deepseek-v3").unwrap()],
        iterations: 2,
        use_profiling: false,
        use_reference: false,
        baseline: BaselineKind::Eager,
        seed: 0xAB,
        workers: 4,
    }
}

fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult) {
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.problem_id, y.problem_id);
        assert_eq!(x.persona, y.persona);
        assert_eq!(x.level, y.level);
        assert_eq!(x.state_history, y.state_history);
        assert_eq!(x.outcome.correct, y.outcome.correct, "{}", x.problem_id);
        assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits(), "{}", x.problem_id);
        assert_eq!(x.best_iteration, y.best_iteration);
        assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits(), "{}", x.problem_id);
        assert_eq!(
            x.best_candidate_s.map(f64::to_bits),
            y.best_candidate_s.map(f64::to_bits),
            "{}",
            x.problem_id
        );
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("kforge_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_store_is_bit_identical_to_cold_across_instances() {
    let suite = Suite::sample(3);
    let c = cfg("store_warm_cold_prop");
    let cold = run_campaign_with(&Store::disabled(), &suite, None, &c);
    assert_eq!(cold.results.len(), 24); // 2 personas × 12 problems (3 per level)
    let dir = tmpdir("warm");
    {
        let s = Store::at_dir(&dir, false).unwrap();
        let first = run_campaign_with(&s, &suite, None, &c);
        assert_eq!(first.cache.misses, 24);
        assert_eq!(first.cache.hits, 0);
        assert!(first.cache.bytes_written > 0, "disk store must persist entries");
        assert_bit_identical(&cold, &first);
    }
    // a fresh Store instance models a fresh process: every job must be
    // answered from disk, bit-identical to the cold computation
    let s2 = Store::at_dir(&dir, false).unwrap();
    let warm = run_campaign_with(&s2, &suite, None, &c);
    assert_eq!(warm.cache.hits, 24, "{:?}", warm.cache);
    assert_eq!(warm.cache.misses, 0);
    assert!(warm.cache.bytes_read > 0);
    assert_bit_identical(&cold, &warm);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_and_truncated_entries_degrade_to_misses() {
    let suite = Suite::sample(2);
    let c = cfg("store_corruption_prop");
    let cold = run_campaign_with(&Store::disabled(), &suite, None, &c);
    let n = cold.results.len() as u64; // 16
    let dir = tmpdir("corrupt");
    {
        let s = Store::at_dir(&dir, false).unwrap();
        run_campaign_with(&s, &suite, None, &c);
    }
    // vandalize three entries: truncate, garbage, empty
    let mut objects: Vec<PathBuf> = std::fs::read_dir(dir.join("objects"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    objects.sort();
    assert_eq!(objects.len() as u64, n);
    let data = std::fs::read(&objects[0]).unwrap();
    std::fs::write(&objects[0], &data[..data.len() / 3]).unwrap();
    std::fs::write(&objects[1], b"complete garbage, not an entry").unwrap();
    std::fs::write(&objects[2], b"").unwrap();
    let s = Store::at_dir(&dir, false).unwrap();
    let run = run_campaign_with(&s, &suite, None, &c);
    assert_eq!(run.cache.hits, n - 3, "{:?}", run.cache);
    assert_eq!(run.cache.misses, 3);
    // recomputed-through-corruption results are still bit-identical
    assert_bit_identical(&cold, &run);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_after_simulated_kill_has_no_duplicated_or_missing_jobs() {
    let suite = Suite::sample(3);
    let c = cfg("store_resume_prop");
    let uninterrupted = run_campaign_with(&Store::disabled(), &suite, None, &c);
    let n = uninterrupted.results.len(); // 24
    let dir = tmpdir("resume");
    {
        let s = Store::at_dir(&dir, false).unwrap();
        run_campaign_with(&s, &suite, None, &c);
    }
    let journals: Vec<PathBuf> = std::fs::read_dir(dir.join("journals"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert_eq!(journals.len(), 1, "one journal per campaign");
    // simulate a kill mid-campaign: keep the header, k complete
    // records, and half of the next record; wipe the object store (a
    // dead process's memory tier is gone, and the disk tier may be too)
    let data = std::fs::read_to_string(&journals[0]).unwrap();
    let lines: Vec<&str> = data.lines().collect();
    assert_eq!(lines.len(), n + 1, "header + one record per job");
    let k = 7;
    let mut kept = lines[..1 + k].join("\n");
    kept.push('\n');
    kept.push_str(&lines[1 + k][..lines[1 + k].len() / 2]);
    std::fs::write(&journals[0], kept).unwrap();
    Store::at_dir(&dir, false).unwrap().cache().clear().unwrap();

    let s = Store::at_dir(&dir, true).unwrap();
    assert!(s.resume());
    let resumed = run_campaign_with(&s, &suite, None, &c);
    assert_eq!(resumed.cache.resumed, k as u64, "{:?}", resumed.cache);
    assert_eq!(resumed.cache.misses, (n - k) as u64);
    assert_eq!(resumed.cache.hits, 0);
    assert_bit_identical(&uninterrupted, &resumed);
    // no duplicated or missing jobs
    let mut seen = std::collections::HashSet::new();
    for r in &resumed.results {
        assert!(seen.insert((r.persona, r.problem_id.clone())), "duplicate {}", r.problem_id);
    }
    assert_eq!(seen.len(), n);

    // the resumed run repaired the journal: a second resume (object
    // store wiped again) restores every job without recomputing any
    let s2 = Store::at_dir(&dir, true).unwrap();
    s2.cache().clear().unwrap();
    let again = run_campaign_with(&s2, &suite, None, &c);
    assert_eq!(again.cache.resumed, n as u64, "{:?}", again.cache);
    assert_eq!(again.cache.misses, 0);
    assert_bit_identical(&uninterrupted, &again);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// tune results: the ISSUE 5 acceptance properties
// ---------------------------------------------------------------------------

fn tune_cfg(workers: usize) -> kforge::search::TuneConfig {
    let mut c = kforge::search::TuneConfig::new(kforge::platform::by_name("cuda").unwrap());
    c.budget = 96;
    c.workers = workers;
    c
}

fn assert_tune_bit_identical(a: &kforge::search::TuneReport, b: &kforge::search::TuneReport) {
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.problem_id, y.problem_id);
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(x.naive_s.to_bits(), y.naive_s.to_bits(), "{}", x.problem_id);
        assert_eq!(x.expert_s.to_bits(), y.expert_s.to_bits(), "{}", x.problem_id);
        assert_eq!(x.tuned_s.to_bits(), y.tuned_s.to_bits(), "{}", x.problem_id);
        assert_eq!(x.schedule, y.schedule, "{}", x.problem_id);
        assert_eq!(x.evals, y.evals, "{}", x.problem_id);
    }
}

#[test]
fn tune_bit_identical_across_worker_counts_and_store_temperature() {
    use kforge::search::tune_suite_with;
    let suite = Suite::sample(2); // 8 problems (2 per level, L4 included)
    // worker counts 1, 4, 16 against a disabled store: pure computation
    let runs: Vec<kforge::search::TuneReport> = [1usize, 4, 16]
        .iter()
        .map(|&w| tune_suite_with(&Store::disabled(), &tune_cfg(w), &suite))
        .collect();
    assert_eq!(runs[0].outcomes.len(), 8);
    for run in &runs[1..] {
        assert_tune_bit_identical(&runs[0], run);
    }
    // disabled store reports all-zero counters
    assert_eq!(runs[0].cache, kforge::store::CacheStats::default());

    // warm vs cold: a memory store answers the second run entirely
    // from cache, bit-identical to the cold computation
    let store = Store::memory();
    let cold = tune_suite_with(&store, &tune_cfg(4), &suite);
    assert_eq!(cold.cache.misses, 8);
    assert_eq!(cold.cache.hits, 0);
    let warm = tune_suite_with(&store, &tune_cfg(1), &suite); // different workers: same keys
    assert_eq!(warm.cache.hits, 8, "{:?}", warm.cache);
    assert_eq!(warm.cache.misses, 0);
    assert_tune_bit_identical(&runs[0], &cold);
    assert_tune_bit_identical(&runs[0], &warm);
}

#[test]
fn tune_disk_store_round_trips_and_tolerates_corruption() {
    use kforge::search::tune_suite_with;
    let suite = Suite::sample(1); // 4 problems (one per level)
    let dir = tmpdir("tune_disk");
    let cold = {
        let s = Store::at_dir(&dir, false).unwrap();
        let r = tune_suite_with(&s, &tune_cfg(4), &suite);
        assert_eq!(r.cache.misses, 4);
        assert!(r.cache.bytes_written > 0, "disk store must persist tune entries");
        r
    };
    // a fresh instance (fresh process model) answers from disk
    let warm = {
        let s = Store::at_dir(&dir, false).unwrap();
        tune_suite_with(&s, &tune_cfg(4), &suite)
    };
    assert_eq!(warm.cache.hits, 4, "{:?}", warm.cache);
    assert!(warm.cache.bytes_read > 0);
    assert_tune_bit_identical(&cold, &warm);
    // vandalize one object: it degrades to a recompute, bit-identical
    let mut objects: Vec<PathBuf> = std::fs::read_dir(dir.join("objects"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    objects.sort();
    assert_eq!(objects.len(), 4);
    std::fs::write(&objects[0], b"not a cache entry").unwrap();
    let repaired = {
        let s = Store::at_dir(&dir, false).unwrap();
        tune_suite_with(&s, &tune_cfg(4), &suite)
    };
    assert_eq!(repaired.cache.hits, 3, "{:?}", repaired.cache);
    assert_eq!(repaired.cache.misses, 1);
    assert_tune_bit_identical(&cold, &repaired);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tune_and_campaign_entries_share_a_store_without_collisions() {
    // one --cache-dir serves both object kinds: a campaign and a tune
    // run over the same problems coexist, and each warm pass answers
    // fully from its own entries
    use kforge::search::tune_suite_with;
    let suite = Suite::sample(1); // 4 problems (one per level)
    let dir = tmpdir("tune_mixed");
    {
        let s = Store::at_dir(&dir, false).unwrap();
        let c = cfg("mixed_store_prop");
        let campaign_cold = run_campaign_with(&s, &suite, None, &c);
        assert_eq!(campaign_cold.cache.misses, 8); // 2 personas × 4 problems
        let tune_cold = tune_suite_with(&s, &tune_cfg(4), &suite);
        assert_eq!(tune_cold.cache.misses, 4);
        let campaign_warm = run_campaign_with(&s, &suite, None, &c);
        assert_eq!(campaign_warm.cache.hits, 8, "{:?}", campaign_warm.cache);
        let tune_warm = tune_suite_with(&s, &tune_cfg(4), &suite);
        assert_eq!(tune_warm.cache.hits, 4, "{:?}", tune_warm.cache);
        assert_tune_bit_identical(&tune_cold, &tune_warm);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// serve traffic over the store: the ISSUE 6 acceptance properties
// ---------------------------------------------------------------------------

fn assert_serve_results_bit_identical(
    a: &[(String, kforge::coordinator::TaskResult)],
    b: &[(String, kforge::coordinator::TaskResult)],
) {
    let index: std::collections::HashMap<&String, &kforge::coordinator::TaskResult> =
        b.iter().map(|(j, r)| (j, r)).collect();
    assert_eq!(a.len(), b.len());
    for (job, x) in a {
        let y = index.get(job).unwrap_or_else(|| panic!("job {job} missing"));
        assert_eq!(x.problem_id, y.problem_id, "{job}");
        assert_eq!(x.state_history, y.state_history, "{job}");
        assert_eq!(x.outcome.correct, y.outcome.correct, "{job}");
        assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits(), "{job}");
        assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits(), "{job}");
        assert_eq!(
            x.best_candidate_s.map(f64::to_bits),
            y.best_candidate_s.map(f64::to_bits),
            "{job}"
        );
    }
}

/// A deliberately lossy serve scenario: a tiny queue under bursty
/// traffic with near-instant deadlines, so requests are shed at the
/// door and expire while queued.
fn lossy_serve_cfg() -> kforge::serve::ScenarioConfig {
    let mut cfg = kforge::serve::ScenarioConfig::new(0xD00D, 48, 2);
    cfg.queue_capacity = 3;
    cfg.shed_depth = 3;
    cfg.warm_hottest = 0;
    cfg.load.deadline_ms = 1.5;
    cfg
}

#[test]
fn lossy_serve_traffic_never_corrupts_the_store() {
    use kforge::serve::run_scenario;
    let cfg = lossy_serve_cfg();
    let dir = tmpdir("serve_lossy");
    let first = {
        let s = Store::at_dir(&dir, false).unwrap();
        run_scenario(&s, &cfg)
    };
    let shed = first.requests.iter().filter(|r| r.outcome.is_rejected()).count();
    let expired =
        first.requests.iter().filter(|r| r.outcome.label() == "deadline_exceeded").count();
    assert!(shed > 0, "a 3-deep queue must shed under 12-request bursts");
    assert!(expired > 0, "1.5 ms deadlines must expire while queued");
    assert!(!first.results.is_empty());
    let n = first.results.len() as u64;
    assert_eq!(first.cache.misses, n, "{:?}", first.cache);
    assert!(first.cache.bytes_written > 0);
    // every disk object written under lossy traffic is readable: a
    // fresh store instance answers the identical rerun entirely from
    // disk, bit-identical
    let second = {
        let s = Store::at_dir(&dir, false).unwrap();
        run_scenario(&s, &cfg)
    };
    assert_eq!(second.cache.hits, n, "{:?}", second.cache);
    assert_eq!(second.cache.misses, 0);
    assert_serve_results_bit_identical(&first.results, &second.results);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lossy_serve_traffic_never_corrupts_the_journals() {
    use kforge::serve::run_scenario;
    let cfg = lossy_serve_cfg();
    let dir = tmpdir("serve_journals");
    let first = {
        let s = Store::at_dir(&dir, false).unwrap();
        run_scenario(&s, &cfg)
    };
    // serve jobs run as single-job campaigns: one journal per distinct
    // executed job, no collisions
    let journals: Vec<PathBuf> = std::fs::read_dir(dir.join("journals"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert_eq!(journals.len(), first.results.len());
    // every journal replays: with the object store wiped, --resume
    // restores every job without recomputing, bit-identical
    let s = Store::at_dir(&dir, true).unwrap();
    s.cache().clear().unwrap();
    let resumed = run_scenario(&s, &cfg);
    assert_eq!(resumed.cache.resumed, first.results.len() as u64, "{:?}", resumed.cache);
    assert_eq!(resumed.cache.misses, 0);
    assert_serve_results_bit_identical(&first.results, &resumed.results);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_results_match_a_storeless_run_job_for_job() {
    use kforge::serve::run_scenario;
    // hit modeling differs with the store off, so the virtual outcome
    // census (and thus the executed job set) may differ — but any job
    // both runs execute must synthesize bit-identically: serve jobs
    // are pure functions of their key, not of serving conditions
    let cfg = lossy_serve_cfg();
    let with_store = run_scenario(&Store::memory(), &cfg);
    let without = run_scenario(&Store::disabled(), &cfg);
    let index: std::collections::HashMap<&String, &kforge::coordinator::TaskResult> =
        without.results.iter().map(|(j, r)| (j, r)).collect();
    let mut overlap = 0;
    for (job, x) in &with_store.results {
        if let Some(y) = index.get(job) {
            overlap += 1;
            assert_eq!(x.outcome.correct, y.outcome.correct, "{job}");
            assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits(), "{job}");
            assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits(), "{job}");
            assert_eq!(x.state_history, y.state_history, "{job}");
        }
    }
    assert!(overlap > 0, "runs share no jobs; the comparison proved nothing");
}

// ---------------------------------------------------------------------------
// level-4 whole-model jobs through the store: the ISSUE 7 acceptance
// ---------------------------------------------------------------------------

#[test]
fn level4_campaign_round_trips_the_disk_store() {
    use kforge::workloads::Level;
    // a whole-model-only suite: synthesis, pricing and verification all
    // run over multi-kernel DAGs, cached like any other job
    let full = Suite::full();
    let problems: Vec<_> = full.by_level(Level::L4).into_iter().take(3).cloned().collect();
    assert_eq!(problems.len(), 3);
    let suite = Suite { problems: std::sync::Arc::new(problems) };
    let c = cfg("store_level4_prop");
    let cold_ref = run_campaign_with(&Store::disabled(), &suite, None, &c);
    assert_eq!(cold_ref.results.len(), 6); // 2 personas × 3 models
    assert!(cold_ref.results.iter().all(|r| r.level == Level::L4));
    let dir = tmpdir("level4");
    {
        let s = Store::at_dir(&dir, false).unwrap();
        let first = run_campaign_with(&s, &suite, None, &c);
        assert_eq!(first.cache.misses, 6, "{:?}", first.cache);
        assert_eq!(first.cache.hits, 0);
        assert_bit_identical(&cold_ref, &first);
    }
    // fresh instance (fresh process model): every whole-model job
    // answers from disk, bit-identical — the ISSUE 7 cache-hit-on-rerun
    // acceptance criterion
    let s2 = Store::at_dir(&dir, false).unwrap();
    let warm = run_campaign_with(&s2, &suite, None, &c);
    assert_eq!(warm.cache.hits, 6, "{:?}", warm.cache);
    assert_eq!(warm.cache.misses, 0);
    assert_bit_identical(&cold_ref, &warm);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// cross-process safety: the ISSUE 10 store-side acceptance properties
// ---------------------------------------------------------------------------

/// A synthetic, content-stable result for store stress tests: every
/// writer of key `i` writes these exact bytes, so any interleaving of
/// racing same-key writers leaves a valid object.
fn fake_result(i: usize) -> kforge::coordinator::TaskResult {
    kforge::coordinator::TaskResult {
        problem_id: format!("stress_{i:02}"),
        level: kforge::workloads::Level::L1,
        persona: "openai-gpt-5",
        state_history: vec!["correct", "correct"],
        outcome: kforge::metrics::TaskOutcome::correct(1.0 + i as f64 * 0.25),
        best_iteration: Some(1),
        baseline_s: 0.5 + i as f64,
        best_candidate_s: Some(0.125 * (i + 1) as f64),
    }
}

#[test]
fn two_store_instances_on_one_dir_survive_concurrent_writes() {
    use kforge::store::{Cache, JobKey};
    // two Cache instances model two shard processes sharing one
    // --cache-dir; four threads (two per instance) write every key in
    // skewed orders, so same-key races across instances are guaranteed
    let dir = tmpdir("two_writers");
    let a = Cache::at(&dir).unwrap();
    let b = Cache::at(&dir).unwrap();
    let n = 24usize;
    let keys: Vec<JobKey> =
        (0..n).map(|i| JobKey::from_text(format!("kforge-stress v1\nkey {i}"))).collect();
    std::thread::scope(|s| {
        for (w, cache) in [&a, &b, &a, &b].into_iter().enumerate() {
            let keys = &keys;
            s.spawn(move || {
                for i in 0..keys.len() {
                    let k = (i + w * 7) % keys.len();
                    let written = cache.put(&keys[k], &fake_result(k));
                    assert!(written > 0, "atomic persist dropped key {k}");
                }
            });
        }
    });
    // a fresh instance (fresh process model, no memory tier) must read
    // every object back clean and bit-identical to what was written
    let fresh = Cache::at(&dir).unwrap();
    for (i, key) in keys.iter().enumerate() {
        let (got, bytes) = fresh.get(key).unwrap_or_else(|| panic!("key {i} unreadable"));
        assert!(bytes > 0, "key {i} answered from the wrong tier");
        let want = fake_result(i);
        assert_eq!(got.problem_id, want.problem_id);
        assert_eq!(got.state_history, want.state_history);
        assert_eq!(got.outcome.speedup.to_bits(), want.outcome.speedup.to_bits());
        assert_eq!(got.baseline_s.to_bits(), want.baseline_s.to_bits());
        assert_eq!(
            got.best_candidate_s.map(f64::to_bits),
            want.best_candidate_s.map(f64::to_bits)
        );
    }
    // exactly one object per key, and no temp-file litter from the
    // atomic rename protocol
    assert_eq!(fresh.disk_entries().unwrap().len(), n);
    for entry in std::fs::read_dir(dir.join("objects")).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        assert!(!name.contains(".tmp."), "orphaned temp file {name}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_racing_a_leased_writer_never_evicts_its_objects() {
    use kforge::store::{Cache, JobKey, Lease};
    use std::time::{Duration, SystemTime};
    // deterministic injected ordering, all through file mtimes: four
    // "old" objects predate the writer's lease, four "live" ones are
    // written under it — exactly the state when `kforge cache gc`
    // races an in-flight shard
    let dir = tmpdir("gc_race");
    let cache = Cache::at(&dir).unwrap();
    let t0 = SystemTime::UNIX_EPOCH + Duration::from_secs(1_000_000);
    let stamp = |key: &JobKey, t: SystemTime| {
        let path = dir.join("objects").join(key.hex());
        std::fs::File::options().write(true).open(path).unwrap().set_modified(t).unwrap();
    };
    let old_keys: Vec<JobKey> =
        (0..4).map(|i| JobKey::from_text(format!("kforge-stress v1\nold {i}"))).collect();
    for (i, k) in old_keys.iter().enumerate() {
        cache.put(k, &fake_result(i));
        stamp(k, t0);
    }
    // the writer takes its lease *after* the old objects existed...
    let lease = Lease::acquire(&dir, "gc-race-writer", "test writer").unwrap();
    std::fs::File::options()
        .write(true)
        .open(lease.path())
        .unwrap()
        .set_modified(t0 + Duration::from_secs(100))
        .unwrap();
    // ...and streams fresh objects while holding it
    let live_keys: Vec<JobKey> =
        (0..4).map(|i| JobKey::from_text(format!("kforge-stress v1\nlive {i}"))).collect();
    for (i, k) in live_keys.iter().enumerate() {
        cache.put(k, &fake_result(10 + i));
        stamp(k, t0 + Duration::from_secs(200));
    }
    // gc to zero bytes: only the pre-lease objects may go
    let (evicted, _kept) = cache.gc(0).unwrap();
    assert_eq!(evicted, old_keys.len(), "gc crossed the lease floor");
    let fresh = Cache::at(&dir).unwrap();
    for (i, k) in live_keys.iter().enumerate() {
        assert!(fresh.get(k).is_some(), "leased-era object {i} evicted");
    }
    for k in &old_keys {
        assert!(fresh.get(k).is_none(), "pre-lease object survived gc to zero");
    }
    // lease released: the same gc now empties the disk tier
    drop(lease);
    let (evicted, kept) = Cache::at(&dir).unwrap().gc(0).unwrap();
    assert_eq!(evicted, live_keys.len());
    assert_eq!(kept, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_untouched_journal_recomputes_nothing() {
    // the no-kill degenerate case: rerunning with --resume after a
    // completed campaign is a pure journal replay
    let suite = Suite::sample(2);
    let c = cfg("store_resume_complete_prop");
    let dir = tmpdir("resume_complete");
    let full = {
        let s = Store::at_dir(&dir, false).unwrap();
        run_campaign_with(&s, &suite, None, &c)
    };
    let s = Store::at_dir(&dir, true).unwrap();
    s.cache().clear().unwrap();
    let replay = run_campaign_with(&s, &suite, None, &c);
    assert_eq!(replay.cache.resumed, full.results.len() as u64);
    assert_eq!(replay.cache.misses, 0);
    assert_bit_identical(&full, &replay);
    let _ = std::fs::remove_dir_all(&dir);
}
