//! Trace-layer integration: the ISSUE 9 acceptance properties.
//!
//! The tracer is process-global, so every test here serializes on one
//! lock and brackets its workload with `reset`/`enable`/`disable`.
//! What is pinned:
//!
//! - the logical trace digest (`Snapshot::canon`) of a campaign, a
//!   tune run and a synthetic serve scenario is bit-identical across
//!   execution pool widths 1/4/16 *and* warm vs cold store;
//! - the exec digest (`Snapshot::canon_exec`) of cold campaign and
//!   tune runs is bit-identical across pool widths;
//! - a traced campaign returns bit-identical `TaskResult`s (every
//!   field, f64s by bit pattern) to an untraced one;
//! - the disabled tracer records nothing across a full campaign;
//! - the exported chrome-trace is well-formed (every `B` matched by an
//!   `E` on its tid, tids within pool bounds) and round-trips through
//!   the rocprof frontend into nonzero-fidelity `Evidence`;
//! - `STORE_SCHEMA` sits at 4 (the v4 tune-key widening for transfer
//!   seeding); tracing itself is observational and must never be the
//!   reason the schema moves again.

use kforge::agents::persona::by_name;
use kforge::coordinator::{
    run_campaign, run_campaign_with, BaselineKind, ExperimentConfig, TaskResult,
};
use kforge::obs::{self, Snapshot};
use kforge::serve::{run_scenario, ScenarioConfig};
use kforge::store::{Store, STORE_SCHEMA};
use kforge::util::json::{self, Json};
use kforge::workloads::Suite;
use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

static LOCK: Mutex<()> = Mutex::new(());

fn locked() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` under a fresh enabled tracer; return its value plus the
/// recorded snapshot.
fn traced<T>(f: impl FnOnce() -> T) -> (T, Snapshot) {
    obs::reset();
    obs::enable();
    let out = f();
    obs::disable();
    let snap = obs::snapshot();
    obs::reset();
    (out, snap)
}

fn small_cfg(workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: "trace-test".into(),
        platform: kforge::platform::by_name("cuda").unwrap(),
        personas: vec![by_name("openai-gpt-5").unwrap(), by_name("deepseek-v3").unwrap()],
        iterations: 2,
        use_profiling: false,
        use_reference: false,
        baseline: BaselineKind::Eager,
        seed: 77,
        workers,
    }
}

fn assert_bit_identical(a: &TaskResult, b: &TaskResult) {
    assert_eq!(a.problem_id, b.problem_id);
    assert_eq!(a.persona, b.persona);
    assert_eq!(a.level, b.level);
    assert_eq!(a.state_history, b.state_history);
    assert_eq!(a.outcome.correct, b.outcome.correct, "{}", a.problem_id);
    assert_eq!(a.outcome.speedup.to_bits(), b.outcome.speedup.to_bits(), "{}", a.problem_id);
    assert_eq!(a.best_iteration, b.best_iteration);
    assert_eq!(a.baseline_s.to_bits(), b.baseline_s.to_bits());
    assert_eq!(
        a.best_candidate_s.map(f64::to_bits),
        b.best_candidate_s.map(f64::to_bits),
        "{}",
        a.problem_id
    );
}

#[test]
fn store_schema_stays_at_4() {
    // tracing reads results; it never feeds a fingerprinted input.
    // Schema 4 is the tune-key widening (transfer flag + family keys)
    // that shipped with distributed campaigns — an intentional,
    // reviewed bump.  If this assertion fires, either revert the
    // accidental schema change or update this pin alongside a
    // store-format rationale in ROADMAP.md.
    assert_eq!(STORE_SCHEMA, 4, "the store schema moved without review");
}

#[test]
fn disabled_tracer_is_a_noop_across_a_campaign() {
    let _g = locked();
    obs::reset();
    assert!(!obs::enabled());
    let before = obs::recorded_total();
    let suite = Suite::sample(1);
    let _ = run_campaign(&suite, None, &small_cfg(2));
    assert_eq!(
        obs::recorded_total(),
        before,
        "a disabled tracer recorded events during an untraced campaign"
    );
}

#[test]
fn campaign_trace_bit_identical_across_workers_and_store_temperature() {
    let _g = locked();
    let suite = Suite::sample(2);
    // cold runs (disabled global store) across pool widths
    let colds: Vec<Snapshot> = [1usize, 4, 16]
        .iter()
        .map(|&w| traced(|| run_campaign(&suite, None, &small_cfg(w))).1)
        .collect();
    for (i, s) in colds.iter().enumerate().skip(1) {
        assert_eq!(
            colds[0].canon(),
            s.canon(),
            "logical trace diverged between workers=1 and run {i}"
        );
        assert_eq!(
            colds[0].canon_exec(),
            s.canon_exec(),
            "exec trace diverged between workers=1 and run {i}"
        );
    }
    assert!(colds[0].canon().contains("lane job:"), "{}", colds[0].canon());

    // warm vs cold: a store-answered campaign emits the identical
    // logical stream (exec legitimately differs — nothing ran)
    let store = Store::memory();
    let cfg = small_cfg(4);
    let (cold_result, cold_snap) = traced(|| run_campaign_with(&store, &suite, None, &cfg));
    assert_eq!(cold_result.cache.hits, 0);
    let (warm_result, warm_snap) = traced(|| run_campaign_with(&store, &suite, None, &cfg));
    assert_eq!(warm_result.cache.misses, 0, "second run must be fully warm");
    assert_eq!(
        cold_snap.canon(),
        warm_snap.canon(),
        "logical trace diverged between cold and warm store"
    );
    // and the store-enabled logical stream matches the disabled-store one
    assert_eq!(colds[0].canon(), cold_snap.canon());
    // the warm run consulted the store: hit instants, no puts
    assert!(warm_snap.events.iter().any(|e| e.name == "store.hit"));
    assert!(!warm_snap.events.iter().any(|e| e.name == "store.put"));
}

#[test]
fn traced_campaign_results_bit_identical_to_untraced() {
    let _g = locked();
    let suite = Suite::sample(2);
    let cfg = small_cfg(4);
    obs::reset();
    assert!(!obs::enabled());
    let untraced = run_campaign(&suite, None, &cfg);
    let (traced_run, snap) = traced(|| run_campaign(&suite, None, &cfg));
    assert!(!snap.events.is_empty(), "traced run recorded nothing");
    assert_eq!(untraced.results.len(), traced_run.results.len());
    for (a, b) in untraced.results.iter().zip(&traced_run.results) {
        assert_bit_identical(a, b);
    }
}

#[test]
fn tune_trace_bit_identical_across_workers_and_store_temperature() {
    let _g = locked();
    use kforge::search::{tune_suite_with, TuneConfig};
    let suite = Suite::sample(2);
    let mk = |workers: usize| {
        let mut cfg = TuneConfig::new(kforge::platform::by_name("cuda").unwrap());
        cfg.budget = 96;
        cfg.workers = workers;
        cfg
    };
    let colds: Vec<Snapshot> = [1usize, 4, 16]
        .iter()
        .map(|&w| traced(|| tune_suite_with(&Store::disabled(), &mk(w), &suite)).1)
        .collect();
    for (i, s) in colds.iter().enumerate().skip(1) {
        assert_eq!(colds[0].canon(), s.canon(), "tune logical trace diverged on run {i}");
        assert_eq!(colds[0].canon_exec(), s.canon_exec(), "tune exec trace diverged on run {i}");
    }
    assert!(colds[0].canon().contains("lane tune:"), "{}", colds[0].canon());
    assert!(colds[0].canon_exec().contains("oracle.evaluations"), "{}", colds[0].canon_exec());

    let store = Store::memory();
    let cold = traced(|| tune_suite_with(&store, &mk(4), &suite)).1;
    let (warm_report, warm) = traced(|| tune_suite_with(&store, &mk(4), &suite));
    assert_eq!(warm_report.cache.misses, 0, "second tune run must be fully warm");
    assert_eq!(cold.canon(), warm.canon(), "tune logical trace diverged warm vs cold");
}

#[test]
fn serve_scenario_logical_trace_bit_identical_across_widths_and_temperature() {
    let _g = locked();
    let mk = |exec_workers: usize| {
        let mut cfg = ScenarioConfig::new(0x5EED, 48, 2);
        cfg.exec_workers = Some(exec_workers);
        cfg
    };
    // the execution fan runs concurrent single-job campaigns, so only
    // the logical digest is order-deterministic (exec record order in
    // the per-thread root lanes races by design; tid/wall are already
    // excluded).  Cold runs: a fresh memory store per width.
    let colds: Vec<Snapshot> = [1usize, 4, 16]
        .iter()
        .map(|&w| traced(|| run_scenario(&Store::memory(), &mk(w))).1)
        .collect();
    for (i, s) in colds.iter().enumerate().skip(1) {
        assert_eq!(
            colds[0].canon(),
            s.canon(),
            "serve logical trace diverged between exec_workers=1 and run {i}"
        );
    }
    let canon = colds[0].canon();
    assert!(canon.contains("lane serve"), "{canon}");
    assert!(canon.contains("serve.queue_wait_ms"), "{canon}");
    assert!(canon.contains("counter serve.requests = 48"), "{canon}");

    let store = Store::memory();
    let cold = traced(|| run_scenario(&store, &mk(4))).1;
    let warm = traced(|| run_scenario(&store, &mk(4))).1;
    assert_eq!(cold.canon(), warm.canon(), "serve logical trace diverged warm vs cold");
    assert_eq!(cold.canon(), canon, "store temperature leaked into the width runs");
}

#[test]
fn exported_trace_is_well_formed_and_roundtrips_rocprof() {
    let _g = locked();
    let suite = Suite::sample(2);
    let workers = 4usize;
    let (_, snap) = traced(|| run_campaign(&suite, None, &small_cfg(workers)));
    let text = obs::export::chrome_trace(&snap, "trace-test");
    let doc = json::parse(&text).expect("exported trace must parse as JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty());

    // every B matched by an E on its tid (file order is per-thread
    // chronological), depth never negative, all stacks closed
    let mut depth: BTreeMap<i64, i64> = BTreeMap::new();
    let mut max_tid: i64 = 0;
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        let tid = e.get("tid").and_then(Json::as_i64).unwrap_or(-1);
        assert!(tid >= 0, "negative tid in {e:?}");
        max_tid = max_tid.max(tid);
        match ph {
            "B" => *depth.entry(tid).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                *d -= 1;
                assert!(*d >= 0, "E without matching B on tid {tid}");
            }
            _ => {}
        }
    }
    for (tid, d) in &depth {
        assert_eq!(*d, 0, "unclosed span(s) on tid {tid}");
    }
    // a single top-level pool numbers its workers 1..=N (tid 0 is the
    // main thread) — the ISSUE's "tid = worker index" contract
    assert!(
        max_tid <= workers as i64,
        "tid {max_tid} exceeds the worker pool bound {workers}"
    );

    // round-trip: the emitted trace through the rocprof frontend is
    // Evidence with real kernel rows and nonzero fidelity
    let ev = obs::export::self_evidence(&text).expect("rocprof interpret");
    assert!(ev.n_kernels() > 0, "no exec phases interpreted");
    assert!(ev.fidelity_score() > 0.0, "zero-fidelity self-profile");

    // and the summarizer renders coverage plus the self-profile line
    let summary = obs::summary::summarize(&text).expect("summarize");
    assert!(summary.contains("coverage: "), "{summary}");
    assert!(summary.contains("self-profile [rocprof]: hottest phase"), "{summary}");
}
