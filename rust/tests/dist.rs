//! Distributed-campaign integration: the ISSUE 10 acceptance
//! properties, driven in-process (shard "processes" are modeled by
//! fresh `Store` instances over one shared directory — exactly what a
//! fresh process constructs from `--cache-dir`).
//!
//! - an N-shard campaign (N ∈ {2, 4}, deterministic chunk partition
//!   injected through pre-seeded claim files) merges bit-identical to
//!   the 1-process run — every `TaskResult` field, f64s by bit
//!   pattern, no duplicated or missing jobs;
//! - a shard killed mid-journal-write resumes, recomputes exactly its
//!   missing jobs, and the merge stays bit-identical;
//! - merge refuses a job set with a hole (dead shard never re-run);
//! - shards against a warm shared store answer everything from
//!   objects other shards wrote (cross-shard store hits);
//! - `ShardReport` counts what actually happened.

use kforge::agents::persona::by_name;
use kforge::coordinator::{run_campaign_with, BaselineKind, CampaignResult, ExperimentConfig};
use kforge::dist::{merge_shards, plan_chunks, run_shard};
use kforge::store::{lease, Store};
use kforge::workloads::Suite;
use std::path::PathBuf;

fn cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        platform: kforge::platform::by_name("cuda").unwrap(),
        personas: vec![by_name("openai-gpt-5").unwrap(), by_name("deepseek-v3").unwrap()],
        iterations: 2,
        use_profiling: false,
        use_reference: false,
        baseline: BaselineKind::Eager,
        seed: 0xD15,
        workers: 4,
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("kforge_dist_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The campaign digest (16 hex chars) as embedded in the trailing
/// segment of the journal filename a disk-backed run leaves behind —
/// the same digest shard claim files are named under.
fn campaign_digest_hex(dir: &PathBuf) -> String {
    let mut journals: Vec<String> = std::fs::read_dir(dir.join("journals"))
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    journals.sort();
    assert_eq!(journals.len(), 1, "expected exactly one journal: {journals:?}");
    journals[0]
        .strip_suffix(".journal")
        .unwrap()
        .rsplit_once('-')
        .unwrap()
        .1
        .to_string()
}

/// Pre-seed the chunk claims so chunk `ci` belongs to shard
/// `ci % shards` — a deterministic partition in place of the live race
/// (a shard re-reading claims it already owns is the crash-resume
/// path, so this drives exactly the production code).
fn partition_round_robin(dir: &PathBuf, digest: &str, n_jobs: usize, shards: usize) -> Vec<usize> {
    let chunks = plan_chunks(n_jobs, shards);
    let mut per_shard = vec![0usize; shards];
    for (ci, c) in chunks.iter().enumerate() {
        let owner = format!("shard{}of{shards}", ci % shards);
        assert!(lease::claim(dir, &format!("{digest}-c{ci:04}"), &owner).unwrap());
        per_shard[ci % shards] += c.end - c.start;
    }
    per_shard
}

fn assert_unique_jobs(r: &CampaignResult, n: usize) {
    let mut seen = std::collections::HashSet::new();
    for t in &r.results {
        assert!(seen.insert((t.persona, t.problem_id.clone())), "duplicate {}", t.problem_id);
    }
    assert_eq!(seen.len(), n);
}

#[test]
fn sharded_campaign_merges_bit_identical_to_one_process() {
    let suite = Suite::sample(2); // 2 personas × 8 problems = 16 jobs
    let c = cfg("dist_merge_prop");
    // the 1-process reference, on its own store dir (also donates the
    // campaign digest for claim naming)
    let solo_dir = tmpdir("merge_solo");
    let solo = run_campaign_with(&Store::at_dir(&solo_dir, false).unwrap(), &suite, None, &c);
    let n = solo.results.len();
    assert_eq!(n, 16);
    let digest = campaign_digest_hex(&solo_dir);

    for shards in [2usize, 4] {
        let dir = tmpdir(&format!("merge_{shards}way"));
        std::fs::create_dir_all(&dir).unwrap();
        let per_shard = partition_round_robin(&dir, &digest, n, shards);
        assert!(per_shard.iter().all(|&j| j > 0), "a shard got no work: {per_shard:?}");
        for shard_id in 0..shards {
            // a fresh Store instance per shard run models a fresh process
            let s = Store::at_dir(&dir, false).unwrap();
            let report = run_shard(&s, &suite, None, &c, shards, shard_id).unwrap();
            assert_eq!(report.jobs_total, n);
            assert_eq!(report.restored, 0, "cold shard restored jobs");
            assert_eq!(report.store_hits, 0, "disjoint cold chunks cannot hit");
            assert_eq!(report.computed, per_shard[shard_id], "shard {shard_id}/{shards}");
            assert!(report.summary().contains(&format!("shard {shard_id}/{shards}")));
        }
        let merged =
            merge_shards(&Store::at_dir(&dir, false).unwrap(), &suite, None, &c, shards).unwrap();
        kforge::dist::assert_bit_identical(&merged, &solo).unwrap();
        assert_unique_jobs(&merged, n);
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&solo_dir);
}

#[test]
fn killed_shard_resumes_without_duplicated_or_missing_jobs() {
    let suite = Suite::sample(2);
    let c = cfg("dist_resume_prop");
    let solo_dir = tmpdir("kill_solo");
    let solo = run_campaign_with(&Store::at_dir(&solo_dir, false).unwrap(), &suite, None, &c);
    let n = solo.results.len();
    let digest = campaign_digest_hex(&solo_dir);

    let shards = 2usize;
    let dir = tmpdir("kill_shards");
    std::fs::create_dir_all(&dir).unwrap();
    let per_shard = partition_round_robin(&dir, &digest, n, shards);
    for shard_id in 0..shards {
        let s = Store::at_dir(&dir, false).unwrap();
        run_shard(&s, &suite, None, &c, shards, shard_id).unwrap();
    }
    // kill shard 1 retroactively: chop its journal mid-record (the
    // tail record loses its second half) and wipe the object store —
    // a dead process's memory tier is gone and gc may have taken the
    // disk tier.  Its chunk claims persist, which is the point.
    let shard1: Vec<PathBuf> = std::fs::read_dir(dir.join("journals"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.file_name().unwrap().to_string_lossy().contains(&format!("shard1of{shards}")))
        .collect();
    assert_eq!(shard1.len(), 1, "{shard1:?}");
    let data = std::fs::read_to_string(&shard1[0]).unwrap();
    let lines: Vec<&str> = data.lines().collect();
    assert_eq!(lines.len(), per_shard[1] + 1, "header + one record per owned job");
    let complete = per_shard[1] - 1;
    let mut kept = lines[..1 + complete].join("\n");
    kept.push('\n');
    let half = &lines[1 + complete][..lines[1 + complete].len() / 2];
    kept.push_str(half);
    std::fs::write(&shard1[0], kept).unwrap();
    Store::at_dir(&dir, false).unwrap().cache().clear().unwrap();

    // merge now refuses: one job is in no journal
    let err = merge_shards(&Store::at_dir(&dir, false).unwrap(), &suite, None, &c, shards)
        .unwrap_err()
        .to_string();
    assert!(err.contains("1 of 16 job(s) missing"), "{err}");

    // re-running the dead shard restores its complete records and
    // recomputes exactly the lost job
    let s = Store::at_dir(&dir, false).unwrap();
    let report = run_shard(&s, &suite, None, &c, shards, 1).unwrap();
    assert_eq!(report.restored, complete, "{report:?}");
    assert_eq!(report.computed, 1, "{report:?}");
    assert_eq!(report.store_hits, 0, "object store was wiped");

    let merged =
        merge_shards(&Store::at_dir(&dir, false).unwrap(), &suite, None, &c, shards).unwrap();
    kforge::dist::assert_bit_identical(&merged, &solo).unwrap();
    assert_unique_jobs(&merged, n);
    assert_eq!(merged.cache.resumed, n as u64, "merge counters carry the fold size");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&solo_dir);
}

#[test]
fn shards_over_a_warm_store_hit_objects_other_shards_wrote() {
    let suite = Suite::sample(2);
    let c = cfg("dist_warm_prop");
    let solo_dir = tmpdir("warm_solo");
    let solo = run_campaign_with(&Store::at_dir(&solo_dir, false).unwrap(), &suite, None, &c);
    let n = solo.results.len();
    let digest = campaign_digest_hex(&solo_dir);

    // a 4-way cold run populates the shared objects, each shard
    // writing only its own slice
    let shards = 4usize;
    let dir = tmpdir("warm_shards");
    std::fs::create_dir_all(&dir).unwrap();
    partition_round_robin(&dir, &digest, n, shards);
    for shard_id in 0..shards {
        let s = Store::at_dir(&dir, false).unwrap();
        let r = run_shard(&s, &suite, None, &c, shards, shard_id).unwrap();
        assert!(r.bytes_written > 0, "shard {shard_id} persisted nothing");
    }
    // second campaign generation over the same dir: wipe the claims
    // and journals (not the objects) and run 1 shard owning the whole
    // grid — every job must be answered by an object some *other*
    // shard wrote, with nothing recomputed
    std::fs::remove_dir_all(dir.join("journals")).unwrap();
    std::fs::remove_dir_all(dir.join(kforge::store::lease::LEASE_DIR)).unwrap();
    let s = Store::at_dir(&dir, false).unwrap();
    let report = run_shard(&s, &suite, None, &c, 1, 0).unwrap();
    assert_eq!(report.store_hits, n, "{report:?}");
    assert_eq!(report.computed, 0, "{report:?}");
    assert_eq!(report.restored, 0, "{report:?}");
    // the store hits were journal-backfilled, so the fold is complete
    // and still bit-identical
    let merged =
        merge_shards(&Store::at_dir(&dir, false).unwrap(), &suite, None, &c, 1).unwrap();
    kforge::dist::assert_bit_identical(&merged, &solo).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&solo_dir);
}

#[test]
fn merge_without_journals_is_a_clear_error() {
    let suite = Suite::sample(1);
    let c = cfg("dist_empty_prop");
    let dir = tmpdir("empty_merge");
    let s = Store::at_dir(&dir, false).unwrap();
    let err = merge_shards(&s, &suite, None, &c, 4).unwrap_err().to_string();
    assert!(err.contains("no shard journals"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
