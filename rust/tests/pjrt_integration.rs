//! PJRT integration: load the real AOT artifacts and execute them.
//!
//! These tests require `make artifacts` to have produced `artifacts/`
//! and a build with the `pjrt` cargo feature; they are skipped (with a
//! loud message) otherwise so `cargo test` stays green on a fresh
//! checkout.

use kforge::runtime::{PjrtRuntime, Registry};

fn runtime() -> Option<PjrtRuntime> {
    if !cfg!(feature = "pjrt") {
        eprintln!(
            "SKIP: built without the `pjrt` feature — PjrtRuntime is a stub \
             (enabling it requires adding the `xla` dependency locally)"
        );
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    let registry = Registry::load(&dir).expect("manifest parses");
    Some(PjrtRuntime::new(registry).expect("PJRT CPU client"))
}

#[test]
fn registry_loads_and_has_references() {
    let Some(rt) = runtime() else { return };
    let workloads = rt.registry().workloads();
    assert!(workloads.len() >= 8, "expected >=8 workloads, got {workloads:?}");
    for w in &workloads {
        let batches: Vec<usize> = rt
            .registry()
            .entries
            .iter()
            .filter(|e| &e.workload == w)
            .map(|e| e.batch)
            .collect();
        for b in batches {
            assert!(rt.registry().reference(w, b).is_some(), "{w} b{b} missing reference");
        }
    }
}

#[test]
fn swish_variants_match_reference_numerically() {
    let Some(rt) = runtime() else { return };
    let Some(reference) = rt.registry().reference("swish", 16) else {
        eprintln!("SKIP: swish b16 not lowered");
        return;
    };
    let key = reference.key.clone();
    let inputs = rt.seeded_inputs(&key, 0).unwrap();
    let want = rt.execute(&key, &inputs).unwrap();
    for variant in rt.registry().variants("swish", 16) {
        if variant.is_reference {
            continue;
        }
        let got = rt.execute(&variant.key, &inputs).unwrap();
        assert_eq!(got[0].shape, want[0].shape, "{}", variant.key);
        // ept8 uses fast-math: looser tolerance (§7.2 trade-off)
        let (rtol, atol) = if variant.variant == "ept8" { (5e-3, 5e-4) } else { (1e-4, 1e-5) };
        assert!(
            got[0].allclose(&want[0], rtol, atol),
            "{}: max |diff| = {}",
            variant.key,
            got[0].max_abs_diff(&want[0])
        );
    }
}

#[test]
fn reduction_chain_reduced_variant_matches() {
    let Some(rt) = runtime() else { return };
    let Some(reference) = rt.registry().reference("reduction_chain", 16) else {
        eprintln!("SKIP: reduction_chain b16 not lowered");
        return;
    };
    let key = reference.key.clone();
    let inputs = rt.seeded_inputs(&key, 3).unwrap();
    let want = rt.execute(&key, &inputs).unwrap();
    let reduced_key = key.replace("naive", "reduced");
    if rt.registry().get(&reduced_key).is_none() {
        return;
    }
    let got = rt.execute(&reduced_key, &inputs).unwrap();
    // §7.4: the algebraically reduced graph is numerically equivalent
    assert!(
        got[0].allclose(&want[0], 5e-3, 5e-3),
        "max |diff| = {}",
        got[0].max_abs_diff(&want[0])
    );
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    let key = rt.registry().entries[0].key.clone();
    let inputs = rt.seeded_inputs(&key, 0).unwrap();
    rt.execute(&key, &inputs).unwrap();
    let after_first = rt.cache_len();
    rt.execute(&key, &inputs).unwrap();
    rt.execute(&key, &inputs).unwrap();
    assert_eq!(rt.cache_len(), after_first);
}

#[test]
fn execute_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let key = rt.registry().entries[0].key.clone();
    assert!(rt.execute(&key, &[]).is_err());
    assert!(rt.execute("nonexistent__x__b0", &[]).is_err());
}

#[test]
fn all_artifacts_execute() {
    let Some(rt) = runtime() else { return };
    for entry in rt.registry().entries.clone() {
        let inputs = rt.seeded_inputs(&entry.key, 9).unwrap();
        let out = rt
            .execute(&entry.key, &inputs)
            .unwrap_or_else(|e| panic!("{}: {e:#}", entry.key));
        assert!(!out.is_empty(), "{}", entry.key);
        for t in &out {
            assert!(t.data.iter().all(|v| v.is_finite()), "{}", entry.key);
        }
    }
}
