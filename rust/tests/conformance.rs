//! Conformance-subsystem acceptance tests:
//!
//! 1. **Differential KIR fuzzing** — ≥ 1,000 seeded random graphs per
//!    rewrite pass (and the full pipeline in all 6 pass orders) must
//!    preserve validator invariants and interpreter semantics; failures
//!    shrink to a minimal repro keyed by the generator seed.  A second
//!    sweep (`differential_patch_*`) holds each pass's patch-based form
//!    to a *stricter* claim: bit-identical — nodes, shapes, outputs and
//!    interpreter values by f32 bit pattern — to its retained wholesale
//!    reference.
//! 2. **Renderer determinism** — two in-process renders of the full
//!    golden artifact set are byte-identical (the property the golden
//!    differ rests on).
//! 3. **Golden round trip** — bless → check passes; a mutated golden
//!    fails with a cell-level report; stale/missing goldens fail.
//! 4. **Synthetic-suite census** — fuzz-generated problems drive every
//!    §3.3 execution state through the verification pipeline and every
//!    platform's unsupported-op filter.

use kforge::conformance::{self, golden};
use kforge::harness::Scale;
use kforge::kir::fuzz;
use kforge::kir::interp;
use kforge::kir::rewrite::{apply_all, dce, Rewrite};
use kforge::kir::validate::validate;
use kforge::kir::Graph;
use kforge::workloads::Suite;

/// Seeded graphs per rewrite pass (acceptance floor: 1,000).
const SEEDS_PER_PASS: u64 = 1200;
/// Rewrites may reassociate float reductions; this is the paper-grade
/// tolerance the verification pipeline itself grants candidates.
const RTOL: f32 = 1e-3;
const ATOL: f32 = 1e-3;

/// A numeric claim needs every *intermediate* value finite, not just
/// the outputs: a rewrite may legally replace `x - x` with zero, but
/// `inf - inf` is NaN, and downstream ops (`max`, …) can launder a NaN
/// back into a finite output that then disagrees.  Evaluate the graph
/// with every node exposed as an output and require all of it finite.
/// A small fraction of random transcendental chains overflow and are
/// skipped this way.
fn finite_reference(g: &Graph, ins: &[kforge::tensor::Tensor]) -> bool {
    // dead nodes may hold harmless non-finites (they cannot reach an
    // output on either side of the comparison), so prune them first —
    // only *live* intermediates poison the differential claim
    let mut all_nodes = dce(g);
    all_nodes.outputs = (0..all_nodes.nodes.len()).collect();
    match interp::eval(&all_nodes, ins) {
        Ok(out) => out
            .iter()
            .all(|t| t.data.iter().all(|v| v.is_finite())),
        Err(_) => false,
    }
}

/// Run one rewrite over the seed sweep, shrinking any failure to a
/// minimal repro before panicking.
fn sweep(pass_name: &str, apply: &dyn Fn(&Graph) -> Graph) {
    let mut skipped = 0usize;
    for seed in 0..SEEDS_PER_PASS {
        let g = fuzz::graph(seed);
        validate(&g).unwrap_or_else(|e| {
            panic!("seed {seed}: fuzz generator emitted an invalid graph: {e}\n{}", g.render())
        });
        let ins = fuzz::inputs(&g, seed);
        if !finite_reference(&g, &ins) {
            skipped += 1;
            continue;
        }
        let rewritten = apply(&g);
        if let Err(why) = fuzz::equivalent(&g, &rewritten, &ins, RTOL, ATOL) {
            let still_fails = |cand: &Graph| {
                let cins = fuzz::inputs(cand, seed);
                finite_reference(cand, &cins)
                    && fuzz::equivalent(cand, &apply(cand), &cins, RTOL, ATOL).is_err()
            };
            let min = fuzz::shrink(&g, &still_fails);
            panic!(
                "pass {pass_name} diverged on seed {seed}: {why}\n\
                 minimized repro (from kforge::kir::fuzz::graph({seed})):\n{}\n\
                 rewritten form:\n{}",
                min.render(),
                apply(&min).render()
            );
        }
    }
    assert!(
        skipped * 5 < SEEDS_PER_PASS as usize,
        "{pass_name}: {skipped}/{SEEDS_PER_PASS} seeds skipped as non-finite — generator drifted"
    );
}

#[test]
fn differential_fuzz_constant_fold() {
    sweep("constant_fold", &|g| Rewrite::ConstantFold.apply(g));
}

#[test]
fn differential_fuzz_algebraic_reduce() {
    sweep("algebraic_reduce", &|g| Rewrite::AlgebraicReduce.apply(g));
}

#[test]
fn differential_fuzz_cse() {
    sweep("cse", &|g| Rewrite::Cse.apply(g));
}

#[test]
fn differential_fuzz_dce() {
    sweep("dce", &dce);
}

/// Bit-identity oracle for the patch-vs-whole harness: the two graphs
/// must agree structurally (nodes, shapes, outputs — `Graph: PartialEq`
/// covers all of it) and every interpreter output value must match by
/// f32 *bit pattern* (strictly stronger than `allclose`; NaN payloads
/// included).
fn bit_identical(a: &Graph, b: &Graph, ins: &[kforge::tensor::Tensor]) -> Result<(), String> {
    if a != b {
        return Err("graph structures differ".into());
    }
    match (interp::eval(a, ins), interp::eval(b, ins)) {
        (Ok(va), Ok(vb)) => {
            if va.len() != vb.len() {
                return Err(format!("output arity differs: {} vs {}", va.len(), vb.len()));
            }
            for (i, (ta, tb)) in va.iter().zip(&vb).enumerate() {
                if ta.shape != tb.shape {
                    return Err(format!("output {i} shape differs: {} vs {}", ta.shape, tb.shape));
                }
                for (j, (x, y)) in ta.data.iter().zip(&tb.data).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "output {i}[{j}] bits differ: {x:?} ({:#010x}) vs {y:?} ({:#010x})",
                            x.to_bits(),
                            y.to_bits()
                        ));
                    }
                }
            }
            Ok(())
        }
        (Err(ea), Err(eb)) if ea.to_string() == eb.to_string() => Ok(()),
        (ra, rb) => Err(format!(
            "evaluation outcomes differ: {:?} vs {:?}",
            ra.map(|_| "ok").map_err(|e| e.to_string()),
            rb.map(|_| "ok").map_err(|e| e.to_string())
        )),
    }
}

/// Sweep one pass's patch-based form against its wholesale reference
/// over the full seed budget, minimizing any divergence to a
/// seed-keyed repro.  No finite-reference skip: bit identity is a
/// structural claim and holds for overflowing seeds too.
fn patch_sweep(
    pass_name: &str,
    patched: &dyn Fn(&Graph) -> Graph,
    wholesale: &dyn Fn(&Graph) -> Graph,
) {
    for seed in 0..SEEDS_PER_PASS {
        let g = fuzz::graph(seed);
        let ins = fuzz::inputs(&g, seed);
        let p = patched(&g);
        let w = wholesale(&g);
        if let Err(why) = bit_identical(&p, &w, &ins) {
            let still_fails = |cand: &Graph| patched(cand) != wholesale(cand);
            let min = fuzz::shrink(&g, &still_fails);
            panic!(
                "pass {pass_name}: patch form diverged from wholesale on seed {seed}: {why}\n\
                 minimized repro (from kforge::kir::fuzz::graph({seed})):\n{}\n\
                 patched form:\n{}\n\
                 wholesale form:\n{}",
                min.render(),
                patched(&min).render(),
                wholesale(&min).render()
            );
        }
    }
}

#[test]
fn differential_patch_vs_whole_constant_fold() {
    use kforge::kir::rewrite::constant_fold;
    patch_sweep("constant_fold", &constant_fold::fold, &constant_fold::fold_wholesale);
}

#[test]
fn differential_patch_vs_whole_algebraic_reduce() {
    use kforge::kir::rewrite::algebraic;
    patch_sweep(
        "algebraic_reduce",
        &algebraic::reduce_matmul_chains,
        &algebraic::reduce_matmul_chains_wholesale,
    );
}

#[test]
fn differential_patch_vs_whole_cse() {
    use kforge::kir::rewrite::cse;
    patch_sweep("cse", &cse::eliminate, &cse::eliminate_wholesale);
}

#[test]
fn differential_patch_vs_whole_dce() {
    use kforge::kir::rewrite::dce_wholesale;
    patch_sweep("dce", &dce, &dce_wholesale);
}

#[test]
fn differential_patch_vs_whole_fusion_refresh() {
    // fusion is a schedule decision, not a graph edit, so its
    // incremental form is plan-level: refreshing the greedy plan across
    // a patch must equal recomputing it on the patched graph
    use kforge::kir::rewrite::{cse, fusion};
    for seed in 0..SEEDS_PER_PASS {
        let g = fuzz::graph(seed);
        let prev = fusion::greedy_epilogue(&g);
        let (g2, dirty) = cse::patch(&g)
            .apply()
            .unwrap_or_else(|e| panic!("seed {seed}: cse patch failed to apply: {e}"));
        let inc = fusion::greedy_refresh(&g2, &prev, &dirty);
        let full = fusion::greedy_epilogue(&g2);
        assert_eq!(
            inc, full,
            "seed {seed}: plan refresh diverged from full recompute on\n{}",
            g2.render()
        );
    }
}

#[test]
fn differential_fuzz_full_pipeline_every_pass_order() {
    use Rewrite::{AlgebraicReduce, Cse, ConstantFold};
    let orders: [[Rewrite; 3]; 6] = [
        [ConstantFold, AlgebraicReduce, Cse],
        [ConstantFold, Cse, AlgebraicReduce],
        [AlgebraicReduce, ConstantFold, Cse],
        [AlgebraicReduce, Cse, ConstantFold],
        [Cse, ConstantFold, AlgebraicReduce],
        [Cse, AlgebraicReduce, ConstantFold],
    ];
    for (i, order) in orders.iter().enumerate() {
        let name = format!(
            "pipeline[{}]",
            order.iter().map(|r| r.name()).collect::<Vec<_>>().join("->")
        );
        // a third of the per-pass budget per order still sweeps 2,400
        // pipeline applications; stagger seeds so orders see different
        // graphs too
        let base = (i as u64) * 101;
        for seed in base..base + SEEDS_PER_PASS / 3 {
            let g = fuzz::graph(seed);
            let ins = fuzz::inputs(&g, seed);
            if !finite_reference(&g, &ins) {
                continue;
            }
            let rewritten = apply_all(&g, order);
            if let Err(why) = fuzz::equivalent(&g, &rewritten, &ins, RTOL, ATOL) {
                let still_fails = |cand: &Graph| {
                    let cins = fuzz::inputs(cand, seed);
                    finite_reference(cand, &cins)
                        && fuzz::equivalent(cand, &apply_all(cand, order), &cins, RTOL, ATOL)
                            .is_err()
                };
                let min = fuzz::shrink(&g, &still_fails);
                panic!(
                    "{name} diverged on seed {seed}: {why}\n\
                     minimized repro (from kforge::kir::fuzz::graph({seed})):\n{}",
                    min.render()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// golden artifacts
// ---------------------------------------------------------------------------

#[test]
fn renderers_deterministic_and_golden_round_trip() {
    let scale = Scale::Quick(2);
    let first = conformance::render_all(scale);
    let n_platforms = kforge::platform::registry().len();
    assert_eq!(
        first.len(),
        10 + 2 * n_platforms,
        "manifest + nine paper artifacts + one census and one search frontier per registered platform"
    );
    assert_eq!(first[0].name, "manifest");
    for p in kforge::platform::registry().platforms() {
        assert!(
            first.iter().any(|a| a.name == format!("search_frontier_{}", p.name())),
            "missing search frontier artifact for {}",
            p.name()
        );
    }
    assert!(first[0].text.contains("scale: Quick(2)"), "{}", first[0].text);

    // (a) determinism: a second in-process render is byte-identical —
    // the property the golden differ depends on
    let second = conformance::render_all(scale);
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.name, b.name);
        assert_eq!(
            a.text.as_bytes(),
            b.text.as_bytes(),
            "renderer {} is nondeterministic across in-process runs",
            a.name
        );
        assert!(!a.text.is_empty(), "artifact {} rendered empty", a.name);
    }

    // (b) round trip through the on-disk golden store
    let dir = std::env::temp_dir().join(format!("kforge_conformance_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    golden::bless_with(&dir, &first).unwrap();
    let report = golden::check_against(&dir, &first).unwrap();
    assert!(report.passed(), "{}", report.summary());

    // (c) a mutated golden cell fails with a per-cell report
    let table2 = dir.join("table2.txt");
    let pristine = std::fs::read_to_string(&table2).unwrap();
    assert!(pristine.contains("100"));
    std::fs::write(&table2, pristine.replacen("100", "999", 1)).unwrap();
    let drifted = golden::check_against(&dir, &first).unwrap();
    assert!(!drifted.passed());
    assert_eq!(drifted.drifted.len(), 1);
    assert_eq!(drifted.drifted[0].name, "table2");
    assert!(
        drifted.drifted[0].report.contains("999"),
        "cell report must show the drifted value:\n{}",
        drifted.drifted[0].report
    );
    std::fs::write(&table2, pristine).unwrap();

    // (d) stale and missing goldens both fail
    std::fs::write(dir.join("ghost.txt"), "boo").unwrap();
    let stale = golden::check_against(&dir, &first).unwrap();
    assert_eq!(stale.stale, vec!["ghost".to_string()]);
    assert!(!stale.passed());
    std::fs::remove_file(dir.join("ghost.txt")).unwrap();
    std::fs::remove_file(dir.join("fig2.txt")).unwrap();
    let missing = golden::check_against(&dir, &first).unwrap();
    assert_eq!(missing.missing, vec!["fig2".to_string()]);
    assert!(!missing.passed());

    let _ = std::fs::remove_dir_all(&dir);
}

/// Once `goldens/` is blessed and committed (the CI bootstrap uploads
/// the set — see goldens/README.md), the tier-1 gate itself enforces
/// it: any artifact drift fails `cargo test` with the cell-level
/// report, independent of whether the CI conformance job runs.
#[test]
fn committed_goldens_match_when_present() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("goldens");
    let has_goldens = std::fs::read_dir(&dir)
        .map(|entries| {
            entries
                .flatten()
                .any(|e| e.path().extension().and_then(|x| x.to_str()) == Some("txt"))
        })
        .unwrap_or(false);
    if !has_goldens {
        eprintln!(
            "goldens/ holds no blessed artifacts yet; skipping (run `kforge conformance --bless`)"
        );
        return;
    }
    let arts = conformance::render_all(conformance::SCALE);
    let report = golden::check_against(&dir, &arts).unwrap();
    assert!(
        report.passed(),
        "{}\n{}",
        report.summary(),
        report.full_diff()
    );
}

// ---------------------------------------------------------------------------
// synthetic workload census
// ---------------------------------------------------------------------------

#[test]
fn synthetic_problems_exercise_every_exec_state() {
    use kforge::agents::generation::tests_support::trivial_program;
    use kforge::kir::op::{BinaryKind, Op};
    use kforge::kir::Node;
    use kforge::util::rng::Pcg;
    use kforge::verify;
    use std::collections::BTreeSet;

    let spec = kforge::platform::cuda::h100();
    let suite = Suite::synthetic(0xABCD, 12);
    let mut rng = Pcg::seed(0);
    let mut seen: BTreeSet<&'static str> = BTreeSet::new();
    for p in suite.problems.iter() {
        // generation failure: the agent returned no program
        seen.insert(verify::verify(&spec, p, None, &mut rng).state.label());
        // compilation failure: dangling output reference
        let mut bad = trivial_program(p);
        bad.graph.outputs = vec![bad.graph.len() + 9];
        seen.insert(verify::verify(&spec, p, Some(&bad), &mut rng).state.label());
        // runtime error: threadgroup over the device limit
        let mut ill = trivial_program(p);
        ill.schedule.threadgroup = 4096;
        seen.insert(verify::verify(&spec, p, Some(&ill), &mut rng).state.label());
        // mismatch: +1 on the first output (well-typed, wrong values)
        let mut wrong = trivial_program(p);
        let out0 = wrong.graph.outputs[0];
        let shape = wrong.graph.nodes[out0].shape.clone();
        wrong.graph.nodes.push(Node {
            op: Op::ConstFill { value: 1.0, shape: shape.clone() },
            shape: shape.clone(),
        });
        let c = wrong.graph.nodes.len() - 1;
        wrong.graph.nodes.push(Node {
            op: Op::Binary { kind: BinaryKind::Add, lhs: out0, rhs: c },
            shape,
        });
        wrong.graph.outputs[0] = wrong.graph.nodes.len() - 1;
        seen.insert(verify::verify(&spec, p, Some(&wrong), &mut rng).state.label());
        // correct: the reference graph itself
        let ok = trivial_program(p);
        seen.insert(verify::verify(&spec, p, Some(&ok), &mut rng).state.label());
    }
    for state in [
        "generation_failure",
        "compilation_failure",
        "runtime_error",
        "mismatch",
        "correct",
    ] {
        assert!(seen.contains(state), "state {state:?} never reached; saw {seen:?}");
    }
}

#[test]
fn synthetic_campaign_runs_end_to_end() {
    use kforge::coordinator::{run_campaign, BaselineKind, ExperimentConfig};
    // the real §3 loop over a generated suite: the point of
    // Suite::synthetic is that campaigns accept it like any other suite
    let suite = Suite::synthetic(0xCAFE, 9);
    let cfg = ExperimentConfig {
        name: "synthetic_campaign".into(),
        platform: kforge::platform::by_name("cuda").unwrap(),
        personas: vec![kforge::agents::persona::by_name("openai-gpt-5").unwrap()],
        iterations: 2,
        use_profiling: false,
        use_reference: false,
        baseline: BaselineKind::Eager,
        seed: 11,
        workers: 3,
    };
    let a = run_campaign(&suite, None, &cfg);
    assert_eq!(a.results.len(), 9, "cuda supports every synthetic problem");
    let b = run_campaign(&suite, None, &cfg);
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.problem_id, y.problem_id);
        assert_eq!(x.state_history, y.state_history);
    }
    // census labels stay within the §3.3 vocabulary
    for key in a.state_census().keys() {
        assert!(matches!(
            *key,
            "generation_failure" | "compilation_failure" | "runtime_error" | "mismatch" | "correct"
        ));
    }
}

#[test]
fn synthetic_suites_respect_platform_filters_in_campaigns() {
    use kforge::coordinator::{run_campaign, BaselineKind, ExperimentConfig};
    let suite = Suite::synthetic(0xF117E5, 15);
    for platform in kforge::platform::registry().platforms() {
        let kept = suite.supported_on(platform.spec()).len();
        if platform.spec().unsupported_ops.is_empty() {
            assert_eq!(kept, suite.len());
            continue;
        }
        assert!(kept < suite.len(), "{} filter unexercised", platform.name());
        let cfg = ExperimentConfig {
            name: format!("synth_filter_{}", platform.name()),
            platform: platform.clone(),
            personas: vec![kforge::agents::persona::by_name("deepseek-v3").unwrap()],
            iterations: 1,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 5,
            workers: 2,
        };
        let campaign = run_campaign(&suite, None, &cfg);
        assert_eq!(campaign.results.len(), kept, "{}", platform.name());
    }
}
