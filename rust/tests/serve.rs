//! Serve-subsystem integration: the ISSUE 6 acceptance properties.
//!
//! - a scenario's outcome census, pop order, virtual latencies and
//!   makespan are bit-reproducible given a seed;
//! - synthesized results are bit-identical across execution pool
//!   widths 1/4/16 (virtual service capacity held fixed);
//! - the queue is FIFO per priority class under a seeded burst;
//! - the declared p99 / shed-rate budgets hold for the default
//!   scenario, requests are conserved, and nothing fails;
//! - cache warming + `gc` eviction pressure behave on a disk store.

use kforge::serve::{
    run_scenario, summarize, Priority, ScenarioConfig, ScenarioReport, SERVE_SCHEMA,
};
use kforge::store::Store;
use kforge::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("kforge_serve_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The deterministic (virtual-phase) view of a report: everything
/// except wall-clock measurements and store byte counters.
fn virtual_fingerprint(r: &ScenarioReport) -> Vec<String> {
    let mut out = Vec::new();
    for req in &r.requests {
        out.push(format!(
            "{}|{}|{}|{:?}|{}|{:?}|{:?}",
            req.id,
            req.priority.label(),
            req.job,
            req.outcome.latency_ms().map(f64::to_bits),
            req.outcome.label(),
            req.started_ms.map(f64::to_bits),
            req.chunk_ms.iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
        ));
    }
    out.push(format!("pop={:?}", r.pop_order));
    out.push(format!("depth={} makespan={}", r.max_depth, r.makespan_ms.to_bits()));
    out.push(format!("warmed={:?} jobs={:?}", r.warmed, r.results.iter().map(|(j, _)| j).collect::<Vec<_>>()));
    out
}

fn assert_results_bit_identical(a: &ScenarioReport, b: &ScenarioReport) {
    let index: HashMap<&String, &kforge::coordinator::TaskResult> =
        b.results.iter().map(|(j, r)| (j, r)).collect();
    assert_eq!(a.results.len(), b.results.len());
    for (job, x) in &a.results {
        let y = index.get(job).unwrap_or_else(|| panic!("job {job} missing from other run"));
        assert_eq!(x.problem_id, y.problem_id, "{job}");
        assert_eq!(x.persona, y.persona, "{job}");
        assert_eq!(x.state_history, y.state_history, "{job}");
        assert_eq!(x.outcome.correct, y.outcome.correct, "{job}");
        assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits(), "{job}");
        assert_eq!(x.best_iteration, y.best_iteration, "{job}");
        assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits(), "{job}");
        assert_eq!(x.best_candidate_s.map(f64::to_bits), y.best_candidate_s.map(f64::to_bits), "{job}");
    }
}

#[test]
fn scenario_outcome_is_deterministic_given_a_seed() {
    let cfg = ScenarioConfig::new(0xC0FFEE, 48, 4);
    let a = run_scenario(&Store::memory(), &cfg);
    let b = run_scenario(&Store::memory(), &cfg);
    assert_eq!(virtual_fingerprint(&a), virtual_fingerprint(&b));
    assert_results_bit_identical(&a, &b);
    // a different seed reshapes the scenario
    let c = run_scenario(&Store::memory(), &ScenarioConfig::new(0xC0FFEF, 48, 4));
    assert_ne!(virtual_fingerprint(&a), virtual_fingerprint(&c));
}

#[test]
fn results_bit_identical_across_exec_worker_counts() {
    // virtual service capacity stays at 4 (part of the deterministic
    // scenario); only the real execution pool width varies
    let mut reports = Vec::new();
    for exec_workers in [1usize, 4, 16] {
        let mut cfg = ScenarioConfig::new(0xBEEF, 32, 4);
        cfg.exec_workers = Some(exec_workers);
        reports.push(run_scenario(&Store::memory(), &cfg));
    }
    for r in &reports[1..] {
        assert_eq!(virtual_fingerprint(&reports[0]), virtual_fingerprint(r));
        assert_results_bit_identical(&reports[0], r);
    }
}

#[test]
fn results_bit_identical_under_shard_backed_exec_pool() {
    // the dist chunk-claiming pool is a drop-in for the flat worker
    // pool: same virtual scenario, same synthesized bits, any shard
    // count (including more shards than jobs)
    let mut cfg = ScenarioConfig::new(0xBEEF, 32, 4);
    let flat = run_scenario(&Store::memory(), &cfg);
    for shards in [1usize, 2, 4, 32] {
        cfg.exec_shards = Some(shards);
        let sharded = run_scenario(&Store::memory(), &cfg);
        assert_eq!(virtual_fingerprint(&flat), virtual_fingerprint(&sharded), "shards={shards}");
        assert_results_bit_identical(&flat, &sharded);
    }
}

/// A scenario with guaranteed streaming traffic: a problem pool with
/// level-4 models and every level-4 request arriving as a stream.
fn streaming_cfg(exec_workers: Option<usize>) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::new(0x57AE, 48, 4);
    cfg.load.synthetic_problems = 16;
    cfg.load.streaming_fraction = 1.0;
    cfg.exec_workers = exec_workers;
    cfg
}

#[test]
fn streaming_scenario_is_bit_identical_across_exec_worker_counts() {
    // the ISSUE 7 streaming determinism property at the serve tier:
    // chunk schedules, outcomes and synthesized results are all
    // invariant under real execution pool width
    let reports: Vec<ScenarioReport> =
        [1usize, 4, 16].iter().map(|&w| run_scenario(&Store::memory(), &streaming_cfg(Some(w)))).collect();
    let streamed = reports[0].requests.iter().filter(|r| !r.chunk_ms.is_empty()).count();
    assert!(streamed > 0, "no streaming miss in the scenario");
    // every started streaming job was verified pulsed == whole, and
    // none diverged
    assert!(reports[0].stream_checked > 0, "streaming verification never ran");
    for r in &reports {
        assert_eq!(r.stream_mismatches, 0, "pulsed execution diverged");
    }
    for r in &reports[1..] {
        assert_eq!(virtual_fingerprint(&reports[0]), virtual_fingerprint(r));
        assert_results_bit_identical(&reports[0], r);
        assert_eq!(reports[0].stream_checked, r.stream_checked);
    }
    // the streaming summary surfaces chunks and holds the chunk budget
    let cfg = streaming_cfg(None);
    let summary = summarize(&cfg, &reports[1]);
    assert!(summary.chunks > 0);
    assert_eq!(summary.streaming_requests, streamed);
    assert!(
        summary.within_chunk_budget(),
        "chunk p99 {:?} over the {} ms budget",
        summary.chunk_latency.map(|s| s.p99),
        summary.chunk_budget_ms
    );
    let j = summary.to_json("synthetic");
    let s = j.get("streaming").unwrap();
    assert_eq!(s.get("stream_mismatches").and_then(Json::as_i64), Some(0));
    assert!(s.get("chunks").and_then(Json::as_i64).unwrap() > 0);
}

#[test]
fn queue_is_fifo_per_priority_class_under_a_seeded_burst() {
    // small service capacity so bursts actually queue
    let mut cfg = ScenarioConfig::new(0xF1F0, 96, 2);
    cfg.queue_capacity = 12;
    cfg.shed_depth = 12;
    let report = run_scenario(&Store::memory(), &cfg);
    assert!(!report.pop_order.is_empty());
    let mut last_interactive = None;
    let mut last_batch = None;
    for &(priority, id) in &report.pop_order {
        let last = match priority {
            Priority::Interactive => &mut last_interactive,
            Priority::Batch => &mut last_batch,
        };
        if let Some(prev) = *last {
            assert!(id > prev, "{} lane popped {id} after {prev}", priority.label());
        }
        *last = Some(id);
    }
    // both classes flowed through the queue
    assert!(report.pop_order.iter().any(|(p, _)| *p == Priority::Interactive));
    assert!(report.pop_order.iter().any(|(p, _)| *p == Priority::Batch));
    // the queue actually built depth under the burst
    assert!(report.max_depth >= 2, "max depth {}", report.max_depth);
}

#[test]
fn default_scenario_holds_its_budgets_and_conserves_requests() {
    let cfg = ScenarioConfig::new(0x5EED, 64, 4);
    let report = run_scenario(&Store::memory(), &cfg);
    let summary = summarize(&cfg, &report);
    // conservation: every request resolves to exactly one outcome
    assert_eq!(
        summary.completed + summary.rejected + summary.expired + summary.failed,
        summary.requests
    );
    assert_eq!(summary.requests, 64);
    // synthetic synthesis jobs are infallible
    assert_eq!(summary.failed, 0);
    assert!(summary.completed > 0);
    // the declared budgets: virtual p99 and shed rate
    let p99 = summary.latency.expect("completed requests exist").p99;
    assert!(
        summary.within_latency_budget(),
        "virtual p99 {p99:.2} ms over the {:.1} ms budget",
        summary.p99_budget_ms
    );
    assert!(
        summary.within_shed_budget(),
        "shed rate {:.3} over the {:.2} budget",
        summary.shed_rate(),
        summary.shed_budget
    );
    // histogram counts completed requests exactly
    assert_eq!(summary.hist.total(), summary.completed as u64);
    // the JSON surface carries the schema and the same census
    let j = summary.to_json("synthetic");
    assert_eq!(j.get("schema").and_then(Json::as_str), Some(SERVE_SCHEMA));
    let reqs = j.get("requests").unwrap();
    assert_eq!(reqs.get("total").and_then(Json::as_i64), Some(64));
    assert_eq!(reqs.get("failed").and_then(Json::as_i64), Some(0));
    assert_eq!(
        j.get("budgets").unwrap().get("within").and_then(Json::as_bool),
        Some(true)
    );
}

#[test]
fn warming_and_gc_pressure_on_a_disk_store() {
    let dir = tmpdir("warm_gc");
    let mut cfg = ScenarioConfig::new(21, 48, 4);
    // nothing sheds or expires: every request (and so every hot job)
    // completes and executes
    cfg.queue_capacity = 64;
    cfg.shed_depth = 64;
    cfg.load.deadline_ms = 1e9;
    cfg.warm_hottest = 2;
    cfg.gc_max_bytes = Some(0); // evict the whole disk tier after warming
    let store = Store::at_dir(&dir, false).unwrap();
    let report = run_scenario(&store, &cfg);
    assert_eq!(report.warmed.len(), 2);
    assert!(report.results.len() > 2, "only {} distinct jobs", report.results.len());
    let stats = report.cache;
    // the warm phase wrote one disk entry per warmed job; gc --max-bytes 0
    // then evicted them all
    assert_eq!(stats.evictions, 2, "{stats:?}");
    // warmed jobs still hit when served: eviction only empties the disk
    // tier, the in-process memory tier keeps the hot entries
    assert!(stats.hits >= 2, "{stats:?}");
    assert!(stats.misses > 0, "{stats:?}");
    assert!(stats.bytes_written > 0, "{stats:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disabled_store_models_no_hits_and_warms_nothing() {
    let mut cfg = ScenarioConfig::new(33, 32, 4);
    cfg.warm_hottest = 4;
    let report = run_scenario(&Store::disabled(), &cfg);
    assert!(report.warmed.is_empty(), "a disabled store cannot be warmed");
    assert!(report.requests.iter().all(|r| !r.virtual_hit));
    assert_eq!(report.cache, kforge::store::CacheStats::default());
    // requests still conserve and execute
    let summary = summarize(&cfg, &report);
    assert_eq!(
        summary.completed + summary.rejected + summary.expired,
        summary.requests
    );
    assert!(!report.results.is_empty());
}
