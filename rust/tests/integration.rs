//! Integration tests: the full KForge loop across modules, at Quick
//! scale (simulation only — PJRT integration lives in
//! pjrt_integration.rs and needs `make artifacts`).

use kforge::agents::persona::{by_name, PERSONAS};
use kforge::coordinator::{run_campaign, BaselineKind, ExperimentConfig};
use kforge::harness::{self, Scale};
use kforge::metrics;
use kforge::workloads::refcorpus::RefCorpus;
use kforge::workloads::{Level, Suite};

fn cfg(platform: &str, personas: Vec<&'static kforge::agents::Persona>) -> ExperimentConfig {
    let mut c = ExperimentConfig::iterative(
        kforge::platform::by_name(platform).unwrap(),
        personas,
    );
    c.name = "integration".into();
    c
}

#[test]
fn full_loop_produces_all_five_states_somewhere() {
    // across a weak persona and enough problems, every §3.3 state shows up
    let suite = Suite::sample(25);
    let mut c = cfg("cuda", vec![by_name("deepseek-v3").unwrap()]);
    c.iterations = 3;
    let campaign = run_campaign(&suite, None, &c);
    let census = campaign.state_census();
    assert!(census.contains_key("correct"), "{census:?}");
    assert!(census.contains_key("mismatch"), "{census:?}");
    assert!(
        census.contains_key("compilation_failure") || census.contains_key("runtime_error"),
        "{census:?}"
    );
}

#[test]
fn reasoning_gap_grows_with_level() {
    // paper §5.1: the reasoning-vs-chat gap is maximal on Level 3
    let suite = Suite::sample(20);
    let personas = vec![by_name("openai-gpt-5").unwrap(), by_name("openai-gpt-4o").unwrap()];
    let campaign = run_campaign(&suite, None, &cfg("cuda", personas));
    let gap = |level: Level| {
        metrics::correctness_rate(&campaign.outcomes("openai-gpt-5", level))
            - metrics::correctness_rate(&campaign.outcomes("openai-gpt-4o", level))
    };
    assert!(
        gap(Level::L3) > gap(Level::L1) - 0.15,
        "L3 gap {} should exceed L1 gap {}",
        gap(Level::L3),
        gap(Level::L1)
    );
    assert!(gap(Level::L3) > 0.15, "L3 gap too small: {}", gap(Level::L3));
}

#[test]
fn fast1_much_lower_than_fast0() {
    // paper: performance at fast_1 decreases significantly for all models
    let suite = Suite::sample(15);
    let campaign = run_campaign(
        &suite,
        None,
        &cfg("cuda", vec![by_name("openai-gpt-5").unwrap()]),
    );
    let all: Vec<_> = campaign.results.iter().map(|r| r.outcome).collect();
    let f0 = metrics::fast_p(&all, 0.0);
    let f15 = metrics::fast_p(&all, 1.5);
    assert!(f0 > f15, "fast_0 {f0} should exceed fast_1.5 {f15}");
}

#[test]
fn profiling_loop_runs_on_all_platforms() {
    let suite = Suite::sample(5);
    for platform in ["cuda", "metal", "rocm"] {
        let mut c = cfg(platform, vec![by_name("openai-gpt-5").unwrap()]);
        c.use_profiling = true;
        c.name = format!("prof_{platform}");
        let campaign = run_campaign(&suite, None, &c);
        assert!(!campaign.results.is_empty());
        let correct = campaign.results.iter().filter(|r| r.outcome.correct).count();
        assert!(correct > 0, "{platform} produced no correct programs");
    }
}

#[test]
fn reference_corpus_pipeline_end_to_end() {
    let suite = Suite::sample(6);
    let corpus = RefCorpus::build(&suite, 5, 1);
    assert!(corpus.coverage(&suite) > 0.5);
    let mut c = cfg("metal", vec![by_name("claude-opus-4").unwrap()]);
    c.use_reference = true;
    let campaign = run_campaign(&suite, Some(&corpus), &c);
    assert!(!campaign.results.is_empty());
}

#[test]
fn compile_baseline_vs_eager_baseline_ordering() {
    // same persona, same problems: speedups against compile ≠ eager
    let suite = Suite::sample(8);
    let mut eager_cfg = cfg("cuda", vec![by_name("openai-gpt-5").unwrap()]);
    eager_cfg.name = "base_eager".into();
    let mut compile_cfg = eager_cfg.clone();
    compile_cfg.name = "base_compile".into();
    compile_cfg.baseline = BaselineKind::TorchCompile;
    let a = run_campaign(&suite, None, &eager_cfg);
    let b = run_campaign(&suite, None, &compile_cfg);
    // both complete with same problem sets
    assert_eq!(a.results.len(), b.results.len());
    // baselines must differ (different executors)
    let diff = a
        .results
        .iter()
        .zip(&b.results)
        .filter(|(x, y)| (x.baseline_s - y.baseline_s).abs() / x.baseline_s > 0.01)
        .count();
    assert!(diff > a.results.len() / 3, "baselines suspiciously identical");
}

#[test]
fn runlog_roundtrip_through_json() {
    let suite = Suite::sample(3);
    let campaign = run_campaign(
        &suite,
        None,
        &cfg("cuda", vec![by_name("deepseek-r1").unwrap()]),
    );
    let doc = kforge::coordinator::runlog::to_json(&campaign);
    let parsed = kforge::util::json::parse(&doc.to_pretty()).unwrap();
    assert_eq!(
        parsed.get("results").unwrap().as_arr().unwrap().len(),
        campaign.results.len()
    );
}

#[test]
fn harness_table2_exact() {
    let (t2, _) = harness::table2::run();
    let sum = |r: &[usize]| r.iter().sum::<usize>();
    // paper counts plus the 8-problem level-4 whole-model tier
    assert_eq!(sum(t2.row("KernelBench-Metal").unwrap()), 228);
    assert_eq!(sum(t2.row("KernelBench").unwrap()), 258);
    assert_eq!(sum(t2.row("KernelBench-CUDA").unwrap()), 258);
}

#[test]
fn registry_platforms_round_trip_through_the_whole_api() {
    // every registered platform yields a usable spec, a profiler
    // frontend choice, a prompt language, and calibrated persona priors
    let suite = Suite::sample(1);
    let problem = &suite.problems[0];
    for platform in kforge::platform::registry().platforms() {
        let spec = platform.spec();
        assert!(spec.peak_flops_f32 > 0.0 && spec.mem_bw > 0.0, "{}", platform.name());
        // the profiler frontend round-trips a real profile to Evidence
        let frontend = platform.profiler_frontend();
        assert!(!frontend.name().is_empty());
        let plan = kforge::perfsim::lower::lower(&problem.perf_graph, &kforge::sched::Schedule::naive());
        let mut rng = kforge::util::rng::Pcg::seed(3);
        let sim = kforge::perfsim::simulate(spec, &plan, &mut rng, 10, 2);
        let profile = kforge::profiler::Profile::from_sim(&problem.id, spec.name, &sim);
        let evidence = frontend
            .evidence(&profile)
            .unwrap_or_else(|e| panic!("{}: frontend {} failed: {e:#}", platform.name(), frontend.name()));
        assert_eq!(evidence.n_kernels(), profile.kernels.len(), "{}", platform.name());
        assert!(evidence.fidelity_score() > 0.0, "{}", platform.name());
        // the prompt renders with the platform's language and no holes
        let prompt = kforge::agents::prompt::synthesis_prompt(spec, problem, None, None, None);
        assert!(prompt.contains(platform.language()), "{}", platform.name());
        assert!(!prompt.contains("<missing:"), "{}", platform.name());
        // persona priors resolve (calibrated row or declared fallback)
        for persona in PERSONAS {
            let row = persona.single_shot(&**platform);
            assert!(row.iter().all(|p| *p > 0.0 && *p < 1.0), "{}", persona.name);
        }
        // the expert schedule the refinement loop converges to is legal
        kforge::sched::legal::check(&platform.expert_schedule(), spec).unwrap();
    }
}

#[test]
fn rocm_level1_problem_end_to_end() {
    // the acceptance path for the third platform: a level-1 problem
    // runs the full iterative job (synthesize → verify → perfsim) on
    // the ROCm profile, registered purely through the platform API
    let suite = Suite::sample(4);
    let platform = kforge::platform::by_name("rocm").unwrap();
    assert_eq!(platform.name(), "rocm");
    let c = cfg("rocm", vec![by_name("openai-gpt-5").unwrap()]);
    let spec = c.spec();
    let l1: Vec<_> = suite
        .problems
        .iter()
        .filter(|p| p.level == Level::L1 && p.supported_on(&spec))
        .collect();
    assert!(!l1.is_empty(), "no L1 problems supported on rocm");
    let mut best_seen = None;
    for problem in &l1 {
        let result =
            kforge::coordinator::experiment::run_task(&c, &spec, c.personas[0], problem, None);
        assert_eq!(result.state_history.len(), c.iterations);
        assert!(result.baseline_s > 0.0);
        if let Some(t) = result.best_candidate_s {
            assert!(t > 0.0 && result.outcome.correct);
            best_seen = Some(t);
        }
    }
    // gpt-5's named MI300X calibration row is 0.80 at L1 over 5
    // iterations: at least one sampled problem must complete correctly
    assert!(best_seen.is_some(), "no correct rocm candidate across L1 sample");
}

#[test]
fn harness_quick_smoke_all_figures() {
    // every figure harness completes at tiny scale and emits its title
    let (_, f2) = harness::fig2::run(Scale::Quick(2));
    assert!(f2.contains("Figure 2"));
    let (_, f3) = harness::fig3::run(Scale::Quick(2));
    assert!(f3.contains("Figure 3"));
    let (_, f4) = harness::fig4::run(Scale::Quick(2));
    assert!(f4.contains("Figure 4"));
}

#[test]
fn all_personas_complete_one_problem() {
    let suite = Suite::sample(1);
    // sample(1) draws one problem per registered level
    let campaign = run_campaign(&suite, None, &cfg("cuda", PERSONAS.iter().collect()));
    assert_eq!(campaign.results.len(), suite.problems.len() * PERSONAS.len());
    assert_eq!(suite.problems.len(), Level::COUNT);
}
