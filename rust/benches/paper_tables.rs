//! Bench target: regenerate every paper TABLE (2, 4, 5, 6) plus the §7
//! case studies, timing each regeneration.
//!
//! `cargo bench --bench paper_tables` runs at the paper's full scale
//! (250 problems); set `KFORGE_QUICK=<n>` for an n-per-level smoke run.

use kforge::harness::{self, Scale};
use std::time::Instant;

fn scale() -> Scale {
    match std::env::var("KFORGE_QUICK") {
        Ok(n) => Scale::Quick(n.parse().expect("KFORGE_QUICK=<n>")),
        Err(_) => Scale::Full,
    }
}

fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let text = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{text}");
    println!("[bench] {name}: {dt:.2}s\n");
}

fn main() {
    let s = scale();
    println!("# paper tables @ {s:?}\n");
    timed("table2", || harness::table2::run().1);
    timed("table4", || harness::table4::run(s).1);
    timed("table5", || harness::table5::run(s).1);
    timed("table6", || harness::table6::run().1);
    timed("case_studies", || harness::casestudy::run().1);
    timed("ablation", || harness::ablation::run(s).1);
}
