//! Micro-benchmarks of the coordinator hot paths (the §Perf targets):
//! interpreter throughput, lowering+simulation, verification, one full
//! iterative task, and the worker-pool scaling of a mini campaign.
//!
//! Hand-rolled harness (criterion is not available offline): median of
//! N timed runs after warmup, printed as ns/op.

use kforge::agents::generation::tests_support::trivial_program;
use kforge::agents::persona::by_name;
use kforge::coordinator::{run_campaign, ExperimentConfig};
use kforge::kir::interp;
use kforge::perfsim::{lower, simulate};
use kforge::platform::cuda;
use kforge::sched::Schedule;
use kforge::util::rng::Pcg;
use kforge::verify;
use kforge::workloads::Suite;
use std::time::Instant;

fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let total: f64 = samples.iter().sum();
    println!(
        "{name:<44} median {:>12.3} us   mean {:>12.3} us   ({iters} iters)",
        med * 1e6,
        total / iters as f64 * 1e6
    );
}

fn main() {
    let suite = Suite::full();
    let spec = cuda::h100();
    println!("# coordinator hot paths\n");

    // interpreter on a mid-size problem
    let p = suite.get("l2_gemm_bias_swish_0").unwrap();
    let ins = p.eval_inputs(0);
    bench("interp: l2 gemm_bias_swish eval graph", 500, || {
        interp::eval(&p.eval_graph, &ins).unwrap()
    });

    // conv-heavy interpreter path
    let fire = suite.get("l3_squeezenet_fire").unwrap();
    let fire_ins = fire.eval_inputs(0);
    bench("interp: l3 fire module eval graph", 200, || {
        interp::eval(&fire.eval_graph, &fire_ins).unwrap()
    });

    // lowering + simulation
    let sched = Schedule::expert();
    bench("lower+simulate: l3 fire perf graph", 500, || {
        let plan = lower::lower(&fire.perf_graph, &sched);
        let mut rng = Pcg::seed(0);
        simulate(&spec, &plan, &mut rng, 100, 10)
    });

    // full verification of a correct program
    let prog = trivial_program(p);
    bench("verify: correct candidate end-to-end", 200, || {
        let mut rng = Pcg::seed(0);
        verify::verify(&spec, p, Some(&prog), &mut rng)
    });

    // one full iterative task (5 iterations)
    let persona = by_name("openai-gpt-5").unwrap();
    let cfg = ExperimentConfig::cuda_iterative(vec![persona]);
    bench("run_task: 5-iteration loop, one problem", 50, || {
        kforge::coordinator::experiment::run_task(&cfg, &spec, persona, p, None)
    });

    // campaign scaling across workers
    println!();
    let mini = Suite::sample(8);
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = ExperimentConfig::cuda_iterative(vec![persona]);
        cfg.workers = workers;
        cfg.name = format!("scale_{workers}");
        let t0 = Instant::now();
        let c = run_campaign(&mini, None, &cfg);
        println!(
            "campaign: 24 problems x 5 iters, workers={workers:<2} {:>8.2} ms  ({} results)",
            t0.elapsed().as_secs_f64() * 1e3,
            c.results.len()
        );
    }

    // agents with profiling in the loop (Metal screenshot path)
    println!();
    let persona_metal = by_name("claude-opus-4").unwrap();
    let mut mcfg = ExperimentConfig::mps_iterative(vec![persona_metal]);
    mcfg.use_profiling = true;
    let mspec = mcfg.spec();
    let mp = suite.get("l2_gemm_bias_swish_0").unwrap();
    bench("run_task: metal + screenshot profiling", 50, || {
        kforge::coordinator::experiment::run_task(&mcfg, &mspec, persona_metal, mp, None)
    });
}
