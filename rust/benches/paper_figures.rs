//! Bench target: regenerate every paper FIGURE (2, 3, 4), timing each.
//!
//! `cargo bench --bench paper_figures` runs at the paper's full scale;
//! set `KFORGE_QUICK=<n>` for an n-per-level smoke run.

use kforge::harness::{self, Scale};
use std::time::Instant;

fn scale() -> Scale {
    match std::env::var("KFORGE_QUICK") {
        Ok(n) => Scale::Quick(n.parse().expect("KFORGE_QUICK=<n>")),
        Err(_) => Scale::Full,
    }
}

fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let text = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{text}");
    println!("[bench] {name}: {dt:.2}s\n");
}

fn main() {
    let s = scale();
    println!("# paper figures @ {s:?}\n");
    timed("fig2", || harness::fig2::run(s).1);
    timed("fig3", || harness::fig3::run(s).1);
    timed("fig4", || harness::fig4::run(s).1);
}
