//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Strategy at equal budget** — single-shot vs repeated-sampling
//!    (k=5) vs iterative refinement (5 iterations): the paper's three
//!    §3 strategies compared head-to-head.
//! 2. **Specialized analysis agent** — the dedicated G agent vs
//!    feeding raw profiles to the generator (modeled as degraded
//!    instruction-following: the paper's §3.2 retrieval-degradation
//!    argument).
//! 3. **Reference-transfer components** — full transfer vs
//!    correctness-effect-only (no schedule transfer): which part of
//!    §6.2's gain comes from code patterns vs error-rate reduction.

use super::{render, Scale};
use crate::agents::persona::by_name;
use crate::agents::sampling;
use crate::agents::GenerationAgent;
use crate::coordinator::{run_campaign, ExperimentConfig};
use crate::metrics::{self, TaskOutcome};
use crate::util::rng::Pcg;
use crate::workloads::Suite;

pub struct Ablation {
    /// (row label, fast_0, fast_1, fast_1.5)
    pub rows: Vec<(String, f64, f64, f64)>,
}

fn summarize(label: &str, outcomes: &[TaskOutcome]) -> (String, f64, f64, f64) {
    (
        label.to_string(),
        metrics::fast_p(outcomes, 0.0),
        metrics::fast_p(outcomes, 1.0),
        metrics::fast_p(outcomes, 1.5),
    )
}

pub fn run(scale: Scale) -> (Ablation, String) {
    let suite = match scale {
        Scale::Full => Suite::sample(25), // 75 problems is plenty for ablations
        Scale::Quick(n) => Suite::sample(n),
    };
    let persona = by_name("openai-gpt-5").unwrap();
    let spec = crate::platform::cuda::h100();
    let mut rows = Vec::new();

    // --- 1. strategy ablation at budget = 5 generations -----------------
    let mut single = ExperimentConfig::cuda_iterative(vec![persona]);
    single.name = "abl_single".into();
    single.iterations = 1;
    let single_c = run_campaign(&suite, None, &single);
    rows.push(summarize(
        "single-shot (budget 1)",
        &single_c.results.iter().map(|r| r.outcome).collect::<Vec<_>>(),
    ));

    // repeated sampling: 5 independent samples, keep fastest correct
    let agent =
        GenerationAgent::new(persona, crate::platform::by_name("cuda").expect("builtin cuda"));
    let sampled: Vec<TaskOutcome> = suite
        .problems
        .iter()
        .map(|p| {
            let mut rng = Pcg::new(0xAB1A, crate::util::rng::fnv1a(p.id.as_bytes()));
            let mut brng = rng.fork("baseline");
            let base = crate::baseline::eager::measure(&p.perf_graph, &spec, &mut brng).measured_s;
            match sampling::repeated_sampling(&agent, &spec, p, None, 5, &mut rng).best {
                Some((_, t)) => TaskOutcome::correct(base / t),
                None => TaskOutcome::incorrect(),
            }
        })
        .collect();
    rows.push(summarize("repeated sampling (k=5)", &sampled));

    let mut iter = ExperimentConfig::cuda_iterative(vec![persona]);
    iter.name = "abl_iter".into();
    let iter_c = run_campaign(&suite, None, &iter);
    rows.push(summarize(
        "iterative refinement (5 iters)",
        &iter_c.results.iter().map(|r| r.outcome).collect::<Vec<_>>(),
    ));

    let mut iter_prof = ExperimentConfig::cuda_iterative(vec![persona]);
    iter_prof.name = "abl_iter_prof".into();
    iter_prof.use_profiling = true;
    let iter_prof_c = run_campaign(&suite, None, &iter_prof);
    rows.push(summarize(
        "iterative + analysis agent (5 iters)",
        &iter_prof_c.results.iter().map(|r| r.outcome).collect::<Vec<_>>(),
    ));

    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(l, f0, f1, f15)| {
            vec![
                l.clone(),
                format!("{f0:.3}"),
                format!("{f1:.3}"),
                format!("{f15:.3}"),
            ]
        })
        .collect();
    let text = render::table(
        "Ablation: synthesis strategies at comparable budget (gpt-5, CUDA)",
        &["strategy", "fast_0", "fast_1", "fast_1.5"],
        &table_rows,
    );
    (Ablation { rows }, text)
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("ablation", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_ordering() {
        let (a, text) = run(Scale::Quick(8));
        assert!(text.contains("Ablation"));
        let get = |label: &str| {
            a.rows
                .iter()
                .find(|(l, _, _, _)| l.starts_with(label))
                .cloned()
                .unwrap()
        };
        let single = get("single-shot");
        let sampled = get("repeated");
        let iter = get("iterative refinement");
        let prof = get("iterative + analysis");
        // more budget -> more correct
        assert!(sampled.1 >= single.1 - 1e-9, "sampling fast0 below single-shot");
        assert!(iter.1 >= single.1 - 1e-9, "iteration fast0 below single-shot");
        // the feedback loop converts budget into *speed* better than
        // feedback-free sampling (the paper's premise for focusing on it)
        assert!(
            iter.3 + prof.3 >= sampled.3 - 1e-9,
            "refinement fast1.5 {} + {} below sampling {}",
            iter.3,
            prof.3,
            sampled.3
        );
    }
}
