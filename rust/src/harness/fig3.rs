//! Figure 3: CUDA — iterative refinement vs iterative refinement +
//! profiling information, measured against torch.compile, for the
//! three top reasoning models.

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::coordinator::{run_campaign, BaselineKind, CampaignResult, ExperimentConfig};
use crate::metrics;
use crate::workloads::Level;

pub struct Fig3 {
    pub thresholds: Vec<f64>,
    /// (persona, level, with_profiling, curve)
    pub series: Vec<(String, Level, bool, Vec<f64>)>,
    pub plain: CampaignResult,
    pub profiled: CampaignResult,
}

pub fn run(scale: Scale) -> (Fig3, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let mut cfg = ExperimentConfig::cuda_iterative(personas.clone());
    cfg.name = "cuda_iter_vs_compile".into();
    cfg.baseline = BaselineKind::TorchCompile;
    let plain = run_campaign(&suite, None, &cfg);
    let mut cfg_prof = cfg.clone();
    cfg_prof.name = "cuda_iter_prof_vs_compile".into();
    cfg_prof.use_profiling = true;
    let profiled = run_campaign(&suite, None, &cfg_prof);

    let thresholds = metrics::standard_thresholds();
    let mut series = Vec::new();
    for persona in &personas {
        for level in Level::ALL {
            for (campaign, with_prof) in [(&plain, false), (&profiled, true)] {
                let outcomes = campaign.outcomes(persona.name, level);
                let curve: Vec<f64> = thresholds
                    .iter()
                    .map(|&p| metrics::fast_p(&outcomes, p))
                    .collect();
                series.push((persona.name.to_string(), level, with_prof, curve));
            }
        }
    }
    let mut text = String::new();
    for level in Level::ALL {
        let level_series: Vec<(String, Vec<f64>)> = series
            .iter()
            .filter(|(_, l, _, _)| *l == level)
            .map(|(n, _, prof, c)| {
                (
                    format!("{n}{}", if *prof { "+prof" } else { "" }),
                    c.clone(),
                )
            })
            .collect();
        text.push_str(&render::curves(
            &format!(
                "Figure 3 ({}): CUDA iter vs iter+profiling, vs torch.compile, fast_p",
                level.name()
            ),
            &thresholds,
            &level_series,
        ));
        text.push('\n');
    }
    (
        Fig3 {
            thresholds,
            series,
            plain,
            profiled,
        },
        text,
    )
}

impl Fig3 {
    pub fn value(&self, persona: &str, level: Level, with_prof: bool, p: f64) -> f64 {
        let idx = self.thresholds.iter().position(|&t| (t - p).abs() < 1e-9).unwrap();
        self.series
            .iter()
            .find(|(n, l, pr, _)| n == persona && *l == level && *pr == with_prof)
            .map(|(_, _, _, c)| c[idx])
            .unwrap()
    }
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("fig3", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_helps_gpt5_quick() {
        let (fig, text) = run(Scale::Quick(10));
        assert!(text.contains("Figure 3"));
        // paper: profiling info is most consistently helpful for gpt-5;
        // aggregate over levels at fast_1.0
        let mut plain_sum = 0.0;
        let mut prof_sum = 0.0;
        for level in Level::ALL {
            plain_sum += fig.value("openai-gpt-5", level, false, 1.0);
            prof_sum += fig.value("openai-gpt-5", level, true, 1.0);
        }
        assert!(
            prof_sum >= plain_sum - 0.11,
            "profiling should not hurt gpt-5 materially: {prof_sum} vs {plain_sum}"
        );
    }
}
