//! Table 6: execution time (ms) across batch sizes for the three
//! Table-6 architectures — PyTorch Eager vs torch.compile vs the
//! autotuned-search baseline vs KForge.
//!
//! The §7.1 case study: at small batch KForge's launch-lean programs
//! win; at large batch torch.compile's graph planning wins.  The
//! "Autotuned Search" arm is the best-effort non-agent comparator:
//! the beam autotuner retunes each batch's own graph, so the agent
//! rows are read against real search, not just naive/stock baselines.

use super::render;
use crate::agents::persona::by_name;
use crate::agents::GenerationAgent;
use crate::baseline::{autotuned, compilebase, eager};
use crate::platform::cuda;
use crate::util::rng::Pcg;
use crate::verify;
use crate::workloads::level3;
use crate::workloads::spec::{Level, Problem};

pub const BATCHES: [usize; 5] = [8, 16, 32, 64, 128];

pub struct Table6 {
    /// (method, workload, [ms per batch])
    pub rows: Vec<(String, String, [f64; 5])>,
}

fn problem_for(name: &str, ctor: fn(usize) -> crate::kir::Graph, batch: usize) -> Problem {
    Problem {
        id: format!("table6_{name}_b{batch}"),
        level: Level::L3,
        // table 6 uses perf-scale pricing only; eval graph small
        eval_graph: ctor(1),
        perf_graph: ctor(batch),
        op_families: vec![],
        constant_output: false,
        reducible: false,
    }
}

/// The batch size the programs are synthesized at (the paper evaluates
/// whether programs "generalize beyond their training shapes" — §7.1).
pub const GEN_BATCH: usize = 16;

/// Synthesize the best KForge program at GEN_BATCH with the gpt-5
/// persona (the §7.1 case study uses gpt-5-synthesized programs) and
/// return its schedule.
fn synthesize_best(name: &str, ctor: fn(usize) -> crate::kir::Graph, rng: &mut Pcg) -> crate::sched::Schedule {
    let spec = cuda::h100();
    let persona = by_name("openai-gpt-5").unwrap();
    let agent =
        GenerationAgent::new(persona, crate::platform::by_name("cuda").expect("builtin cuda"));
    let problem = problem_for(name, ctor, GEN_BATCH);
    let mut best: Option<(f64, crate::sched::Schedule)> = None;
    let mut current = None;
    let mut last_error: Option<String> = None;
    for _ in 0..5 {
        let cand = match (&current, &last_error) {
            (None, _) => agent.synthesize(&problem, None, rng),
            (Some(prev), Some(err)) => agent.refine(&problem, prev, Some(err.as_str()), None, rng),
            (Some(prev), None) => agent.refine(&problem, prev, None, None, rng),
        };
        let out = verify::verify(&spec, &problem, cand.as_ref(), rng);
        match out.state {
            crate::verify::ExecState::Correct => {
                let t = out.sim.unwrap().measured_s;
                if best.as_ref().map(|(b, _)| t < *b).unwrap_or(true) {
                    best = Some((t, cand.as_ref().unwrap().schedule.clone()));
                }
                last_error = None;
                current = cand;
            }
            ref f => {
                last_error = f.error_text().map(String::from);
                if cand.is_some() {
                    current = cand;
                }
            }
        }
    }
    best.map(|(_, s)| s).unwrap_or_else(crate::sched::Schedule::naive)
}

/// Price the synthesized program at a different batch size.  The
/// generated kernels carry a *fixed grid* sized for GEN_BATCH (the
/// paper's "robust to shape variation" question): at larger batches
/// each thread loops over proportionally more elements, drifting the
/// schedule off its sweet spot — the mechanism behind the paper's
/// large-batch degradation where torch.compile's shape-generic
/// planning wins (Table 6).
fn kforge_time_at(schedule: &crate::sched::Schedule, name: &str, ctor: fn(usize) -> crate::kir::Graph, batch: usize, rng: &mut Pcg) -> f64 {
    let spec = cuda::h100();
    let problem = problem_for(name, ctor, batch);
    let mut sched = schedule.clone();
    if batch > GEN_BATCH {
        sched.ept = (sched.ept * batch / GEN_BATCH).next_power_of_two().min(128);
    }
    let plan = crate::perfsim::lower::lower(&problem.perf_graph, &sched);
    crate::perfsim::simulate(&spec, &plan, rng, crate::baseline::RUNS, crate::baseline::WARMUP)
        .measured_s
}

pub fn run() -> (Table6, String) {
    let spec = cuda::h100();
    let workloads: [(&str, fn(usize) -> crate::kir::Graph); 3] = [
        ("SqueezeNetFire", level3::squeezenet_fire),
        ("MobileNetV2", level3::mobilenetv2_block),
        ("MinGPT", level3::mingpt_block),
    ];
    let mut rows = Vec::new();
    for method in ["PyTorch Eager", "Torch Compile", "Autotuned Search", "KForge (ours)"] {
        for (wname, ctor) in workloads {
            // one synthesized program per workload, generated at GEN_BATCH
            // the paper reports the best synthesized implementation; run a
            // few independent synthesis campaigns and keep the fastest
            let kforge_sched = if method == "KForge (ours)" {
                let spec6 = cuda::h100();
                let gen_problem = problem_for(wname, ctor, GEN_BATCH);
                let mut best: Option<(f64, crate::sched::Schedule)> = None;
                for restart in 0..3u64 {
                    let mut rng = Pcg::new(
                        0x7AB1E6 ^ restart,
                        crate::util::rng::fnv1a(wname.as_bytes()),
                    );
                    let sched = synthesize_best(wname, ctor, &mut rng);
                    let plan = crate::perfsim::lower::lower(&gen_problem.perf_graph, &sched);
                    let t = crate::perfsim::simulate(&spec6, &plan, &mut rng, 100, 10).measured_s;
                    if best.as_ref().map(|(b, _)| t < *b).unwrap_or(true) {
                        best = Some((t, sched));
                    }
                }
                Some(best.unwrap().1)
            } else {
                None
            };
            let mut ms = [0.0f64; 5];
            for (bi, &batch) in BATCHES.iter().enumerate() {
                let problem = problem_for(wname, ctor, batch);
                let mut rng = Pcg::new(
                    0x7AB1E6,
                    crate::util::rng::fnv1a(problem.id.as_bytes()),
                );
                let secs = match method {
                    "PyTorch Eager" => eager::measure(&problem.perf_graph, &spec, &mut rng).measured_s,
                    "Torch Compile" => {
                        compilebase::measure(&problem.perf_graph, &spec, &mut rng).measured_s
                    }
                    // the best-effort search arm tunes each batch's own
                    // graph (search is shape-aware and cheap), unlike
                    // the synthesized program, which carries its
                    // GEN_BATCH-shaped grid to every batch
                    "Autotuned Search" => {
                        autotuned::measure(&problem.perf_graph, &spec, &mut rng).measured_s
                    }
                    _ => kforge_time_at(kforge_sched.as_ref().unwrap(), wname, ctor, batch, &mut rng),
                };
                ms[bi] = secs * 1e3;
            }
            rows.push((method.to_string(), wname.to_string(), ms));
        }
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(m, w, ms)| {
            let mut row = vec![m.clone(), w.clone()];
            row.extend(ms.iter().map(|v| format!("{v:.3}")));
            row
        })
        .collect();
    let text = render::table(
        "Table 6: execution time (ms) across batch sizes, H100-sim",
        &["Method", "Workload", "b=8", "b=16", "b=32", "b=64", "b=128"],
        &table_rows,
    );
    (Table6 { rows }, text)
}

impl Table6 {
    pub fn time(&self, method: &str, workload: &str, batch: usize) -> f64 {
        let bi = BATCHES.iter().position(|&b| b == batch).unwrap();
        self.rows
            .iter()
            .find(|(m, w, _)| m == method && w == workload)
            .map(|(_, _, ms)| ms[bi])
            .unwrap()
    }
}

/// Stable serialization hook for the conformance golden set.  Table 6
/// always evaluates its three fixed architectures at the paper's batch
/// grid, so the scale knob does not apply.
pub fn artifact(_scale: super::Scale) -> super::Artifact {
    super::Artifact::new("table6", run().1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_batch_kforge_wins_large_batch_compile_wins() {
        let (t, text) = run();
        assert!(text.contains("Table 6"));
        // DESIGN.md shape criterion (v): small-batch crossover.
        // aggregate across the three workloads at batch 8 vs 128
        let works = ["SqueezeNetFire", "MobileNetV2", "MinGPT"];
        let mut kforge_wins_small = 0;
        let mut compile_wins_large = 0;
        for w in works {
            if t.time("KForge (ours)", w, 8) < t.time("Torch Compile", w, 8) {
                kforge_wins_small += 1;
            }
            if t.time("Torch Compile", w, 128) < t.time("KForge (ours)", w, 128) {
                compile_wins_large += 1;
            }
        }
        assert!(kforge_wins_small >= 2, "KForge won only {kforge_wins_small}/3 at batch 8");
        // paper: at large batch torch.compile's graph-level planning wins
        // over the shape-overfitted synthesized programs
        assert!(compile_wins_large >= 2, "compile won only {compile_wins_large}/3 at batch 128");
        // KForge beats eager at its generation batch (it subsumes eager)
        for w in works {
            assert!(
                t.time("KForge (ours)", w, GEN_BATCH) < t.time("PyTorch Eager", w, GEN_BATCH) * 1.2,
                "{w} at generation batch"
            );
        }
        // times grow with batch
        for (_, _, ms) in &t.rows {
            assert!(ms[4] > ms[0]);
        }
        // the search arm never loses to eager: its seeds include the
        // stock (eager) schedule and the noise streams are aligned
        for w in works {
            for &b in &BATCHES {
                assert!(
                    t.time("Autotuned Search", w, b) <= t.time("PyTorch Eager", w, b),
                    "{w} b={b}: search lost to eager"
                );
            }
        }
    }
}
