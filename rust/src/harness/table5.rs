//! Table 5: impact of profiling information — CUDA-Reference vs
//! CUDA-Reference + Prof-Info at fast_1.0 and fast_1.5.
//!
//! The paper reports MPS; we additionally run every registered
//! platform through whatever profiler frontend it actually exposes
//! (nsys CSV on CUDA, scraped Xcode screens on Metal, rocprof
//! chrome-trace JSON on ROCm), so each platform's row is produced from
//! its own tool's artifacts — the frontend column records which.

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::coordinator::{run_campaign, ExperimentConfig};
use crate::metrics;
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::Level;

pub struct Table5 {
    /// (platform, frontend, persona, threshold, ref, ref+prof) — the
    /// last two are per-level fast_p vectors aligned with
    /// [`Level::ALL`], so a new suite tier adds a column.
    pub rows: Vec<(String, String, String, f64, Vec<f64>, Vec<f64>)>,
}

impl Table5 {
    /// Rows for one platform.
    pub fn platform_rows(
        &self,
        platform: &str,
    ) -> Vec<&(String, String, String, f64, Vec<f64>, Vec<f64>)> {
        self.rows.iter().filter(|r| r.0 == platform).collect()
    }
}

pub fn run(scale: Scale) -> (Table5, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let corpus = RefCorpus::build(&suite, scale.corpus_attempts(), 0xC0DE);

    let mut rows = Vec::new();
    for platform in crate::platform::registry().platforms() {
        let frontend = platform.profiler_frontend().name().to_string();

        let mut cfg = ExperimentConfig::iterative(platform.clone(), personas.clone());
        cfg.name = format!("{}_cudaref_table5", platform.name());
        cfg.use_reference = true;
        let with_ref = run_campaign(&suite, Some(&corpus), &cfg);

        let mut cfg_prof = cfg.clone();
        cfg_prof.name = format!("{}_cudaref_prof_table5", platform.name());
        cfg_prof.use_profiling = true;
        let with_prof = run_campaign(&suite, Some(&corpus), &cfg_prof);

        for &threshold in &[1.0, 1.5] {
            for persona in &personas {
                let mut r = vec![0.0; Level::COUNT];
                let mut pr = vec![0.0; Level::COUNT];
                for (i, level) in Level::ALL.iter().enumerate() {
                    r[i] = metrics::fast_p(&with_ref.outcomes(persona.name, *level), threshold);
                    pr[i] = metrics::fast_p(&with_prof.outcomes(persona.name, *level), threshold);
                }
                rows.push((
                    platform.name().to_string(),
                    frontend.clone(),
                    persona.name.to_string(),
                    threshold,
                    r,
                    pr,
                ));
            }
        }
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(plat, fe, n, t, r, p)| {
            let mut row = vec![plat.clone(), fe.clone(), format!("fast_{t}"), n.clone()];
            for arm in [r, p] {
                row.extend(arm.iter().map(|v| format!("{v:.3}")));
            }
            row
        })
        .collect();
    let mut header: Vec<String> =
        ["platform", "frontend", "metric", "Model"].map(String::from).to_vec();
    for arm in ["ref", "prof"] {
        header.extend(Level::ALL.iter().map(|l| format!("{arm} {}", l.tag())));
    }
    let header: Vec<&str> = header.iter().map(String::as_str).collect();
    let text = render::table(
        "Table 5: impact of profiling information per platform/frontend (CUDA-ref vs CUDA-ref+prof)",
        &header,
        &table_rows,
    );
    (Table5 { rows }, text)
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("table5", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_helps_at_fast1_on_l2_l3_quick() {
        let (t, text) = run(Scale::Quick(10));
        assert!(text.contains("Table 5"));
        // paper shape on the MPS block: at fast_1.0, prof info helps on
        // L2/L3 (sum over the three models); at fast_1.5 trends are
        // inconsistent — we only assert the fast_1.0 direction with
        // slack.
        let mut ref_sum = 0.0;
        let mut prof_sum = 0.0;
        for (_, _, _, thr, r, p) in t.platform_rows("metal") {
            if (*thr - 1.0).abs() < 1e-9 {
                ref_sum += r[1] + r[2];
                prof_sum += p[1] + p[2];
            }
        }
        assert!(
            prof_sum >= ref_sum - 0.12,
            "prof {prof_sum} should not trail ref {ref_sum} materially"
        );
    }

    #[test]
    fn every_platform_profiled_through_its_own_frontend() {
        let (t, text) = run(Scale::Quick(4));
        // acceptance: the ROCm rows come from rocprof artifacts, not
        // nsys CSVs — and each platform is labeled with its frontend
        for (platform, frontend) in [("cuda", "nsys"), ("metal", "xcode"), ("rocm", "rocprof")] {
            let rows = t.platform_rows(platform);
            assert!(!rows.is_empty(), "no rows for {platform}");
            assert!(rows.iter().all(|r| r.1 == frontend), "{platform} rows: {rows:?}");
        }
        assert!(text.contains("rocprof"));
    }
}
