//! Table 5: MPS — impact of profiling information.  fast_1.0 and
//! fast_1.5 for CUDA-Reference vs CUDA-Reference + Prof-Info.

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::coordinator::{run_campaign, ExperimentConfig};
use crate::metrics;
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::Level;

pub struct Table5 {
    /// (persona, threshold, [ref L1,L2,L3], [ref+prof L1,L2,L3])
    pub rows: Vec<(String, f64, [f64; 3], [f64; 3])>,
}

pub fn run(scale: Scale) -> (Table5, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let corpus = RefCorpus::build(&suite, scale.corpus_attempts(), 0xC0DE);

    let mut cfg = ExperimentConfig::mps_iterative(personas.clone());
    cfg.name = "mps_cudaref_table5".into();
    cfg.use_reference = true;
    let with_ref = run_campaign(&suite, Some(&corpus), &cfg);

    let mut cfg_prof = cfg.clone();
    cfg_prof.name = "mps_cudaref_prof_table5".into();
    cfg_prof.use_profiling = true;
    let with_prof = run_campaign(&suite, Some(&corpus), &cfg_prof);

    let mut rows = Vec::new();
    for &threshold in &[1.0, 1.5] {
        for persona in &personas {
            let mut r = [0.0; 3];
            let mut pr = [0.0; 3];
            for (i, level) in Level::ALL.iter().enumerate() {
                r[i] = metrics::fast_p(&with_ref.outcomes(persona.name, *level), threshold);
                pr[i] = metrics::fast_p(&with_prof.outcomes(persona.name, *level), threshold);
            }
            rows.push((persona.name.to_string(), threshold, r, pr));
        }
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, t, r, p)| {
            vec![
                format!("fast_{t}"),
                n.clone(),
                format!("{:.3}", r[0]),
                format!("{:.3}", r[1]),
                format!("{:.3}", r[2]),
                format!("{:.3}", p[0]),
                format!("{:.3}", p[1]),
                format!("{:.3}", p[2]),
            ]
        })
        .collect();
    let text = render::table(
        "Table 5: MPS — impact of profiling information (CUDA-ref vs CUDA-ref+prof)",
        &["metric", "Model", "ref L1", "ref L2", "ref L3", "prof L1", "prof L2", "prof L3"],
        &table_rows,
    );
    (Table5 { rows }, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_helps_at_fast1_on_l2_l3_quick() {
        let (t, text) = run(Scale::Quick(10));
        assert!(text.contains("Table 5"));
        // paper shape: at fast_1.0, prof info helps on L2/L3 (sum over
        // the three models); at fast_1.5 trends are inconsistent — we
        // only assert the fast_1.0 direction with slack.
        let mut ref_sum = 0.0;
        let mut prof_sum = 0.0;
        for (_, thr, r, p) in &t.rows {
            if (*thr - 1.0).abs() < 1e-9 {
                ref_sum += r[1] + r[2];
                prof_sum += p[1] + p[2];
            }
        }
        assert!(
            prof_sum >= ref_sum - 0.12,
            "prof {prof_sum} should not trail ref {ref_sum} materially"
        );
    }
}
