//! Figure 2: CUDA program synthesis — iterative refinement against
//! PyTorch eager mode.  fast_p curves for all 8 models × 3 levels.

use super::{render, Scale};
use crate::agents::persona::PERSONAS;
use crate::coordinator::{run_campaign, CampaignResult, ExperimentConfig};
use crate::metrics;
use crate::workloads::Level;

/// Figure-2 data: per (persona, level), the fast_p curve.
pub struct Fig2 {
    pub thresholds: Vec<f64>,
    /// (persona, level, curve values at each threshold)
    pub series: Vec<(String, Level, Vec<f64>)>,
    pub campaign: CampaignResult,
}

pub fn run(scale: Scale) -> (Fig2, String) {
    let suite = scale.suite();
    let cfg = ExperimentConfig::cuda_iterative(PERSONAS.iter().collect());
    let campaign = run_campaign(&suite, None, &cfg);
    let thresholds = metrics::standard_thresholds();
    let mut series = Vec::new();
    for persona in PERSONAS {
        for level in Level::ALL {
            let outcomes = campaign.outcomes(persona.name, level);
            let curve: Vec<f64> = thresholds
                .iter()
                .map(|&p| metrics::fast_p(&outcomes, p))
                .collect();
            series.push((persona.name.to_string(), level, curve));
        }
    }
    let mut text = String::new();
    for level in Level::ALL {
        let level_series: Vec<(String, Vec<f64>)> = series
            .iter()
            .filter(|(_, l, _)| *l == level)
            .map(|(n, _, c)| (n.clone(), c.clone()))
            .collect();
        text.push_str(&render::curves(
            &format!("Figure 2 ({}): CUDA iterative refinement vs Eager, fast_p", level.name()),
            &thresholds,
            &level_series,
        ));
        text.push('\n');
    }
    (
        Fig2 {
            thresholds,
            series,
            campaign,
        },
        text,
    )
}

impl Fig2 {
    pub fn value(&self, persona: &str, level: Level, p: f64) -> f64 {
        let idx = self
            .thresholds
            .iter()
            .position(|&t| (t - p).abs() < 1e-9)
            .expect("threshold on grid");
        self.series
            .iter()
            .find(|(n, l, _)| n == persona && *l == level)
            .map(|(_, _, c)| c[idx])
            .expect("series present")
    }
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("fig2", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_criteria_quick() {
        // Quick scale: 12 problems/level is enough for ordering checks
        let (fig, text) = run(Scale::Quick(12));
        assert!(text.contains("Figure 2"));
        // (i) reasoning beats chat at L3 correctness (fast_0)
        let gpt5 = fig.value("openai-gpt-5", Level::L3, 0.0);
        let gpt4o = fig.value("openai-gpt-4o", Level::L3, 0.0);
        assert!(gpt5 > gpt4o, "gpt5 {gpt5} vs gpt4o {gpt4o}");
        // (ii) curves decay with p
        for (_, _, c) in &fig.series {
            for w in c.windows(2) {
                assert!(w[1] <= w[0] + 1e-9);
            }
        }
        // (iii) gpt-5 correctness high (paper: consistently > 0.9)
        assert!(fig.value("openai-gpt-5", Level::L1, 0.0) >= 0.8);
    }
}
