//! Table 4: MPS single-shot correctness, Baseline vs CUDA-reference.

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::coordinator::{run_campaign, ExperimentConfig};
use crate::metrics;
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::Level;

pub struct Table4 {
    /// (persona, [baseline L1,L2,L3], [cuda-ref L1,L2,L3])
    pub rows: Vec<(String, [f64; 3], [f64; 3])>,
}

pub fn run(scale: Scale) -> (Table4, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let corpus = RefCorpus::build(&suite, scale.corpus_attempts(), 0xC0DE);

    let mut base_cfg = ExperimentConfig::mps_iterative(personas.clone());
    base_cfg.name = "mps_single_shot".into();
    base_cfg.iterations = 1;
    let baseline = run_campaign(&suite, None, &base_cfg);

    let mut ref_cfg = base_cfg.clone();
    ref_cfg.name = "mps_single_shot_cudaref".into();
    ref_cfg.use_reference = true;
    let with_ref = run_campaign(&suite, Some(&corpus), &ref_cfg);

    let mut rows = Vec::new();
    for persona in &personas {
        let mut b = [0.0; 3];
        let mut r = [0.0; 3];
        for (i, level) in Level::ALL.iter().enumerate() {
            b[i] = metrics::correctness_rate(&baseline.outcomes(persona.name, *level));
            r[i] = metrics::correctness_rate(&with_ref.outcomes(persona.name, *level));
        }
        rows.push((persona.name.to_string(), b, r));
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, b, r)| {
            vec![
                n.clone(),
                format!("{:.2}", b[0]),
                format!("{:.2}", b[1]),
                format!("{:.2}", b[2]),
                format!("{:.2}", r[0]),
                format!("{:.2}", r[1]),
                format!("{:.2}", r[2]),
            ]
        })
        .collect();
    let text = render::table(
        "Table 4: MPS single-shot correctness — Baseline vs CUDA reference",
        &["Model", "base L1", "base L2", "base L3", "ref L1", "ref L2", "ref L3"],
        &table_rows,
    );
    (Table4 { rows }, text)
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("table4", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_direction_matches_paper_quick() {
        let (t, text) = run(Scale::Quick(12));
        assert!(text.contains("Table 4"));
        let get = |name: &str| t.rows.iter().find(|(n, _, _)| n == name).unwrap();
        // (iii) DESIGN.md shape criterion: reference raises correctness
        // for claude (everywhere) and lowers it for o3 (directionally;
        // small samples get slack)
        let (_, ob, or) = get("claude-opus-4");
        let opus_base: f64 = ob.iter().sum();
        let opus_ref: f64 = or.iter().sum();
        assert!(opus_ref > opus_base, "opus: {opus_ref} vs {opus_base}");
        let (_, b3, r3) = get("openai-o3");
        let o3_base: f64 = b3.iter().sum();
        let o3_ref: f64 = r3.iter().sum();
        assert!(o3_ref < o3_base + 0.15, "o3: {o3_ref} vs {o3_base}");
    }
}
