//! Table 4: MPS single-shot correctness — Baseline vs CUDA-reference
//! vs autotuned-search reference.
//!
//! The third arm is the search subsystem's contribution to the §6.2
//! transfer question: instead of a model-synthesized CUDA program, the
//! reference is a defect-free program carrying the schedule the beam
//! autotuner found for the problem on CUDA — so the table compares
//! "no reference" vs "agent-found reference" vs "best-effort-search
//! reference" under identical RNG streams per arm.

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::agents::Program;
use crate::coordinator::{run_campaign, ExperimentConfig};
use crate::metrics;
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::{Level, Suite};

pub struct Table4 {
    /// (persona, baseline, cuda-ref, autotuned-ref) — each arm is a
    /// per-level correctness vector aligned with [`Level::ALL`], so a
    /// new suite tier adds a column instead of panicking an index.
    pub rows: Vec<(String, Vec<f64>, Vec<f64>, Vec<f64>)>,
}

/// The autotuned reference corpus: per problem, a clean program whose
/// schedule the beam autotuner found on the CUDA spec (the corpus
/// language of §6.2), deterministic with full coverage — search cannot
/// fail to produce a reference the way synthesis can.
fn autotuned_corpus(suite: &Suite) -> RefCorpus {
    let cuda = crate::platform::cuda::h100();
    let mut programs = std::collections::HashMap::new();
    for problem in suite.problems.iter() {
        let schedule = crate::baseline::autotuned::schedule_for(&problem.perf_graph, &cuda);
        programs.insert(
            problem.id.clone(),
            Program::with_schedule(problem.eval_graph.clone(), schedule),
        );
    }
    RefCorpus { programs }
}

pub fn run(scale: Scale) -> (Table4, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let corpus = RefCorpus::build(&suite, scale.corpus_attempts(), 0xC0DE);
    let auto_corpus = autotuned_corpus(&suite);

    let mut base_cfg = ExperimentConfig::mps_iterative(personas.clone());
    base_cfg.name = "mps_single_shot".into();
    base_cfg.iterations = 1;
    let baseline = run_campaign(&suite, None, &base_cfg);

    let mut ref_cfg = base_cfg.clone();
    ref_cfg.name = "mps_single_shot_cudaref".into();
    ref_cfg.use_reference = true;
    let with_ref = run_campaign(&suite, Some(&corpus), &ref_cfg);

    let mut auto_cfg = base_cfg.clone();
    auto_cfg.name = "mps_single_shot_autoref".into();
    auto_cfg.use_reference = true;
    let with_auto = run_campaign(&suite, Some(&auto_corpus), &auto_cfg);

    let mut rows = Vec::new();
    for persona in &personas {
        let mut b = vec![0.0; Level::COUNT];
        let mut r = vec![0.0; Level::COUNT];
        let mut a = vec![0.0; Level::COUNT];
        for (i, level) in Level::ALL.iter().enumerate() {
            b[i] = metrics::correctness_rate(&baseline.outcomes(persona.name, *level));
            r[i] = metrics::correctness_rate(&with_ref.outcomes(persona.name, *level));
            a[i] = metrics::correctness_rate(&with_auto.outcomes(persona.name, *level));
        }
        rows.push((persona.name.to_string(), b, r, a));
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, b, r, a)| {
            let mut row = vec![n.clone()];
            for arm in [b, r, a] {
                row.extend(arm.iter().map(|v| format!("{v:.2}")));
            }
            row
        })
        .collect();
    let mut header: Vec<String> = vec!["Model".into()];
    for arm in ["base", "ref", "auto"] {
        header.extend(Level::ALL.iter().map(|l| format!("{arm} {}", l.tag())));
    }
    let header: Vec<&str> = header.iter().map(String::as_str).collect();
    let text = render::table(
        "Table 4: MPS single-shot correctness — Baseline vs CUDA reference vs autotuned reference",
        &header,
        &table_rows,
    );
    (Table4 { rows }, text)
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("table4", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_direction_matches_paper_quick() {
        let (t, text) = run(Scale::Quick(12));
        assert!(text.contains("Table 4"));
        assert!(text.contains("auto L1"));
        // the level registry drives the columns: the whole-model tier
        // appears in every arm
        assert!(text.contains("base L4") && text.contains("auto L4"));
        let get = |name: &str| t.rows.iter().find(|(n, _, _, _)| n == name).unwrap();
        // (iii) DESIGN.md shape criterion: reference raises correctness
        // for claude (everywhere) and lowers it for o3 (directionally;
        // small samples get slack)
        let (_, ob, or, _) = get("claude-opus-4");
        let opus_base: f64 = ob.iter().sum();
        let opus_ref: f64 = or.iter().sum();
        assert!(opus_ref > opus_base, "opus: {opus_ref} vs {opus_base}");
        let (_, b3, r3, _) = get("openai-o3");
        let o3_base: f64 = b3.iter().sum();
        let o3_ref: f64 = r3.iter().sum();
        assert!(o3_ref < o3_base + 0.15, "o3: {o3_ref} vs {o3_base}");
    }

    #[test]
    fn autotuned_reference_arm_has_full_coverage_and_sane_rates() {
        let suite = Scale::Quick(6).suite();
        let corpus = autotuned_corpus(&suite);
        // search never fails to produce a reference (unlike synthesis)
        assert_eq!(corpus.coverage(&suite), 1.0);
        for (id, prog) in &corpus.programs {
            assert!(prog.defects.is_empty(), "{id}: reference carries defects");
        }
        let (t, _) = run(Scale::Quick(6));
        for (name, _, _, a) in &t.rows {
            for (i, v) in a.iter().enumerate() {
                assert!((0.0..=1.0).contains(v), "{name} auto L{}: {v}", i + 1);
            }
        }
    }
}
