//! Rendering helpers shared by the harness: fixed-width tables and
//! ASCII fast_p curves in the paper's row/series format.

/// Render a fixed-width table.
pub fn table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("== {title} ==\n");
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Render a fast_p curve family: one series per (label), thresholds as
/// columns — the textual equivalent of the paper's figures.
pub fn curves(title: &str, thresholds: &[f64], series: &[(String, Vec<f64>)]) -> String {
    let mut header: Vec<String> = vec!["series".into()];
    header.extend(thresholds.iter().map(|p| format!("p={p}")));
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(label, ys)| {
            let mut row = vec![label.clone()];
            row.extend(ys.iter().map(|y| format!("{y:.3}")));
            row
        })
        .collect();
    table(
        title,
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            "T",
            &["a", "long-header"],
            &[vec!["x".into(), "1".into()], vec!["yyyy".into(), "2".into()]],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("long-header"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn curves_format() {
        let c = curves(
            "F",
            &[0.0, 1.0],
            &[("m1".into(), vec![0.9, 0.5])],
        );
        assert!(c.contains("p=1"));
        assert!(c.contains("0.500"));
    }
}
