//! The benchmark harness: one module per paper table/figure.
//!
//! Every entry point regenerates its artifact end-to-end (campaigns →
//! metrics → rendered rows) and returns both the rendered text and the
//! underlying numbers, so tests can assert the *shape* criteria from
//! DESIGN.md §4 (who wins, by roughly what factor, where crossovers
//! fall) without chasing absolute values.
//!
//! Campaigns inside these modules go through `run_campaign`, which
//! consults the process-wide result store (`crate::store`): under the
//! CLI every artifact module shares one store, so jobs overlapping
//! between artifacts (or between `kforge bench` and `kforge
//! conformance` against a `--cache-dir`) are computed exactly once.
//! Cached substitution cannot change rendered bytes — stored results
//! are bit-exact copies of computed ones.

pub mod render;
pub mod table2;
pub mod fig2;
pub mod fig3;
pub mod table4;
pub mod fig4;
pub mod table5;
pub mod table6;
pub mod casestudy;
pub mod ablation;

/// Scale knob for harness runs: `Full` reproduces the paper's set;
/// `Quick(n)` uses n problems per level (CI / smoke runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Full,
    Quick(usize),
}

impl Scale {
    pub fn suite(&self) -> crate::workloads::Suite {
        match self {
            Scale::Full => crate::workloads::Suite::full(),
            Scale::Quick(n) => crate::workloads::Suite::sample(*n),
        }
    }

    /// Reference-corpus attempts per problem.
    pub fn corpus_attempts(&self) -> usize {
        match self {
            Scale::Full => 8,
            Scale::Quick(_) => 4,
        }
    }
}

/// A rendered paper artifact with a stable name — the serialization
/// hook the conformance golden set consumes.  Every harness module
/// exposes `artifact(scale)` returning one of these; the rendered text
/// is byte-deterministic for a fixed scale (seeded RNG streams, ordered
/// registries, fixed-precision formatting), which is what makes golden
/// diffing possible at all.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub text: String,
}

impl Artifact {
    pub fn new(name: impl Into<String>, text: String) -> Artifact {
        Artifact {
            name: name.into(),
            text,
        }
    }
}

/// Every paper artifact at one scale, in a stable order.  (The
/// conformance subsystem appends its per-platform census artifacts on
/// top of these — see `crate::conformance::render_all`.)
pub fn artifacts(scale: Scale) -> Vec<Artifact> {
    vec![
        table2::artifact(scale),
        fig2::artifact(scale),
        fig3::artifact(scale),
        table4::artifact(scale),
        fig4::artifact(scale),
        table5::artifact(scale),
        table6::artifact(scale),
        casestudy::artifact(scale),
        ablation::artifact(scale),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn artifact_names_are_stable_and_unique() {
        // names only — rendering is covered by the conformance tests
        let names = [
            "table2", "fig2", "fig3", "table4", "fig4", "table5", "table6", "cases", "ablation",
        ];
        let mut sorted = names.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len());
    }
}
