//! The benchmark harness: one module per paper table/figure.
//!
//! Every entry point regenerates its artifact end-to-end (campaigns →
//! metrics → rendered rows) and returns both the rendered text and the
//! underlying numbers, so tests can assert the *shape* criteria from
//! DESIGN.md §4 (who wins, by roughly what factor, where crossovers
//! fall) without chasing absolute values.

pub mod render;
pub mod table2;
pub mod fig2;
pub mod fig3;
pub mod table4;
pub mod fig4;
pub mod table5;
pub mod table6;
pub mod casestudy;
pub mod ablation;

/// Scale knob for harness runs: `Full` reproduces the paper's set;
/// `Quick(n)` uses n problems per level (CI / smoke runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Full,
    Quick(usize),
}

impl Scale {
    pub fn suite(&self) -> crate::workloads::Suite {
        match self {
            Scale::Full => crate::workloads::Suite::full(),
            Scale::Quick(n) => crate::workloads::Suite::sample(*n),
        }
    }

    /// Reference-corpus attempts per problem.
    pub fn corpus_attempts(&self) -> usize {
        match self {
            Scale::Full => 8,
            Scale::Quick(_) => 4,
        }
    }
}
