//! Table 2: problem distribution per registered platform.
//!
//! The paper reports the full KernelBench suite and the Metal subset
//! (MPS-unsupported ops excluded).  With the open platform API the
//! census is registry-driven: one row per registered platform (each
//! applying its own unsupported-op list) plus the unfiltered suite.

use super::render;
use crate::platform::registry;
use crate::workloads::Suite;

/// Table-2 data: (benchmark, l1, l2, l3).
pub struct Table2 {
    pub rows: Vec<(String, usize, usize, usize)>,
}

impl Table2 {
    /// Look up a row by benchmark name.
    pub fn row(&self, benchmark: &str) -> Option<(usize, usize, usize)> {
        self.rows
            .iter()
            .find(|(n, _, _, _)| n == benchmark)
            .map(|(_, a, b, c)| (*a, *b, *c))
    }
}

pub fn run() -> (Table2, String) {
    let full = Suite::full();
    let mut rows = Vec::new();
    for platform in registry().platforms() {
        let filtered = full.supported_on(platform.spec());
        let (l1, l2, l3) = filtered.distribution();
        rows.push((format!("KernelBench-{}", platform.language()), l1, l2, l3));
    }
    let (f1, f2, f3) = full.distribution();
    rows.push(("KernelBench".into(), f1, f2, f3));
    let data = Table2 { rows };
    let rows: Vec<Vec<String>> = data
        .rows
        .iter()
        .map(|(n, a, b, c)| vec![n.clone(), a.to_string(), b.to_string(), c.to_string()])
        .collect();
    let text = render::table(
        "Table 2: problem distribution (each platform excludes its unsupported ops)",
        &["Benchmark", "Level 1", "Level 2", "Level 3"],
        &rows,
    );
    (data, text)
}

/// Stable serialization hook for the conformance golden set.  The
/// census is scale-independent: it always reports the full suite.
pub fn artifact(_scale: super::Scale) -> super::Artifact {
    super::Artifact::new("table2", run().1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn matches_paper_counts() {
        let (data, text) = super::run();
        // the paper's pair, by name (no positional coupling)
        assert_eq!(data.row("KernelBench-Metal"), Some((91, 79, 50)));
        assert_eq!(data.row("KernelBench"), Some((100, 100, 50)));
        // CUDA supports the full suite
        assert_eq!(data.row("KernelBench-CUDA"), Some((100, 100, 50)));
        assert!(text.contains("91"));
    }

    #[test]
    fn one_row_per_registered_platform_plus_full() {
        let (data, text) = super::run();
        let n_platforms = crate::platform::registry().len();
        assert_eq!(data.rows.len(), n_platforms + 1);
        assert!(n_platforms >= 3);
        assert!(text.contains("KernelBench-HIP"));
    }

    #[test]
    fn rocm_census_applies_its_own_exclusions() {
        // rocm excludes only the transposed-3D-conv family; compute the
        // expectation from the suite itself rather than hardcoding
        let (data, _) = super::run();
        let full = crate::workloads::Suite::full();
        let excluded = full
            .problems
            .iter()
            .filter(|p| p.op_families.contains(&"conv3d_transpose"))
            .count();
        assert!(excluded > 0);
        let (l1, l2, l3) = data.row("KernelBench-HIP").unwrap();
        assert_eq!(l1 + l2 + l3, full.len() - excluded);
    }
}
