//! Table 2: problem distribution per registered platform.
//!
//! The paper reports the full KernelBench suite and the Metal subset
//! (MPS-unsupported ops excluded).  With the open platform API the
//! census is registry-driven: one row per registered platform (each
//! applying its own unsupported-op list) plus the unfiltered suite.
//! Columns are level-registry-driven ([`Level::ALL`]), so a new tier
//! (like the level-4 whole-model workloads) appears without an edit.

use super::render;
use crate::platform::registry;
use crate::workloads::{Level, Suite};

/// Table-2 data: per benchmark, the per-level counts aligned with
/// [`Level::ALL`].
pub struct Table2 {
    pub rows: Vec<(String, Vec<usize>)>,
}

impl Table2 {
    /// Look up a row by benchmark name.
    pub fn row(&self, benchmark: &str) -> Option<&[usize]> {
        self.rows
            .iter()
            .find(|(n, _)| n == benchmark)
            .map(|(_, counts)| counts.as_slice())
    }
}

pub fn run() -> (Table2, String) {
    let full = Suite::full();
    let mut rows = Vec::new();
    for platform in registry().platforms() {
        let filtered = full.supported_on(platform.spec());
        rows.push((
            format!("KernelBench-{}", platform.language()),
            filtered.distribution(),
        ));
    }
    rows.push(("KernelBench".into(), full.distribution()));
    let data = Table2 { rows };
    let rows: Vec<Vec<String>> = data
        .rows
        .iter()
        .map(|(n, counts)| {
            let mut row = vec![n.clone()];
            row.extend(counts.iter().map(|c| c.to_string()));
            row
        })
        .collect();
    let headers: Vec<&'static str> = std::iter::once("Benchmark")
        .chain(Level::ALL.iter().map(|l| l.name()))
        .collect();
    let text = render::table(
        "Table 2: problem distribution (each platform excludes its unsupported ops)",
        &headers,
        &rows,
    );
    (data, text)
}

/// Stable serialization hook for the conformance golden set.  The
/// census is scale-independent: it always reports the full suite.
pub fn artifact(_scale: super::Scale) -> super::Artifact {
    super::Artifact::new("table2", run().1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn matches_paper_counts() {
        let (data, text) = super::run();
        // the paper's pair, by name (no positional coupling); the
        // level-4 whole-model tier rides along as the fourth column
        assert_eq!(data.row("KernelBench-Metal"), Some(&[91, 79, 50, 8][..]));
        assert_eq!(data.row("KernelBench"), Some(&[100, 100, 50, 8][..]));
        // CUDA supports the full suite
        assert_eq!(data.row("KernelBench-CUDA"), Some(&[100, 100, 50, 8][..]));
        assert!(text.contains("91"));
        assert!(text.contains("Level 4"));
    }

    #[test]
    fn one_row_per_registered_platform_plus_full() {
        let (data, text) = super::run();
        let n_platforms = crate::platform::registry().len();
        assert_eq!(data.rows.len(), n_platforms + 1);
        assert!(n_platforms >= 3);
        assert!(text.contains("KernelBench-HIP"));
    }

    #[test]
    fn rocm_census_applies_its_own_exclusions() {
        // rocm excludes only the transposed-3D-conv family; compute the
        // expectation from the suite itself rather than hardcoding
        let (data, _) = super::run();
        let full = crate::workloads::Suite::full();
        let excluded = full
            .problems
            .iter()
            .filter(|p| p.op_families.contains(&"conv3d_transpose"))
            .count();
        assert!(excluded > 0);
        let counts = data.row("KernelBench-HIP").unwrap();
        assert_eq!(counts.iter().sum::<usize>(), full.len() - excluded);
    }
}
