//! Table 2: problem distribution for Metal experiments.

use super::render;
use crate::platform::metal;
use crate::workloads::Suite;

/// Table-2 data: (benchmark, l1, l2, l3).
pub struct Table2 {
    pub rows: Vec<(String, usize, usize, usize)>,
}

pub fn run() -> (Table2, String) {
    let full = Suite::full();
    let m = full.supported_on(&metal::m4_max());
    let (f1, f2, f3) = full.distribution();
    let (m1, m2, m3) = m.distribution();
    let data = Table2 {
        rows: vec![
            ("KernelBench-Metal".into(), m1, m2, m3),
            ("KernelBench".into(), f1, f2, f3),
        ],
    };
    let rows: Vec<Vec<String>> = data
        .rows
        .iter()
        .map(|(n, a, b, c)| vec![n.clone(), a.to_string(), b.to_string(), c.to_string()])
        .collect();
    let text = render::table(
        "Table 2: problem distribution (Metal excludes MPS-unsupported ops)",
        &["Benchmark", "Level 1", "Level 2", "Level 3"],
        &rows,
    );
    (data, text)
}

#[cfg(test)]
mod tests {
    #[test]
    fn matches_paper_counts() {
        let (data, text) = super::run();
        assert_eq!(data.rows[0], ("KernelBench-Metal".to_string(), 91, 79, 50));
        assert_eq!(data.rows[1], ("KernelBench".to_string(), 100, 100, 50));
        assert!(text.contains("91"));
    }
}
