//! §7 case studies:
//! - §7.2 Swish: the elements-per-thread + fast-math schedule vs naive
//!   (the paper reports a 5× Metal speedup);
//! - §7.3 invariance exploitation: constant-output problems collapse
//!   to a cached constant (~1% of L1+L2);
//! - §7.4 computational-graph reduction: the matmul→matvec collapse.

use super::render;
use crate::baseline::eager;
use crate::kir::rewrite::{algebraic, constant_fold, cse};
use crate::perfsim::{lower, simulate};
use crate::platform::metal;
use crate::sched::Schedule;
use crate::util::rng::Pcg;
use crate::workloads::Suite;

pub struct CaseStudies {
    /// §7.2: speedup of the ept8+fastmath swish over eager on Metal-sim.
    pub swish_speedup: f64,
    /// §7.3: number + fraction of constant-output problems in L1+L2.
    pub constant_count: usize,
    pub constant_fraction: f64,
    /// §7.3: speedup from constant-collapse on the GemmMaxSubtractGELU.
    pub constant_speedup: f64,
    /// §7.4: speedup from the algebraic reduction on problem 12.
    pub reduction_speedup: f64,
}

pub fn run() -> (CaseStudies, String) {
    let suite = Suite::full();
    let spec = metal::m4_max();
    let mut rng = Pcg::seed(0xCA5E);

    // §7.2 — swish: naive (stock eager) vs tuned schedule
    let swish = suite.get("l1_act_swish_0").expect("swish problem");
    let eager_sim = eager::measure(&swish.perf_graph, &spec, &mut rng);
    let tuned = Schedule::expert_for(&spec);
    let plan = lower::lower(&swish.perf_graph, &tuned);
    let tuned_sim = simulate(&spec, &plan, &mut rng, 100, 10);
    let swish_speedup = eager_sim.measured_s / tuned_sim.measured_s;

    // §7.3 — constant-output census + speedup
    let l12: Vec<_> = suite
        .problems
        .iter()
        .filter(|p| p.level != crate::workloads::Level::L3)
        .collect();
    let constant_count = l12
        .iter()
        .filter(|p| constant_fold::output_is_constant(&p.eval_graph))
        .count();
    let constant_fraction = constant_count as f64 / l12.len() as f64;
    let gmsg = suite.get("l2_080_gemm_max_sub_gelu").unwrap();
    let base = eager::measure(&gmsg.perf_graph, &spec, &mut rng);
    let folded = constant_fold::fold(&gmsg.perf_graph);
    let folded_sim = simulate(
        &spec,
        &lower::lower(&folded, &Schedule::naive()),
        &mut rng,
        100,
        10,
    );
    let constant_speedup = base.measured_s / folded_sim.measured_s;

    // §7.4 — algebraic reduction speedup
    let p12 = suite.get("l2_012_reduction_chain").unwrap();
    let base12 = eager::measure(&p12.perf_graph, &spec, &mut rng);
    let reduced = algebraic::reduce_matmul_chains(&cse::eliminate(&p12.perf_graph));
    let red_sched = Schedule::expert_for(&spec);
    let red_sim = simulate(
        &spec,
        &lower::lower(&reduced, &red_sched),
        &mut rng,
        100,
        10,
    );
    let reduction_speedup = base12.measured_s / red_sim.measured_s;

    let data = CaseStudies {
        swish_speedup,
        constant_count,
        constant_fraction,
        constant_speedup,
        reduction_speedup,
    };
    let rows = vec![
        vec![
            "§7.2 Swish ept=8 + fast-math (Metal-sim)".to_string(),
            format!("{swish_speedup:.2}x vs eager"),
        ],
        vec![
            "§7.3 constant-output problems in L1+L2".to_string(),
            format!("{constant_count} ({:.1}%)", 100.0 * data.constant_fraction),
        ],
        vec![
            "§7.3 GemmMaxSubtractGELU constant collapse".to_string(),
            format!("{constant_speedup:.1}x vs eager"),
        ],
        vec![
            "§7.4 problem-12 matmul→matvec reduction".to_string(),
            format!("{reduction_speedup:.1}x vs eager"),
        ],
    ];
    let text = render::table("Case studies (§7)", &["case", "result"], &rows);
    (data, text)
}

/// Stable serialization hook for the conformance golden set.  The case
/// studies run at their fixed paper shapes regardless of scale.
pub fn artifact(_scale: super::Scale) -> super::Artifact {
    super::Artifact::new("cases", run().1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn case_study_shapes() {
        let (c, text) = super::run();
        assert!(text.contains("§7.2"));
        // paper: 5x swish speedup — accept the ballpark (>2.5x) on sim
        assert!(c.swish_speedup > 2.5, "swish speedup {}", c.swish_speedup);
        // ~1% of L1+L2 are constant-output
        assert_eq!(c.constant_count, 2);
        assert!((c.constant_fraction - 0.01).abs() < 0.005);
        // constant collapse is a huge win; reduction is a big win
        assert!(c.constant_speedup > 10.0, "{}", c.constant_speedup);
        assert!(c.reduction_speedup > 3.0, "{}", c.reduction_speedup);
    }
}
