//! Figure 4: MPS program synthesis — iterative refinement (solid) vs
//! iterative refinement + CUDA reference implementation (dashed).

use super::{render, Scale};
use crate::agents::persona::top_reasoning;
use crate::coordinator::{run_campaign, CampaignResult, ExperimentConfig};
use crate::metrics;
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::Level;

pub struct Fig4 {
    pub thresholds: Vec<f64>,
    /// (persona, level, with_reference, curve)
    pub series: Vec<(String, Level, bool, Vec<f64>)>,
    pub plain: CampaignResult,
    pub with_ref: CampaignResult,
}

pub fn run(scale: Scale) -> (Fig4, String) {
    let suite = scale.suite();
    let personas = top_reasoning();
    let corpus = RefCorpus::build(&suite, scale.corpus_attempts(), 0xC0DE);

    let mut cfg = ExperimentConfig::mps_iterative(personas.clone());
    cfg.name = "mps_iterative_fig4".into();
    let plain = run_campaign(&suite, None, &cfg);
    let mut cfg_ref = cfg.clone();
    cfg_ref.name = "mps_iterative_cudaref_fig4".into();
    cfg_ref.use_reference = true;
    let with_ref = run_campaign(&suite, Some(&corpus), &cfg_ref);

    let thresholds = metrics::standard_thresholds();
    let mut series = Vec::new();
    for persona in &personas {
        for level in Level::ALL {
            for (campaign, has_ref) in [(&plain, false), (&with_ref, true)] {
                let outcomes = campaign.outcomes(persona.name, level);
                let curve: Vec<f64> = thresholds
                    .iter()
                    .map(|&p| metrics::fast_p(&outcomes, p))
                    .collect();
                series.push((persona.name.to_string(), level, has_ref, curve));
            }
        }
    }
    let mut text = String::new();
    for level in Level::ALL {
        let level_series: Vec<(String, Vec<f64>)> = series
            .iter()
            .filter(|(_, l, _, _)| *l == level)
            .map(|(n, _, has_ref, c)| {
                (
                    format!("{n}{}", if *has_ref { "+cudaref" } else { "" }),
                    c.clone(),
                )
            })
            .collect();
        text.push_str(&render::curves(
            &format!(
                "Figure 4 ({}): MPS iter refinement vs +CUDA reference, fast_p vs Eager",
                level.name()
            ),
            &thresholds,
            &level_series,
        ));
        text.push('\n');
    }
    (
        Fig4 {
            thresholds,
            series,
            plain,
            with_ref,
        },
        text,
    )
}

impl Fig4 {
    pub fn value(&self, persona: &str, level: Level, has_ref: bool, p: f64) -> f64 {
        let idx = self.thresholds.iter().position(|&t| (t - p).abs() < 1e-9).unwrap();
        self.series
            .iter()
            .find(|(n, l, r, _)| n == persona && *l == level && *r == has_ref)
            .map(|(_, _, _, c)| c[idx])
            .unwrap()
    }
}

/// Stable serialization hook for the conformance golden set.
pub fn artifact(scale: Scale) -> super::Artifact {
    super::Artifact::new("fig4", run(scale).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_boosts_majority_quick() {
        let (fig, text) = run(Scale::Quick(10));
        assert!(text.contains("Figure 4"));
        // paper: the CUDA reference boosts performance on the majority
        // of fast_p thresholds for claude-opus-4 (the big gainer)
        let mut better = 0;
        let mut total = 0;
        for level in Level::ALL {
            for &p in &[0.0, 0.5, 1.0] {
                total += 1;
                if fig.value("claude-opus-4", level, true, p)
                    >= fig.value("claude-opus-4", level, false, p)
                {
                    better += 1;
                }
            }
        }
        assert!(better * 2 >= total, "reference helped only {better}/{total}");
    }
}
