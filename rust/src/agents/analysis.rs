//! The performance-analysis agent `G : (o, k, {v^i}) → r` (§3.2).
//!
//! The agent consumes **only** the [`Evidence`] IR.  Its platform's
//! [`crate::platform::Platform::profiler_frontend`] turns the raw
//! profile into a tool-native artifact and back into `Evidence`; by the
//! time data reaches this agent, *how* it was captured is gone — only
//! the per-fact fidelity tags remain.  Programmatic frontends (nsys,
//! rocprof) deliver recommendation-grade facts; the Xcode screenshot
//! scrape delivers rounded values, truncated names and missing joins.
//! The agent ranks candidate bottlenecks by estimated impact, emits
//! **one** recommendation, and reports the evidence fidelity as its
//! confidence.
//!
//! Specialization rationale (from the paper): profiling data is
//! extensive but optimization signals are sparse, and retrieval
//! degrades with input length — so a dedicated agent with a narrow
//! contract (one recommendation) replaces feeding raw profiles to the
//! synthesis agent.

use super::recommend::{Advice, Recommendation};
use crate::platform::{LaunchAmortization, PlatformRef};
use crate::profiler::{Evidence, Profile};
use crate::sched::Schedule;

/// The analysis agent.
#[derive(Debug, Clone)]
pub struct AnalysisAgent {
    pub platform: PlatformRef,
}

/// The bottleneck facts the agent extracts from evidence before
/// ranking.
#[derive(Debug, Clone, Copy, Default)]
struct Facts {
    launch_fraction: f64,
    n_kernels: usize,
    hottest_memory_bound: bool,
    hottest_mem_util: f64,
    hottest_mm_util: f64,
    hottest_is_matmul: bool,
    hottest_transcendental: bool,
    min_occupancy: f64,
}

impl AnalysisAgent {
    pub fn new(platform: PlatformRef) -> Self {
        AnalysisAgent { platform }
    }

    /// The full loop step: capture the profile through this platform's
    /// frontend, interpret it into evidence, rank.  An uninterpretable
    /// capture yields `LooksOptimal` at zero confidence — the agent
    /// can't see a bottleneck it can't read (the paper's "profiling
    /// information is not always sufficient" failure mode).
    pub fn advise(&self, profile: &Profile, schedule: &Schedule) -> Advice {
        match self.platform.profiler_frontend().evidence(profile) {
            Ok(ev) => self.advise_from_evidence(&ev, schedule),
            Err(_) => Advice { recommendation: Recommendation::LooksOptimal, confidence: 0.0 },
        }
    }

    /// Like [`AnalysisAgent::advise`], keeping only the recommendation.
    pub fn recommend(&self, profile: &Profile, schedule: &Schedule) -> Recommendation {
        self.advise(profile, schedule).recommendation
    }

    /// Rank already-interpreted evidence (any frontend's).
    pub fn advise_from_evidence(&self, evidence: &Evidence, schedule: &Schedule) -> Advice {
        Advice {
            recommendation: self.rank(self.facts(evidence), schedule),
            confidence: evidence.fidelity_score(),
        }
    }

    fn facts(&self, ev: &Evidence) -> Facts {
        let hottest = ev.hottest();
        let families = ["swish", "sigmoid", "gelu", "tanh", "exp", "softmax", "layernorm"];
        Facts {
            launch_fraction: ev.launch_fraction().or(0.0),
            n_kernels: ev.n_kernels(),
            hottest_memory_bound: hottest
                .and_then(|k| k.compute_bound)
                .map(|b| !b)
                .unwrap_or(false),
            hottest_mem_util: hottest.map(|k| k.mem_utilization.or(1.0)).unwrap_or(1.0),
            hottest_mm_util: hottest.map(|k| k.mm_utilization.or(1.0)).unwrap_or(1.0),
            // truncated names still carry the op-family prefix, so
            // `contains` survives every frontend's name fidelity
            hottest_is_matmul: hottest
                .map(|k| {
                    k.name.contains("matmul") || k.name.contains("conv") || k.name.contains("attention")
                })
                .unwrap_or(false),
            hottest_transcendental: hottest
                .map(|k| families.iter().any(|t| k.name.contains(t)))
                .unwrap_or(false),
            min_occupancy: ev.min_occupancy().or(1.0),
        }
    }

    /// The launch-consolidation advice appropriate to this platform's
    /// amortization mechanism (device graphs vs pipeline-state caching).
    fn launch_recommendation(&self) -> Recommendation {
        match self.platform.spec().launch_amortization {
            LaunchAmortization::DeviceGraphs { .. } => Recommendation::UseCudaGraphs,
            LaunchAmortization::PipelineCache { .. } => Recommendation::CachePipelineState,
        }
    }

    /// Rank bottlenecks by impact; emit the single best recommendation.
    fn rank(&self, f: Facts, schedule: &Schedule) -> Recommendation {
        // launch-bound: the biggest single lever
        if f.launch_fraction > 0.30 {
            if !schedule.use_graphs {
                return self.launch_recommendation();
            }
            if f.n_kernels > 1 && schedule.fusion_depth != usize::MAX {
                return Recommendation::IncreaseFusion;
            }
        }
        if f.hottest_is_matmul && f.hottest_mm_util < 0.55 {
            return Recommendation::RetileMatmul;
        }
        if f.hottest_memory_bound && f.hottest_mem_util < 0.85 && (schedule.vec_width < 4 || schedule.ept < 8) {
            return Recommendation::Vectorize;
        }
        if f.hottest_transcendental && !schedule.fast_math {
            return Recommendation::UseFastMath;
        }
        if f.min_occupancy < 0.45 && schedule.threadgroup != 256 {
            return Recommendation::AdjustThreadgroup;
        }
        if f.launch_fraction > 0.15 && schedule.fusion_depth != usize::MAX {
            return Recommendation::IncreaseFusion;
        }
        Recommendation::LooksOptimal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::perfsim::lower::lower;
    use crate::perfsim::simulate;
    use crate::platform::{by_name, cuda, metal};
    use crate::profiler::nsys::NsysFrontend;
    use crate::profiler::rocprof::RocprofFrontend;
    use crate::profiler::xcode::XcodeFrontend;
    use crate::profiler::{Profile, ProfilerFrontend};
    use crate::tensor::Shape;
    use crate::util::rng::Pcg;

    fn profile_for(fused: bool, dim: usize, spec: &crate::platform::PlatformSpec) -> (Profile, Schedule) {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[dim, dim]));
        let w = b.input(Shape::of(&[dim, dim]));
        let bias = b.input(Shape::of(&[dim]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Swish, a);
        let g = b.finish(vec![r]);
        let mut s = Schedule::naive();
        if fused {
            s.fusion_depth = usize::MAX;
        }
        let plan = lower(&g, &s);
        let mut rng = Pcg::seed(0);
        let sim = simulate(spec, &plan, &mut rng, 10, 2);
        (Profile::from_sim("t", spec.name, &sim), s)
    }

    #[test]
    fn launch_bound_cuda_gets_graphs() {
        let spec = cuda::h100();
        let (p, s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        let rec = agent.recommend(&p, &s);
        assert_eq!(rec, Recommendation::UseCudaGraphs, "profile: {p:?}");
    }

    #[test]
    fn launch_bound_rocm_gets_graphs_via_rocprof() {
        // rocm profiles through its own rocprof trace frontend and
        // amortizes with hipGraph — the evidence path must route it to
        // device graphs without ever branching on the capture format
        let rocm = by_name("rocm").unwrap();
        assert_eq!(rocm.profiler_frontend().name(), "rocprof");
        let spec = rocm.spec().clone();
        let (p, s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(rocm);
        let advice = agent.advise(&p, &s);
        assert_eq!(advice.recommendation, Recommendation::UseCudaGraphs, "profile: {p:?}");
        assert!(advice.confidence > 0.97, "{}", advice.confidence);
    }

    #[test]
    fn launch_bound_metal_gets_pipeline_caching_then_fusion() {
        let spec = metal::m4_max();
        let (p, mut s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(by_name("metal").unwrap());
        let rec = agent.recommend(&p, &s);
        assert_eq!(rec, Recommendation::CachePipelineState);
        // once caching is on, the next advice is fusion
        s.use_graphs = true;
        let rec2 = agent.recommend(&p, &s);
        assert_eq!(rec2, Recommendation::IncreaseFusion);
    }

    #[test]
    fn compute_heavy_naive_tiles_get_retile() {
        let spec = cuda::h100();
        let (p, mut s) = profile_for(true, 2048, &spec);
        s.use_graphs = true; // silence the launch path
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        let rec = agent.recommend(&p, &s);
        assert_eq!(rec, Recommendation::RetileMatmul, "{p:?}");
    }

    #[test]
    fn unreadable_capture_yields_looks_optimal_at_zero_confidence() {
        // a capture the scraper cannot read (no kernel rows survive
        // rendering) must not invent a bottleneck: the agent reports
        // LooksOptimal and zero confidence
        let agent = AnalysisAgent::new(by_name("metal").unwrap());
        let (mut p, s) = profile_for(false, 32, &metal::m4_max());
        p.kernels.clear();
        let advice = agent.advise(&p, &s);
        assert_eq!(advice.recommendation, Recommendation::LooksOptimal);
        assert_eq!(advice.confidence, 0.0);
    }

    #[test]
    fn lossless_frontends_give_identical_recommendations() {
        // acceptance: the two programmatic frontends — different
        // formats, field names and units — produce the same
        // recommendation on the same profile, at comparable confidence
        let spec = cuda::h100();
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        for (dim, fused) in [(32, false), (2048, true), (256, false)] {
            let (p, mut s) = profile_for(fused, dim, &spec);
            if fused {
                s.use_graphs = true;
            }
            let nsys = agent.advise_from_evidence(&NsysFrontend.evidence(&p).unwrap(), &s);
            let rocprof = agent.advise_from_evidence(&RocprofFrontend.evidence(&p).unwrap(), &s);
            assert_eq!(
                nsys.recommendation, rocprof.recommendation,
                "dim={dim} fused={fused}: {p:?}"
            );
            assert!((nsys.confidence - rocprof.confidence).abs() < 0.05);
        }
    }

    #[test]
    fn screenshot_frontend_is_strictly_degraded_but_bottleneck_consistent() {
        // acceptance: on a clear bottleneck the lossy scrape reaches
        // the same recommendation as the lossless frontends, at
        // strictly lower confidence
        let spec = cuda::h100();
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        let (p, s) = profile_for(false, 32, &spec);
        let nsys = agent.advise_from_evidence(&NsysFrontend.evidence(&p).unwrap(), &s);
        let rocprof = agent.advise_from_evidence(&RocprofFrontend.evidence(&p).unwrap(), &s);
        let scraped = agent.advise_from_evidence(&XcodeFrontend.evidence(&p).unwrap(), &s);
        assert_eq!(scraped.recommendation, nsys.recommendation);
        assert!(
            scraped.confidence < nsys.confidence.min(rocprof.confidence),
            "scrape {} should trail nsys {} / rocprof {}",
            scraped.confidence,
            nsys.confidence,
            rocprof.confidence
        );
    }
}
