//! The performance-analysis agent `G : (o, k, {v^i}) → r` (§3.2).
//!
//! On programmatic-CSV platforms (CUDA's nsys, ROCm's rocprof) the
//! inputs are structured, lossless reports; on GUI-only platforms
//! (Metal's Xcode) they are screenshots that must be screen-scraped
//! first (lossy).  The agent ranks candidate bottlenecks by estimated
//! impact and emits **one** recommendation.
//!
//! Specialization rationale (from the paper): profiling data is
//! extensive but optimization signals are sparse, and retrieval
//! degrades with input length — so a dedicated agent with a narrow
//! contract (one recommendation) replaces feeding raw profiles to the
//! synthesis agent.

use super::recommend::Recommendation;
use crate::platform::{LaunchAmortization, PlatformRef, ProfilerAccess};
use crate::profiler::parse::{scrape, ScrapedProfile};
use crate::profiler::Profile;
use crate::sched::Schedule;

/// The analysis agent.
#[derive(Debug, Clone)]
pub struct AnalysisAgent {
    pub platform: PlatformRef,
}

/// The bottleneck facts the agent extracts before ranking.
#[derive(Debug, Clone, Copy, Default)]
struct Facts {
    launch_fraction: f64,
    n_kernels: usize,
    hottest_memory_bound: bool,
    hottest_mem_util: f64,
    hottest_mm_util: f64,
    hottest_is_matmul: bool,
    hottest_transcendental: bool,
    min_occupancy: f64,
}

impl AnalysisAgent {
    pub fn new(platform: PlatformRef) -> Self {
        AnalysisAgent { platform }
    }

    /// Programmatic path (nsys / rocprof): the CSV is lossless, so we
    /// read the typed records directly — equivalent to parsing the
    /// CSVs.
    pub fn recommend_from_profile(&self, profile: &Profile, schedule: &Schedule) -> Recommendation {
        self.rank(self.facts_from_profile(profile), schedule)
    }

    /// GUI path (Xcode): only the rendered screenshots are available;
    /// scrape them (lossy) and work from what survives.  A scrape
    /// failure yields `LooksOptimal` — the agent can't see a bottleneck
    /// it can't read (this is the paper's "profiling information is not
    /// always sufficient" failure mode).
    pub fn recommend_from_screens(&self, screens: &[String], schedule: &Schedule) -> Recommendation {
        match scrape(screens) {
            Ok(s) => self.rank(self.facts_from_scrape(&s), schedule),
            Err(_) => Recommendation::LooksOptimal,
        }
    }

    /// Platform dispatch used by the verification pipeline: pick the
    /// profiler frontend this agent's platform actually exposes.
    pub fn recommend(&self, profile: &Profile, schedule: &Schedule) -> Recommendation {
        match self.platform.spec().profiler {
            ProfilerAccess::ProgrammaticCsv => self.recommend_from_profile(profile, schedule),
            ProfilerAccess::GuiScreenshot => {
                let screens = crate::profiler::xcode::capture_screens(profile);
                self.recommend_from_screens(&screens, schedule)
            }
        }
    }

    fn facts_from_profile(&self, p: &Profile) -> Facts {
        let hottest = p.hottest();
        Facts {
            launch_fraction: p.launch_fraction(),
            n_kernels: p.kernels.len(),
            hottest_memory_bound: hottest.map(|k| !k.compute_bound).unwrap_or(false),
            hottest_mem_util: hottest.map(|k| k.mem_utilization).unwrap_or(1.0),
            hottest_mm_util: hottest.map(|k| k.mm_utilization).unwrap_or(1.0),
            hottest_is_matmul: hottest
                .map(|k| k.name.contains("matmul") || k.name.contains("conv") || k.name.contains("attention"))
                .unwrap_or(false),
            hottest_transcendental: hottest
                .map(|k| {
                    ["swish", "sigmoid", "gelu", "tanh", "exp", "softmax", "layernorm"]
                        .iter()
                        .any(|t| k.name.contains(t))
                })
                .unwrap_or(false),
            min_occupancy: p.kernels.iter().map(|k| k.occupancy).fold(1.0, f64::min),
        }
    }

    fn facts_from_scrape(&self, s: &ScrapedProfile) -> Facts {
        let hottest = s
            .kernels
            .iter()
            .max_by(|a, b| {
                a.time_us
                    .unwrap_or(a.mem_pct)
                    .partial_cmp(&b.time_us.unwrap_or(b.mem_pct))
                    .unwrap()
            });
        Facts {
            launch_fraction: s.encoder_overhead_us / s.gpu_time_us.max(1e-9),
            n_kernels: s.dispatches,
            hottest_memory_bound: hottest.map(|k| !k.limiter_alu).unwrap_or(false),
            hottest_mem_util: hottest.map(|k| k.mem_pct / 100.0).unwrap_or(1.0),
            hottest_mm_util: hottest.map(|k| k.alu_pct / 100.0).unwrap_or(1.0),
            hottest_is_matmul: hottest
                .map(|k| k.name.contains("matmul") || k.name.contains("conv") || k.name.contains("attention"))
                .unwrap_or(false),
            // truncated 20-char names still carry the op family prefix
            hottest_transcendental: hottest
                .map(|k| {
                    ["swish", "sigmoid", "gelu", "tanh", "exp", "softmax", "layernorm"]
                        .iter()
                        .any(|t| k.name.contains(t))
                })
                .unwrap_or(false),
            min_occupancy: s
                .kernels
                .iter()
                .map(|k| k.occupancy_pct / 100.0)
                .fold(1.0, f64::min),
        }
    }

    /// The launch-consolidation advice appropriate to this platform's
    /// amortization mechanism (device graphs vs pipeline-state caching).
    fn launch_recommendation(&self) -> Recommendation {
        match self.platform.spec().launch_amortization {
            LaunchAmortization::DeviceGraphs { .. } => Recommendation::UseCudaGraphs,
            LaunchAmortization::PipelineCache { .. } => Recommendation::CachePipelineState,
        }
    }

    /// Rank bottlenecks by impact; emit the single best recommendation.
    fn rank(&self, f: Facts, schedule: &Schedule) -> Recommendation {
        // launch-bound: the biggest single lever
        if f.launch_fraction > 0.30 {
            if !schedule.use_graphs {
                return self.launch_recommendation();
            }
            if f.n_kernels > 1 && schedule.fusion_depth != usize::MAX {
                return Recommendation::IncreaseFusion;
            }
        }
        if f.hottest_is_matmul && f.hottest_mm_util < 0.55 {
            return Recommendation::RetileMatmul;
        }
        if f.hottest_memory_bound && f.hottest_mem_util < 0.85 && (schedule.vec_width < 4 || schedule.ept < 8) {
            return Recommendation::Vectorize;
        }
        if f.hottest_transcendental && !schedule.fast_math {
            return Recommendation::UseFastMath;
        }
        if f.min_occupancy < 0.45 && schedule.threadgroup != 256 {
            return Recommendation::AdjustThreadgroup;
        }
        if f.launch_fraction > 0.15 && schedule.fusion_depth != usize::MAX {
            return Recommendation::IncreaseFusion;
        }
        Recommendation::LooksOptimal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::perfsim::lower::lower;
    use crate::perfsim::simulate;
    use crate::platform::{by_name, cuda, metal};
    use crate::profiler::Profile;
    use crate::tensor::Shape;
    use crate::util::rng::Pcg;

    fn profile_for(fused: bool, dim: usize, spec: &crate::platform::PlatformSpec) -> (Profile, Schedule) {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[dim, dim]));
        let w = b.input(Shape::of(&[dim, dim]));
        let bias = b.input(Shape::of(&[dim]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Swish, a);
        let g = b.finish(vec![r]);
        let mut s = Schedule::naive();
        if fused {
            s.fusion_depth = usize::MAX;
        }
        let plan = lower(&g, &s);
        let mut rng = Pcg::seed(0);
        let sim = simulate(spec, &plan, &mut rng, 10, 2);
        (Profile::from_sim("t", spec.name, &sim), s)
    }

    #[test]
    fn launch_bound_cuda_gets_graphs() {
        let spec = cuda::h100();
        let (p, s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        let rec = agent.recommend_from_profile(&p, &s);
        assert_eq!(rec, Recommendation::UseCudaGraphs, "profile: {p:?}");
    }

    #[test]
    fn launch_bound_rocm_gets_graphs_via_csv_path() {
        // rocm profiles programmatically (rocprof CSV) and amortizes
        // with hipGraph — the CSV path must route it to device graphs
        let rocm = by_name("rocm").unwrap();
        let spec = rocm.spec().clone();
        let (p, s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(rocm);
        let rec = agent.recommend(&p, &s);
        assert_eq!(rec, Recommendation::UseCudaGraphs, "profile: {p:?}");
    }

    #[test]
    fn launch_bound_metal_gets_pipeline_caching_then_fusion() {
        let spec = metal::m4_max();
        let (p, mut s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(by_name("metal").unwrap());
        let screens = crate::profiler::xcode::capture_screens(&p);
        let rec = agent.recommend_from_screens(&screens, &s);
        assert_eq!(rec, Recommendation::CachePipelineState);
        // once caching is on, the next advice is fusion
        s.use_graphs = true;
        let rec2 = agent.recommend_from_screens(&screens, &s);
        assert_eq!(rec2, Recommendation::IncreaseFusion);
    }

    #[test]
    fn compute_heavy_naive_tiles_get_retile() {
        let spec = cuda::h100();
        let (p, mut s) = profile_for(true, 2048, &spec);
        s.use_graphs = true; // silence the launch path
        let agent = AnalysisAgent::new(by_name("cuda").unwrap());
        let rec = agent.recommend_from_profile(&p, &s);
        assert_eq!(rec, Recommendation::RetileMatmul, "{p:?}");
    }

    #[test]
    fn garbage_screens_yield_looks_optimal() {
        let agent = AnalysisAgent::new(by_name("metal").unwrap());
        let rec =
            agent.recommend_from_screens(&["?".into(), "?".into(), "?".into()], &Schedule::naive());
        assert_eq!(rec, Recommendation::LooksOptimal);
    }

    #[test]
    fn lossless_and_scraped_views_agree_on_clear_bottleneck() {
        // the scrape is lossy but a dominant launch bottleneck survives
        let spec = metal::m4_max();
        let (p, s) = profile_for(false, 32, &spec);
        let agent = AnalysisAgent::new(by_name("metal").unwrap());
        let lossless_view = agent.rank(agent.facts_from_profile(&p), &s);
        let screens = crate::profiler::xcode::capture_screens(&p);
        let scraped_view = agent.recommend_from_screens(&screens, &s);
        assert_eq!(lossless_view, scraped_view);
    }
}
