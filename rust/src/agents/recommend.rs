//! The recommendation taxonomy the performance-analysis agent emits.
//!
//! The agent is prompted to generate "a single recommendation for
//! maximum performance improvement" (§3.2); each recommendation maps
//! onto a schedule lever or graph rewrite the generation agent can act
//! on in the next iteration.

use crate::sched::schedule::Lever;

/// A recommendation plus how much the analysis agent trusts it: the
/// mean fidelity of the [`crate::profiler::Evidence`] it was ranked
/// from.  Lossless programmatic frontends yield confidence near 1;
/// screen-scraped captures are visibly lower; unreadable captures are
/// 0 — the paper's "profiling information is not always sufficient"
/// failure mode, quantified.
#[derive(Debug, Clone, PartialEq)]
pub struct Advice {
    pub recommendation: Recommendation,
    /// Evidence fidelity score ∈ [0, 1].
    pub confidence: f64,
}

/// One actionable optimization recommendation.
#[derive(Debug, Clone, PartialEq)]
pub enum Recommendation {
    /// Consolidate launches with CUDA graphs (launch-bound plans).
    UseCudaGraphs,
    /// Cache pipeline state / command queue across invocations (the
    /// Metal analog of launch consolidation — §7.2's listing).
    CachePipelineState,
    /// Fuse more ops to cut launches and HBM round trips.
    IncreaseFusion,
    /// Retile the matmul/conv kernels (low MM-engine utilization).
    RetileMatmul,
    /// Widen vector loads / raise elements-per-thread (memory-bound).
    Vectorize,
    /// Use fast-math intrinsics for transcendental-heavy kernels.
    UseFastMath,
    /// Adjust threadgroup size (poor occupancy).
    AdjustThreadgroup,
    /// No further opportunity found.
    LooksOptimal,
}

impl Recommendation {
    /// The schedule lever this recommendation targets.
    pub fn lever(&self) -> Option<Lever> {
        match self {
            Recommendation::UseCudaGraphs => Some(Lever::Graphs),
            Recommendation::CachePipelineState => Some(Lever::Graphs),
            Recommendation::IncreaseFusion => Some(Lever::Fusion),
            Recommendation::RetileMatmul => Some(Lever::Tile),
            Recommendation::Vectorize => Some(Lever::Ept),
            Recommendation::UseFastMath => Some(Lever::FastMath),
            Recommendation::AdjustThreadgroup => Some(Lever::Threadgroup),
            Recommendation::LooksOptimal => None,
        }
    }

    /// Natural-language rendering (what `r` looks like in the prompt).
    pub fn text(&self) -> &'static str {
        match self {
            Recommendation::UseCudaGraphs => {
                "Launch overhead dominates this workload: capture the kernel \
                 sequence into a CUDA graph so the per-kernel dispatch cost is \
                 paid once per graph launch."
            }
            Recommendation::CachePipelineState => {
                "Encoder setup dominates this workload: cache the device \
                 handle, pipeline state and command queue in thread-local \
                 storage so repeated invocations skip re-initialization."
            }
            Recommendation::IncreaseFusion => {
                "The timeline shows many short kernels separated by gaps: fuse \
                 the elementwise epilogues into their producing matmul/conv \
                 kernels to remove launches and intermediate memory traffic."
            }
            Recommendation::RetileMatmul => {
                "The matmul kernels underutilize the matrix engine: increase \
                 the output tile (e.g. 128x128 with a 64-deep K slab) so each \
                 threadblock reuses operands from on-chip memory."
            }
            Recommendation::Vectorize => {
                "The hottest kernel is memory-bound with low effective \
                 bandwidth: use vectorized loads and process 8 elements per \
                 thread to amortize per-access overhead."
            }
            Recommendation::UseFastMath => {
                "A large fraction of time is spent in transcendental math: \
                 switch to fast::exp-style intrinsics; the precision trade-off \
                 is acceptable for this workload."
            }
            Recommendation::AdjustThreadgroup => {
                "Occupancy is low: tune the threadgroup size toward 256 \
                 threads based on maxTotalThreadsPerThreadgroup."
            }
            Recommendation::LooksOptimal => {
                "The profile shows no dominant bottleneck; the implementation \
                 is near the achievable roofline."
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levers_cover_actionable_recs() {
        assert_eq!(Recommendation::UseCudaGraphs.lever(), Some(Lever::Graphs));
        assert_eq!(Recommendation::LooksOptimal.lever(), None);
    }

    #[test]
    fn texts_nonempty_and_distinct() {
        let recs = [
            Recommendation::UseCudaGraphs,
            Recommendation::CachePipelineState,
            Recommendation::IncreaseFusion,
            Recommendation::RetileMatmul,
            Recommendation::Vectorize,
            Recommendation::UseFastMath,
            Recommendation::AdjustThreadgroup,
            Recommendation::LooksOptimal,
        ];
        let texts: Vec<&str> = recs.iter().map(|r| r.text()).collect();
        for t in &texts {
            assert!(t.len() > 20);
        }
        let mut sorted = texts.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), texts.len());
    }
}
