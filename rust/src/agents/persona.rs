//! The 8 model personas (paper Table 1) and their calibration.
//!
//! Each persona is parameterized by rates calibrated to the paper's
//! reported results:
//! - `single_shot` — named per-platform rows of P(first candidate
//!   fully correct) at [L1, L2, L3]: Metal values from Table 4
//!   (Baseline columns); CUDA values from the §5.1 discussion (gpt-5
//!   ≥0.9, o1-era ≈0.6, chat models lower); ROCm (MI300X) rows from
//!   measured single-shot runs — HIP sits close to CUDA, so they land
//!   a hair under each persona's CUDA row.  Platforms without a
//!   dedicated row fall back to the row their
//!   [`Platform::calibration_fallback`] names, with the failure rate
//!   inflated — the paper's "a single-shot example is enough to target
//!   a new platform" prior;
//! - `ref_effect[level]` — multiplier on the *failure* rate when a
//!   CUDA reference implementation is provided on a platform where
//!   that acts as cross-architecture transfer (Table 4 CUDA-Reference
//!   columns: opus improves a lot, o3 *degrades*, gpt-5 mixed);
//! - `fix_skill` — per-iteration probability of repairing the defect
//!   the verifier reported, scaled by level difficulty;
//! - `opt_skill` — probability an optimization iteration (no profile)
//!   finds a useful schedule lever on its own;
//! - `instruction_following` — probability the agent applies the
//!   analysis agent's recommendation verbatim;
//! - `internal_samples` — reasoning models internally consider k
//!   candidates and self-check before answering (k=1 for chat models);
//! - `schedule_skill[level]` — how close the initial schedule lands to
//!   the platform expert point.

use crate::platform::Platform;
use crate::workloads::Level;

/// Model provider (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provider {
    OpenAi,
    Anthropic,
    DeepSeek,
}

/// A calibrated model persona.
#[derive(Debug, Clone)]
pub struct Persona {
    pub name: &'static str,
    pub provider: Provider,
    pub reasoning: bool,
    /// Named per-platform calibration rows: (platform id, P(single-shot
    /// correct) at [L1, L2, L3]).  Looked up by platform *name*, never
    /// by position.
    pub single_shot: &'static [(&'static str, [f64; 3])],
    /// Failure-rate multiplier with a CUDA reference (cross-platform
    /// transfer, §6.2).
    pub ref_effect: [f64; 3],
    pub fix_skill: f64,
    pub opt_skill: f64,
    pub instruction_following: f64,
    pub internal_samples: usize,
    /// Initial schedule quality per level ∈ [0,1].
    pub schedule_skill: [f64; 3],
    /// P(discovers the §7.3 constant-output collapse when present).
    pub p_constant_fold: f64,
    /// P(discovers the §7.4 algebraic reduction when present).
    pub p_algebraic: f64,
    /// P(generation failure): network error / no code in output (§3.3).
    pub p_generation_failure: f64,
}

impl Persona {
    /// Index into the `[f64; 3]` calibration rows.  The rows are
    /// measured for L1–L3 only; the whole-model tier (L4) clamps to the
    /// hardest measured bucket (see [`Level::calibration_bucket`]).
    pub fn level_idx(level: Level) -> usize {
        level.calibration_bucket()
    }

    /// The dedicated calibration row for a platform id, if one exists.
    pub fn single_shot_row(&self, platform_id: &str) -> Option<[f64; 3]> {
        self.single_shot
            .iter()
            .find(|(id, _)| *id == platform_id)
            .map(|(_, row)| *row)
    }

    /// Single-shot calibration for a platform, falling back to the
    /// platform's declared nearest-calibrated row (failure inflated by
    /// the platform's factor) when no dedicated row exists — the
    /// principled default for unseen accelerators.
    pub fn single_shot(&self, platform: &dyn Platform) -> [f64; 3] {
        if let Some(row) = self.single_shot_row(platform.name()) {
            return row;
        }
        let (fallback, failure_factor) = platform.calibration_fallback();
        let base = self
            .single_shot_row(fallback)
            // a persona with no usable fallback row is treated as a
            // weak chat model rather than panicking
            .unwrap_or([0.3, 0.2, 0.05]);
        base.map(|p| (1.0 - (1.0 - p) * failure_factor).clamp(0.01, 0.995))
    }

    /// Single-shot success probability for (platform, level), with the
    /// optional reference-implementation effect applied on platforms
    /// where a CUDA reference is cross-architecture transfer.
    pub fn p_single_shot(&self, platform: &dyn Platform, level: Level, with_reference: bool) -> f64 {
        let base = self.single_shot(platform)[Self::level_idx(level)];
        if with_reference && platform.reference_transfer() {
            // the reference modulates the *failure* rate
            let fail = (1.0 - base) * self.ref_effect[Self::level_idx(level)];
            (1.0 - fail).clamp(0.01, 0.995)
        } else {
            base
        }
    }

    /// Per-iteration repair probability for a reported error at `level`.
    /// Indexed through [`Level::index`] so a new tier extends the table
    /// instead of a match; L4's factor sits below L3's — cross-kernel
    /// failures are harder to localize than single-kernel ones.
    pub fn p_fix(&self, level: Level) -> f64 {
        const LEVEL_FACTOR: [f64; Level::COUNT] = [1.0, 0.8, 0.35, 0.25];
        (self.fix_skill * LEVEL_FACTOR[level.index()]).clamp(0.0, 0.95)
    }

    /// Schedule skill for a level.
    pub fn sched_skill(&self, level: Level) -> f64 {
        self.schedule_skill[Self::level_idx(level)]
    }
}

/// The 8 personas of Table 1, calibrated per DESIGN.md §1.
pub static PERSONAS: &[Persona] = &[
    Persona {
        name: "openai-gpt-5",
        provider: Provider::OpenAi,
        reasoning: true,
        single_shot: &[
            ("cuda", [0.82, 0.75, 0.55]),
            ("metal", [0.78, 0.65, 0.44]), // Table 4 row
            ("rocm", [0.80, 0.72, 0.50]),  // MI300X single-shot run
        ],
        ref_effect: [1.4, 0.8, 0.93], // L1 worse, L2/L3 better
        fix_skill: 0.70,
        opt_skill: 0.55,
        instruction_following: 0.85,
        internal_samples: 4,
        schedule_skill: [0.75, 0.7, 0.6],
        p_constant_fold: 0.8,
        p_algebraic: 0.7,
        p_generation_failure: 0.01,
    },
    Persona {
        name: "openai-o3",
        provider: Provider::OpenAi,
        reasoning: true,
        single_shot: &[
            ("cuda", [0.72, 0.68, 0.48]),
            ("metal", [0.59, 0.72, 0.44]), // Table 4 row
            ("rocm", [0.69, 0.64, 0.43]),  // MI300X single-shot run
        ],
        ref_effect: [1.15, 2.0, 1.29], // reference *hurts* o3
        fix_skill: 0.65,
        opt_skill: 0.45,
        instruction_following: 0.75,
        internal_samples: 4,
        schedule_skill: [0.65, 0.6, 0.5],
        p_constant_fold: 0.7,
        p_algebraic: 0.6,
        p_generation_failure: 0.01,
    },
    Persona {
        name: "openai-gpt-4o",
        provider: Provider::OpenAi,
        reasoning: false,
        single_shot: &[
            ("cuda", [0.45, 0.33, 0.10]),
            ("metal", [0.38, 0.30, 0.08]),
            ("rocm", [0.41, 0.30, 0.08]),
        ],
        ref_effect: [0.85, 0.85, 0.95],
        fix_skill: 0.35,
        opt_skill: 0.18,
        instruction_following: 0.55,
        internal_samples: 1,
        schedule_skill: [0.35, 0.3, 0.2],
        p_constant_fold: 0.1,
        p_algebraic: 0.05,
        p_generation_failure: 0.03,
    },
    Persona {
        name: "openai-gpt-4.1",
        provider: Provider::OpenAi,
        reasoning: false,
        single_shot: &[
            ("cuda", [0.50, 0.38, 0.13]),
            ("metal", [0.42, 0.34, 0.10]),
            ("rocm", [0.46, 0.34, 0.11]),
        ],
        ref_effect: [0.85, 0.85, 0.95],
        fix_skill: 0.38,
        opt_skill: 0.20,
        instruction_following: 0.60,
        internal_samples: 1,
        schedule_skill: [0.38, 0.33, 0.22],
        p_constant_fold: 0.12,
        p_algebraic: 0.06,
        p_generation_failure: 0.03,
    },
    Persona {
        name: "claude-opus-4",
        provider: Provider::Anthropic,
        reasoning: true,
        single_shot: &[
            ("cuda", [0.75, 0.70, 0.45]),
            ("metal", [0.66, 0.62, 0.22]), // Table 4 row
            ("rocm", [0.72, 0.66, 0.40]),  // MI300X single-shot run
        ],
        ref_effect: [0.41, 0.45, 0.74], // big transfer gain
        fix_skill: 0.60,
        opt_skill: 0.40,
        instruction_following: 0.80,
        internal_samples: 3,
        schedule_skill: [0.6, 0.55, 0.4],
        p_constant_fold: 0.6,
        p_algebraic: 0.5,
        p_generation_failure: 0.01,
    },
    Persona {
        name: "claude-sonnet-4",
        provider: Provider::Anthropic,
        reasoning: false,
        single_shot: &[
            ("cuda", [0.55, 0.45, 0.18]),
            ("metal", [0.48, 0.40, 0.14]),
            ("rocm", [0.51, 0.41, 0.15]),
        ],
        ref_effect: [0.7, 0.7, 0.85],
        fix_skill: 0.42,
        opt_skill: 0.30,
        instruction_following: 0.70,
        internal_samples: 1,
        schedule_skill: [0.5, 0.45, 0.3],
        p_constant_fold: 0.3,
        p_algebraic: 0.2,
        p_generation_failure: 0.02,
    },
    Persona {
        name: "deepseek-r1",
        provider: Provider::DeepSeek,
        reasoning: true,
        single_shot: &[
            ("cuda", [0.60, 0.50, 0.30]),
            ("metal", [0.50, 0.45, 0.25]),
            ("rocm", [0.56, 0.46, 0.26]),
        ],
        ref_effect: [0.8, 0.8, 0.9],
        fix_skill: 0.48,
        opt_skill: 0.32,
        instruction_following: 0.65,
        internal_samples: 3,
        schedule_skill: [0.5, 0.45, 0.35],
        p_constant_fold: 0.4,
        p_algebraic: 0.3,
        p_generation_failure: 0.04,
    },
    Persona {
        name: "deepseek-v3",
        provider: Provider::DeepSeek,
        reasoning: false,
        // §5.1: deepseek-v3 L1 fast_1 = 18% in our runs vs 9% reported
        single_shot: &[
            ("cuda", [0.48, 0.35, 0.12]),
            ("metal", [0.40, 0.32, 0.10]),
            ("rocm", [0.44, 0.32, 0.10]),
        ],
        ref_effect: [0.8, 0.8, 0.92],
        fix_skill: 0.33,
        opt_skill: 0.22,
        instruction_following: 0.55,
        internal_samples: 1,
        schedule_skill: [0.42, 0.35, 0.22],
        p_constant_fold: 0.15,
        p_algebraic: 0.08,
        p_generation_failure: 0.04,
    },
];

/// Look up a persona by name.
pub fn by_name(name: &str) -> Option<&'static Persona> {
    PERSONAS.iter().find(|p| p.name == name)
}

/// The three top reasoning models the paper focuses on after Fig 2.
pub fn top_reasoning() -> Vec<&'static Persona> {
    ["openai-gpt-5", "openai-o3", "claude-opus-4"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{by_name as platform_by_name, PlatformRef};

    fn metal() -> PlatformRef {
        platform_by_name("metal").unwrap()
    }

    fn cuda() -> PlatformRef {
        platform_by_name("cuda").unwrap()
    }

    #[test]
    fn eight_personas_table1() {
        assert_eq!(PERSONAS.len(), 8);
        assert_eq!(PERSONAS.iter().filter(|p| p.reasoning).count(), 4);
    }

    #[test]
    fn table4_metal_baseline_values() {
        let opus = by_name("claude-opus-4").unwrap();
        assert_eq!(opus.single_shot_row("metal").unwrap(), [0.66, 0.62, 0.22]);
        let o3 = by_name("openai-o3").unwrap();
        assert_eq!(o3.single_shot_row("metal").unwrap(), [0.59, 0.72, 0.44]);
        let gpt5 = by_name("openai-gpt-5").unwrap();
        assert_eq!(gpt5.single_shot_row("metal").unwrap(), [0.78, 0.65, 0.44]);
    }

    #[test]
    fn table4_reference_effect_direction() {
        // with a CUDA reference, opus improves everywhere, o3 degrades
        let m = metal();
        let opus = by_name("claude-opus-4").unwrap();
        let o3 = by_name("openai-o3").unwrap();
        for level in Level::ALL {
            assert!(
                opus.p_single_shot(&*m, level, true) > opus.p_single_shot(&*m, level, false)
            );
            assert!(o3.p_single_shot(&*m, level, true) < o3.p_single_shot(&*m, level, false));
        }
    }

    #[test]
    fn table4_reference_values_close() {
        // Table 4 CUDA-reference column targets within a point or two
        let m = metal();
        let cases = [
            ("claude-opus-4", [0.86, 0.83, 0.42]),
            ("openai-o3", [0.53, 0.44, 0.28]),
            ("openai-gpt-5", [0.69, 0.72, 0.48]),
        ];
        for (name, want) in cases {
            let p = by_name(name).unwrap();
            // the measured targets cover the three calibrated levels;
            // zip stops there (L4 clamps to the L3 bucket, below)
            for (level, want) in Level::ALL.iter().zip(want) {
                let got = p.p_single_shot(&*m, *level, true);
                assert!(
                    (got - want).abs() < 0.02,
                    "{name} {level:?}: got {got:.3}, want {want}"
                );
            }
        }
    }

    #[test]
    fn level4_clamps_to_the_l3_calibration_bucket() {
        let m = metal();
        for p in PERSONAS {
            assert_eq!(
                p.p_single_shot(&*m, Level::L4, true),
                p.p_single_shot(&*m, Level::L3, true),
                "{}",
                p.name
            );
            assert_eq!(p.sched_skill(Level::L4), p.sched_skill(Level::L3), "{}", p.name);
            // repair is strictly harder across kernel boundaries
            assert!(p.p_fix(Level::L4) <= p.p_fix(Level::L3), "{}", p.name);
        }
    }

    #[test]
    fn reference_does_not_change_cuda() {
        let c = cuda();
        let p = by_name("openai-gpt-5").unwrap();
        assert_eq!(
            p.p_single_shot(&*c, Level::L1, true),
            p.p_single_shot(&*c, Level::L1, false)
        );
    }

    #[test]
    fn reasoning_beats_chat_on_l3() {
        for r in PERSONAS.iter().filter(|p| p.reasoning) {
            for c in PERSONAS.iter().filter(|p| !p.reasoning) {
                assert!(
                    r.single_shot_row("cuda").unwrap()[2] > c.single_shot_row("cuda").unwrap()[2],
                    "{} vs {}",
                    r.name,
                    c.name
                );
            }
        }
    }

    #[test]
    fn every_persona_calibrated_on_all_builtin_platforms() {
        for p in PERSONAS {
            for platform in ["cuda", "metal", "rocm"] {
                assert!(p.single_shot_row(platform).is_some(), "{} on {platform}", p.name);
            }
        }
    }

    #[test]
    fn rocm_rows_pinned_and_below_cuda() {
        // MI300X named calibration rows (satellite of the rocprof PR):
        // personas no longer ride the declared fallback prior on rocm
        let pins = [
            ("openai-gpt-5", [0.80, 0.72, 0.50]),
            ("openai-o3", [0.69, 0.64, 0.43]),
            ("claude-opus-4", [0.72, 0.66, 0.40]),
        ];
        for (name, want) in pins {
            assert_eq!(by_name(name).unwrap().single_shot_row("rocm").unwrap(), want, "{name}");
        }
        let rocm = platform_by_name("rocm").unwrap();
        for p in PERSONAS {
            let row = p.single_shot(&*rocm);
            assert_eq!(row, p.single_shot_row("rocm").unwrap(), "{}: named row must win", p.name);
            let cuda_row = p.single_shot_row("cuda").unwrap();
            for i in 0..3 {
                assert!(
                    row[i] <= cuda_row[i] + 1e-12,
                    "{}: HIP row should not beat the CUDA home row",
                    p.name
                );
            }
        }
    }

    /// A platform with no calibration row anywhere (exercises the
    /// fallback path now that all built-ins carry named rows).
    #[derive(Debug)]
    struct UncalibratedNpu {
        spec: crate::platform::PlatformSpec,
    }

    impl crate::platform::Platform for UncalibratedNpu {
        fn spec(&self) -> &crate::platform::PlatformSpec {
            &self.spec
        }

        fn calibration_fallback(&self) -> (&'static str, f64) {
            ("cuda", 1.25)
        }
    }

    #[test]
    fn unseen_platform_falls_back_with_haircut() {
        // an uncalibrated platform: personas fall back to their CUDA
        // calibration with the failure rate inflated — never a panic,
        // never zero
        let mut spec = crate::platform::cuda::h100();
        spec.platform_id = "npu";
        let npu = UncalibratedNpu { spec };
        for p in PERSONAS {
            assert!(p.single_shot_row("npu").is_none(), "{}", p.name);
            let fallback = p.single_shot(&npu);
            let home = p.single_shot_row("cuda").unwrap();
            for i in 0..3 {
                assert!(fallback[i] > 0.0 && fallback[i] < 1.0);
                assert!(
                    fallback[i] <= home[i] + 1e-12,
                    "{}: fallback should not beat the calibrated home row",
                    p.name
                );
            }
        }
        // ordering between personas is preserved by the haircut
        let gpt5 = by_name("openai-gpt-5").unwrap().single_shot(&npu);
        let gpt4o = by_name("openai-gpt-4o").unwrap().single_shot(&npu);
        assert!(gpt5[0] > gpt4o[0]);
    }

    #[test]
    fn fix_skill_decreases_with_level() {
        let p = by_name("claude-opus-4").unwrap();
        assert!(p.p_fix(Level::L1) > p.p_fix(Level::L2));
        assert!(p.p_fix(Level::L2) > p.p_fix(Level::L3));
    }

    #[test]
    fn top_reasoning_is_three() {
        assert_eq!(top_reasoning().len(), 3);
    }
}
