//! Repeated sampling — the third §3 strategy.
//!
//! The paper supports three complementary strategies (iterative
//! refinement, reference implementation, repeated sampling) and
//! focuses its experiments on the first two, citing HumanEval's
//! pass@100 results.  We implement repeated sampling so the ablation
//! harness can compare all three at equal generation budget.

use super::generation::GenerationAgent;
use super::Program;
use crate::platform::PlatformSpec;
use crate::util::rng::Pcg;
use crate::verify::{self, ExecState};
use crate::workloads::Problem;

/// Result of a repeated-sampling run.
#[derive(Debug, Clone)]
pub struct SamplingResult {
    /// Number of samples drawn.
    pub samples: usize,
    /// Index of the first correct sample, if any (pass@k evidence).
    pub first_correct: Option<usize>,
    /// Best (fastest) correct program and its measured seconds.
    pub best: Option<(Program, f64)>,
    /// Execution-state labels per sample.
    pub states: Vec<&'static str>,
}

/// Draw `k` independent samples (no feedback between them), verify
/// each, and keep the fastest correct one.
pub fn repeated_sampling(
    agent: &GenerationAgent,
    spec: &PlatformSpec,
    problem: &Problem,
    reference: Option<&Program>,
    k: usize,
    rng: &mut Pcg,
) -> SamplingResult {
    let mut states = Vec::with_capacity(k);
    let mut first_correct = None;
    let mut best: Option<(Program, f64)> = None;
    for i in 0..k {
        // independence: each sample gets its own forked stream
        let mut srng = rng.fork(&format!("sample{i}"));
        let cand = agent.synthesize(problem, reference, &mut srng);
        let out = verify::verify(spec, problem, cand.as_ref(), &mut srng);
        states.push(out.state.label());
        if let ExecState::Correct = out.state {
            if first_correct.is_none() {
                first_correct = Some(i);
            }
            let t = out.sim.expect("correct implies sim").measured_s;
            if best.as_ref().map(|(_, b)| t < *b).unwrap_or(true) {
                best = Some((cand.expect("correct implies candidate"), t));
            }
        }
    }
    SamplingResult {
        samples: k,
        first_correct,
        best,
        states,
    }
}

/// pass@k estimate over a problem set: fraction of problems where at
/// least one of k samples is correct.
pub fn pass_at_k(
    agent: &GenerationAgent,
    spec: &PlatformSpec,
    problems: &[&Problem],
    k: usize,
    seed: u64,
) -> f64 {
    if problems.is_empty() {
        return 0.0;
    }
    let solved = problems
        .iter()
        .filter(|p| {
            let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(p.id.as_bytes()));
            repeated_sampling(agent, spec, p, None, k, &mut rng)
                .first_correct
                .is_some()
        })
        .count();
    solved as f64 / problems.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::persona::by_name;
    use crate::platform::cuda;
    use crate::workloads::Suite;

    fn cuda_platform() -> crate::platform::PlatformRef {
        crate::platform::by_name("cuda").unwrap()
    }

    #[test]
    fn more_samples_solve_more() {
        let suite = Suite::sample(8);
        let spec = cuda::h100();
        let agent = GenerationAgent::new(by_name("deepseek-v3").unwrap(), cuda_platform());
        let problems: Vec<&crate::workloads::Problem> = suite.problems.iter().collect();
        let p1 = pass_at_k(&agent, &spec, &problems, 1, 0);
        let p8 = pass_at_k(&agent, &spec, &problems, 8, 0);
        assert!(p8 >= p1, "pass@8 {p8} < pass@1 {p1}");
        assert!(p8 > 0.3, "pass@8 too low: {p8}");
    }

    #[test]
    fn best_is_fastest_correct() {
        let suite = Suite::sample(1);
        let spec = cuda::h100();
        let agent = GenerationAgent::new(by_name("openai-gpt-5").unwrap(), cuda_platform());
        let mut rng = Pcg::seed(5);
        let r = repeated_sampling(&agent, &spec, &suite.problems[0], None, 6, &mut rng);
        assert_eq!(r.states.len(), 6);
        if let Some(fc) = r.first_correct {
            assert_eq!(r.states[fc], "correct");
            assert!(r.best.is_some());
        }
    }

    #[test]
    fn deterministic() {
        let suite = Suite::sample(1);
        let spec = cuda::h100();
        let agent = GenerationAgent::new(by_name("claude-opus-4").unwrap(), cuda_platform());
        let mut r1 = Pcg::seed(9);
        let mut r2 = Pcg::seed(9);
        let a = repeated_sampling(&agent, &spec, &suite.problems[0], None, 4, &mut r1);
        let b = repeated_sampling(&agent, &spec, &suite.problems[0], None, 4, &mut r2);
        assert_eq!(a.states, b.states);
        assert_eq!(a.first_correct, b.first_correct);
    }
}
