//! The program-synthesis agent `F : (p, k_{t-1}, r_{t-1}) → k_t`.
//!
//! A synthesized **Program** is a concrete artifact: a (possibly
//! rewritten) KIR graph, a schedule, and any injected defects.  Defects
//! are *real transformations* that genuinely fail the downstream
//! stage for their class:
//! - `Syntax` — corrupts an operand reference → `kir::validate` fails
//!   (compilation failure);
//! - `IllegalSchedule` — oversizes threadgroup/tile → `sched::legal`
//!   fails at dispatch (runtime error);
//! - `WrongNumerics` — swaps an activation / drops an epilogue /
//!   flips a reduce axis → the interpreter produces genuinely wrong
//!   values (numerical mismatch).
//!
//! Refinement consumes the verifier's actual error channel: a fix
//! targets the defect class the error names, with persona-dependent
//! success probability.  Optimization iterations move schedule levers —
//! toward the analysis agent's recommendation when one is supplied
//! (`instruction_following`), else by the persona's own search skill.

use super::persona::Persona;
use super::Recommendation;
use crate::kir::op::{Op, ReduceKind, UnaryKind};
use crate::kir::rewrite::{self, Rewrite};
use crate::kir::Graph;
use crate::platform::PlatformRef;
use crate::sched::schedule::Lever;
use crate::sched::Schedule;
use crate::util::rng::Pcg;
use crate::workloads::Problem;

/// Defect classes a synthesized program may carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Defect {
    Syntax,
    IllegalSchedule,
    WrongNumerics,
}

/// A synthesized candidate program.
#[derive(Debug, Clone)]
pub struct Program {
    pub graph: Graph,
    pub schedule: Schedule,
    pub defects: Vec<Defect>,
    /// Rendered "source code" (goes into prompts / the reference corpus).
    pub source_listing: String,
}

impl Program {
    fn new(graph: Graph, schedule: Schedule, defects: Vec<Defect>) -> Program {
        let mut listing = graph.render();
        listing.push_str(&format!(
            "// schedule: fusion={} tile={}x{}x{} ept={} tg={} fast_math={} graphs={} vec={}\n",
            if schedule.fusion_depth == usize::MAX { "full".to_string() } else { schedule.fusion_depth.to_string() },
            schedule.tile.bm,
            schedule.tile.bn,
            schedule.tile.bk,
            schedule.ept,
            schedule.threadgroup,
            schedule.fast_math,
            schedule.use_graphs,
            schedule.vec_width
        ));
        Program {
            graph,
            schedule,
            defects,
            source_listing: listing,
        }
    }

    /// A defect-free program from an explicit graph + schedule — the
    /// constructor non-agent producers use (the schedule autotuner's
    /// reference arm in Table 4, tests).
    pub fn with_schedule(graph: Graph, schedule: Schedule) -> Program {
        Program::new(graph, schedule, vec![])
    }
}

/// The generation agent: one persona synthesizing for one platform.
#[derive(Debug, Clone)]
pub struct GenerationAgent {
    pub persona: &'static Persona,
    pub platform: PlatformRef,
}

impl GenerationAgent {
    pub fn new(persona: &'static Persona, platform: PlatformRef) -> Self {
        GenerationAgent { persona, platform }
    }

    /// Initial synthesis (iteration 0).  `reference` is the CUDA
    /// reference program for the Metal transfer configuration (§6.2).
    /// Returns None on a generation failure (§3.3 state 1).
    pub fn synthesize(
        &self,
        problem: &Problem,
        reference: Option<&Program>,
        rng: &mut Pcg,
    ) -> Option<Program> {
        if rng.chance(self.persona.p_generation_failure) {
            return None;
        }
        let p_ok = self
            .persona
            .p_single_shot(&*self.platform, problem.level, reference.is_some());
        // Reasoning models self-check k internal candidates; the
        // calibrated p_ok already reflects the final answer, so a single
        // draw decides correctness while internal sampling shapes the
        // schedule (best-of-k on distance from the expert point).
        let correct = rng.chance(p_ok);

        let graph = self.rewrite_graph(problem, rng);
        let schedule = self.initial_schedule(problem, reference, rng);

        let defects = if correct {
            vec![]
        } else {
            vec![self.sample_defect(rng)]
        };
        let mut prog = Program::new(graph, schedule, defects.clone());
        apply_defects(&mut prog, rng);
        Some(prog)
    }

    /// Refinement (iterations ≥ 1).  `error` is the verifier output for
    /// a failed candidate; `recommendation` is G's advice for a correct
    /// one.  Mirrors `F : (p, k_{t-1}, r_{t-1}) → k_t`.
    pub fn refine(
        &self,
        problem: &Problem,
        prev: &Program,
        error: Option<&str>,
        recommendation: Option<&Recommendation>,
        rng: &mut Pcg,
    ) -> Option<Program> {
        if rng.chance(self.persona.p_generation_failure) {
            return None;
        }
        let mut next = prev.clone();
        match error {
            Some(err) => {
                // functional pass: attempt to repair the reported defect
                if rng.chance(self.persona.p_fix(problem.level)) {
                    next = self.repair(problem, prev, err, rng);
                } else if rng.chance(0.25) {
                    // failed fix sometimes mutates into a different defect
                    next.defects = vec![self.sample_defect(rng)];
                    let graph = self.rewrite_graph(problem, rng);
                    next = Program::new(graph, next.schedule.clone(), next.defects.clone());
                    apply_defects(&mut next, rng);
                }
            }
            None => {
                // optimization pass
                let lever = match recommendation.and_then(|r| r.lever()) {
                    Some(lever) if rng.chance(self.persona.instruction_following) => Some(lever),
                    _ => {
                        if rng.chance(self.persona.opt_skill) {
                            Some(*rng.choose(&Lever::ALL))
                        } else {
                            None
                        }
                    }
                };
                if let Some(lever) = lever {
                    let mut sched = next.schedule.clone();
                    if lever == Lever::Tile || lever == Lever::Threadgroup {
                        // move toward the *platform* expert point
                        let expert = self.platform.expert_schedule();
                        match lever {
                            Lever::Tile => sched.tile = expert.tile,
                            Lever::Threadgroup => sched.threadgroup = expert.threadgroup,
                            _ => unreachable!(),
                        }
                    } else {
                        sched.improve(lever);
                    }
                    next = Program::new(next.graph.clone(), sched, next.defects.clone());
                }
                // occasionally an optimization attempt breaks correctness
                let p_break = if self.persona.reasoning { 0.03 } else { 0.08 };
                if rng.chance(p_break) {
                    next.defects = vec![Defect::WrongNumerics];
                    apply_defects(&mut next, rng);
                }
            }
        }
        Some(next)
    }

    /// Graph-level rewrites the persona discovers (constant-output
    /// collapse, algebraic reduction, CSE).
    fn rewrite_graph(&self, problem: &Problem, rng: &mut Pcg) -> Graph {
        let mut rewrites: Vec<Rewrite> = vec![Rewrite::Cse];
        if problem.constant_output && rng.chance(self.persona.p_constant_fold) {
            rewrites.push(Rewrite::ConstantFold);
        }
        if problem.reducible && rng.chance(self.persona.p_algebraic) {
            rewrites.push(Rewrite::AlgebraicReduce);
        }
        rewrite::apply_all(&problem.eval_graph, &rewrites)
    }

    /// Initial schedule: persona skill × internal best-of-k, optionally
    /// warm-started from the reference program's schedule (transfer).
    fn initial_schedule(
        &self,
        problem: &Problem,
        reference: Option<&Program>,
        rng: &mut Pcg,
    ) -> Schedule {
        let skill = self.persona.sched_skill(problem.level);
        let k = self.persona.internal_samples.max(1);
        let mut best: Option<Schedule> = None;
        for _ in 0..k {
            let cand = Schedule::sample(rng, skill);
            let better = match &best {
                None => true,
                Some(b) => cand.distance_from_expert() < b.distance_from_expert(),
            };
            if better {
                best = Some(cand);
            }
        }
        let mut sched = best.unwrap();
        if let Some(r) = reference {
            // transfer: adopt the reference's fusion/tiling/vectorization
            // decisions (the "language-agnostic implementation patterns"
            // of §6.2); the platform clamp below keeps tiles legal
            sched.fusion_depth = r.schedule.fusion_depth;
            sched.ept = r.schedule.ept;
            sched.vec_width = r.schedule.vec_width;
            sched.fast_math = r.schedule.fast_math;
            sched.tile = r.schedule.tile;
        }
        // platform sanity the persona always knows: the threadgroup-memory
        // budget is in the prompt's single-shot example, so sampled tiles
        // are clamped to the platform expert tile when they overflow its
        // on-chip budget (illegal schedules enter only via the explicit
        // IllegalSchedule defect, keeping the §3.3 state mix aligned with
        // the calibrated single-shot rates); a no-op on devices whose
        // expert tile is already the largest sampleable tile
        let expert = self.platform.expert_schedule();
        if sched.tile.onchip_bytes() > expert.tile.onchip_bytes() {
            sched.tile = expert.tile;
        }
        sched
    }

    fn sample_defect(&self, rng: &mut Pcg) -> Defect {
        // §3.3 error-state mix among failures: compilation failures are
        // rarer for reasoning models, numeric mismatches dominate.
        let weights: [(Defect, f64); 3] = if self.persona.reasoning {
            [
                (Defect::Syntax, 0.18),
                (Defect::IllegalSchedule, 0.22),
                (Defect::WrongNumerics, 0.60),
            ]
        } else {
            [
                (Defect::Syntax, 0.35),
                (Defect::IllegalSchedule, 0.25),
                (Defect::WrongNumerics, 0.40),
            ]
        };
        *rng.choose_weighted(&weights)
    }

    /// Repair: remove the defect class the error message names.  A fix
    /// *sanitizes* the offending field (safe value), it does not gift an
    /// optimized schedule — optimization is the later pass's job.
    fn repair(&self, problem: &Problem, prev: &Program, error: &str, rng: &mut Pcg) -> Program {
        let mut schedule = prev.schedule.clone();
        if error.contains("runtime error") {
            let spec = self.platform.spec();
            let legal_max_tile = self.platform.expert_schedule().tile;
            if schedule.threadgroup == 0
                || schedule.threadgroup % spec.simd_width != 0
                || schedule.threadgroup > spec.max_threadgroup
            {
                schedule.threadgroup = 256;
            }
            if schedule.tile.onchip_bytes() > legal_max_tile.onchip_bytes() {
                schedule.tile = legal_max_tile;
            }
            schedule.ept = schedule.ept.clamp(1, 8).next_power_of_two();
            schedule.vec_width = schedule.vec_width.clamp(1, 4).next_power_of_two();
        }
        // rebuild the graph cleanly (drops syntax/numeric corruption)
        let graph = self.rewrite_graph(problem, rng);
        Program::new(graph, schedule, vec![])
    }
}

/// Realize the defects as genuine corruption of the program.
fn apply_defects(prog: &mut Program, rng: &mut Pcg) {
    for defect in prog.defects.clone() {
        match defect {
            Defect::Syntax => corrupt_syntax(&mut prog.graph, rng),
            Defect::IllegalSchedule => corrupt_schedule(&mut prog.schedule, rng),
            Defect::WrongNumerics => corrupt_numerics(&mut prog.graph, rng),
        }
    }
}

/// Dangle an operand reference → validation fails (compilation error).
fn corrupt_syntax(g: &mut Graph, rng: &mut Pcg) {
    let candidates: Vec<usize> = (0..g.nodes.len())
        .filter(|&i| !g.nodes[i].op.operands().is_empty())
        .collect();
    if candidates.is_empty() {
        g.outputs = vec![g.nodes.len() + 7];
        return;
    }
    let id = *rng.choose(&candidates);
    let bad = g.nodes.len() + 3;
    g.nodes[id].op = g.nodes[id].op.map_operands(|o| if rng.chance(0.5) { bad } else { o });
    // ensure at least one dangling ref even if chance missed them all
    let ops = g.nodes[id].op.operands();
    if ops.iter().all(|&o| o < g.nodes.len()) {
        g.nodes[id].op = g.nodes[id].op.map_operands(|_| bad);
    }
}

/// Exceed a device limit → dispatch fails (runtime error).
fn corrupt_schedule(s: &mut Schedule, rng: &mut Pcg) {
    match rng.below(3) {
        0 => s.threadgroup = 2048,
        1 => s.tile = crate::sched::schedule::Tile { bm: 512, bn: 512, bk: 128 },
        _ => s.ept = 13, // non-power-of-two
    }
}

/// Genuinely wrong math → numeric mismatch at verification.
fn corrupt_numerics(g: &mut Graph, rng: &mut Pcg) {
    // find a mutable site: swap a unary kind, or flip add→sub
    let sites: Vec<usize> = (0..g.nodes.len())
        .filter(|&i| {
            matches!(
                g.nodes[i].op,
                Op::Unary { .. } | Op::Binary { .. } | Op::Reduce { .. }
            )
        })
        .collect();
    if sites.is_empty() {
        // nothing to corrupt structurally: perturb via an extra exp on
        // the first non-input node if any, else give up (program will
        // verify correct — rare and harmless)
        return;
    }
    let id = *rng.choose(&sites);
    let node = &mut g.nodes[id];
    node.op = match node.op.clone() {
        Op::Unary { kind, input } => {
            let swapped = match kind {
                UnaryKind::Relu => UnaryKind::Sigmoid,
                UnaryKind::Sigmoid => UnaryKind::Tanh,
                UnaryKind::Swish => UnaryKind::Gelu,
                UnaryKind::Gelu => UnaryKind::Relu,
                UnaryKind::Tanh => UnaryKind::Exp,
                UnaryKind::Exp => UnaryKind::Square,
                UnaryKind::Neg => UnaryKind::Relu,
                UnaryKind::Square => UnaryKind::Sqrt,
                UnaryKind::Sqrt => UnaryKind::Square,
            };
            Op::Unary { kind: swapped, input }
        }
        Op::Binary { kind, lhs, rhs } => {
            use crate::kir::op::BinaryKind;
            let swapped = match kind {
                BinaryKind::Add => BinaryKind::Sub,
                BinaryKind::Sub => BinaryKind::Add,
                BinaryKind::Mul => BinaryKind::Add,
                BinaryKind::Div => BinaryKind::Mul,
                BinaryKind::Max => BinaryKind::Add,
            };
            Op::Binary { kind: swapped, lhs, rhs }
        }
        Op::Reduce { kind, axis, input } => {
            let swapped = match kind {
                ReduceKind::Sum => ReduceKind::Mean,
                ReduceKind::Mean => ReduceKind::Sum,
                ReduceKind::Max => ReduceKind::Sum,
                ReduceKind::LogSumExp => ReduceKind::Max,
            };
            Op::Reduce { kind: swapped, axis, input }
        }
        other => other,
    };
    // keep annotated shape consistent so this fails *numerically*, not
    // at validation (shapes of these swaps are unchanged)
}

/// Test support: a trivially-correct program for a problem.
#[doc(hidden)]
pub mod tests_support {
    use super::*;

    pub fn trivial_program(problem: &Problem) -> Program {
        Program::new(problem.eval_graph.clone(), Schedule::naive(), vec![])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::persona::by_name;
    use crate::kir::validate::validate;
    use crate::sched::legal;
    use crate::workloads::Suite;

    fn agent(name: &str, platform: &str) -> GenerationAgent {
        GenerationAgent::new(
            by_name(name).unwrap(),
            crate::platform::by_name(platform).unwrap(),
        )
    }

    #[test]
    fn correct_programs_have_no_defects_and_validate() {
        let suite = Suite::sample(2);
        let a = agent("openai-gpt-5", "cuda");
        let mut rng = Pcg::seed(1);
        let mut found_correct = false;
        for p in suite.problems.iter() {
            for _ in 0..4 {
                if let Some(prog) = a.synthesize(p, None, &mut rng) {
                    if prog.defects.is_empty() {
                        found_correct = true;
                        validate(&prog.graph).unwrap();
                        legal::check(&prog.schedule, &crate::platform::cuda::h100()).unwrap();
                    }
                }
            }
        }
        assert!(found_correct);
    }

    #[test]
    fn syntax_defect_fails_validation() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let mut rng = Pcg::seed(0);
        let mut prog = tests_support::trivial_program(p);
        prog.defects = vec![Defect::Syntax];
        apply_defects(&mut prog, &mut rng);
        assert!(validate(&prog.graph).is_err());
    }

    #[test]
    fn schedule_defect_fails_legality() {
        let mut rng = Pcg::seed(0);
        for seed in 0..6 {
            let mut rng2 = Pcg::seed(seed);
            let mut s = Schedule::naive();
            corrupt_schedule(&mut s, &mut rng2);
            assert!(legal::check(&s, &crate::platform::cuda::h100()).is_err());
        }
        let _ = &mut rng;
    }

    #[test]
    fn numeric_defect_changes_output() {
        use crate::kir::interp::eval;
        let suite = Suite::sample(3);
        // pick a problem with a corruptible site
        let p = suite
            .problems
            .iter()
            .find(|p| p.id.contains("act_"))
            .expect("activation problem in sample");
        let mut rng = Pcg::seed(3);
        let mut prog = tests_support::trivial_program(p);
        prog.defects = vec![Defect::WrongNumerics];
        apply_defects(&mut prog, &mut rng);
        let ins = p.eval_inputs(0);
        let want = eval(&p.eval_graph, &ins).unwrap();
        let got = eval(&prog.graph, &ins).unwrap();
        assert!(!got[0].allclose(&want[0], 1e-4, 1e-4), "corruption was a no-op");
    }

    #[test]
    fn single_shot_rate_tracks_calibration() {
        let suite = Suite::full();
        let a = agent("claude-opus-4", "metal");
        let mut rng = Pcg::seed(42);
        let l1: Vec<_> = suite.by_level(crate::workloads::Level::L1);
        let mut ok = 0;
        let mut total = 0;
        for p in &l1 {
            for _ in 0..5 {
                total += 1;
                if let Some(prog) = a.synthesize(p, None, &mut rng) {
                    if prog.defects.is_empty() {
                        ok += 1;
                    }
                }
            }
        }
        let rate = ok as f64 / total as f64;
        // calibration: 0.66 for opus metal L1 (±6 points sampling noise)
        assert!((rate - 0.66).abs() < 0.06, "rate={rate}");
    }

    #[test]
    fn refine_repairs_errors_eventually() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let a = agent("openai-gpt-5", "cuda");
        let mut rng = Pcg::seed(9);
        let mut prog = tests_support::trivial_program(p);
        prog.defects = vec![Defect::Syntax];
        apply_defects(&mut prog, &mut rng);
        let mut fixed = false;
        let mut cur = prog;
        for _ in 0..10 {
            if let Some(next) = a.refine(p, &cur, Some("error: node %2 references undefined value"), None, &mut rng) {
                if next.defects.is_empty() && validate(&next.graph).is_ok() {
                    fixed = true;
                    break;
                }
                cur = next;
            }
        }
        assert!(fixed);
    }

    #[test]
    fn optimization_follows_recommendation() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let a = agent("openai-gpt-5", "cuda");
        let mut rng = Pcg::seed(5);
        let mut prog = tests_support::trivial_program(p);
        assert!(!prog.schedule.fast_math);
        let mut applied = false;
        for _ in 0..10 {
            if let Some(next) = a.refine(p, &prog, None, Some(&Recommendation::UseFastMath), &mut rng) {
                if next.schedule.fast_math {
                    applied = true;
                    break;
                }
                prog = next;
            }
        }
        assert!(applied);
    }

    #[test]
    fn metal_agent_schedules_stay_legal_when_correct() {
        let suite = Suite::sample(2);
        let a = agent("openai-gpt-5", "metal");
        let spec = crate::platform::metal::m4_max();
        let mut rng = Pcg::seed(11);
        for p in suite.problems.iter() {
            if let Some(prog) = a.synthesize(p, None, &mut rng) {
                if prog.defects.is_empty() {
                    legal::check(&prog.schedule, &spec).unwrap();
                }
            }
        }
    }

    #[test]
    fn reference_transfers_schedule_decisions() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let a = agent("claude-opus-4", "metal");
        let mut rng = Pcg::seed(13);
        let mut reference = tests_support::trivial_program(p);
        reference.schedule = Schedule::expert();
        let prog = a.synthesize(p, Some(&reference), &mut rng).unwrap();
        assert_eq!(prog.schedule.ept, 8);
        assert_eq!(prog.schedule.fusion_depth, usize::MAX);
    }
}
