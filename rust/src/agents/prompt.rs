//! Prompt assembly — the Listing-1 template as a tiny Jinja-like
//! renderer plus the KForge prompt constructors.
//!
//! The paper parameterizes prompts with Jinja2 (`{{ accelerator }}`,
//! `{{ example_arch_src }}`, `{{ arc_src }}`); we implement the same
//! substitution surface so prompt construction is a first-class,
//! testable artifact (it *directs the mode of operation* — §3).

use crate::agents::generation::Program;
use crate::agents::Recommendation;
use crate::platform::PlatformSpec;
use crate::workloads::Problem;
use std::collections::BTreeMap;

/// Render a `{{ var }}` template against a variable map.  Unknown
/// variables render as `<missing:name>` (loud, like Jinja's undefined).
pub fn render(template: &str, vars: &BTreeMap<&str, String>) -> String {
    let mut out = String::with_capacity(template.len());
    let mut rest = template;
    while let Some(start) = rest.find("{{") {
        out.push_str(&rest[..start]);
        let after = &rest[start + 2..];
        match after.find("}}") {
            Some(end) => {
                let name = after[..end].trim();
                match vars.get(name) {
                    Some(v) => out.push_str(v),
                    None => out.push_str(&format!("<missing:{name}>")),
                }
                rest = &after[end + 2..];
            }
            None => {
                out.push_str("{{");
                rest = after;
            }
        }
    }
    out.push_str(rest);
    out
}

/// The Listing-1 synthesis prompt template.
pub const SYNTHESIS_TEMPLATE: &str = "\
You write custom {{ accelerator }} kernels to replace the operators in \
the given architecture to get speedups.

Here's an example to show you the syntax of inline embedding custom \
{{ accelerator }} operators:
{{ example_arch_src }}

The example new arch with custom {{ accelerator }} kernels:
{{ example_new_arch_src }}

You are given the following architecture:
{{ arc_src }}
{{ reference_section }}{{ feedback_section }}
Optimize the architecture named Model with custom {{ accelerator }} \
operators. Output the new code in codeblocks.
";

/// The single-shot example: vector addition (the paper's Appendix A/B
/// example, in KIR rendering).
pub fn vector_add_example() -> (String, String) {
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::BinaryKind;
    use crate::tensor::Shape;
    let mut b = GraphBuilder::new("vector_add");
    let x = b.input(Shape::of(&[1024]));
    let y = b.input(Shape::of(&[1024]));
    let z = b.binary(BinaryKind::Add, x, y);
    let g = b.finish(vec![z]);
    let arch = g.render();
    let new_arch = format!(
        "{arch}// schedule: threadgroup=256 vec_width=4 ept=1 (one bounds check per thread)\n"
    );
    (arch, new_arch)
}

/// Assemble the full synthesis prompt for a problem.
pub fn synthesis_prompt(
    spec: &PlatformSpec,
    problem: &Problem,
    reference: Option<&Program>,
    prev: Option<(&Program, &str)>,
    recommendation: Option<&Recommendation>,
) -> String {
    let (example, example_new) = vector_add_example();
    let reference_section = match reference {
        Some(r) => format!(
            "\nHere is a functionally correct CUDA implementation of the same \
             architecture to use as a reference:\n{}\n",
            r.source_listing
        ),
        None => String::new(),
    };
    let feedback_section = match (prev, recommendation) {
        (Some((prog, err)), None) => format!(
            "\nYour previous attempt was:\n{}\nIt failed with:\n{err}\nFix the error.\n",
            prog.source_listing
        ),
        (Some((prog, _)), Some(rec)) => format!(
            "\nYour previous attempt was correct:\n{}\nPerformance analysis \
             recommendation:\n{}\nImprove its performance.\n",
            prog.source_listing,
            rec.text()
        ),
        (None, _) => String::new(),
    };
    let mut vars: BTreeMap<&str, String> = BTreeMap::new();
    vars.insert("accelerator", spec.language.to_string());
    vars.insert("example_arch_src", example);
    vars.insert("example_new_arch_src", example_new);
    vars.insert("arc_src", problem.eval_graph.render());
    vars.insert("reference_section", reference_section);
    vars.insert("feedback_section", feedback_section);
    render(SYNTHESIS_TEMPLATE, &vars)
}

/// The performance-analysis prompt (o in `G : (o, k, {v}) → r`).
pub fn analysis_prompt(spec: &PlatformSpec, program: &Program, artifacts_desc: &str) -> String {
    format!(
        "You are a {} performance engineer. Given the kernel source and the \
         profiling data below, produce a single recommendation for maximum \
         performance improvement.\n\nKernel source:\n{}\nProfiling data:\n{}\n",
        spec.language,
        program.source_listing,
        artifacts_desc
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cuda;
    use crate::workloads::Suite;

    #[test]
    fn render_substitutes() {
        let mut vars = BTreeMap::new();
        vars.insert("a", "X".to_string());
        assert_eq!(render("{{ a }}-{{ a }}", &vars), "X-X");
        assert_eq!(render("{{ b }}", &vars), "<missing:b>");
        assert_eq!(render("no vars", &vars), "no vars");
    }

    #[test]
    fn render_handles_unclosed() {
        let vars = BTreeMap::new();
        assert_eq!(render("oops {{ tail", &vars), "oops {{ tail");
    }

    #[test]
    fn synthesis_prompt_mentions_platform_and_arch() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let spec = cuda::h100();
        let prompt = synthesis_prompt(&spec, p, None, None, None);
        assert!(prompt.contains("CUDA"));
        assert!(prompt.contains("graph"));
        assert!(!prompt.contains("<missing:"));
    }

    #[test]
    fn reference_and_feedback_sections_appear() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let spec = cuda::h100();
        let prog = crate::agents::generation::tests_support::trivial_program(p);
        let with_ref = synthesis_prompt(&spec, p, Some(&prog), None, None);
        assert!(with_ref.contains("reference"));
        let with_err = synthesis_prompt(&spec, p, None, Some((&prog, "error: boom")), None);
        assert!(with_err.contains("error: boom"));
        let with_rec = synthesis_prompt(
            &spec,
            p,
            None,
            Some((&prog, "")),
            Some(&Recommendation::Vectorize),
        );
        assert!(with_rec.contains("vectorized loads"));
    }
}
