//! The two collaborating agents (the paper's §3):
//!
//! - [`generation`] — the program-synthesis agent `F : (p, k_{t-1},
//!   r_{t-1}) → k_t`: produces a candidate `Program` (rewritten KIR
//!   graph + schedule + any injected defects), and refines it across
//!   iterations from verification feedback and recommendations.
//! - [`analysis`] — the performance-analysis agent `G : (o, k, {v^i})
//!   → r`: consumes the profiler `Evidence` IR (produced by whichever
//!   frontend the platform registers — nsys CSV, Xcode screenshot
//!   scrape, rocprof trace JSON) and emits **one** recommendation with
//!   a fidelity-derived confidence.
//!
//! [`persona`] defines the 8 calibrated model personas (Table 1);
//! [`prompt`] assembles the Listing-1-style prompts; [`recommend`] is
//! the recommendation taxonomy both agents share.
//!
//! ## Why personas instead of LLM calls
//! The paper's claims are about the *loop* — iterative refinement,
//! reference transfer, profile-guided optimization — not about any
//! specific model's weights.  Personas are mechanistic synthesizers
//! whose stochastic choices are calibrated to the paper's reported
//! rates (Tables 4/5, §5–6 text); every downstream stage (validation,
//! legality, numerics, simulation, profiling) runs for real on the
//! programs they emit.  See DESIGN.md §1.

pub mod persona;
pub mod prompt;
pub mod recommend;
pub mod generation;
pub mod sampling;
pub mod analysis;

pub use generation::{GenerationAgent, Program};
pub use persona::{Persona, PERSONAS};
pub use recommend::{Advice, Recommendation};
