//! Cross-process leases and claims over a shared cache directory.
//!
//! Two cooperation primitives, both built on the one atomic filesystem
//! operation every platform gives us — `O_CREAT|O_EXCL` file creation
//! (`OpenOptions::create_new`) inside the cache dir:
//!
//! - a [`Lease`] is a *liveness* marker: an RAII guard file under
//!   `<dir>/leases/` held for the duration of some activity (a shard
//!   executing its slice of a campaign, a writer streaming objects).
//!   `Cache::gc` consults the active leases and never evicts an object
//!   written at or after the oldest acquisition — so eviction racing an
//!   in-flight campaign can never delete a just-written object that a
//!   journal already references.  Leases are removed on drop; a crashed
//!   holder leaves a stale file, which `Lease::sweep` ages out.
//! - a [`claim`] is an *ownership* marker: a persistent `.claim` file
//!   whose create-new winner owns a work chunk forever (within one
//!   campaign digest — the digest is part of the claim name).  Claims
//!   are what make the distributed shard splitter self-coordinating:
//!   two shards racing for the same chunk resolve through the
//!   filesystem, and a resumed shard re-reads its own claims.  Claims
//!   deliberately do NOT pin gc (only `.lease` files do): they outlive
//!   their writer by design.
//!
//! Everything here degrades softly: a cache dir without a `leases/`
//! subdirectory means no active leases, and lease I/O errors are
//! surfaced to callers who log and continue — coordination failures
//! must never lose results, only parallelism.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

/// Subdirectory of the cache dir holding lease and claim files.
pub const LEASE_DIR: &str = "leases";

fn lease_dir(root: &Path) -> PathBuf {
    root.join(LEASE_DIR)
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect()
}

/// An acquired liveness lease (RAII): the file exists while the guard
/// lives and is removed on drop.  While any lease is active, `gc`
/// refuses to evict objects written at or after the oldest acquisition.
#[derive(Debug)]
pub struct Lease {
    path: PathBuf,
}

impl Lease {
    /// Acquire the named lease under `root/leases/`, failing if the
    /// name is already held.  `owner` is recorded in the file for
    /// diagnostics (`pid`, shard id, hostname — free-form).
    pub fn acquire(root: &Path, name: &str, owner: &str) -> Result<Lease> {
        let dir = lease_dir(root);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating lease dir {}", dir.display()))?;
        let path = dir.join(format!("{}.lease", sanitize(name)));
        let mut f = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("acquiring lease {}", path.display()))?;
        // content is diagnostic only; acquisition time is the file mtime
        let _ = writeln!(f, "{owner}");
        let _ = f.flush();
        Ok(Lease { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Explicit release (identical to drop, but surfaces the error).
    pub fn release(self) -> Result<()> {
        let path = self.path.clone();
        std::mem::forget(self); // skip the drop-time second removal
        std::fs::remove_file(&path)
            .with_context(|| format!("releasing lease {}", path.display()))
    }

    /// Remove lease files older than `max_age` (crashed holders).
    /// Returns how many were swept.
    pub fn sweep(root: &Path, max_age: Duration) -> Result<usize> {
        let mut swept = 0;
        for (path, mtime) in list_marker_files(root, ".lease")? {
            let stale = SystemTime::now()
                .duration_since(mtime)
                .map(|age| age > max_age)
                .unwrap_or(false);
            if stale && std::fs::remove_file(&path).is_ok() {
                swept += 1;
            }
        }
        Ok(swept)
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn list_marker_files(root: &Path, suffix: &str) -> Result<Vec<(PathBuf, SystemTime)>> {
    let dir = lease_dir(root);
    let entries = match std::fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading lease dir {}", dir.display())),
    };
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry?;
        if !entry.file_name().to_string_lossy().ends_with(suffix) {
            continue;
        }
        let meta = match entry.metadata() {
            Ok(m) if m.is_file() => m,
            _ => continue, // raced with a release: a vanished lease is inactive
        };
        out.push((
            entry.path(),
            meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH),
        ));
    }
    out.sort();
    Ok(out)
}

/// The oldest acquisition time among active leases, or `None` when no
/// lease is held.  `Cache::gc` treats this as its eviction floor:
/// objects with mtime at or after it are never removed.
pub fn active_floor(root: &Path) -> Option<SystemTime> {
    list_marker_files(root, ".lease")
        .ok()?
        .into_iter()
        .map(|(_, mtime)| mtime)
        .min()
}

/// Try to claim persistent ownership of `name` for `owner`.  Returns
/// `true` exactly once per name across every process sharing `root` —
/// the create-new winner.  A claim survives its creator (crash-resume
/// re-reads it via [`claim_owner`]); it never pins gc.
pub fn claim(root: &Path, name: &str, owner: &str) -> Result<bool> {
    let dir = lease_dir(root);
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating lease dir {}", dir.display()))?;
    let path = dir.join(format!("{}.claim", sanitize(name)));
    match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{owner}");
            let _ = f.flush();
            Ok(true)
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(e).with_context(|| format!("claiming {}", path.display())),
    }
}

/// The recorded owner of an existing claim (first line of the file),
/// or `None` when unclaimed/unreadable.
pub fn claim_owner(root: &Path, name: &str) -> Option<String> {
    let path = lease_dir(root).join(format!("{}.claim", sanitize(name)));
    let data = std::fs::read_to_string(path).ok()?;
    Some(data.lines().next().unwrap_or("").to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kforge_lease_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lease_is_exclusive_and_released_on_drop() {
        let root = tmp("excl");
        let a = Lease::acquire(&root, "shard-0", "pid 1").unwrap();
        assert!(a.path().exists());
        assert!(Lease::acquire(&root, "shard-0", "pid 2").is_err(), "double acquire");
        // a different name is independent
        let b = Lease::acquire(&root, "shard-1", "pid 2").unwrap();
        drop(a);
        // released: the same name can be re-acquired
        let again = Lease::acquire(&root, "shard-0", "pid 3").unwrap();
        again.release().unwrap();
        assert!(Lease::acquire(&root, "shard-0", "pid 4").is_ok());
        drop(b);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn active_floor_tracks_oldest_lease_only() {
        let root = tmp("floor");
        assert!(active_floor(&root).is_none(), "no leases yet");
        let a = Lease::acquire(&root, "a", "x").unwrap();
        // inject an ordering: make `a` deterministically the oldest
        let old = SystemTime::UNIX_EPOCH + Duration::from_secs(1_000);
        std::fs::File::options()
            .write(true)
            .open(a.path())
            .unwrap()
            .set_modified(old)
            .unwrap();
        let b = Lease::acquire(&root, "b", "y").unwrap();
        assert_eq!(active_floor(&root), Some(old));
        drop(a);
        let floor = active_floor(&root).expect("b still active");
        assert!(floor > old);
        drop(b);
        assert!(active_floor(&root).is_none());
        // claims never contribute to the floor
        assert!(claim(&root, "chunk-0", "z").unwrap());
        assert!(active_floor(&root).is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn claims_are_first_winner_and_persistent() {
        let root = tmp("claim");
        assert!(claim(&root, "c7", "shard 2").unwrap());
        assert!(!claim(&root, "c7", "shard 3").unwrap(), "second claimer must lose");
        assert_eq!(claim_owner(&root, "c7").as_deref(), Some("shard 2"));
        assert!(claim_owner(&root, "c8").is_none());
        // odd names sanitize instead of escaping the directory
        assert!(claim(&root, "../evil/../name", "s").unwrap());
        assert!(claim_owner(&root, "../evil/../name").is_some());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sweep_removes_only_stale_leases() {
        let root = tmp("sweep");
        let a = Lease::acquire(&root, "old", "x").unwrap();
        std::fs::File::options()
            .write(true)
            .open(a.path())
            .unwrap()
            .set_modified(SystemTime::now() - Duration::from_secs(3600))
            .unwrap();
        let b = Lease::acquire(&root, "fresh", "y").unwrap();
        let swept = Lease::sweep(&root, Duration::from_secs(60)).unwrap();
        assert_eq!(swept, 1);
        assert!(!a.path().exists());
        assert!(b.path().exists());
        std::mem::forget(a); // its file is already gone
        drop(b);
        let _ = std::fs::remove_dir_all(&root);
    }
}
