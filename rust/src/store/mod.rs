//! The synthesis result store: a content-addressed job cache with
//! crash-safe, resumable campaigns.
//!
//! Every (persona, problem) job in a campaign runs up to five
//! generation/verify/profile iterations, and the harness artifacts plus
//! the conformance gate re-run heavily overlapping campaigns.  PR 3
//! proved campaigns bit-identical across worker counts — which is what
//! makes a cached [`crate::coordinator::TaskResult`] provably safe to
//! substitute for a fresh run.  This subsystem never computes the same
//! job twice:
//!
//! - [`key`] — the canonical [`JobKey`] fingerprint covering everything
//!   that determines a result (including a schema version and a
//!   compile-time pipeline fingerprint, so editing a rewrite pass or a
//!   `PlatformSpec` field auto-invalidates);
//! - [`cache`] — the content-addressed in-memory + on-disk store;
//!   corrupt or truncated entries are logged misses, never crashes;
//! - [`journal`] — append-only per-campaign journals behind
//!   `kforge run --resume` / `kforge bench --resume`;
//! - [`stats`] — hits/misses/resumed/bytes/evictions, surfaced per
//!   campaign in [`crate::coordinator::CampaignResult`] and per process
//!   via `kforge cache stats`.
//!
//! One [`Store`] is shared per process (see [`global`]); the CLI
//! configures it at startup (`--cache-dir`, `--no-cache`, `--resume`),
//! so `kforge conformance` and `kforge bench` stop recomputing jobs
//! their artifact modules share.  The **default global store is
//! disabled**: library consumers (tests, benches) get cold runs unless
//! they opt in with [`crate::coordinator::experiment::run_campaign_with`]
//! — determinism tests stay meaningful, and the hot-path bench still
//! measures synthesis, not the cache.

pub mod cache;
pub mod journal;
pub mod key;
pub mod lease;
pub mod stats;

pub use cache::Cache;
pub use journal::Journal;
pub use key::{JobKey, KeyScope, STORE_SCHEMA};
pub use lease::Lease;
pub use stats::CacheStats;

use crate::coordinator::job::TaskResult;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// Default on-disk location used by the `kforge cache` subcommands
/// when `--cache-dir` is not given.
pub const DEFAULT_DIR: &str = ".kforge-cache";

/// A process-wide result store: the cache plus journal policy.
pub struct Store {
    enabled: bool,
    cache: Cache,
    journal_dir: Option<PathBuf>,
    resume: bool,
}

impl Store {
    /// Pass-through store: every lookup misses, nothing is written.
    pub fn disabled() -> Store {
        Store { enabled: false, cache: Cache::memory(), journal_dir: None, resume: false }
    }

    /// Memory-only store (shared within one process, no persistence,
    /// no journaling — there is no disk to resume from).
    pub fn memory() -> Store {
        Store { enabled: true, cache: Cache::memory(), journal_dir: None, resume: false }
    }

    /// Disk-backed store rooted at `dir`: objects under `dir/objects`,
    /// campaign journals under `dir/journals`.  With `resume`, a
    /// campaign whose journal exists continues from the last completed
    /// job instead of starting over.
    pub fn at_dir(dir: &Path, resume: bool) -> Result<Store> {
        Ok(Store {
            enabled: true,
            cache: Cache::at(dir)?,
            journal_dir: Some(dir.join("journals")),
            resume,
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn resume(&self) -> bool {
        self.enabled && self.resume
    }

    pub fn cache(&self) -> &Cache {
        &self.cache
    }

    /// The journal path for a campaign, when journaling is on.  The
    /// file name embeds the campaign digest, so configs with the same
    /// name but different suites/knobs never share a journal.
    pub fn journal_path(&self, config_name: &str, keys: &[JobKey]) -> Option<PathBuf> {
        if !self.enabled {
            return None;
        }
        let dir = self.journal_dir.as_ref()?;
        let sanitized: String = config_name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        Some(dir.join(format!(
            "{sanitized}-{:016x}.journal",
            journal::campaign_digest(config_name, keys)
        )))
    }

    /// The journal path for one shard of an N-shard campaign.  Shard
    /// journals use the same format and the same *global* key list as
    /// the 1-process journal (records are keyed by global job index),
    /// so the merge phase can fold any subset of them with the plain
    /// [`Journal::resume`] reader.
    pub fn shard_journal_path(
        &self,
        config_name: &str,
        keys: &[JobKey],
        shards: usize,
        shard_id: usize,
    ) -> Option<PathBuf> {
        let base = self.journal_path(config_name, keys)?;
        let file = base.file_name()?.to_string_lossy().into_owned();
        let stem = file.strip_suffix(".journal")?;
        // the digest suffix stays at the end so dist::merge can glob
        // every `*-shard*of*-{digest}.journal` for one campaign
        let (name, digest) = stem.rsplit_once('-')?;
        Some(base.with_file_name(format!("{name}-shard{shard_id}of{shards}-{digest}.journal")))
    }

    /// The root directory shared across processes (the `--cache-dir`),
    /// when this store is disk-backed — where leases and claims live.
    pub fn shared_dir(&self) -> Option<&Path> {
        if !self.enabled {
            return None;
        }
        self.cache.dir()
    }

    /// Look up a job result; `None` when disabled or absent.  Returns
    /// the result plus bytes read from disk (0 for memory hits).
    pub fn get(&self, key: &JobKey) -> Option<(TaskResult, u64)> {
        if !self.enabled {
            return None;
        }
        match self.cache.get(key) {
            Some((r, bytes)) => {
                crate::obs::instant("store.hit");
                crate::obs::counter("store.bytes_read", bytes);
                Some((r, bytes))
            }
            None => {
                crate::obs::instant("store.miss");
                None
            }
        }
    }

    /// Store a job result; returns bytes written to disk.
    pub fn put(&self, key: &JobKey, result: &TaskResult) -> u64 {
        if !self.enabled {
            return 0;
        }
        let bytes = self.cache.put(key, result);
        crate::obs::instant("store.put");
        crate::obs::counter("store.bytes_written", bytes);
        bytes
    }

    /// Look up a raw-text object (the autotuner's `kforge-tunekey`
    /// kind); `None` when disabled or absent.  Returns the payload plus
    /// bytes read from disk (0 for memory hits).
    pub fn get_blob(&self, key: &JobKey) -> Option<(String, u64)> {
        if !self.enabled {
            return None;
        }
        self.cache.get_blob(key)
    }

    /// [`Store::get_blob`] with caller-side payload validation: the
    /// lookup only counts as a hit (in the process counters and the
    /// returned value) when `parse` accepts the payload, so a corrupt
    /// entry is a consistent miss at every counting level.
    pub fn get_blob_checked<T>(
        &self,
        key: &JobKey,
        parse: impl Fn(&str) -> Result<T>,
    ) -> Option<(T, u64)> {
        if !self.enabled {
            return None;
        }
        match self.cache.get_blob_checked(key, parse) {
            Some((v, bytes)) => {
                crate::obs::instant("store.hit");
                crate::obs::counter("store.bytes_read", bytes);
                Some((v, bytes))
            }
            None => {
                crate::obs::instant("store.miss");
                None
            }
        }
    }

    /// Store a raw-text object; returns bytes written to disk.
    pub fn put_blob(&self, key: &JobKey, payload: &str) -> u64 {
        if !self.enabled {
            return 0;
        }
        let bytes = self.cache.put_blob(key, payload);
        crate::obs::instant("store.put");
        crate::obs::counter("store.bytes_written", bytes);
        bytes
    }

    /// Count a journal-restored job in the process-level counters.
    pub fn record_resumed(&self) {
        if self.enabled {
            self.cache.record_resumed();
            crate::obs::counter("journal.restored", 1);
        }
    }

    /// Process-level counters (what `kforge conformance` prints).
    pub fn snapshot(&self) -> CacheStats {
        self.cache.snapshot()
    }
}

static GLOBAL: OnceLock<Store> = OnceLock::new();

/// The process-wide store.  Defaults to [`Store::disabled`] until
/// [`configure`] installs one — the CLI does so at startup; library
/// consumers opt in explicitly.
pub fn global() -> &'static Store {
    GLOBAL.get_or_init(Store::disabled)
}

/// Install the process-wide store.  Must run before the first
/// [`global`] access (the CLI calls it first thing); errors if a store
/// is already installed.
pub fn configure(store: Store) -> Result<&'static Store> {
    let mut installed = false;
    let s = GLOBAL.get_or_init(|| {
        installed = true;
        store
    });
    anyhow::ensure!(installed, "store already configured for this process");
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_store_is_a_pass_through() {
        let s = Store::disabled();
        assert!(!s.enabled());
        assert!(!s.resume());
        let keys = Vec::new();
        assert!(s.journal_path("x", &keys).is_none());
        // the global default is disabled: tests and benches get cold
        // runs unless they opt in
        assert!(!global().enabled());
    }

    #[test]
    fn journal_path_sanitizes_and_pins_digest() {
        let dir = std::env::temp_dir().join(format!("kforge_store_jp_{}", std::process::id()));
        let s = Store::at_dir(&dir, true).unwrap();
        assert!(s.resume());
        let p = s.journal_path("weird name/with:stuff", &[]).unwrap();
        let file = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(file.starts_with("weird_name_with_stuff-"), "{file}");
        assert!(file.ends_with(".journal"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_store_has_no_journal() {
        let s = Store::memory();
        assert!(s.enabled());
        assert!(s.journal_path("c", &[]).is_none());
        assert!(s.shard_journal_path("c", &[], 4, 0).is_none());
        assert!(s.shared_dir().is_none());
    }

    #[test]
    fn shard_journal_path_keeps_digest_suffix() {
        let dir = std::env::temp_dir().join(format!("kforge_store_sjp_{}", std::process::id()));
        let s = Store::at_dir(&dir, false).unwrap();
        assert_eq!(s.shared_dir(), Some(dir.as_path()));
        let base = s.journal_path("quick-cuda", &[]).unwrap();
        let shard = s.shard_journal_path("quick-cuda", &[], 4, 2).unwrap();
        let base_file = base.file_name().unwrap().to_string_lossy().into_owned();
        let shard_file = shard.file_name().unwrap().to_string_lossy().into_owned();
        let digest = base_file.strip_suffix(".journal").unwrap().rsplit_once('-').unwrap().1;
        assert_eq!(shard_file, format!("quick-cuda-shard2of4-{digest}.journal"));
        assert_eq!(shard.parent(), base.parent());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
