//! Store statistics: per-campaign counters surfaced in
//! [`crate::coordinator::CampaignResult`] and process-wide atomic
//! counters behind `kforge cache stats`.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for one campaign (or one process, when snapshotted from
/// [`StatCounters`]).  All fields are plain totals; `Default` is all
/// zeros, which is also what a disabled store reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Jobs answered from the store (memory or disk) without running.
    pub hits: u64,
    /// Jobs that had to be computed.
    pub misses: u64,
    /// Jobs restored from a campaign journal by `--resume`.
    pub resumed: u64,
    /// Bytes read from disk entries (0 for memory hits).
    pub bytes_read: u64,
    /// Bytes written to disk entries.
    pub bytes_written: u64,
    /// Disk entries removed by `kforge cache gc`.
    pub evictions: u64,
}

impl CacheStats {
    /// Store lookups that could have been answered (hits + misses;
    /// resumed jobs never reached the cache).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups answered from the store (0.0 when none).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups() as f64
    }

    /// Counter delta since an `earlier` snapshot of the same counters
    /// (saturating, so a stale snapshot from another store reads as
    /// zeros rather than wrapping).  The serve path brackets its
    /// execution phase with two [`crate::store::Store::snapshot`]s and
    /// reports this difference.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            resumed: self.resumed.saturating_sub(earlier.resumed),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            bytes_written: self.bytes_written.saturating_sub(earlier.bytes_written),
            evictions: self.evictions.saturating_sub(earlier.evictions),
        }
    }
}

impl fmt::Display for CacheStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "hits={} misses={} resumed={} read={}B written={}B evictions={} hit_rate={:.1}%",
            self.hits,
            self.misses,
            self.resumed,
            self.bytes_read,
            self.bytes_written,
            self.evictions,
            self.hit_rate() * 100.0
        )
    }
}

/// Process-wide counters (lock-free; shared across every campaign that
/// consults one [`crate::store::Store`]).
#[derive(Debug, Default)]
pub struct StatCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    resumed: AtomicU64,
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
    evictions: AtomicU64,
}

impl StatCounters {
    pub const fn new() -> StatCounters {
        StatCounters {
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            resumed: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn record_hit(&self, bytes_read: u64) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes_read, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_resumed(&self) {
        self.resumed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_write(&self, bytes: u64) {
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_evictions(&self, n: u64) {
        self.evictions.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            resumed: self.resumed.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_and_lookups() {
        let s = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn counters_snapshot() {
        let c = StatCounters::new();
        c.record_hit(10);
        c.record_hit(0);
        c.record_miss();
        c.record_resumed();
        c.record_write(7);
        c.record_evictions(2);
        let s = c.snapshot();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.resumed, 1);
        assert_eq!(s.bytes_read, 10);
        assert_eq!(s.bytes_written, 7);
        assert_eq!(s.evictions, 2);
    }

    #[test]
    fn since_is_a_saturating_delta() {
        let early = CacheStats { hits: 2, misses: 1, bytes_written: 100, ..Default::default() };
        let late = CacheStats { hits: 5, misses: 1, bytes_written: 160, ..Default::default() };
        let d = late.since(&early);
        assert_eq!(d.hits, 3);
        assert_eq!(d.misses, 0);
        assert_eq!(d.bytes_written, 60);
        // a snapshot from the "future" saturates to zero, never wraps
        let weird = early.since(&late);
        assert_eq!(weird.hits, 0);
        assert_eq!(weird.bytes_written, 0);
    }

    #[test]
    fn display_is_greppable() {
        let s = CacheStats { hits: 12, misses: 4, ..Default::default() };
        let text = s.to_string();
        assert!(text.contains("hits=12") && text.contains("evictions=0"), "{text}");
        assert!(text.contains("hit_rate=75.0%"), "{text}");
    }
}
