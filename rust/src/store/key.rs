//! The canonical job fingerprint.
//!
//! A [`JobKey`] covers *everything* that determines a
//! [`crate::coordinator::TaskResult`], so a stored result may be
//! substituted for a fresh run only when the key matches exactly:
//!
//! - the store schema version ([`STORE_SCHEMA`]) and a **pipeline
//!   fingerprint** hashing the KIR rewrite-pass sources and
//!   `platform/spec.rs` at build time — editing a rewrite pass or a
//!   `PlatformSpec` field definition invalidates every cached entry
//!   automatically.  Semantic changes *outside* those files (the
//!   verifier, the simulator, the generation agent) must bump
//!   [`STORE_SCHEMA`] in the same PR;
//! - the campaign config knobs that feed the per-job RNG stream and the
//!   loop shape: config name, seed, iteration budget, profiling,
//!   reference mode, baseline kind;
//! - the platform: id, a structural hash over the full `PlatformSpec`,
//!   the registered profiler frontend, and the reference-transfer hook;
//! - the persona: name plus a hash of every behavioral rate *as
//!   resolved for this platform* (the calibration row, fallback
//!   applied), so adding a row for some other platform does not
//!   invalidate this one;
//! - the problem: id, level, structural hashes of the eval and perf
//!   graphs, op families and the §7.3/§7.4 tags;
//! - the reference program actually supplied to the job (or `none`).
//!
//! The key keeps its full canonical text alongside a 128-bit digest;
//! the cache verifies the text on every hit, so even a digest collision
//! degrades to a miss instead of a wrong substitution.

use crate::agents::{Persona, Program};
use crate::coordinator::experiment::ExperimentConfig;
use crate::platform::{Platform, PlatformRef, PlatformSpec};
use crate::util::rng::fnv1a;
use crate::workloads::Problem;
use std::sync::OnceLock;

/// Bump on any semantic change to the synthesis loop that the pipeline
/// fingerprint's source set does not cover (verifier, simulator,
/// agents, coordinator, search strategies).  Every bump invalidates
/// all stored results.
///
/// v2: the schedule autotuner PR — a new `BaselineKind::Autotuned`
/// campaign arm and a second stored object kind (`kforge-tunekey` tune
/// results, see `crate::search::tune`).
///
/// v3: the whole-model workloads PR — the level-4 suite tier
/// (multi-kernel DAG problems from `crate::model`, including the
/// synthetic suite's L4 slots) and the serve tier's streaming
/// semantics change what a cached serve-path result means, and the
/// model layer sits outside the pipeline fingerprint's source set.
///
/// v4: the distributed-campaigns PR — cross-problem schedule transfer
/// seeds the autotuner's population from family-mate schedules (the
/// [`family_fingerprint`] widening of the structural hash), which
/// changes what a cached tune entry means (its search trajectory now
/// depends on the family map), and the dist layer spans process
/// boundaries outside the pipeline fingerprint's source set.
pub const STORE_SCHEMA: u32 = 4;

/// Second FNV-1a chain over domain-separated input, so the digest is
/// 128 bits (two independent 64-bit chains), not one chain reused.
fn fnv1a_alt(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in b"kforge-store-alt\x00" {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash of the KIR rewrite pipeline and platform-spec *sources*, baked
/// in at compile time.  Editing any of these files changes the
/// fingerprint of every key the new binary computes, so stale disk
/// entries from the old binary can never be substituted.
pub fn pipeline_fingerprint() -> u64 {
    static FP: OnceLock<u64> = OnceLock::new();
    *FP.get_or_init(|| {
        let sources = [
            include_str!("../kir/patch.rs"),
            include_str!("../kir/rewrite/mod.rs"),
            include_str!("../kir/rewrite/constant_fold.rs"),
            include_str!("../kir/rewrite/algebraic.rs"),
            include_str!("../kir/rewrite/cse.rs"),
            include_str!("../kir/rewrite/fusion.rs"),
            include_str!("../platform/spec.rs"),
        ];
        let mut h: u64 = 0;
        for src in sources {
            h = h.rotate_left(17) ^ fnv1a(src.as_bytes());
        }
        h
    })
}

/// Bit-exact f64 rendering (IEEE-754 pattern in hex) — the one format
/// every stored f64 uses; `cache::parse_bits` is its inverse.
pub(crate) fn bits(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Structural hash over a full [`PlatformSpec`] (the derived `Debug`
/// rendering carries every field).  Shared by the campaign job key,
/// the tune key and the autotuned-baseline memo, so the three can
/// never hash different representations of the same spec.
pub fn spec_hash(spec: &PlatformSpec) -> u64 {
    fnv1a(format!("{spec:?}").as_bytes())
}

fn bits3(xs: &[f64; 3]) -> String {
    format!("{}{}{}", bits(xs[0]), bits(xs[1]), bits(xs[2]))
}

/// All persona rates that reach the generation path, with the
/// single-shot calibration resolved *for this platform* (fallback
/// applied), hashed to one value.
fn persona_fingerprint(p: &Persona, platform: &dyn Platform) -> u64 {
    let row = p.single_shot(platform);
    let text = format!(
        "{} {:?} reasoning {} row {} ref {} fix {} opt {} instr {} k {} sched {} pcf {} palg {} pgen {}",
        p.name,
        p.provider,
        p.reasoning,
        bits3(&row),
        bits3(&p.ref_effect),
        bits(p.fix_skill),
        bits(p.opt_skill),
        bits(p.instruction_following),
        p.internal_samples,
        bits3(&p.schedule_skill),
        bits(p.p_constant_fold),
        bits(p.p_algebraic),
        bits(p.p_generation_failure),
    );
    fnv1a(text.as_bytes())
}

/// Structural hash of a KIR graph: ops with all their parameters,
/// inferred shapes, declared inputs and outputs (the derived `Debug`
/// rendering carries every field).
pub fn graph_fingerprint(g: &crate::kir::Graph) -> u64 {
    fnv1a(format!("{g:?}").as_bytes())
}

/// Family hash of a KIR graph: deliberately coarser than
/// [`graph_fingerprint`].  The graph *name* is excluded, `ConstFill`
/// values are masked, and every dimension equal to the leading batch
/// dimension (input 0's first dim) renders as `B` — so two problems
/// that differ only in constants or batch size land in the same
/// family.  Structural parameters (op kinds, connectivity, strides,
/// kernel sizes, reduce axes, non-batch dims) all still distinguish.
///
/// Used ONLY to key cross-problem schedule *transfer* (population
/// seeding in the autotuner): every transferred seed is re-checked for
/// legality and re-costed, and the tuner keeps its naive fallback, so
/// an over-wide family can waste evaluations but never corrupt a
/// result.
pub fn family_fingerprint(g: &crate::kir::Graph) -> u64 {
    use crate::kir::Op;
    let batch = g.input_shapes.first().and_then(|s| s.dims().first()).copied();
    let dim = |d: usize| match batch {
        Some(b) if d == b => "B".to_string(),
        _ => d.to_string(),
    };
    let shape = |s: &crate::tensor::Shape| {
        let dims: Vec<String> = s.dims().iter().map(|&d| dim(d)).collect();
        format!("[{}]", dims.join(","))
    };
    let mut text = String::from("kforge-family v1\ninputs");
    for s in &g.input_shapes {
        text.push(' ');
        text.push_str(&shape(s));
    }
    text.push('\n');
    for (i, n) in g.nodes.iter().enumerate() {
        let body = match &n.op {
            Op::ConstFill { value: _, shape: sh } => format!("const * {}", shape(sh)),
            Op::Reshape { input, shape: sh } => format!("reshape %{input} {}", shape(sh)),
            other => {
                let args: Vec<String> = other.operands().iter().map(|o| format!("%{o}")).collect();
                let params = match other {
                    Op::Conv2d { stride, padding, .. }
                    | Op::DepthwiseConv2d { stride, padding, .. } => format!(" s{stride} p{padding}"),
                    Op::MaxPool2d { k, stride, .. } | Op::AvgPool2d { k, stride, .. } => {
                        format!(" k{k} s{stride}")
                    }
                    Op::Concat { axis, .. } => format!(" axis{axis}"),
                    _ => String::new(),
                };
                format!("{}{params} {}", other.mnemonic(), args.join(","))
            }
        };
        text.push_str(&format!("%{i} {body} -> {}\n", shape(&n.shape)));
    }
    let outs: Vec<String> = g.outputs.iter().map(|o| format!("%{o}")).collect();
    text.push_str(&format!("outputs {}\n", outs.join(",")));
    fnv1a(text.as_bytes())
}

fn reference_fingerprint(reference: Option<&Program>) -> String {
    match reference {
        None => "none".to_string(),
        Some(p) => format!("{:016x}", fnv1a(format!("{p:?}").as_bytes())),
    }
}

/// A computed job fingerprint: the canonical key text plus its 128-bit
/// digest.  Construct via [`KeyScope::key`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobKey {
    /// Canonical multi-line description (no trailing newline).  Stored
    /// verbatim in every cache entry and compared on hit.
    pub text: String,
    digest: [u64; 2],
}

impl JobKey {
    fn of_text(text: String) -> JobKey {
        let digest = [fnv1a(text.as_bytes()), fnv1a_alt(text.as_bytes())];
        JobKey { text, digest }
    }

    /// 32-hex-char content address (the on-disk object name).
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.digest[0], self.digest[1])
    }

    /// A key for a non-job object kind (e.g. the schedule autotuner's
    /// `kforge-tunekey` results).  The caller's text must begin with
    /// its own magic line so key *kinds* can never collide textually
    /// with job keys — the full text is still verified on every hit,
    /// so even a digest collision across kinds degrades to a miss.
    pub fn from_text(text: String) -> JobKey {
        JobKey::of_text(text)
    }
}

/// The per-campaign part of the key, computed once and reused for every
/// (persona, problem) job in the campaign.
pub struct KeyScope {
    head: String,
    platform: PlatformRef,
}

impl KeyScope {
    pub fn new(cfg: &ExperimentConfig, spec: &PlatformSpec) -> KeyScope {
        let frontend = cfg.platform.profiler_frontend();
        let head = format!(
            "kforge-jobkey v1\nschema {}\npipeline {:016x}\nconfig {}\nseed {:016x}\niterations {}\nprofiling {}\nreference_mode {}\nbaseline {:?}\nplatform {} spec {:016x} impl {:?} frontend {} transfer {}\n",
            STORE_SCHEMA,
            pipeline_fingerprint(),
            cfg.name,
            cfg.seed,
            cfg.iterations,
            cfg.use_profiling,
            cfg.use_reference,
            cfg.baseline,
            cfg.platform.name(),
            spec_hash(spec),
            cfg.platform,
            frontend.name(),
            cfg.platform.reference_transfer(),
        );
        KeyScope {
            head,
            platform: cfg.platform.clone(),
        }
    }

    /// The full key for one (persona, problem, reference) job.
    pub fn key(&self, persona: &Persona, problem: &Problem, reference: Option<&Program>) -> JobKey {
        let text = format!(
            "{}persona {} {:016x}\nproblem {} level {:?} eval {:016x} perf {:016x} families {} const {} red {}\nreference {}",
            self.head,
            persona.name,
            persona_fingerprint(persona, &*self.platform),
            problem.id,
            problem.level,
            graph_fingerprint(&problem.eval_graph),
            graph_fingerprint(&problem.perf_graph),
            problem.op_families.join(","),
            problem.constant_output,
            problem.reducible,
            reference_fingerprint(reference),
        );
        JobKey::of_text(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::persona::by_name;
    use crate::coordinator::experiment::BaselineKind;
    use crate::workloads::Suite;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "key_test".into(),
            platform: crate::platform::by_name("cuda").unwrap(),
            personas: vec![by_name("openai-gpt-5").unwrap()],
            iterations: 2,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 42,
            workers: 1,
        }
    }

    fn one_key(c: &ExperimentConfig) -> JobKey {
        let spec = c.spec();
        let suite = Suite::sample(1);
        KeyScope::new(c, &spec).key(c.personas[0], &suite.problems[0], None)
    }

    #[test]
    fn key_is_stable_and_text_addressed() {
        let a = one_key(&cfg());
        let b = one_key(&cfg());
        assert_eq!(a.text, b.text);
        assert_eq!(a.hex(), b.hex());
        assert_eq!(a.hex().len(), 32);
        assert!(a.text.contains(&format!("schema {STORE_SCHEMA}")));
        assert!(a.text.contains(&format!("pipeline {:016x}", pipeline_fingerprint())));
    }

    #[test]
    fn every_config_knob_flips_the_key() {
        let base = one_key(&cfg());
        let mutations: Vec<Box<dyn Fn(&mut ExperimentConfig)>> = vec![
            Box::new(|c| c.name = "other".into()),
            Box::new(|c| c.seed ^= 1),
            Box::new(|c| c.iterations += 1),
            Box::new(|c| c.use_profiling = true),
            Box::new(|c| c.use_reference = true),
            Box::new(|c| c.baseline = BaselineKind::TorchCompile),
            Box::new(|c| c.platform = crate::platform::by_name("rocm").unwrap()),
        ];
        for (i, m) in mutations.iter().enumerate() {
            let mut c = cfg();
            m(&mut c);
            assert_ne!(one_key(&c).hex(), base.hex(), "mutation {i} did not flip the key");
        }
        // worker count is deliberately NOT in the key: PR 3 proved pool
        // size never changes results, which is what makes cached
        // substitution safe across worker counts
        let mut c = cfg();
        c.workers = 16;
        assert_eq!(one_key(&c).hex(), base.hex());
    }

    #[test]
    fn spec_mutation_flips_the_key() {
        let c = cfg();
        let suite = Suite::sample(1);
        let spec = c.spec();
        let mut warped = spec.clone();
        warped.mem_bw *= 1.0 + 1e-12;
        let a = KeyScope::new(&c, &spec).key(c.personas[0], &suite.problems[0], None);
        let b = KeyScope::new(&c, &warped).key(c.personas[0], &suite.problems[0], None);
        assert_ne!(a.hex(), b.hex());
    }

    #[test]
    fn persona_mutation_flips_the_key() {
        let c = cfg();
        let spec = c.spec();
        let suite = Suite::sample(1);
        let scope = KeyScope::new(&c, &spec);
        let base = scope.key(c.personas[0], &suite.problems[0], None);
        let mut warped = c.personas[0].clone();
        warped.fix_skill += 1e-9;
        assert_ne!(scope.key(&warped, &suite.problems[0], None).hex(), base.hex());
        let mut warped_row = c.personas[0].clone();
        warped_row.ref_effect[1] += 1e-9;
        assert_ne!(scope.key(&warped_row, &suite.problems[0], None).hex(), base.hex());
    }

    #[test]
    fn problem_and_reference_flip_the_key() {
        let c = cfg();
        let spec = c.spec();
        let suite = Suite::sample(2);
        let scope = KeyScope::new(&c, &spec);
        let a = scope.key(c.personas[0], &suite.problems[0], None);
        let b = scope.key(c.personas[0], &suite.problems[1], None);
        assert_ne!(a.hex(), b.hex());
        // a supplied reference program distinguishes the job from a
        // reference-free one even with identical knobs
        let corpus = crate::workloads::refcorpus::RefCorpus::build(&Suite::sample(1), 6, 3);
        if let Some(prog) = corpus.get(&suite.problems[0].id) {
            let with_ref = scope.key(c.personas[0], &suite.problems[0], Some(prog));
            assert_ne!(with_ref.hex(), a.hex());
        }
    }

    #[test]
    fn family_hash_ignores_name_batch_and_constants_but_not_structure() {
        use crate::kir::{GraphBuilder, Op, UnaryKind};
        use crate::tensor::Shape;
        let mm = |name: &str, m: usize, k: usize, n: usize, fill: f32| {
            let mut b = GraphBuilder::new(name);
            let x = b.input(Shape::of(&[m, k]));
            let w = b.input(Shape::of(&[k, n]));
            let p = b.matmul(x, w);
            let c = b.push(Op::ConstFill { value: fill, shape: Shape::of(&[m, n]) });
            let s = b.add(p, c);
            b.finish(vec![s])
        };
        let base = mm("a", 16, 4096, 2048, 0.5);
        // name, batch dim, and constant value are all family-invisible
        assert_eq!(family_fingerprint(&base), family_fingerprint(&mm("b", 16, 4096, 2048, 0.5)));
        assert_eq!(family_fingerprint(&base), family_fingerprint(&mm("a", 1, 4096, 2048, 0.5)));
        assert_eq!(family_fingerprint(&base), family_fingerprint(&mm("a", 64, 4096, 2048, 0.5)));
        assert_eq!(family_fingerprint(&base), family_fingerprint(&mm("a", 16, 4096, 2048, -3.0)));
        // but each of these flips the exact structural hash
        assert_ne!(graph_fingerprint(&base), graph_fingerprint(&mm("a", 1, 4096, 2048, 0.5)));
        assert_ne!(graph_fingerprint(&base), graph_fingerprint(&mm("a", 16, 4096, 2048, -3.0)));
        // non-batch dims and op structure still distinguish families
        assert_ne!(family_fingerprint(&base), family_fingerprint(&mm("a", 16, 4096, 1024, 0.5)));
        assert_ne!(family_fingerprint(&base), family_fingerprint(&mm("a", 16, 2048, 2048, 0.5)));
        let mut b = GraphBuilder::new("a");
        let x = b.input(Shape::of(&[16, 4096]));
        let w = b.input(Shape::of(&[4096, 2048]));
        let p = b.matmul(x, w);
        let r = b.unary(UnaryKind::Relu, p);
        let relu_tail = b.finish(vec![r]);
        assert_ne!(family_fingerprint(&base), family_fingerprint(&relu_tail));
        // square matmuls of any size normalize to one [B,B]x[B,B] family
        let sq = |n: usize| {
            let mut b = GraphBuilder::new("sq");
            let x = b.input(Shape::of(&[n, n]));
            let w = b.input(Shape::of(&[n, n]));
            let p = b.matmul(x, w);
            b.finish(vec![p])
        };
        assert_eq!(family_fingerprint(&sq(256)), family_fingerprint(&sq(1024)));
        assert_ne!(graph_fingerprint(&sq(256)), graph_fingerprint(&sq(1024)));
        // conv stride is structural: it changes the family
        let conv = |stride: usize| {
            let mut b = GraphBuilder::new("c");
            let x = b.input(Shape::of(&[2, 8, 32, 32]));
            let w = b.input(Shape::of(&[16, 8, 3, 3]));
            let c = b.conv2d(x, w, stride, 1);
            b.finish(vec![c])
        };
        assert_ne!(family_fingerprint(&conv(1)), family_fingerprint(&conv(2)));
    }

    #[test]
    fn digest_chains_are_independent() {
        // the two 64-bit chains must not be the same function
        let k = one_key(&cfg());
        assert_ne!(&k.hex()[..16], &k.hex()[16..]);
        assert_ne!(fnv1a(b"x"), fnv1a_alt(b"x"));
    }
}
