//! The content-addressed result store: an in-memory map over
//! versioned on-disk entries.
//!
//! Entries are addressed by [`JobKey::hex`] and carry the full key
//! text, which is re-verified on every hit — a digest collision, a
//! truncated file, or plain garbage all degrade to a *logged miss*,
//! never a crash and never a wrong substitution.
//!
//! Serialization is bit-exact: every `f64` is stored as its IEEE-754
//! bit pattern in hex, so a result loaded from disk is
//! indistinguishable (by `to_bits`) from the freshly computed one —
//! the property the warm-vs-cold conformance guarantee rests on.

use super::key::JobKey;
use super::stats::StatCounters;
use crate::coordinator::job::TaskResult;
use crate::metrics::TaskOutcome;
use crate::workloads::Level;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

pub const ENTRY_MAGIC: &str = "kforge-cache v1";
const RESULT_END: &str = "end kforge-result";

/// Intern a string, returning a `&'static str` — `TaskResult.persona`
/// is a static reference, so deserialized names must live forever.
/// The pool is tiny (one entry per distinct persona name seen), and a
/// name is only interned *after* the entry parses cleanly, so corrupt
/// data never leaks.
pub(crate) fn intern(s: &str) -> &'static str {
    static POOL: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    let mut pool = POOL.get_or_init(|| Mutex::new(Vec::new())).lock().unwrap();
    if let Some(hit) = pool.iter().find(|x| **x == s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    pool.push(leaked);
    leaked
}

/// Map a stored state label back to the verifier's static label set;
/// an unknown label means a corrupt entry, not a new allocation.
fn state_label(s: &str) -> Result<&'static str> {
    Ok(match s {
        "generation_failure" => "generation_failure",
        "compilation_failure" => "compilation_failure",
        "runtime_error" => "runtime_error",
        "mismatch" => "mismatch",
        "correct" => "correct",
        other => bail!("unknown state label {other:?}"),
    })
}

fn level_name(level: Level) -> &'static str {
    level.tag()
}

fn parse_level(s: &str) -> Result<Level> {
    Level::from_tag(s).ok_or_else(|| anyhow::anyhow!("unknown level {s:?}"))
}

/// Strict inverse of `key::bits` — the one f64 bit-pattern parser every
/// stored object kind shares.
pub(crate) fn parse_bits(s: &str) -> Result<f64> {
    let raw = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(raw))
}

/// Serialize one result, bit-exact, ending with a trailer line that
/// detects truncation.
pub fn serialize_result(r: &TaskResult) -> String {
    let states = if r.state_history.is_empty() {
        "-".to_string()
    } else {
        r.state_history.join(",")
    };
    let best_iteration = match r.best_iteration {
        Some(i) => i.to_string(),
        None => "none".to_string(),
    };
    let best_candidate_s = match r.best_candidate_s {
        Some(t) => format!("{:016x}", t.to_bits()),
        None => "none".to_string(),
    };
    format!(
        "problem_id {}\nlevel {}\npersona {}\nstates {}\ncorrect {}\nspeedup {:016x}\nbest_iteration {}\nbaseline_s {:016x}\nbest_candidate_s {}\n{}\n",
        r.problem_id,
        level_name(r.level),
        r.persona,
        states,
        r.outcome.correct,
        r.outcome.speedup.to_bits(),
        best_iteration,
        r.baseline_s.to_bits(),
        best_candidate_s,
        RESULT_END,
    )
}

/// Strict inverse of [`serialize_result`]: any missing field, unknown
/// label, malformed number, or absent trailer is an error (= a miss).
pub fn parse_result(text: &str) -> Result<TaskResult> {
    let mut lines = text.lines();
    let mut field = |name: &str| -> Result<String> {
        let line = lines.next().with_context(|| format!("entry truncated before {name}"))?;
        let value = line
            .strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .with_context(|| format!("expected {name:?} line, got {line:?}"))?;
        Ok(value.to_string())
    };
    let problem_id = field("problem_id")?;
    let level = parse_level(&field("level")?)?;
    let persona_name = field("persona")?;
    let states_raw = field("states")?;
    let correct = match field("correct")?.as_str() {
        "true" => true,
        "false" => false,
        other => bail!("bad correct flag {other:?}"),
    };
    let speedup = parse_bits(&field("speedup")?)?;
    let best_iteration = match field("best_iteration")?.as_str() {
        "none" => None,
        n => Some(n.parse::<usize>().with_context(|| format!("bad best_iteration {n:?}"))?),
    };
    let baseline_s = parse_bits(&field("baseline_s")?)?;
    let best_candidate_s = match field("best_candidate_s")?.as_str() {
        "none" => None,
        bits => Some(parse_bits(bits)?),
    };
    match lines.next() {
        Some(RESULT_END) => {}
        other => bail!("missing result trailer (got {other:?})"),
    }
    if lines.next().is_some() {
        bail!("trailing data after result trailer");
    }
    let state_history = if states_raw == "-" {
        Vec::new()
    } else {
        states_raw.split(',').map(state_label).collect::<Result<Vec<_>>>()?
    };
    Ok(TaskResult {
        problem_id,
        level,
        persona: intern(&persona_name),
        state_history,
        outcome: if correct { TaskOutcome::correct(speedup) } else { TaskOutcome { correct: false, speedup } },
        best_iteration,
        baseline_s,
        best_candidate_s,
    })
}

/// One on-disk entry: magic, content address, the exact key text
/// (length-prefixed — it is multi-line), then the result block.
pub fn serialize_entry(key: &JobKey, r: &TaskResult) -> String {
    format!(
        "{ENTRY_MAGIC}\nkey {}\nkeytext {}\n{}\n{}",
        key.hex(),
        key.text.len(),
        key.text,
        serialize_result(r),
    )
}

/// Strip the shared entry envelope (magic, content address, verified
/// key text) and return the payload body.  The stored key text must
/// match byte-for-byte, so a digest collision is an error (= a miss)
/// for every object kind.
fn parse_envelope<'a>(data: &'a str, key: &JobKey) -> Result<&'a str> {
    let rest = data
        .strip_prefix(ENTRY_MAGIC)
        .and_then(|r| r.strip_prefix('\n'))
        .context("bad entry magic")?;
    let (key_line, rest) = rest.split_once('\n').context("entry truncated at key line")?;
    let hex = key_line.strip_prefix("key ").context("missing key line")?;
    if hex != key.hex() {
        bail!("entry addressed to {hex}, expected {}", key.hex());
    }
    let (len_line, rest) = rest.split_once('\n').context("entry truncated at keytext line")?;
    let len: usize = len_line
        .strip_prefix("keytext ")
        .and_then(|n| n.parse().ok())
        .context("bad keytext length")?;
    // byte-compare before slicing: a corrupt length must not be able
    // to panic on a UTF-8 boundary (or overflow `len + 1`), only to miss
    let end = len.checked_add(1).context("absurd keytext length")?;
    let bytes = rest.as_bytes();
    if bytes.len() < end {
        bail!("entry truncated inside key text");
    }
    if &bytes[..len] != key.text.as_bytes() {
        bail!("key text mismatch (digest collision)");
    }
    if bytes[len] != b'\n' {
        bail!("missing newline after key text");
    }
    // the prefix equals key.text (valid UTF-8) and byte len is '\n',
    // so len + 1 is a char boundary
    Ok(&rest[len + 1..])
}

/// Parse a result entry *for a specific key*.
pub fn parse_entry(data: &str, key: &JobKey) -> Result<TaskResult> {
    parse_result(parse_envelope(data, key)?)
}

const BLOB_END: &str = "end kforge-blob";

/// Serialize a raw-text object entry — the second stored kind, used
/// for non-`TaskResult` key kinds (the schedule autotuner's tune
/// results).  Same envelope as [`serialize_entry`], with the payload
/// length-prefixed and trailed so truncation is always detectable.
pub fn serialize_blob_entry(key: &JobKey, payload: &str) -> String {
    format!(
        "{ENTRY_MAGIC}\nkey {}\nkeytext {}\n{}\nblob {}\n{}\n{BLOB_END}\n",
        key.hex(),
        key.text.len(),
        key.text,
        payload.len(),
        payload,
    )
}

/// Strict inverse of [`serialize_blob_entry`]: envelope verified, then
/// the payload length and trailer must match exactly.
pub fn parse_blob_entry(data: &str, key: &JobKey) -> Result<String> {
    let body = parse_envelope(data, key)?;
    let (len_line, rest) = body.split_once('\n').context("entry truncated at blob line")?;
    let len: usize = len_line
        .strip_prefix("blob ")
        .and_then(|n| n.parse().ok())
        .context("bad blob length")?;
    let trailer = format!("\n{BLOB_END}\n");
    let expected = len.checked_add(trailer.len()).context("absurd blob length")?;
    let bytes = rest.as_bytes();
    if bytes.len() != expected {
        bail!("blob length mismatch ({} bytes, expected {expected})", bytes.len());
    }
    if &bytes[len..] != trailer.as_bytes() {
        bail!("missing blob trailer");
    }
    // the byte at `len` is the trailer's '\n', so `len` is a char boundary
    Ok(rest[..len].to_string())
}

struct CacheSlot {
    keytext: String,
    result: TaskResult,
}

/// In-memory + optional on-disk content-addressed store.  Two object
/// kinds share the address space and the disk directory: `TaskResult`
/// entries and raw-text blob entries (tune results); their key texts
/// start with different magic lines, so the kinds can never collide.
pub struct Cache {
    mem: Mutex<HashMap<String, CacheSlot>>,
    blob_mem: Mutex<HashMap<String, (String, String)>>,
    dir: Option<PathBuf>,
    counters: StatCounters,
}

impl Cache {
    /// Memory-only store (one process's harness modules share it).
    pub fn memory() -> Cache {
        Cache {
            mem: Mutex::new(HashMap::new()),
            blob_mem: Mutex::new(HashMap::new()),
            dir: None,
            counters: StatCounters::new(),
        }
    }

    /// Disk-backed store rooted at `dir` (objects under `dir/objects`).
    pub fn at(dir: &Path) -> Result<Cache> {
        let objects = dir.join("objects");
        std::fs::create_dir_all(&objects)
            .with_context(|| format!("creating cache dir {}", objects.display()))?;
        Ok(Cache {
            mem: Mutex::new(HashMap::new()),
            blob_mem: Mutex::new(HashMap::new()),
            dir: Some(dir.to_path_buf()),
            counters: StatCounters::new(),
        })
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    fn object_path(&self, hex: &str) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join("objects").join(hex))
    }

    /// Persist `entry` at `path` via temp-file + atomic rename, so a
    /// concurrent reader can never observe a torn object and two
    /// writers (threads *or* processes) can never interleave — the
    /// loser's rename simply replaces the winner's identical bytes.
    /// The temp name carries pid + a per-process sequence number:
    /// pid alone collides when two threads of one process race the
    /// same key.
    fn persist_atomic(path: &Path, entry: &str) -> u64 {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let file = path.file_name().map(|f| f.to_string_lossy().into_owned()).unwrap_or_default();
        let tmp = path.with_file_name(format!("{file}.tmp.{}.{seq}", std::process::id()));
        let written = std::fs::write(&tmp, entry)
            .and_then(|()| std::fs::rename(&tmp, path))
            .map(|()| entry.len() as u64);
        match written {
            Ok(bytes) => bytes,
            Err(e) => {
                crate::kf_error!("[store] failed to persist cache entry {} ({e})", path.display());
                let _ = std::fs::remove_file(&tmp);
                0
            }
        }
    }

    /// Look up a key.  Returns the result plus the bytes read from
    /// disk (0 for a memory hit).  Any disk anomaly is a logged miss.
    pub fn get(&self, key: &JobKey) -> Option<(TaskResult, u64)> {
        let hex = key.hex();
        {
            let mem = self.mem.lock().unwrap();
            if let Some(slot) = mem.get(&hex) {
                if slot.keytext == key.text {
                    self.counters.record_hit(0);
                    return Some((slot.result.clone(), 0));
                }
                // in-memory digest collision: fall through as a miss
            }
        }
        if let Some(path) = self.object_path(&hex) {
            match std::fs::read_to_string(&path) {
                Ok(data) => match parse_entry(&data, key) {
                    Ok(result) => {
                        let bytes = data.len() as u64;
                        self.counters.record_hit(bytes);
                        self.mem.lock().unwrap().insert(
                            hex,
                            CacheSlot { keytext: key.text.clone(), result: result.clone() },
                        );
                        return Some((result, bytes));
                    }
                    Err(e) => {
                        crate::kf_warn!(
                            "[store] corrupt cache entry {} ({e:#}); treating as a miss",
                            path.display()
                        );
                    }
                },
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    crate::kf_warn!("[store] unreadable cache entry {} ({e}); treating as a miss", path.display());
                }
            }
        }
        self.counters.record_miss();
        None
    }

    /// Store a result.  Returns bytes written to disk (0 when
    /// memory-only).  Disk failures are logged, never fatal — the
    /// campaign result is already in hand.
    pub fn put(&self, key: &JobKey, r: &TaskResult) -> u64 {
        let hex = key.hex();
        self.mem.lock().unwrap().insert(
            hex.clone(),
            CacheSlot { keytext: key.text.clone(), result: r.clone() },
        );
        let Some(path) = self.object_path(&hex) else {
            return 0;
        };
        let entry = serialize_entry(key, r);
        let bytes = Self::persist_atomic(&path, &entry);
        if bytes > 0 {
            self.counters.record_write(bytes);
        }
        bytes
    }

    /// Look up a raw-text blob by key.  Same contract as [`Cache::get`]:
    /// the result plus bytes read from disk, any anomaly a logged miss.
    pub fn get_blob(&self, key: &JobKey) -> Option<(String, u64)> {
        self.get_blob_checked(key, |payload| Ok(payload.to_string()))
    }

    /// Like [`Cache::get_blob`], but the caller's `parse` validates the
    /// payload *before* the lookup counts as a hit — mirroring how
    /// [`Cache::get`] fully parses a `TaskResult` entry before recording
    /// one.  A payload the caller cannot parse is a corrupt entry: a
    /// logged miss in the process counters, never a hit followed by a
    /// silent recompute.
    pub fn get_blob_checked<T>(
        &self,
        key: &JobKey,
        parse: impl Fn(&str) -> Result<T>,
    ) -> Option<(T, u64)> {
        let hex = key.hex();
        {
            let mem = self.blob_mem.lock().unwrap();
            if let Some((keytext, payload)) = mem.get(&hex) {
                if *keytext == key.text {
                    if let Ok(value) = parse(payload) {
                        self.counters.record_hit(0);
                        return Some((value, 0));
                    }
                    // unparseable memory payload: fall through as a miss
                }
                // in-memory digest collision: fall through as a miss
            }
        }
        if let Some(path) = self.object_path(&hex) {
            match std::fs::read_to_string(&path) {
                Ok(data) => {
                    let parsed = parse_blob_entry(&data, key)
                        .and_then(|payload| parse(&payload).map(|value| (payload, value)));
                    match parsed {
                        Ok((payload, value)) => {
                            let bytes = data.len() as u64;
                            self.counters.record_hit(bytes);
                            self.blob_mem
                                .lock()
                                .unwrap()
                                .insert(hex, (key.text.clone(), payload));
                            return Some((value, bytes));
                        }
                        Err(e) => {
                            crate::kf_warn!(
                                "[store] corrupt cache entry {} ({e:#}); treating as a miss",
                                path.display()
                            );
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    crate::kf_warn!("[store] unreadable cache entry {} ({e}); treating as a miss", path.display());
                }
            }
        }
        self.counters.record_miss();
        None
    }

    /// Store a raw-text blob.  Same contract as [`Cache::put`]: returns
    /// bytes written to disk, disk failures logged and never fatal.
    pub fn put_blob(&self, key: &JobKey, payload: &str) -> u64 {
        let hex = key.hex();
        self.blob_mem
            .lock()
            .unwrap()
            .insert(hex.clone(), (key.text.clone(), payload.to_string()));
        let Some(path) = self.object_path(&hex) else {
            return 0;
        };
        let entry = serialize_blob_entry(key, payload);
        let bytes = Self::persist_atomic(&path, &entry);
        if bytes > 0 {
            self.counters.record_write(bytes);
        }
        bytes
    }

    /// All on-disk objects as (path, bytes, modified-time).
    pub fn disk_entries(&self) -> Result<Vec<(PathBuf, u64, std::time::SystemTime)>> {
        let Some(dir) = self.dir.as_ref() else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir.join("objects"))? {
            let entry = entry?;
            // in-flight (or crash-orphaned) temp files are not objects
            if entry.file_name().to_string_lossy().contains(".tmp.") {
                continue;
            }
            let meta = entry.metadata()?;
            if meta.is_file() {
                out.push((
                    entry.path(),
                    meta.len(),
                    meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH),
                ));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Drop every entry (memory and disk objects).  Returns the number
    /// of disk objects removed.
    pub fn clear(&self) -> Result<usize> {
        self.mem.lock().unwrap().clear();
        self.blob_mem.lock().unwrap().clear();
        let mut removed = 0;
        for (path, _, _) in self.disk_entries()? {
            std::fs::remove_file(&path)?;
            removed += 1;
        }
        Ok(removed)
    }

    /// Evict oldest-first until the on-disk footprint fits
    /// `max_bytes`.  Returns (evicted count, bytes kept).
    ///
    /// Eviction honors the lease protocol: while any `.lease` under
    /// the cache dir is active, objects written at or after the oldest
    /// acquisition are never removed — a gc racing an in-flight
    /// campaign cannot delete a just-written object that a shard's
    /// journal already references.  Entries are walked oldest-first,
    /// so the first protected entry ends the sweep.
    pub fn gc(&self, max_bytes: u64) -> Result<(usize, u64)> {
        let mut entries = self.disk_entries()?;
        entries.sort_by_key(|(_, _, mtime)| *mtime);
        let floor = self.dir.as_deref().and_then(super::lease::active_floor);
        let mut total: u64 = entries.iter().map(|(_, b, _)| *b).sum();
        let mut evicted = 0;
        for (path, bytes, mtime) in &entries {
            if total <= max_bytes {
                break;
            }
            if floor.is_some_and(|f| *mtime >= f) {
                crate::kf_warn!(
                    "[store] gc stopping early: {} object(s) protected by an active lease",
                    entries.len() - evicted as usize
                );
                break;
            }
            std::fs::remove_file(path)?;
            total -= bytes;
            evicted += 1;
        }
        // evicted disk entries may still sit in this process's memory
        // tier; that is fine (they are valid results), but the CLI's gc
        // runs in its own short-lived process anyway
        self.counters.record_evictions(evicted as u64);
        Ok((evicted as usize, total))
    }

    /// Count a journal-restored job in the process counters (restored
    /// jobs never touch `get`, so they would otherwise be invisible to
    /// the `cache:` line the CLI prints from the global snapshot).
    pub fn record_resumed(&self) {
        self.counters.record_resumed();
    }

    pub fn snapshot(&self) -> super::stats::CacheStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::{BaselineKind, ExperimentConfig};
    use crate::store::key::KeyScope;
    use crate::workloads::Suite;

    fn sample_result() -> TaskResult {
        TaskResult {
            problem_id: "l1_test_0".into(),
            level: Level::L2,
            persona: "openai-gpt-5",
            state_history: vec!["mismatch", "correct"],
            outcome: TaskOutcome::correct(1.0 / 3.0),
            best_iteration: Some(1),
            baseline_s: f64::MIN_POSITIVE,
            best_candidate_s: Some(2.7e-5),
        }
    }

    fn sample_key() -> JobKey {
        let cfg = ExperimentConfig {
            name: "cache_test".into(),
            platform: crate::platform::by_name("cuda").unwrap(),
            personas: vec![crate::agents::persona::by_name("openai-gpt-5").unwrap()],
            iterations: 1,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 1,
            workers: 1,
        };
        let spec = cfg.spec();
        let suite = Suite::sample(1);
        KeyScope::new(&cfg, &spec).key(cfg.personas[0], &suite.problems[0], None)
    }

    fn assert_bit_identical(a: &TaskResult, b: &TaskResult) {
        assert_eq!(a.problem_id, b.problem_id);
        assert_eq!(a.level, b.level);
        assert_eq!(a.persona, b.persona);
        assert_eq!(a.state_history, b.state_history);
        assert_eq!(a.outcome.correct, b.outcome.correct);
        assert_eq!(a.outcome.speedup.to_bits(), b.outcome.speedup.to_bits());
        assert_eq!(a.best_iteration, b.best_iteration);
        assert_eq!(a.baseline_s.to_bits(), b.baseline_s.to_bits());
        assert_eq!(a.best_candidate_s.map(f64::to_bits), b.best_candidate_s.map(f64::to_bits));
    }

    #[test]
    fn result_roundtrip_is_bit_exact() {
        let r = sample_result();
        assert_bit_identical(&parse_result(&serialize_result(&r)).unwrap(), &r);
        // incorrect outcome, empty history, None options
        let r2 = TaskResult {
            problem_id: "x".into(),
            level: Level::L3,
            persona: "deepseek-v3",
            state_history: vec![],
            outcome: TaskOutcome::incorrect(),
            best_iteration: None,
            baseline_s: 1.0 + f64::EPSILON,
            best_candidate_s: None,
        };
        assert_bit_identical(&parse_result(&serialize_result(&r2)).unwrap(), &r2);
    }

    #[test]
    fn parse_rejects_malformed_results() {
        let good = serialize_result(&sample_result());
        // truncation at every interior line boundary (dropping only the
        // final newline still leaves a complete record — lines() treats
        // a missing trailing newline identically)
        for (i, _) in good.match_indices('\n') {
            if i + 1 == good.len() {
                continue;
            }
            assert!(parse_result(&good[..i]).is_err(), "truncated at byte {i} parsed");
        }
        assert!(parse_result(&good.replace("correct true", "correct maybe")).is_err());
        assert!(parse_result(&good.replace("mismatch", "vibes")).is_err());
        assert!(parse_result(&good.replace("level L2", "level L9")).is_err());
        assert!(parse_result(&format!("{good}trailing\n")).is_err());
        assert!(parse_result("").is_err());
    }

    #[test]
    fn entry_roundtrip_and_collision_detection() {
        let key = sample_key();
        let r = sample_result();
        let entry = serialize_entry(&key, &r);
        assert_bit_identical(&parse_entry(&entry, &key).unwrap(), &r);
        // same entry presented for a different key = collision = error
        let other = {
            let cfg = ExperimentConfig {
                name: "cache_test_other".into(),
                platform: crate::platform::by_name("cuda").unwrap(),
                personas: vec![crate::agents::persona::by_name("openai-gpt-5").unwrap()],
                iterations: 1,
                use_profiling: false,
                use_reference: false,
                baseline: BaselineKind::Eager,
                seed: 1,
                workers: 1,
            };
            let spec = cfg.spec();
            let suite = Suite::sample(1);
            KeyScope::new(&cfg, &spec).key(cfg.personas[0], &suite.problems[0], None)
        };
        assert!(parse_entry(&entry, &other).is_err());
        // truncated entries never parse
        for cut in [10, entry.len() / 2, entry.len() - 2] {
            assert!(parse_entry(&entry[..cut], &key).is_err(), "cut at {cut} parsed");
        }
        // an absurd keytext length must error (miss), not overflow/panic
        let huge = entry.replace(
            &format!("keytext {}", key.text.len()),
            "keytext 18446744073709551615",
        );
        assert!(parse_entry(&huge, &key).is_err());
    }

    #[test]
    fn memory_cache_roundtrip() {
        let cache = Cache::memory();
        let key = sample_key();
        assert!(cache.get(&key).is_none());
        cache.put(&key, &sample_result());
        let (got, bytes) = cache.get(&key).unwrap();
        assert_eq!(bytes, 0);
        assert_bit_identical(&got, &sample_result());
        let s = cache.snapshot();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn disk_cache_roundtrip_and_corruption_tolerance() {
        let dir = std::env::temp_dir().join(format!("kforge_cache_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let key = sample_key();
        {
            let cache = Cache::at(&dir).unwrap();
            assert!(cache.put(&key, &sample_result()) > 0);
        }
        // a fresh instance (fresh memory tier) reads it back from disk
        let cache = Cache::at(&dir).unwrap();
        let (got, bytes) = cache.get(&key).unwrap();
        assert!(bytes > 0);
        assert_bit_identical(&got, &sample_result());
        // truncate the object: a new instance must report a miss
        let path = dir.join("objects").join(key.hex());
        let data = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &data[..data.len() / 2]).unwrap();
        let cold = Cache::at(&dir).unwrap();
        assert!(cold.get(&key).is_none(), "truncated entry must miss");
        // garbage object: also a miss
        std::fs::write(&path, "not a cache entry at all").unwrap();
        let cold2 = Cache::at(&dir).unwrap();
        assert!(cold2.get(&key).is_none(), "garbage entry must miss");
        let s = cold2.snapshot();
        assert_eq!((s.hits, s.misses), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_and_gc() {
        let dir = std::env::temp_dir().join(format!("kforge_cache_gc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = Cache::at(&dir).unwrap();
        let key = sample_key();
        cache.put(&key, &sample_result());
        assert_eq!(cache.disk_entries().unwrap().len(), 1);
        // gc with a huge budget keeps everything
        let (evicted, _) = cache.gc(u64::MAX).unwrap();
        assert_eq!(evicted, 0);
        // gc to zero evicts everything
        let (evicted, kept) = cache.gc(0).unwrap();
        assert_eq!(evicted, 1);
        assert_eq!(kept, 0);
        cache.put(&key, &sample_result());
        assert_eq!(cache.clear().unwrap(), 1);
        assert_eq!(cache.disk_entries().unwrap().len(), 0);
        assert!(cache.snapshot().evictions >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn blob_key(tag: &str) -> JobKey {
        JobKey::from_text(format!("kforge-tunekey v-test\ntag {tag}\n"))
    }

    #[test]
    fn blob_entry_roundtrip_truncation_and_collision() {
        let key = blob_key("rt");
        let payload = "problem_id x\ntuned_s 3ff0000000000000\n";
        let entry = serialize_blob_entry(&key, payload);
        assert_eq!(parse_blob_entry(&entry, &key).unwrap(), payload);
        // wrong key = collision = error
        assert!(parse_blob_entry(&entry, &blob_key("other")).is_err());
        // a result entry never parses as a blob and vice versa
        assert!(parse_entry(&entry, &key).is_err());
        let result_entry = serialize_entry(&sample_key(), &sample_result());
        assert!(parse_blob_entry(&result_entry, &sample_key()).is_err());
        // truncation anywhere is an error, never a partial payload
        for cut in [5, entry.len() / 2, entry.len() - 1] {
            assert!(parse_blob_entry(&entry[..cut], &key).is_err(), "cut at {cut} parsed");
        }
        // trailing garbage is an error too
        assert!(parse_blob_entry(&format!("{entry}x"), &key).is_err());
        // a lying length must miss, not panic — including one pointing
        // into the middle of a multi-byte char
        let uni = serialize_blob_entry(&key, "héllo∀");
        assert!(parse_blob_entry(&uni, &key).is_ok());
        let lied = uni.replace(&format!("blob {}", "héllo∀".len()), "blob 2");
        assert!(parse_blob_entry(&lied, &key).is_err());
    }

    #[test]
    fn blob_cache_roundtrip_memory_and_disk() {
        let key = blob_key("cache");
        let cache = Cache::memory();
        assert!(cache.get_blob(&key).is_none());
        cache.put_blob(&key, "payload one");
        assert_eq!(cache.get_blob(&key).unwrap(), ("payload one".to_string(), 0));
        // blobs and results do not shadow each other in memory
        assert!(cache.get(&key).is_none());

        let dir = std::env::temp_dir().join(format!("kforge_cache_blob_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let disk = Cache::at(&dir).unwrap();
            assert!(disk.put_blob(&key, "persisted") > 0);
        }
        let fresh = Cache::at(&dir).unwrap();
        let (payload, bytes) = fresh.get_blob(&key).unwrap();
        assert_eq!(payload, "persisted");
        assert!(bytes > 0);
        // vandalized blob objects degrade to misses
        let path = dir.join("objects").join(key.hex());
        std::fs::write(&path, "garbage").unwrap();
        let cold = Cache::at(&dir).unwrap();
        assert!(cold.get_blob(&key).is_none());
        // clear drops the blob memory tier too
        let again = Cache::at(&dir).unwrap();
        again.put_blob(&key, "back");
        again.clear().unwrap();
        assert!(again.get_blob(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_entries_ignore_inflight_temp_files() {
        let dir = std::env::temp_dir().join(format!("kforge_cache_tmpf_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = Cache::at(&dir).unwrap();
        let key = sample_key();
        cache.put(&key, &sample_result());
        // a crash-orphaned temp file must not count as an object (nor
        // be evictable garbage that gc trips over)
        std::fs::write(dir.join("objects").join(format!("{}.tmp.999.0", key.hex())), "partial")
            .unwrap();
        let entries = cache.disk_entries().unwrap();
        assert_eq!(entries.len(), 1, "{entries:?}");
        assert_eq!(cache.gc(0).unwrap().0, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_never_evicts_objects_written_under_an_active_lease() {
        use std::time::{Duration, SystemTime};
        let dir = std::env::temp_dir().join(format!("kforge_cache_lease_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = Cache::at(&dir).unwrap();
        let set_mtime = |path: &Path, t: SystemTime| {
            std::fs::File::options().write(true).open(path).unwrap().set_modified(t).unwrap()
        };
        // injected ordering: object A written, then a writer takes its
        // lease, then object B lands — gc to zero must evict A (below
        // the floor) but keep B (a journal may already reference it)
        let base = SystemTime::now() - Duration::from_secs(600);
        let key_a = sample_key();
        cache.put(&key_a, &sample_result());
        set_mtime(&dir.join("objects").join(key_a.hex()), base);
        let lease = crate::store::lease::Lease::acquire(&dir, "writer", "test").unwrap();
        set_mtime(lease.path(), base + Duration::from_secs(60));
        let key_b = blob_key("under-lease");
        cache.put_blob(&key_b, "fresh payload");
        set_mtime(&dir.join("objects").join(key_b.hex()), base + Duration::from_secs(120));
        let (evicted, kept) = cache.gc(0).unwrap();
        assert_eq!(evicted, 1, "only the pre-lease object is evictable");
        assert!(kept > 0);
        assert!(!dir.join("objects").join(key_a.hex()).exists());
        assert!(dir.join("objects").join(key_b.hex()).exists());
        // release the lease: the survivor becomes evictable
        lease.release().unwrap();
        let (evicted, kept) = cache.gc(0).unwrap();
        assert_eq!((evicted, kept), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn intern_is_idempotent() {
        let a = intern("some-persona");
        let b = intern("some-persona");
        assert!(std::ptr::eq(a, b));
    }
}
