//! Append-only per-campaign journals: `--resume` for killed campaigns.
//!
//! One journal per (config, job list).  The header pins a campaign
//! digest derived from every job key, so a journal can never be
//! replayed against a different config, suite, seed, or binary (the
//! keys embed the schema version and pipeline fingerprint).  Each
//! record carries the job index, its key address, a checksum, and the
//! full serialized result — resume restores completed jobs from the
//! journal alone, without needing the object store.
//!
//! Crash model: the process dies mid-campaign, so only the *tail* of
//! the file can be a partial line.  Resume reads the longest valid
//! prefix, truncates the file back to it, and reports the restored
//! results; anything malformed past that point is discarded.

use super::cache::{parse_result, serialize_result};
use super::key::JobKey;
use crate::coordinator::job::TaskResult;
use crate::util::rng::fnv1a;
use anyhow::{Context, Result};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub const JOURNAL_MAGIC: &str = "kforge-journal v1";

/// Digest pinning a journal to one exact campaign: the config name,
/// the job count, and every job key address in dispatch order.
pub fn campaign_digest(config_name: &str, keys: &[JobKey]) -> u64 {
    let mut text = format!("{config_name}\x00{}\x00", keys.len());
    for k in keys {
        text.push_str(&k.hex());
        text.push('\n');
    }
    fnv1a(text.as_bytes())
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            other => anyhow::bail!("bad escape \\{other:?}"),
        }
    }
    Ok(out)
}

/// An open campaign journal; `append` is thread-safe (workers call it
/// as each job completes) and flushes per record.
pub struct Journal {
    file: Mutex<std::fs::File>,
    path: PathBuf,
}

fn header(digest: u64, njobs: usize) -> String {
    format!("{JOURNAL_MAGIC} campaign {digest:016x} jobs {njobs}\n")
}

/// Parse one `done` record against the expected key list.
fn parse_record(line: &str, keys: &[JobKey]) -> Result<(usize, TaskResult)> {
    let rest = line.strip_prefix("done ").context("not a done record")?;
    let (idx, rest) = rest.split_once(' ').context("missing index")?;
    let idx: usize = idx.parse().context("bad index")?;
    let key = keys.get(idx).with_context(|| format!("index {idx} out of range"))?;
    let (hex, rest) = rest.split_once(' ').context("missing key address")?;
    anyhow::ensure!(hex == key.hex(), "record key {hex} != expected {}", key.hex());
    let (sum, payload) = rest.split_once(' ').context("missing checksum")?;
    let payload = unescape(payload)?;
    let expect = u64::from_str_radix(sum, 16).context("bad checksum")?;
    anyhow::ensure!(fnv1a(payload.as_bytes()) == expect, "checksum mismatch");
    Ok((idx, parse_result(&payload)?))
}

impl Journal {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Start a fresh journal (truncating any prior file).
    pub fn fresh(path: &Path, config_name: &str, keys: &[JobKey]) -> Result<Journal> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        file.write_all(header(campaign_digest(config_name, keys), keys.len()).as_bytes())?;
        file.flush()?;
        Ok(Journal { file: Mutex::new(file), path: path.to_path_buf() })
    }

    /// Open for resume: restore the longest valid prefix of completed
    /// jobs, truncate any partial tail, and return the journal opened
    /// for appending.  A missing file, or a header pinned to a
    /// different campaign, starts fresh (restoring nothing).
    pub fn resume(
        path: &Path,
        config_name: &str,
        keys: &[JobKey],
    ) -> Result<(Journal, Vec<(usize, TaskResult)>)> {
        let digest = campaign_digest(config_name, keys);
        let data = match std::fs::read_to_string(path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok((Journal::fresh(path, config_name, keys)?, Vec::new()));
            }
            Err(e) => return Err(e).with_context(|| format!("reading journal {}", path.display())),
        };
        let expected_header = header(digest, keys.len());
        if !data.starts_with(&expected_header) {
            crate::kf_warn!(
                "[store] journal {} belongs to a different campaign; starting fresh",
                path.display()
            );
            return Ok((Journal::fresh(path, config_name, keys)?, Vec::new()));
        }
        let mut restored: Vec<(usize, TaskResult)> = Vec::new();
        let mut seen = vec![false; keys.len()];
        let mut valid_len = expected_header.len();
        let mut rest = &data[expected_header.len()..];
        while let Some((line, tail)) = rest.split_once('\n') {
            match parse_record(line, keys) {
                Ok((idx, result)) if !seen[idx] => {
                    seen[idx] = true;
                    restored.push((idx, result));
                }
                Ok(_) => {} // duplicate record: first one wins
                Err(e) => {
                    crate::kf_warn!(
                        "[store] journal {} record invalid ({e:#}); resuming from the valid prefix",
                        path.display()
                    );
                    break;
                }
            }
            valid_len += line.len() + 1;
            rest = tail;
        }
        // a trailing fragment without '\n' is the crash tail; drop it
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("reopening journal {}", path.display()))?;
        file.set_len(valid_len as u64)?;
        file.seek(SeekFrom::End(0))?;
        Ok((Journal { file: Mutex::new(file), path: path.to_path_buf() }, restored))
    }

    /// Record one completed job.  Errors are returned (the caller logs
    /// and keeps going — a journal failure must not fail the campaign).
    pub fn append(&self, idx: usize, key: &JobKey, result: &TaskResult) -> Result<()> {
        let payload = serialize_result(result);
        let line = format!(
            "done {idx} {} {:016x} {}\n",
            key.hex(),
            fnv1a(payload.as_bytes()),
            escape(&payload)
        );
        let mut file = self.file.lock().unwrap();
        file.write_all(line.as_bytes())?;
        file.flush()?;
        drop(file);
        crate::obs::instant("journal.append");
        crate::obs::counter("journal.bytes", line.len() as u64);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::{BaselineKind, ExperimentConfig};
    use crate::metrics::TaskOutcome;
    use crate::store::key::KeyScope;
    use crate::workloads::{Level, Suite};

    fn keys_for(name: &str, n_per_level: usize) -> Vec<JobKey> {
        let cfg = ExperimentConfig {
            name: name.into(),
            platform: crate::platform::by_name("cuda").unwrap(),
            personas: vec![crate::agents::persona::by_name("openai-gpt-5").unwrap()],
            iterations: 1,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 3,
            workers: 1,
        };
        let spec = cfg.spec();
        let scope = KeyScope::new(&cfg, &spec);
        Suite::sample(n_per_level)
            .problems
            .iter()
            .map(|p| scope.key(cfg.personas[0], p, None))
            .collect()
    }

    fn result(i: usize) -> TaskResult {
        TaskResult {
            problem_id: format!("p{i}"),
            level: Level::L1,
            persona: "openai-gpt-5",
            state_history: vec!["correct"],
            outcome: TaskOutcome::correct(1.0 + i as f64 / 7.0),
            best_iteration: Some(0),
            baseline_s: 0.25 * (i + 1) as f64,
            best_candidate_s: Some(0.125),
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kforge_journal_{name}_{}", std::process::id()))
    }

    #[test]
    fn fresh_append_resume_roundtrip() {
        let path = tmp("roundtrip");
        let keys = keys_for("jr", 2);
        {
            let j = Journal::fresh(&path, "jr", &keys).unwrap();
            for i in 0..3 {
                j.append(i, &keys[i], &result(i)).unwrap();
            }
        }
        let (_, restored) = Journal::resume(&path, "jr", &keys).unwrap();
        assert_eq!(restored.len(), 3);
        for (k, (idx, r)) in restored.iter().enumerate() {
            assert_eq!(*idx, k);
            assert_eq!(r.problem_id, format!("p{k}"));
            assert_eq!(r.baseline_s.to_bits(), result(k).baseline_s.to_bits());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn partial_tail_is_dropped_and_truncated() {
        let path = tmp("tail");
        let keys = keys_for("jt", 2);
        {
            let j = Journal::fresh(&path, "jt", &keys).unwrap();
            j.append(0, &keys[0], &result(0)).unwrap();
            j.append(1, &keys[1], &result(1)).unwrap();
        }
        // simulate a kill mid-write: chop the last record in half
        let data = std::fs::read_to_string(&path).unwrap();
        let cut = data.len() - 20;
        std::fs::write(&path, &data[..cut]).unwrap();
        let (j, restored) = Journal::resume(&path, "jt", &keys).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].0, 0);
        // the file was truncated back to the valid prefix, so a new
        // append produces a well-formed journal
        j.append(1, &keys[1], &result(1)).unwrap();
        drop(j);
        let (_, restored2) = Journal::resume(&path, "jt", &keys).unwrap();
        assert_eq!(restored2.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mismatched_campaign_starts_fresh() {
        let path = tmp("mismatch");
        let keys = keys_for("ja", 1);
        {
            let j = Journal::fresh(&path, "ja", &keys).unwrap();
            j.append(0, &keys[0], &result(0)).unwrap();
        }
        // same path, different campaign (different config name → keys)
        let other = keys_for("jb", 1);
        let (_, restored) = Journal::resume(&path, "jb", &other).unwrap();
        assert!(restored.is_empty(), "stale journal must not restore");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_middle_record_stops_the_prefix() {
        let path = tmp("corrupt");
        let keys = keys_for("jc", 2);
        {
            let j = Journal::fresh(&path, "jc", &keys).unwrap();
            for i in 0..4 {
                j.append(i, &keys[i], &result(i)).unwrap();
            }
        }
        // flip a checksum digit in record 2
        let data = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = data.lines().collect();
        let mut bad = lines.clone();
        let tampered = lines[3].replacen("done 2 ", "done 2 f", 1);
        bad[3] = &tampered;
        std::fs::write(&path, format!("{}\n", bad.join("\n"))).unwrap();
        let (_, restored) = Journal::resume(&path, "jc", &keys).unwrap();
        assert_eq!(restored.len(), 2, "prefix before the corrupt record only");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line1\nline2\\with\\slashes\r\n";
        assert_eq!(unescape(&escape(s)).unwrap(), s);
        assert!(!escape(s).contains('\n'));
        assert!(unescape("bad\\q").is_err());
    }

    #[test]
    fn digest_covers_order_and_count() {
        let keys = keys_for("jd", 2);
        let d = campaign_digest("jd", &keys);
        assert_ne!(d, campaign_digest("jd", &keys[..3]));
        assert_ne!(d, campaign_digest("other", &keys));
        let mut rev: Vec<JobKey> = keys.clone();
        rev.reverse();
        assert_ne!(d, campaign_digest("jd", &rev));
    }
}
