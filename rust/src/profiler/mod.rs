//! Profiling frontends — the paper's central platform asymmetry.
//!
//! CUDA: `nsys stats`-style **programmatic CSV** reports (kernel
//! summary, API summary, memory ops) — [`nsys`].
//!
//! Metal: no programmatic API.  The paper automated Xcode Instruments
//! with cliclick and captured **screenshots** of the summary / memory /
//! timeline views; we reproduce the shape of that pipeline by rendering
//! the simulated timeline into fixed-layout ASCII "screenshots"
//! ([`xcode`]) which the performance-analysis agent must *parse back*
//! ([`parse`]) before it can reason about them — exercising the same
//! lossy, visual-only path.

pub mod record;
pub mod nsys;
pub mod xcode;
pub mod parse;

pub use record::{KernelRecord, Profile};
