//! Profiling frontends — the paper's central platform asymmetry, as an
//! **open plugin API**.
//!
//! The paper's analysis agent interprets "diverse profiling data (from
//! programmatic APIs to GUI-based tools)".  This module makes that
//! diversity structural instead of a closed enum:
//!
//! - [`record`] — the platform-neutral [`Profile`] extracted from a
//!   simulation (the ground truth every tool captures *from*);
//! - [`frontend`] — the [`ProfilerFrontend`] trait: one profiling
//!   *tool*, which `capture`s a `Profile` into its native
//!   [`ProfileArtifact`] (named report parts: CSV tables, rendered
//!   screens, trace JSON) and `interpret`s that artifact back;
//! - [`evidence`] — the [`Evidence`] IR both steps meet at: per-fact
//!   values tagged with the [`evidence::Fidelity`] the capture
//!   preserved (`Lossless` / `Rounded` / `Truncated` / `Missing`).
//!
//! Three peer frontends ship in-tree, selected per platform via
//! `Platform::profiler_frontend()`:
//!
//! - [`nsys`] — CUDA's `nsys stats` CSV report family (programmatic,
//!   recommendation-grade precision);
//! - [`xcode`] — Metal's Xcode-Instruments path: fixed-layout rendered
//!   "screenshots" that must be screen-scraped back ([`parse`]),
//!   reproducing the paper's lossy cliclick+screenshot pipeline;
//! - [`rocprof`] — ROCm's chrome-trace JSON dialect (own field names,
//!   ns units, gap-reconstructed launch overhead), landed entirely in
//!   its own module as proof the API is open.
//!
//! The analysis agent consumes **only** [`Evidence`]; nothing outside
//! this module inspects how profile data was captured.  Capture
//! lossiness surfaces as degraded fidelity tags and lower
//! recommendation confidence — not as different agent code paths.
//! See ROADMAP.md's "Adding a profiler frontend" for the recipe.

pub mod record;
pub mod evidence;
pub mod frontend;
pub mod nsys;
pub mod xcode;
pub mod parse;
pub mod rocprof;

pub use evidence::{Evidence, Fidelity, KernelEvidence, Measure};
pub use frontend::{
    ArtifactKind, ArtifactPart, ProfileArtifact, ProfilerFrontend, ProfilerFrontendRef,
};
pub use record::{KernelRecord, Profile};
