//! Platform-neutral profile records extracted from a simulation.

use crate::perfsim::SimResult;

/// One kernel's profile row.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRecord {
    pub name: String,
    pub time_us: f64,
    pub pct_of_total: f64,
    pub gap_before_us: f64,
    pub mm_utilization: f64,
    pub mem_utilization: f64,
    pub occupancy: f64,
    pub compute_bound: bool,
}

/// A complete profile of one plan execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    pub workload: String,
    pub platform: String,
    pub kernels: Vec<KernelRecord>,
    pub total_us: f64,
    pub launch_overhead_us: f64,
    pub busy_fraction: f64,
    pub total_flops: f64,
    pub total_bytes: f64,
}

impl Profile {
    /// Extract from a simulation result.
    pub fn from_sim(workload: &str, platform: &str, sim: &SimResult) -> Profile {
        let total = sim.ideal_s.max(1e-15);
        let kernels = sim
            .timeline
            .iter()
            .map(|t| KernelRecord {
                name: t.name.clone(),
                time_us: t.duration_s * 1e6,
                pct_of_total: 100.0 * t.duration_s / total,
                gap_before_us: t.gap_before_s * 1e6,
                mm_utilization: t.cost.mm_utilization,
                mem_utilization: t.cost.mem_utilization,
                occupancy: t.cost.occupancy,
                compute_bound: t.cost.compute_s > t.cost.memory_s,
            })
            .collect();
        let launch: f64 = sim.timeline.iter().map(|t| t.gap_before_s).sum();
        Profile {
            workload: workload.to_string(),
            platform: platform.to_string(),
            kernels,
            total_us: sim.ideal_s * 1e6,
            launch_overhead_us: launch * 1e6,
            busy_fraction: sim.busy_fraction(),
            total_flops: sim.total_flops,
            total_bytes: sim.total_bytes,
        }
    }

    /// The single slowest kernel (optimization target).
    pub fn hottest(&self) -> Option<&KernelRecord> {
        self.kernels
            .iter()
            .max_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
    }

    /// Fraction of wall time lost to launch gaps.
    pub fn launch_fraction(&self) -> f64 {
        self.launch_overhead_us / self.total_us.max(1e-9)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::perfsim::lower::lower;
    use crate::perfsim::simulate;
    use crate::platform::cuda;
    use crate::sched::Schedule;
    use crate::tensor::Shape;
    use crate::util::rng::Pcg;

    pub(crate) fn sample_profile() -> Profile {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Swish, m);
        let g = b.finish(vec![r]);
        let plan = lower(&g, &Schedule::naive());
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let sim = simulate(&spec, &plan, &mut rng, 10, 2);
        Profile::from_sim("t", spec.name, &sim)
    }

    #[test]
    fn percentages_sum_to_busy() {
        let p = sample_profile();
        let pct: f64 = p.kernels.iter().map(|k| k.pct_of_total).sum();
        assert!((pct / 100.0 - p.busy_fraction).abs() < 1e-6);
    }

    #[test]
    fn hottest_is_max() {
        let p = sample_profile();
        let h = p.hottest().unwrap();
        assert!(p.kernels.iter().all(|k| k.time_us <= h.time_us));
    }
}
