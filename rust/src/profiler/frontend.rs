//! The open profiler-frontend API.
//!
//! A [`ProfilerFrontend`] is one profiling *tool*: it renders a
//! platform-neutral [`Profile`] into the artifact that tool actually
//! produces ([`ProfileArtifact`] — named report parts: CSV tables,
//! rendered screens, trace JSON) and then interprets that artifact
//! back into the common [`Evidence`] IR.  The round trip is the point:
//! whatever the artifact format loses, the `Evidence` honestly reports
//! as degraded [`super::evidence::Fidelity`], and the analysis agent
//! downstream never sees anything *but* `Evidence`.
//!
//! Frontends are selected per platform via
//! `Platform::profiler_frontend()`; adding a profiling tool is one new
//! module implementing this trait plus that one-line hook (see
//! [`super::rocprof`] for the reference example, and ROADMAP.md's
//! "Adding a profiler frontend" guide).

use super::evidence::Evidence;
use super::record::Profile;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::Arc;

/// Shared handle to a profiler frontend.
pub type ProfilerFrontendRef = Arc<dyn ProfilerFrontend>;

/// The artifact family a frontend produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Machine-readable CSV report tables (nsys stats).
    CsvTables,
    /// Fixed-layout rendered GUI screens (Xcode Instruments).
    RenderedScreens,
    /// Trace/stats JSON (rocprof chrome-trace output).
    TraceJson,
}

/// One named part of a profiler's report bundle — a CSV table, a
/// rendered screen, a JSON document.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactPart {
    pub name: &'static str,
    pub content: String,
}

/// The full capture a frontend produces for one profile.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileArtifact {
    /// The frontend that captured this.
    pub frontend: &'static str,
    pub kind: ArtifactKind,
    pub parts: Vec<ArtifactPart>,
}

impl ProfileArtifact {
    /// A part's content by name.
    pub fn part(&self, name: &str) -> Option<&str> {
        self.parts
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.content.as_str())
    }

    /// A part's content by name, or an error naming exactly what is
    /// missing (never a bare count).
    pub fn require(&self, name: &str) -> Result<&str> {
        match self.part(name) {
            Some(content) => Ok(content),
            None => bail!(
                "{} artifact is missing part {name:?} (has: {})",
                self.frontend,
                self.part_names().join(", ")
            ),
        }
    }

    /// The part names present, in order.
    pub fn part_names(&self) -> Vec<&'static str> {
        self.parts.iter().map(|p| p.name).collect()
    }
}

/// One profiling tool: capture a [`Profile`] into that tool's native
/// artifact, then interpret the artifact into [`Evidence`].
///
/// Implementations must be pure functions of the profile (no ambient
/// state): the coordinator captures and interprets on worker threads.
pub trait ProfilerFrontend: fmt::Debug + Send + Sync {
    /// Stable lowercase tool id ("nsys", "xcode", "rocprof").
    fn name(&self) -> &'static str;

    /// The artifact family this tool emits.
    fn kind(&self) -> ArtifactKind;

    /// Does the capture path preserve recommendation-grade precision?
    /// Programmatic report tools say yes; rendered-screen scrapes say
    /// no.  This is advisory metadata for harness labels — ranking
    /// reads fidelity from the `Evidence` itself.
    fn lossless(&self) -> bool;

    /// The named report parts [`ProfilerFrontend::capture`] produces,
    /// in order.  Interpreters and scrape errors refer to parts by
    /// these names.
    fn part_names(&self) -> &'static [&'static str];

    /// Render the profile into this tool's artifact.
    fn capture(&self, profile: &Profile) -> ProfileArtifact;

    /// Parse an artifact back into the Evidence IR.  Errors name the
    /// missing or malformed part.
    fn interpret(&self, artifact: &ProfileArtifact) -> Result<Evidence>;

    /// The full capture → interpret round trip.
    fn evidence(&self, profile: &Profile) -> Result<Evidence> {
        self.interpret(&self.capture(profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact() -> ProfileArtifact {
        ProfileArtifact {
            frontend: "test",
            kind: ArtifactKind::CsvTables,
            parts: vec![
                ArtifactPart { name: "alpha", content: "a".into() },
                ArtifactPart { name: "beta", content: "b".into() },
            ],
        }
    }

    #[test]
    fn part_lookup_by_name() {
        let a = artifact();
        assert_eq!(a.part("alpha"), Some("a"));
        assert_eq!(a.part("gamma"), None);
        assert_eq!(a.require("beta").unwrap(), "b");
    }

    #[test]
    fn missing_part_error_names_the_part() {
        let a = artifact();
        let err = a.require("gamma").unwrap_err().to_string();
        assert!(err.contains("gamma"), "{err}");
        assert!(err.contains("alpha"), "error should list present parts: {err}");
    }
}
