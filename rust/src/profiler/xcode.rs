//! Xcode-Instruments-style "screenshots" for the Metal platform.
//!
//! macOS exposes no programmatic GPU-profiling API; the paper drove
//! Xcode's GUI with cliclick and captured screenshots of the summary,
//! memory and timeline views (§6.3).  We reproduce that gate through
//! [`XcodeFrontend`]: the only Metal profiling artifact is a *rendered,
//! fixed-layout text screen* (one per view), and interpreting it runs
//! the [`super::parse`] screen-scraper, which is intentionally lossy
//! (rounded values, truncated names) — like reading numbers off pixels.
//! The resulting [`Evidence`] carries `Rounded`/`Truncated`/`Missing`
//! fidelity tags on every fact it recovered.

use super::evidence::{Evidence, Fidelity, KernelEvidence, Measure};
use super::frontend::{ArtifactKind, ArtifactPart, ProfileArtifact, ProfilerFrontend};
use super::parse::{scrape, ScrapedProfile};
use super::record::Profile;
use anyhow::Result;

pub const SCREEN_W: usize = 78;
/// Width of the kernel-name column in the timeline and counters views.
pub const NAME_W: usize = 20;

/// Char-boundary-safe clip to at most `max` chars (kernel names may be
/// multibyte; byte-indexed `String::truncate` would panic mid-char).
fn clip(text: &str, max: usize) -> String {
    text.chars().take(max).collect()
}

fn line(out: &mut String, text: &str) {
    // char-boundary-safe truncation (the timeline bars are multibyte)
    let t = clip(text, SCREEN_W - 2);
    out.push_str(&format!("│{:<width$}│\n", t, width = SCREEN_W - 2));
}

fn top(out: &mut String, title: &str) {
    let mut t = format!("─ {title} ");
    while t.chars().count() < SCREEN_W - 2 {
        t.push('─');
    }
    out.push_str(&format!("┌{t}┐\n"));
}

fn bottom(out: &mut String) {
    out.push_str(&format!("└{}┘\n", "─".repeat(SCREEN_W - 2)));
}

/// The gputrace "Summary" view: counters a human reads off the screen.
pub fn summary_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Summary");
    line(&mut s, &format!("Workload: {}   Device: {}", p.workload, p.platform));
    line(&mut s, "");
    line(&mut s, &format!("  GPU Time            {:>10.1} us", p.total_us));
    line(&mut s, &format!("  Encoder Overhead    {:>10.1} us", p.launch_overhead_us));
    line(&mut s, &format!("  GPU Busy            {:>9.0} %", p.busy_fraction * 100.0));
    line(&mut s, &format!("  Dispatches          {:>10}", p.kernels.len()));
    let occ = p.kernels.iter().map(|k| k.occupancy).fold(0.0, f64::max);
    line(&mut s, &format!("  Peak Occupancy      {:>9.0} %", occ * 100.0));
    line(&mut s, "");
    bottom(&mut s);
    s
}

/// The "Timeline" view: proportional bars with per-kernel labels.
pub fn timeline_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Timeline");
    let span = p.total_us.max(1e-9);
    let track_w = 40usize;
    for k in &p.kernels {
        let gap_w = ((k.gap_before_us / span) * track_w as f64).round() as usize;
        let bar_w = ((k.time_us / span) * track_w as f64).round().max(1.0) as usize;
        let name = clip(&k.name, NAME_W);
        line(
            &mut s,
            &format!(
                "  {name:<20} {}{} {:>8.1}us",
                ".".repeat(gap_w.min(track_w)),
                "█".repeat(bar_w.min(track_w)),
                k.time_us
            ),
        );
    }
    line(&mut s, "");
    line(
        &mut s,
        &format!("  idle gaps: {:>5.1} us total ({:.0}% of trace)", p.launch_overhead_us, p.launch_fraction() * 100.0),
    );
    bottom(&mut s);
    s
}

/// The "Memory"/counters view: per-kernel limiter readout.
pub fn memory_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Counters");
    line(&mut s, "  Kernel               Limiter   ALU%   MEM%   Occup%");
    for k in &p.kernels {
        let name = clip(&k.name, NAME_W);
        line(
            &mut s,
            &format!(
                "  {name:<20} {:<9} {:>4.0}   {:>4.0}   {:>5.0}",
                if k.compute_bound { "ALU" } else { "Memory" },
                k.mm_utilization * 100.0,
                k.mem_utilization * 100.0,
                k.occupancy * 100.0
            ),
        );
    }
    bottom(&mut s);
    s
}

/// The three screenshots the capture pipeline produces per gputrace.
pub fn capture_screens(p: &Profile) -> Vec<String> {
    vec![summary_view(p), timeline_view(p), memory_view(p)]
}

/// The Xcode-Instruments screenshot frontend: capture renders the
/// summary / timeline / counters views; interpret screen-scrapes them
/// back.  The lossy half of the paper's profiling asymmetry.
#[derive(Debug, Default, Clone, Copy)]
pub struct XcodeFrontend;

/// Convert a scrape into Evidence, tagging each fact with the fidelity
/// the rendering preserved: times printed with one decimal, ratios as
/// integer percentages (two fractional digits), names clipped to the
/// 20-char GUI column, per-kernel times `Missing` when the timeline
/// join failed.
fn scrape_to_evidence(s: &ScrapedProfile) -> Evidence {
    Evidence {
        frontend: "xcode",
        total_us: Measure::rounded(s.gpu_time_us, 1),
        launch_overhead_us: Measure::rounded(s.encoder_overhead_us, 1),
        busy_fraction: Measure::rounded(s.busy_pct / 100.0, 2),
        kernels: s
            .kernels
            .iter()
            .map(|k| KernelEvidence {
                name: k.name.clone(),
                name_fidelity: if k.name_possibly_truncated {
                    Fidelity::Truncated { chars: NAME_W }
                } else {
                    Fidelity::Lossless
                },
                time_us: match k.time_us {
                    Some(t) => Measure::rounded(t, 1),
                    None => Measure::missing(),
                },
                mm_utilization: Measure::rounded(k.alu_pct / 100.0, 2),
                mem_utilization: Measure::rounded(k.mem_pct / 100.0, 2),
                occupancy: Measure::rounded(k.occupancy_pct / 100.0, 2),
                compute_bound: Some(k.limiter_alu),
            })
            .collect(),
    }
}

impl ProfilerFrontend for XcodeFrontend {
    fn name(&self) -> &'static str {
        "xcode"
    }

    fn kind(&self) -> ArtifactKind {
        ArtifactKind::RenderedScreens
    }

    fn lossless(&self) -> bool {
        false
    }

    fn part_names(&self) -> &'static [&'static str] {
        &["summary", "timeline", "counters"]
    }

    fn capture(&self, profile: &Profile) -> ProfileArtifact {
        ProfileArtifact {
            frontend: self.name(),
            kind: self.kind(),
            parts: vec![
                ArtifactPart { name: "summary", content: summary_view(profile) },
                ArtifactPart { name: "timeline", content: timeline_view(profile) },
                ArtifactPart { name: "counters", content: memory_view(profile) },
            ],
        }
    }

    fn interpret(&self, artifact: &ProfileArtifact) -> Result<Evidence> {
        let screens: Vec<String> = artifact.parts.iter().map(|p| p.content.clone()).collect();
        Ok(scrape_to_evidence(&scrape(&screens)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;

    #[test]
    fn screens_have_fixed_width() {
        let p = sample_profile();
        for screen in capture_screens(&p) {
            for l in screen.lines() {
                assert_eq!(l.chars().count(), SCREEN_W, "line: {l:?}");
            }
        }
    }

    #[test]
    fn summary_mentions_counters() {
        let p = sample_profile();
        let s = summary_view(&p);
        assert!(s.contains("GPU Time") && s.contains("Dispatches"));
    }

    #[test]
    fn timeline_has_one_bar_per_kernel() {
        let p = sample_profile();
        let t = timeline_view(&p);
        let bars = t.lines().filter(|l| l.contains('█')).count();
        assert_eq!(bars, p.kernels.len());
    }

    #[test]
    fn memory_view_lists_limiters() {
        let p = sample_profile();
        let m = memory_view(&p);
        assert!(m.contains("Limiter"));
        assert!(m.contains("ALU") || m.contains("Memory"));
    }

    #[test]
    fn frontend_roundtrip_yields_degraded_evidence() {
        let p = sample_profile();
        let f = XcodeFrontend;
        let artifact = f.capture(&p);
        assert_eq!(artifact.part_names(), f.part_names());
        let ev = f.interpret(&artifact).unwrap();
        assert_eq!(ev.frontend, "xcode");
        assert_eq!(ev.n_kernels(), p.kernels.len());
        // the scrape is lossy: nothing in it may claim losslessness
        // except short names, so it scores strictly below the 0.995+
        // a programmatic frontend reaches on the same profile
        assert!(ev.fidelity_score() < 0.99, "{}", ev.fidelity_score());
        assert!(
            ev.fidelity_score()
                < crate::profiler::nsys::NsysFrontend.evidence(&p).unwrap().fidelity_score()
        );
        assert!((ev.total_us.or(0.0) - p.total_us).abs() / p.total_us.max(1.0) < 0.05);
        // limiter readout survives the screen exactly
        for (k, orig) in ev.kernels.iter().zip(&p.kernels) {
            assert_eq!(k.compute_bound, Some(orig.compute_bound));
        }
    }

    #[test]
    fn missing_part_fails_interpret_by_name() {
        let p = sample_profile();
        let f = XcodeFrontend;
        let mut artifact = f.capture(&p);
        artifact.parts.retain(|part| part.name != "counters");
        let err = f.interpret(&artifact).unwrap_err().to_string();
        assert!(err.contains("Counters"), "{err}");
    }
}
