//! Xcode-Instruments-style "screenshots" for the Metal platform.
//!
//! macOS exposes no programmatic GPU-profiling API; the paper drove
//! Xcode's GUI with cliclick and captured screenshots of the summary,
//! memory and timeline views (§6.3).  We reproduce that gate: the only
//! Metal profiling artifact is a *rendered, fixed-layout text screen*
//! (one per view).  The analysis agent cannot read structured fields —
//! it must run the [`super::parse`] screen-scraper first, and that
//! parser is intentionally lossy (rounded values, truncated names),
//! like reading numbers off pixels.

use super::record::Profile;

pub const SCREEN_W: usize = 78;

fn line(out: &mut String, text: &str) {
    // char-boundary-safe truncation (the timeline bars are multibyte)
    let t: String = text.chars().take(SCREEN_W - 2).collect();
    out.push_str(&format!("│{:<width$}│\n", t, width = SCREEN_W - 2));
}

fn top(out: &mut String, title: &str) {
    let mut t = format!("─ {title} ");
    while t.chars().count() < SCREEN_W - 2 {
        t.push('─');
    }
    out.push_str(&format!("┌{t}┐\n"));
}

fn bottom(out: &mut String) {
    out.push_str(&format!("└{}┘\n", "─".repeat(SCREEN_W - 2)));
}

/// The gputrace "Summary" view: counters a human reads off the screen.
pub fn summary_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Summary");
    line(&mut s, &format!("Workload: {}   Device: {}", p.workload, p.platform));
    line(&mut s, "");
    line(&mut s, &format!("  GPU Time            {:>10.1} us", p.total_us));
    line(&mut s, &format!("  Encoder Overhead    {:>10.1} us", p.launch_overhead_us));
    line(&mut s, &format!("  GPU Busy            {:>9.0} %", p.busy_fraction * 100.0));
    line(&mut s, &format!("  Dispatches          {:>10}", p.kernels.len()));
    let occ = p.kernels.iter().map(|k| k.occupancy).fold(0.0, f64::max);
    line(&mut s, &format!("  Peak Occupancy      {:>9.0} %", occ * 100.0));
    line(&mut s, "");
    bottom(&mut s);
    s
}

/// The "Timeline" view: proportional bars with per-kernel labels.
pub fn timeline_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Timeline");
    let span = p.total_us.max(1e-9);
    let track_w = 40usize;
    for k in &p.kernels {
        let gap_w = ((k.gap_before_us / span) * track_w as f64).round() as usize;
        let bar_w = ((k.time_us / span) * track_w as f64).round().max(1.0) as usize;
        let mut name = k.name.clone();
        name.truncate(20);
        line(
            &mut s,
            &format!(
                "  {name:<20} {}{} {:>8.1}us",
                ".".repeat(gap_w.min(track_w)),
                "█".repeat(bar_w.min(track_w)),
                k.time_us
            ),
        );
    }
    line(&mut s, "");
    line(
        &mut s,
        &format!("  idle gaps: {:>5.1} us total ({:.0}% of trace)", p.launch_overhead_us, p.launch_fraction() * 100.0),
    );
    bottom(&mut s);
    s
}

/// The "Memory"/counters view: per-kernel limiter readout.
pub fn memory_view(p: &Profile) -> String {
    let mut s = String::new();
    top(&mut s, "Xcode Instruments — GPU Trace — Counters");
    line(&mut s, "  Kernel               Limiter   ALU%   MEM%   Occup%");
    for k in &p.kernels {
        let mut name = k.name.clone();
        name.truncate(20);
        line(
            &mut s,
            &format!(
                "  {name:<20} {:<9} {:>4.0}   {:>4.0}   {:>5.0}",
                if k.compute_bound { "ALU" } else { "Memory" },
                k.mm_utilization * 100.0,
                k.mem_utilization * 100.0,
                k.occupancy * 100.0
            ),
        );
    }
    bottom(&mut s);
    s
}

/// The three screenshots the capture pipeline produces per gputrace.
pub fn capture_screens(p: &Profile) -> Vec<String> {
    vec![summary_view(p), timeline_view(p), memory_view(p)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;

    #[test]
    fn screens_have_fixed_width() {
        let p = sample_profile();
        for screen in capture_screens(&p) {
            for l in screen.lines() {
                assert_eq!(l.chars().count(), SCREEN_W, "line: {l:?}");
            }
        }
    }

    #[test]
    fn summary_mentions_counters() {
        let p = sample_profile();
        let s = summary_view(&p);
        assert!(s.contains("GPU Time") && s.contains("Dispatches"));
    }

    #[test]
    fn timeline_has_one_bar_per_kernel() {
        let p = sample_profile();
        let t = timeline_view(&p);
        let bars = t.lines().filter(|l| l.contains('█')).count();
        assert_eq!(bars, p.kernels.len());
    }

    #[test]
    fn memory_view_lists_limiters() {
        let p = sample_profile();
        let m = memory_view(&p);
        assert!(m.contains("Limiter"));
        assert!(m.contains("ALU") || m.contains("Memory"));
    }
}
