//! rocprof-style trace output for the ROCm platform.
//!
//! This module is the proof that the profiler API is open: a third
//! frontend landed **entirely here** — capture, interpretation and
//! tests — plus one `Platform::profiler_frontend()` hook in
//! [`crate::platform::rocm`], with no match arms or special cases
//! anywhere else.
//!
//! It is genuinely distinct from the nsys CSV dialect, not a rename:
//!
//! - the primary artifact is a **chrome-trace JSON** document
//!   (`rocprof --sys-trace`-style `traceEvents`), not CSV tables;
//! - its own field names: `DurationNs` / `BeginNs` / `EndNs`,
//!   `VALUBusyPct` / `MemUnitBusyPct` / `WaveOccupancyPct`, and a
//!   `BoundBy: "VALU" | "MEM"` limiter, mirroring rocprof counter
//!   vocabulary rather than nsys column headers;
//! - its own units: integer **nanoseconds** (rocprof reports ns; nsys
//!   reports fractional microseconds) and one-decimal percentages;
//! - its own lossiness profile: launch overhead is never reported
//!   directly — it is *reconstructed from inter-kernel gaps* in the
//!   event timestamps, and timestamp quantization to whole ns is the
//!   frontend's precision floor (≈ 3 fractional digits in µs terms).
//!
//! A secondary `kernel_stats_csv` part mirrors `rocprof --stats`
//! output for humans; interpretation reads the trace JSON.

use super::evidence::{Evidence, Fidelity, KernelEvidence, Measure};
use super::frontend::{ArtifactKind, ArtifactPart, ProfileArtifact, ProfilerFrontend};
use super::record::Profile;
use crate::util::csvw::Csv;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};

/// The rocprof chrome-trace frontend.
#[derive(Debug, Default, Clone, Copy)]
pub struct RocprofFrontend;

fn ns(us: f64) -> i64 {
    (us * 1e3).round() as i64
}

/// `rocprof --sys-trace`-style chrome-trace JSON.
pub fn kernel_trace_json(p: &Profile) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(p.kernels.len());
    let mut cursor_ns: i64 = 0;
    for k in &p.kernels {
        let begin = cursor_ns + ns(k.gap_before_us);
        let end = begin + ns(k.time_us);
        cursor_ns = end;
        let args = Json::obj()
            .set("BeginNs", begin)
            .set("EndNs", end)
            .set("DurationNs", end - begin)
            .set("VALUBusyPct", round1(k.mm_utilization * 100.0))
            .set("MemUnitBusyPct", round1(k.mem_utilization * 100.0))
            .set("WaveOccupancyPct", round1(k.occupancy * 100.0))
            .set("BoundBy", if k.compute_bound { "VALU" } else { "MEM" });
        events.push(
            Json::obj()
                .set("ph", "X")
                .set("pid", 0i64)
                .set("tid", 0i64)
                .set("name", k.name.clone())
                .set("args", args),
        );
    }
    let other = Json::obj()
        .set("Device", p.platform.clone())
        .set("Workload", p.workload.clone())
        .set("TotalDurationNs", ns(p.total_us))
        .set("GpuBusyPct", round1(p.busy_fraction * 100.0));
    Json::obj()
        .set("otherData", other)
        .set("traceEvents", Json::Arr(events))
        .to_pretty()
}

/// `rocprof --stats`-style per-kernel summary CSV (for humans; the
/// interpreter reads the trace JSON).
pub fn kernel_stats_csv(p: &Profile) -> String {
    let mut csv = Csv::new(&["Name", "Calls", "TotalDurationNs", "AverageNs", "Percentage"]);
    for k in &p.kernels {
        csv.push(vec![
            k.name.clone(),
            "1".into(),
            ns(k.time_us).to_string(),
            ns(k.time_us).to_string(),
            format!("{:.1}", k.pct_of_total),
        ]);
    }
    csv.to_string()
}

fn round1(v: f64) -> f64 {
    (v * 10.0).round() / 10.0
}

fn arg_f64(args: &Json, key: &str, i: usize) -> Result<f64> {
    args.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("trace event {i} args missing {key:?}"))
}

impl ProfilerFrontend for RocprofFrontend {
    fn name(&self) -> &'static str {
        "rocprof"
    }

    fn kind(&self) -> ArtifactKind {
        ArtifactKind::TraceJson
    }

    fn lossless(&self) -> bool {
        true
    }

    fn part_names(&self) -> &'static [&'static str] {
        &["kernel_trace_json", "kernel_stats_csv"]
    }

    fn capture(&self, profile: &Profile) -> ProfileArtifact {
        ProfileArtifact {
            frontend: self.name(),
            kind: self.kind(),
            parts: vec![
                ArtifactPart { name: "kernel_trace_json", content: kernel_trace_json(profile) },
                ArtifactPart { name: "kernel_stats_csv", content: kernel_stats_csv(profile) },
            ],
        }
    }

    fn interpret(&self, artifact: &ProfileArtifact) -> Result<Evidence> {
        let doc = json::parse(artifact.require("kernel_trace_json")?)
            .context("parsing kernel_trace_json")?;
        let other = doc.get("otherData").context("trace has no otherData")?;
        let total_ns = other
            .get("TotalDurationNs")
            .and_then(Json::as_f64)
            .context("otherData missing TotalDurationNs")?;
        let busy_pct = other
            .get("GpuBusyPct")
            .and_then(Json::as_f64)
            .context("otherData missing GpuBusyPct")?;
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .context("trace has no traceEvents")?;

        // (begin_ns, end_ns, kernel) per complete-duration event
        let mut rows: Vec<(f64, f64, KernelEvidence)> = Vec::with_capacity(events.len());
        for (i, e) in events.iter().enumerate() {
            if e.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("trace event {i} has no name"))?
                .to_string();
            let args = e.get("args").with_context(|| format!("trace event {i} has no args"))?;
            let begin = arg_f64(args, "BeginNs", i)?;
            let end = arg_f64(args, "EndNs", i)?;
            let bound = args
                .get("BoundBy")
                .and_then(Json::as_str)
                .with_context(|| format!("trace event {i} args missing BoundBy"))?;
            let compute_bound = match bound {
                "VALU" => true,
                "MEM" => false,
                other => bail!("trace event {i}: unknown BoundBy {other:?}"),
            };
            rows.push((
                begin,
                end,
                KernelEvidence {
                    name,
                    name_fidelity: Fidelity::Lossless,
                    // ns quantization ⇒ 3 fractional digits in µs terms
                    time_us: Measure::rounded((end - begin) / 1e3, 3),
                    mm_utilization: Measure::rounded(arg_f64(args, "VALUBusyPct", i)? / 100.0, 3),
                    mem_utilization: Measure::rounded(
                        arg_f64(args, "MemUnitBusyPct", i)? / 100.0,
                        3,
                    ),
                    occupancy: Measure::rounded(arg_f64(args, "WaveOccupancyPct", i)? / 100.0, 3),
                    compute_bound: Some(compute_bound),
                },
            ));
        }
        rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));

        // rocprof has no cudaLaunchKernel row: launch overhead is the
        // sum of inter-kernel gaps reconstructed from the timestamps
        let mut gaps_ns = 0.0;
        let mut prev_end = 0.0;
        for (begin, end, _) in &rows {
            gaps_ns += (begin - prev_end).max(0.0);
            prev_end = *end;
        }
        Ok(Evidence {
            frontend: "rocprof",
            total_us: Measure::rounded(total_ns / 1e3, 3),
            launch_overhead_us: Measure::rounded(gaps_ns / 1e3, 3),
            busy_fraction: Measure::rounded(busy_pct / 100.0, 3),
            kernels: rows.into_iter().map(|(_, _, k)| k).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;

    #[test]
    fn trace_json_is_chrome_trace_shaped() {
        let p = sample_profile();
        let doc = json::parse(&kernel_trace_json(&p)).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), p.kernels.len());
        for e in events {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            let args = e.get("args").unwrap();
            // rocprof vocabulary, not nsys column names
            assert!(args.get("DurationNs").is_some());
            assert!(args.get("VALUBusyPct").is_some());
            assert!(args.get("BoundBy").is_some());
        }
        assert!(doc.get("otherData").unwrap().get("TotalDurationNs").is_some());
    }

    #[test]
    fn stats_csv_parses_and_sums() {
        let p = sample_profile();
        let parsed = Csv::parse(&kernel_stats_csv(&p)).unwrap();
        assert_eq!(parsed.rows.len(), p.kernels.len());
        let total: f64 = (0..parsed.rows.len())
            .map(|i| parsed.f64_at(i, "TotalDurationNs").unwrap())
            .sum();
        let want: f64 = p.kernels.iter().map(|k| k.time_us * 1e3).sum();
        assert!((total - want).abs() <= p.kernels.len() as f64, "{total} vs {want}");
    }

    #[test]
    fn frontend_roundtrip_is_recommendation_grade() {
        let p = sample_profile();
        let f = RocprofFrontend;
        let ev = f.evidence(&p).unwrap();
        assert_eq!(ev.frontend, "rocprof");
        assert!(f.lossless());
        assert_eq!(ev.n_kernels(), p.kernels.len());
        assert!(ev.fidelity_score() > 0.97, "{}", ev.fidelity_score());
        // ns quantization: values within 1ns-per-kernel of the truth
        let tol = 1e-3 * (p.kernels.len() as f64 + 1.0);
        assert!((ev.total_us.or(0.0) - p.total_us).abs() <= tol);
        assert!((ev.launch_overhead_us.or(0.0) - p.launch_overhead_us).abs() <= tol);
        for (k, orig) in ev.kernels.iter().zip(&p.kernels) {
            assert_eq!(k.name, orig.name);
            assert!((k.time_us.or(0.0) - orig.time_us).abs() <= 1e-3);
            assert_eq!(k.compute_bound, Some(orig.compute_bound));
            assert!((k.occupancy.or(0.0) - orig.occupancy).abs() <= 0.001);
        }
    }

    #[test]
    fn launch_overhead_reconstructed_from_gaps() {
        // hand-build a profile with known gaps; the frontend must
        // recover launch overhead purely from Begin/End timestamps
        use crate::profiler::record::KernelRecord;
        let kernel = |name: &str, t: f64, gap: f64| KernelRecord {
            name: name.into(),
            time_us: t,
            pct_of_total: 25.0,
            gap_before_us: gap,
            mm_utilization: 0.5,
            mem_utilization: 0.5,
            occupancy: 0.5,
            compute_bound: true,
        };
        let p = Profile {
            workload: "w".into(),
            platform: "MI300X".into(),
            kernels: vec![kernel("a", 10.0, 4.0), kernel("b", 20.0, 6.0)],
            total_us: 40.0,
            launch_overhead_us: 10.0,
            busy_fraction: 0.75,
            total_flops: 1e9,
            total_bytes: 1e6,
        };
        let ev = RocprofFrontend.evidence(&p).unwrap();
        assert!((ev.launch_overhead_us.or(0.0) - 10.0).abs() < 1e-9);
        assert!((ev.launch_fraction().or(0.0) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn missing_trace_part_error_names_it() {
        let p = sample_profile();
        let f = RocprofFrontend;
        let mut artifact = f.capture(&p);
        artifact.parts.retain(|part| part.name != "kernel_trace_json");
        let err = format!("{:#}", f.interpret(&artifact).unwrap_err());
        assert!(err.contains("kernel_trace_json"), "{err}");
    }

    #[test]
    fn malformed_trace_rejected() {
        let f = RocprofFrontend;
        let artifact = ProfileArtifact {
            frontend: "rocprof",
            kind: ArtifactKind::TraceJson,
            parts: vec![ArtifactPart {
                name: "kernel_trace_json",
                content: "{\"traceEvents\": \"nope\"".into(),
            }],
        };
        assert!(f.interpret(&artifact).is_err());
    }
}
