//! Screen-scraping the Xcode-style screenshots back into structure.
//!
//! This is the "multimodal" half of the analysis agent: on Metal the
//! profile arrives as rendered text screens, and the values recovered
//! here are *lossy* (rounded to what was printed, names truncated to
//! 20 chars) — exactly the information loss a vision model reading GUI
//! pixels suffers.  The agent's recommendations on Metal are therefore
//! made from coarser data than on CUDA, which the paper observed too
//! (profiling info helps less / less consistently on Metal, Table 5).

use anyhow::{bail, Result};

/// A kernel row recovered from the Counters screen.
#[derive(Debug, Clone, PartialEq)]
pub struct ScrapedKernel {
    pub name: String,
    pub limiter_alu: bool,
    pub alu_pct: f64,
    pub mem_pct: f64,
    pub occupancy_pct: f64,
    /// From the timeline view when join succeeds.
    pub time_us: Option<f64>,
}

/// Everything recoverable from the three screenshots.
#[derive(Debug, Clone, PartialEq)]
pub struct ScrapedProfile {
    pub gpu_time_us: f64,
    pub encoder_overhead_us: f64,
    pub busy_pct: f64,
    pub dispatches: usize,
    pub kernels: Vec<ScrapedKernel>,
}

fn grab_number(line: &str) -> Option<f64> {
    let cleaned: String = line
        .chars()
        .map(|c| if c.is_ascii_digit() || c == '.' || c == '-' { c } else { ' ' })
        .collect();
    cleaned
        .split_whitespace()
        .filter_map(|t| t.parse::<f64>().ok())
        .next_back()
}

fn strip_frame(line: &str) -> &str {
    line.trim_start_matches('│').trim_end_matches('│')
}

/// Parse the three capture screens (summary, timeline, counters).
pub fn scrape(screens: &[String]) -> Result<ScrapedProfile> {
    if screens.len() != 3 {
        bail!("expected 3 screenshots (summary, timeline, counters), got {}", screens.len());
    }
    let (summary, timeline, counters) = (&screens[0], &screens[1], &screens[2]);

    let mut gpu_time = None;
    let mut overhead = None;
    let mut busy = None;
    let mut dispatches = None;
    for l in summary.lines() {
        let l = strip_frame(l);
        if l.contains("GPU Time") {
            gpu_time = grab_number(l);
        } else if l.contains("Encoder Overhead") {
            overhead = grab_number(l);
        } else if l.contains("GPU Busy") {
            busy = grab_number(l);
        } else if l.contains("Dispatches") {
            dispatches = grab_number(l);
        }
    }
    let (Some(gpu_time), Some(overhead), Some(busy), Some(dispatches)) =
        (gpu_time, overhead, busy, dispatches)
    else {
        bail!("summary screen missing counters");
    };

    // timeline rows: "  name  ...████  123.4us"
    let mut times: Vec<(String, f64)> = Vec::new();
    for l in timeline.lines() {
        let l = strip_frame(l);
        if !l.contains('█') {
            continue;
        }
        let name = l.trim_start().split_whitespace().next().unwrap_or("").to_string();
        let us = l.trim_end().strip_suffix("us").and_then(|s| {
            let tail: String = s
                .chars()
                .rev()
                .take_while(|c| c.is_ascii_digit() || *c == '.')
                .collect();
            tail.chars().rev().collect::<String>().parse::<f64>().ok()
        });
        if let Some(us) = us {
            times.push((name, us));
        }
    }

    // counters rows: "  name  ALU|Memory  alu mem occ"
    let mut kernels = Vec::new();
    for l in counters.lines() {
        let l = strip_frame(l);
        let has_limiter = l.contains(" ALU ") || l.contains("ALU  ") || l.contains("Memory");
        if !has_limiter || l.contains("Limiter") {
            continue;
        }
        let toks: Vec<&str> = l.split_whitespace().collect();
        if toks.len() < 5 {
            continue;
        }
        let name = toks[0].to_string();
        let limiter_alu = toks[1] == "ALU";
        let nums: Vec<f64> = toks[2..].iter().filter_map(|t| t.parse().ok()).collect();
        if nums.len() < 3 {
            continue;
        }
        let time_us = times
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, t)| *t);
        kernels.push(ScrapedKernel {
            name,
            limiter_alu,
            alu_pct: nums[0],
            mem_pct: nums[1],
            occupancy_pct: nums[2],
            time_us,
        });
    }
    if kernels.is_empty() {
        bail!("counters screen had no kernel rows");
    }
    Ok(ScrapedProfile {
        gpu_time_us: gpu_time,
        encoder_overhead_us: overhead,
        busy_pct: busy,
        dispatches: dispatches as usize,
        kernels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;
    use crate::profiler::xcode::capture_screens;

    #[test]
    fn roundtrip_recovers_counters() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        assert_eq!(scraped.dispatches, p.kernels.len());
        // values are lossy (printed rounding) but close
        assert!((scraped.gpu_time_us - p.total_us).abs() / p.total_us.max(1.0) < 0.05);
        assert_eq!(scraped.kernels.len(), p.kernels.len());
    }

    #[test]
    fn roundtrip_limiters_match() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        for (s, k) in scraped.kernels.iter().zip(&p.kernels) {
            assert_eq!(s.limiter_alu, k.compute_bound, "{}", k.name);
            assert!((s.occupancy_pct - k.occupancy * 100.0).abs() <= 1.0);
        }
    }

    #[test]
    fn timeline_times_joined() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        // at least the first kernel's time should join by name prefix
        let joined = scraped.kernels.iter().filter(|k| k.time_us.is_some()).count();
        assert!(joined >= 1, "{scraped:?}");
    }

    #[test]
    fn wrong_screen_count_rejected() {
        assert!(scrape(&[]).is_err());
        assert!(scrape(&vec!["x".to_string(); 2]).is_err());
    }

    #[test]
    fn garbage_rejected() {
        let garbage = vec!["not a screen".to_string(); 3];
        assert!(scrape(&garbage).is_err());
    }
}
