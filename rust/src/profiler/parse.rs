//! Screen-scraping the Xcode-style screenshots back into structure.
//!
//! This is the "multimodal" half of the analysis agent: on Metal the
//! profile arrives as rendered text screens, and the values recovered
//! here are *lossy* (rounded to what was printed, names truncated to
//! 20 chars) — exactly the information loss a vision model reading GUI
//! pixels suffers.  The agent's recommendations on Metal are therefore
//! made from coarser data than on CUDA, which the paper observed too
//! (profiling info helps less / less consistently on Metal, Table 5).
//!
//! Screens are identified by the view title rendered into their top
//! border — never by position or count — so a capture with a missing
//! or garbled view fails with an error naming exactly which view is
//! absent (the frontend declares its expected views in
//! [`super::xcode::XcodeFrontend::part_names`]).

use anyhow::{bail, Result};

/// The view titles the capture pipeline renders, in capture order.
pub const VIEWS: [&str; 3] = ["Summary", "Timeline", "Counters"];

/// A kernel row recovered from the Counters screen.
#[derive(Debug, Clone, PartialEq)]
pub struct ScrapedKernel {
    pub name: String,
    /// The GUI column is 20 chars wide: a name that fills it may have
    /// been cut.
    pub name_possibly_truncated: bool,
    pub limiter_alu: bool,
    pub alu_pct: f64,
    pub mem_pct: f64,
    pub occupancy_pct: f64,
    /// From the timeline view when join succeeds.
    pub time_us: Option<f64>,
}

/// Everything recoverable from the capture screens.
#[derive(Debug, Clone, PartialEq)]
pub struct ScrapedProfile {
    pub gpu_time_us: f64,
    pub encoder_overhead_us: f64,
    pub busy_pct: f64,
    pub dispatches: usize,
    pub kernels: Vec<ScrapedKernel>,
}

fn grab_number(line: &str) -> Option<f64> {
    let cleaned: String = line
        .chars()
        .map(|c| if c.is_ascii_digit() || c == '.' || c == '-' { c } else { ' ' })
        .collect();
    cleaned
        .split_whitespace()
        .filter_map(|t| t.parse::<f64>().ok())
        .next_back()
}

fn strip_frame(line: &str) -> &str {
    line.trim_start_matches('│').trim_end_matches('│')
}

/// Does this screen's top border carry the given view title?
fn is_view(screen: &str, view: &str) -> bool {
    screen
        .lines()
        .next()
        .map(|top| top.contains(&format!("— {view}")))
        .unwrap_or(false)
}

/// Find one view among the captured screens, by rendered title.
fn find_view<'a>(screens: &'a [String], view: &str) -> Result<&'a str> {
    for s in screens {
        if is_view(s, view) {
            return Ok(s);
        }
    }
    let present: Vec<&str> = VIEWS
        .iter()
        .copied()
        .filter(|v| screens.iter().any(|s| is_view(s, v)))
        .collect();
    bail!(
        "capture is missing the {view} view ({} screens captured, recognized views: [{}])",
        screens.len(),
        present.join(", ")
    )
}

/// Parse the capture screens.  Views are located by title, in any
/// order; a missing view is reported by name.
pub fn scrape(screens: &[String]) -> Result<ScrapedProfile> {
    let summary = find_view(screens, "Summary")?;
    let timeline = find_view(screens, "Timeline")?;
    let counters = find_view(screens, "Counters")?;

    let mut gpu_time = None;
    let mut overhead = None;
    let mut busy = None;
    let mut dispatches = None;
    for l in summary.lines() {
        let l = strip_frame(l);
        if l.contains("GPU Time") {
            gpu_time = grab_number(l);
        } else if l.contains("Encoder Overhead") {
            overhead = grab_number(l);
        } else if l.contains("GPU Busy") {
            busy = grab_number(l);
        } else if l.contains("Dispatches") {
            dispatches = grab_number(l);
        }
    }
    let missing_counter = [
        ("GPU Time", gpu_time.is_none()),
        ("Encoder Overhead", overhead.is_none()),
        ("GPU Busy", busy.is_none()),
        ("Dispatches", dispatches.is_none()),
    ]
    .iter()
    .find(|(_, missing)| *missing)
    .map(|(name, _)| *name);
    if let Some(name) = missing_counter {
        bail!("Summary view is missing the {name:?} counter");
    }
    let (gpu_time, overhead, busy, dispatches) = (
        gpu_time.unwrap(),
        overhead.unwrap(),
        busy.unwrap(),
        dispatches.unwrap(),
    );

    // timeline rows: "  name  ...████  123.4us"
    let mut times: Vec<(String, f64)> = Vec::new();
    for l in timeline.lines() {
        let l = strip_frame(l);
        if !l.contains('█') {
            continue;
        }
        let name = l.trim_start().split_whitespace().next().unwrap_or("").to_string();
        let us = l.trim_end().strip_suffix("us").and_then(|s| {
            let tail: String = s
                .chars()
                .rev()
                .take_while(|c| c.is_ascii_digit() || *c == '.')
                .collect();
            tail.chars().rev().collect::<String>().parse::<f64>().ok()
        });
        if let Some(us) = us {
            times.push((name, us));
        }
    }

    // counters rows: "  name  ALU|Memory  alu mem occ"
    let mut kernels = Vec::new();
    for l in counters.lines() {
        let l = strip_frame(l);
        let has_limiter = l.contains(" ALU ") || l.contains("ALU  ") || l.contains("Memory");
        if !has_limiter || l.contains("Limiter") {
            continue;
        }
        let toks: Vec<&str> = l.split_whitespace().collect();
        if toks.len() < 5 {
            continue;
        }
        let name = toks[0].to_string();
        let limiter_alu = toks[1] == "ALU";
        let nums: Vec<f64> = toks[2..].iter().filter_map(|t| t.parse().ok()).collect();
        if nums.len() < 3 {
            continue;
        }
        let time_us = times
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, t)| *t);
        kernels.push(ScrapedKernel {
            name_possibly_truncated: name.chars().count() >= super::xcode::NAME_W,
            name,
            limiter_alu,
            alu_pct: nums[0],
            mem_pct: nums[1],
            occupancy_pct: nums[2],
            time_us,
        });
    }
    if kernels.is_empty() {
        bail!("Counters view had no kernel rows");
    }
    Ok(ScrapedProfile {
        gpu_time_us: gpu_time,
        encoder_overhead_us: overhead,
        busy_pct: busy,
        dispatches: dispatches as usize,
        kernels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;
    use crate::profiler::record::{KernelRecord, Profile};
    use crate::profiler::xcode::capture_screens;

    #[test]
    fn roundtrip_recovers_counters() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        assert_eq!(scraped.dispatches, p.kernels.len());
        // values are lossy (printed rounding) but close
        assert!((scraped.gpu_time_us - p.total_us).abs() / p.total_us.max(1.0) < 0.05);
        assert_eq!(scraped.kernels.len(), p.kernels.len());
    }

    #[test]
    fn roundtrip_limiters_match() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        for (s, k) in scraped.kernels.iter().zip(&p.kernels) {
            assert_eq!(s.limiter_alu, k.compute_bound, "{}", k.name);
            assert!((s.occupancy_pct - k.occupancy * 100.0).abs() <= 1.0);
        }
    }

    #[test]
    fn timeline_times_joined() {
        let p = sample_profile();
        let scraped = scrape(&capture_screens(&p)).unwrap();
        // at least the first kernel's time should join by name prefix
        let joined = scraped.kernels.iter().filter(|k| k.time_us.is_some()).count();
        assert!(joined >= 1, "{scraped:?}");
    }

    #[test]
    fn views_found_in_any_order() {
        let p = sample_profile();
        let mut screens = capture_screens(&p);
        screens.reverse();
        let scraped = scrape(&screens).unwrap();
        assert_eq!(scraped.dispatches, p.kernels.len());
    }

    #[test]
    fn missing_view_error_names_it() {
        let p = sample_profile();
        let screens = capture_screens(&p);
        // drop the timeline view: the error must say so by name
        let partial: Vec<String> = screens
            .iter()
            .filter(|s| !s.contains("Timeline"))
            .cloned()
            .collect();
        let err = scrape(&partial).unwrap_err().to_string();
        assert!(err.contains("Timeline"), "{err}");
        assert!(err.contains("Summary"), "error should list recognized views: {err}");
        // empty capture names the first missing view, not a bare count
        let err = scrape(&[]).unwrap_err().to_string();
        assert!(err.contains("Summary"), "{err}");
    }

    #[test]
    fn garbage_rejected_with_named_view() {
        let garbage = vec!["not a screen".to_string(); 3];
        let err = scrape(&garbage).unwrap_err().to_string();
        assert!(err.contains("Summary"), "{err}");
    }

    #[test]
    fn truncated_summary_screen_names_lost_counter() {
        let p = sample_profile();
        let screens = capture_screens(&p);
        // keep the title line but chop the body: counters are gone
        let chopped: String = screens[0].lines().take(2).collect::<Vec<_>>().join("\n");
        let err = scrape(&[chopped, screens[1].clone(), screens[2].clone()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("GPU Time"), "{err}");
    }

    fn synthetic_profile(names: &[&str]) -> Profile {
        Profile {
            workload: "synthetic".into(),
            platform: "Test GPU".into(),
            kernels: names
                .iter()
                .enumerate()
                .map(|(i, n)| KernelRecord {
                    name: n.to_string(),
                    time_us: 10.0 + i as f64,
                    pct_of_total: 40.0,
                    gap_before_us: 2.0,
                    mm_utilization: 0.4,
                    mem_utilization: 0.7,
                    occupancy: 0.5,
                    compute_bound: i % 2 == 0,
                })
                .collect(),
            total_us: 50.0,
            launch_overhead_us: 4.0,
            busy_fraction: 0.8,
            total_flops: 1e9,
            total_bytes: 1e6,
        }
    }

    #[test]
    fn long_kernel_names_truncate_but_scrape() {
        let p = synthetic_profile(&[
            "matmul_with_an_extremely_long_epilogue_fusion_name",
            "softmax_0",
        ]);
        let scraped = scrape(&capture_screens(&p)).unwrap();
        assert_eq!(scraped.kernels.len(), 2);
        let long = &scraped.kernels[0];
        assert_eq!(long.name.chars().count(), crate::profiler::xcode::NAME_W);
        assert!(long.name_possibly_truncated);
        // the op-family prefix survives the 20-char column
        assert!(long.name.starts_with("matmul"));
        assert!(!scraped.kernels[1].name_possibly_truncated);
    }

    #[test]
    fn multibyte_kernel_names_never_panic() {
        // names with multibyte chars around the truncation boundary:
        // rendering must clip on char boundaries and still scrape
        let p = synthetic_profile(&[
            "matmul_αβγδεζηθικλμνξοπρστυ",
            "softmax_日本語カーネル名前が長い場合",
        ]);
        let screens = capture_screens(&p);
        for s in &screens {
            for l in s.lines() {
                assert_eq!(l.chars().count(), crate::profiler::xcode::SCREEN_W, "{l:?}");
            }
        }
        let scraped = scrape(&screens).unwrap();
        assert_eq!(scraped.kernels.len(), 2);
        assert!(scraped.kernels.iter().all(|k| k.name_possibly_truncated));
    }
}
