//! nsys-stats-style CSV reports for the CUDA platform.
//!
//! The paper extracts "CSV reports containing CUDA API summaries, GPU
//! kernel execution statistics, memory transfer metrics, and NVTX
//! region timings" via `nsys stats` (§5.2).  We emit the same report
//! family from the simulated profile; these CSVs (plus the program
//! source) are what the performance-analysis agent receives on CUDA.

use super::record::Profile;
use crate::util::csvw::Csv;

/// `cuda_gpu_kern_sum`-style kernel summary.
pub fn kernel_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&[
        "Time (%)",
        "Total Time (us)",
        "Instances",
        "Avg (us)",
        "Name",
        "TensorCoreUtil",
        "MemBWUtil",
        "Occupancy",
        "Bound",
    ]);
    for k in &p.kernels {
        csv.push(vec![
            format!("{:.1}", k.pct_of_total),
            format!("{:.3}", k.time_us),
            "1".into(),
            format!("{:.3}", k.time_us),
            k.name.clone(),
            format!("{:.2}", k.mm_utilization),
            format!("{:.2}", k.mem_utilization),
            format!("{:.2}", k.occupancy),
            if k.compute_bound { "compute" } else { "memory" }.into(),
        ]);
    }
    csv
}

/// `cuda_api_sum`-style API summary (launch overhead accounting).
pub fn api_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&["Time (us)", "Num Calls", "Avg (us)", "Name"]);
    let n = p.kernels.len().max(1);
    csv.push(vec![
        format!("{:.3}", p.launch_overhead_us),
        n.to_string(),
        format!("{:.3}", p.launch_overhead_us / n as f64),
        "cudaLaunchKernel".into(),
    ]);
    csv.push(vec![
        format!("{:.3}", p.total_us),
        "1".into(),
        format!("{:.3}", p.total_us),
        "cudaDeviceSynchronize".into(),
    ]);
    csv
}

/// NVTX-range-style region timing (one range per forward pass).
pub fn nvtx_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&["Range", "Time (us)", "BusyFraction", "TotalGFLOP", "TotalMB"]);
    csv.push(vec![
        format!("forward/{}", p.workload),
        format!("{:.3}", p.total_us),
        format!("{:.3}", p.busy_fraction),
        format!("{:.4}", p.total_flops / 1e9),
        format!("{:.4}", p.total_bytes / 1e6),
    ]);
    csv
}

/// The full report bundle handed to the analysis agent (concatenated,
/// section-tagged — mirrors feeding several CSV files).
pub fn full_report(p: &Profile) -> String {
    format!(
        "== cuda_gpu_kern_sum ==\n{}\n== cuda_api_sum ==\n{}\n== nvtx_sum ==\n{}",
        kernel_summary(p).to_string(),
        api_summary(p).to_string(),
        nvtx_summary(p).to_string()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;
    use crate::util::csvw::Csv;

    #[test]
    fn kernel_summary_roundtrips() {
        let p = sample_profile();
        let csv = kernel_summary(&p);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        assert_eq!(parsed.rows.len(), p.kernels.len());
        assert_eq!(parsed.f64_at(0, "Total Time (us)").unwrap(), {
            let t: f64 = format!("{:.3}", p.kernels[0].time_us).parse().unwrap();
            t
        });
    }

    #[test]
    fn api_summary_counts_launches() {
        let p = sample_profile();
        let csv = api_summary(&p);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        let launches: f64 = parsed.f64_at(0, "Num Calls").unwrap();
        assert_eq!(launches as usize, p.kernels.len());
    }

    #[test]
    fn full_report_has_three_sections() {
        let p = sample_profile();
        let rep = full_report(&p);
        assert!(rep.contains("cuda_gpu_kern_sum"));
        assert!(rep.contains("cuda_api_sum"));
        assert!(rep.contains("nvtx_sum"));
        assert!(rep.contains("cudaLaunchKernel"));
    }
}
