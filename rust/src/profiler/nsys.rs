//! nsys-stats-style CSV reports for the CUDA platform.
//!
//! The paper extracts "CSV reports containing CUDA API summaries, GPU
//! kernel execution statistics, memory transfer metrics, and NVTX
//! region timings" via `nsys stats` (§5.2).  [`NsysFrontend`] emits the
//! same report family from the simulated profile and parses it back:
//! the CSVs carry full kernel names and 2–3 decimal digits, so the
//! resulting [`Evidence`] is recommendation-grade (`Rounded` at report
//! precision, never `Truncated`/`Missing`).

use super::evidence::{Evidence, Fidelity, KernelEvidence, Measure};
use super::frontend::{ArtifactKind, ArtifactPart, ProfileArtifact, ProfilerFrontend};
use super::record::Profile;
use crate::util::csvw::Csv;
use anyhow::{bail, Context, Result};

/// `cuda_gpu_kern_sum`-style kernel summary.
pub fn kernel_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&[
        "Time (%)",
        "Total Time (us)",
        "Instances",
        "Avg (us)",
        "Name",
        "TensorCoreUtil",
        "MemBWUtil",
        "Occupancy",
        "Bound",
    ]);
    for k in &p.kernels {
        csv.push(vec![
            format!("{:.1}", k.pct_of_total),
            format!("{:.3}", k.time_us),
            "1".into(),
            format!("{:.3}", k.time_us),
            k.name.clone(),
            format!("{:.2}", k.mm_utilization),
            format!("{:.2}", k.mem_utilization),
            format!("{:.2}", k.occupancy),
            if k.compute_bound { "compute" } else { "memory" }.into(),
        ]);
    }
    csv
}

/// `cuda_api_sum`-style API summary (launch overhead accounting).
pub fn api_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&["Time (us)", "Num Calls", "Avg (us)", "Name"]);
    let n = p.kernels.len().max(1);
    csv.push(vec![
        format!("{:.3}", p.launch_overhead_us),
        n.to_string(),
        format!("{:.3}", p.launch_overhead_us / n as f64),
        "cudaLaunchKernel".into(),
    ]);
    csv.push(vec![
        format!("{:.3}", p.total_us),
        "1".into(),
        format!("{:.3}", p.total_us),
        "cudaDeviceSynchronize".into(),
    ]);
    csv
}

/// NVTX-range-style region timing (one range per forward pass).
pub fn nvtx_summary(p: &Profile) -> Csv {
    let mut csv = Csv::new(&["Range", "Time (us)", "BusyFraction", "TotalGFLOP", "TotalMB"]);
    csv.push(vec![
        format!("forward/{}", p.workload),
        format!("{:.3}", p.total_us),
        format!("{:.3}", p.busy_fraction),
        format!("{:.4}", p.total_flops / 1e9),
        format!("{:.4}", p.total_bytes / 1e6),
    ]);
    csv
}

/// The full report bundle handed to the analysis agent (concatenated,
/// section-tagged — mirrors feeding several CSV files).
pub fn full_report(p: &Profile) -> String {
    format!(
        "== cuda_gpu_kern_sum ==\n{}\n== cuda_api_sum ==\n{}\n== nvtx_sum ==\n{}",
        kernel_summary(p).to_string(),
        api_summary(p).to_string(),
        nvtx_summary(p).to_string()
    )
}

/// The nsys-stats CSV frontend: the programmatic (lossless-grade) half
/// of the paper's profiling asymmetry.
#[derive(Debug, Default, Clone, Copy)]
pub struct NsysFrontend;

impl ProfilerFrontend for NsysFrontend {
    fn name(&self) -> &'static str {
        "nsys"
    }

    fn kind(&self) -> ArtifactKind {
        ArtifactKind::CsvTables
    }

    fn lossless(&self) -> bool {
        true
    }

    fn part_names(&self) -> &'static [&'static str] {
        &["cuda_gpu_kern_sum", "cuda_api_sum", "nvtx_sum"]
    }

    fn capture(&self, profile: &Profile) -> ProfileArtifact {
        ProfileArtifact {
            frontend: self.name(),
            kind: self.kind(),
            parts: vec![
                ArtifactPart {
                    name: "cuda_gpu_kern_sum",
                    content: kernel_summary(profile).to_string(),
                },
                ArtifactPart { name: "cuda_api_sum", content: api_summary(profile).to_string() },
                ArtifactPart { name: "nvtx_sum", content: nvtx_summary(profile).to_string() },
            ],
        }
    }

    fn interpret(&self, artifact: &ProfileArtifact) -> Result<Evidence> {
        let kern = Csv::parse(artifact.require("cuda_gpu_kern_sum")?)
            .context("parsing cuda_gpu_kern_sum")?;
        let api = Csv::parse(artifact.require("cuda_api_sum")?).context("parsing cuda_api_sum")?;
        let nvtx = Csv::parse(artifact.require("nvtx_sum")?).context("parsing nvtx_sum")?;

        let name_col = api.col("Name").context("cuda_api_sum has no Name column")?;
        let launch_row = api
            .rows
            .iter()
            .position(|r| r[name_col] == "cudaLaunchKernel")
            .context("cuda_api_sum has no cudaLaunchKernel row")?;
        let launch_us = api
            .f64_at(launch_row, "Time (us)")
            .context("cudaLaunchKernel row has no time")?;

        let total_us = nvtx.f64_at(0, "Time (us)").context("nvtx_sum has no range time")?;
        let busy = nvtx.f64_at(0, "BusyFraction").context("nvtx_sum has no BusyFraction")?;

        let kname = kern.col("Name").context("cuda_gpu_kern_sum has no Name column")?;
        let bound = kern.col("Bound").context("cuda_gpu_kern_sum has no Bound column")?;
        let mut kernels = Vec::with_capacity(kern.rows.len());
        for (i, row) in kern.rows.iter().enumerate() {
            let field = |name: &str| {
                kern.f64_at(i, name)
                    .with_context(|| format!("cuda_gpu_kern_sum row {i} has no {name:?}"))
            };
            kernels.push(KernelEvidence {
                name: row[kname].clone(),
                name_fidelity: Fidelity::Lossless,
                time_us: Measure::rounded(field("Total Time (us)")?, 3),
                mm_utilization: Measure::rounded(field("TensorCoreUtil")?, 2),
                mem_utilization: Measure::rounded(field("MemBWUtil")?, 2),
                occupancy: Measure::rounded(field("Occupancy")?, 2),
                compute_bound: match row[bound].as_str() {
                    "compute" => Some(true),
                    "memory" => Some(false),
                    other => bail!("cuda_gpu_kern_sum row {i}: unknown Bound {other:?}"),
                },
            });
        }
        Ok(Evidence {
            frontend: "nsys",
            total_us: Measure::rounded(total_us, 3),
            launch_overhead_us: Measure::rounded(launch_us, 3),
            busy_fraction: Measure::rounded(busy, 3),
            kernels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::record::tests::sample_profile;
    use crate::util::csvw::Csv;

    #[test]
    fn kernel_summary_roundtrips() {
        let p = sample_profile();
        let csv = kernel_summary(&p);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        assert_eq!(parsed.rows.len(), p.kernels.len());
        assert_eq!(parsed.f64_at(0, "Total Time (us)").unwrap(), {
            let t: f64 = format!("{:.3}", p.kernels[0].time_us).parse().unwrap();
            t
        });
    }

    #[test]
    fn api_summary_counts_launches() {
        let p = sample_profile();
        let csv = api_summary(&p);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        let launches: f64 = parsed.f64_at(0, "Num Calls").unwrap();
        assert_eq!(launches as usize, p.kernels.len());
    }

    #[test]
    fn full_report_has_three_sections() {
        let p = sample_profile();
        let rep = full_report(&p);
        assert!(rep.contains("cuda_gpu_kern_sum"));
        assert!(rep.contains("cuda_api_sum"));
        assert!(rep.contains("nvtx_sum"));
        assert!(rep.contains("cudaLaunchKernel"));
    }

    #[test]
    fn frontend_roundtrip_is_recommendation_grade() {
        let p = sample_profile();
        let f = NsysFrontend;
        let ev = f.evidence(&p).unwrap();
        assert_eq!(ev.frontend, "nsys");
        assert_eq!(ev.n_kernels(), p.kernels.len());
        assert!(f.lossless());
        assert!(ev.fidelity_score() > 0.97, "{}", ev.fidelity_score());
        // values survive at report precision
        assert!((ev.total_us.or(0.0) - p.total_us).abs() < 1e-3);
        for (k, orig) in ev.kernels.iter().zip(&p.kernels) {
            assert_eq!(k.name, orig.name);
            assert!((k.time_us.or(0.0) - orig.time_us).abs() < 1e-3);
            assert_eq!(k.compute_bound, Some(orig.compute_bound));
        }
    }

    #[test]
    fn missing_part_error_names_it() {
        let p = sample_profile();
        let f = NsysFrontend;
        let mut artifact = f.capture(&p);
        artifact.parts.retain(|part| part.name != "nvtx_sum");
        let err = format!("{:#}", f.interpret(&artifact).unwrap_err());
        assert!(err.contains("nvtx_sum"), "{err}");
    }
}
