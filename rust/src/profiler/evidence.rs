//! The Evidence IR: what the analysis agent is allowed to see.
//!
//! Every profiler frontend — programmatic CSV, rendered screenshots,
//! trace JSON — ultimately produces an [`Evidence`] value: per-fact
//! measurements tagged with the [`Fidelity`] the capture path
//! preserved.  The performance-analysis agent ranks bottlenecks from
//! `Evidence` alone; it never learns (and never branches on) *how* the
//! data was captured.  Capture lossiness therefore shows up exactly
//! where the paper observed it (§6.3, Table 5): as coarser values and
//! lower recommendation confidence, not as a different code path.

/// How much of a fact survived the capture pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fidelity {
    /// Exact to machine precision (typed records, raw counters).
    Lossless,
    /// Rounded to `digits` decimal digits in the fact's canonical unit
    /// (microseconds for times, fractions for ratios) — what a printed
    /// report or a rendered screen preserves.
    Rounded { digits: u32 },
    /// A label cut to `chars` characters (fixed-width GUI columns).
    Truncated { chars: usize },
    /// The capture path lost this fact entirely.
    Missing,
}

impl Fidelity {
    /// Fidelity as a score in [0, 1]: 1 = lossless, 0 = missing.
    /// Rounding costs more the fewer digits survive; truncation costs
    /// more the shorter the surviving label.
    pub fn score(&self) -> f64 {
        match self {
            Fidelity::Lossless => 1.0,
            Fidelity::Rounded { digits } => 1.0 / (1.0 + 10f64.powi(-(*digits as i32))),
            Fidelity::Truncated { chars } => *chars as f64 / (*chars as f64 + 10.0),
            Fidelity::Missing => 0.0,
        }
    }

    /// The worse (lower-scoring) of two fidelities — the fidelity of
    /// any value derived from both.
    pub fn worse(self, other: Fidelity) -> Fidelity {
        if self.score() <= other.score() {
            self
        } else {
            other
        }
    }
}

/// One captured numeric fact: a value plus the fidelity it arrived at.
/// A `Missing` measure carries no usable value.
#[derive(Debug, Clone, Copy)]
pub struct Measure {
    value: f64,
    pub fidelity: Fidelity,
}

/// Two measures are equal when they carry the same fidelity and the
/// same usable value; two `Missing` measures are equal (a derived
/// impl would compare the NaN payload and make missing ≠ missing).
impl PartialEq for Measure {
    fn eq(&self, other: &Measure) -> bool {
        self.fidelity == other.fidelity && self.get() == other.get()
    }
}

impl Measure {
    pub fn lossless(value: f64) -> Measure {
        Measure { value, fidelity: Fidelity::Lossless }
    }

    pub fn rounded(value: f64, digits: u32) -> Measure {
        Measure { value, fidelity: Fidelity::Rounded { digits } }
    }

    pub fn missing() -> Measure {
        Measure { value: f64::NAN, fidelity: Fidelity::Missing }
    }

    pub fn is_missing(&self) -> bool {
        self.fidelity == Fidelity::Missing
    }

    /// The value, if the capture path preserved one.
    pub fn get(&self) -> Option<f64> {
        if self.is_missing() {
            None
        } else {
            Some(self.value)
        }
    }

    /// The value, or `default` when missing.
    pub fn or(&self, default: f64) -> f64 {
        self.get().unwrap_or(default)
    }

    /// Divide two measures; the quotient carries the worse fidelity.
    pub fn ratio(&self, denom: &Measure) -> Measure {
        match (self.get(), denom.get()) {
            (Some(n), Some(d)) => Measure {
                value: n / d.max(1e-9),
                fidelity: self.fidelity.worse(denom.fidelity),
            },
            _ => Measure::missing(),
        }
    }
}

/// One kernel's evidence row.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEvidence {
    /// Kernel name as the capture preserved it (GUI columns truncate).
    pub name: String,
    pub name_fidelity: Fidelity,
    pub time_us: Measure,
    /// Matmul-engine utilization ∈ [0, 1].
    pub mm_utilization: Measure,
    /// Memory-bandwidth utilization ∈ [0, 1].
    pub mem_utilization: Measure,
    /// Occupancy ∈ [0, 1].
    pub occupancy: Measure,
    /// Whether the kernel is compute-bound; `None` when the capture
    /// path lost the limiter readout.
    pub compute_bound: Option<bool>,
}

impl KernelEvidence {
    /// Sort key for "hottest": preserved time, else memory pressure —
    /// the same heuristic a human applies to a screen with no time
    /// column joined.
    fn heat(&self) -> f64 {
        self.time_us.get().unwrap_or_else(|| self.mem_utilization.or(0.0))
    }
}

/// Everything a profiler frontend recovered about one plan execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Evidence {
    /// Which frontend produced this (provenance only — nothing ranks
    /// on it).
    pub frontend: &'static str,
    pub total_us: Measure,
    pub launch_overhead_us: Measure,
    pub busy_fraction: Measure,
    pub kernels: Vec<KernelEvidence>,
}

impl Evidence {
    pub fn n_kernels(&self) -> usize {
        self.kernels.len()
    }

    /// Fraction of wall time lost to launch gaps.
    pub fn launch_fraction(&self) -> Measure {
        self.launch_overhead_us.ratio(&self.total_us)
    }

    /// The single hottest kernel (optimization target).
    pub fn hottest(&self) -> Option<&KernelEvidence> {
        self.kernels
            .iter()
            .max_by(|a, b| a.heat().partial_cmp(&b.heat()).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Lowest per-kernel occupancy (missing rows excluded).
    pub fn min_occupancy(&self) -> Measure {
        self.kernels
            .iter()
            .filter(|k| !k.occupancy.is_missing())
            .min_by(|a, b| a.occupancy.or(1.0).partial_cmp(&b.occupancy.or(1.0)).unwrap())
            .map(|k| k.occupancy)
            .unwrap_or_else(Measure::missing)
    }

    /// Mean fidelity score across every fact in the evidence ∈ [0, 1].
    /// This is what the analysis agent surfaces as recommendation
    /// confidence: lossless frontends score near 1, screen scrapes
    /// materially lower, and an empty capture scores 0 — evidence with
    /// no kernel rows cannot support a recommendation, whichever
    /// frontend produced it.
    pub fn fidelity_score(&self) -> f64 {
        if self.kernels.is_empty() {
            return 0.0;
        }
        let mut scores = vec![
            self.total_us.fidelity.score(),
            self.launch_overhead_us.fidelity.score(),
            self.busy_fraction.fidelity.score(),
        ];
        for k in &self.kernels {
            scores.push(k.name_fidelity.score());
            scores.push(k.time_us.fidelity.score());
            scores.push(k.mm_utilization.fidelity.score());
            scores.push(k.mem_utilization.fidelity.score());
            scores.push(k.occupancy.fidelity.score());
            scores.push(if k.compute_bound.is_some() { 1.0 } else { 0.0 });
        }
        scores.iter().sum::<f64>() / scores.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(name: &str, t: f64) -> KernelEvidence {
        KernelEvidence {
            name: name.to_string(),
            name_fidelity: Fidelity::Lossless,
            time_us: Measure::lossless(t),
            mm_utilization: Measure::lossless(0.5),
            mem_utilization: Measure::lossless(0.5),
            occupancy: Measure::lossless(0.5),
            compute_bound: Some(true),
        }
    }

    #[test]
    fn fidelity_scores_ordered() {
        let l = Fidelity::Lossless.score();
        let r3 = Fidelity::Rounded { digits: 3 }.score();
        let r0 = Fidelity::Rounded { digits: 0 }.score();
        let t = Fidelity::Truncated { chars: 20 }.score();
        let m = Fidelity::Missing.score();
        assert!(l > r3 && r3 > r0 && r0 > m);
        assert!(t > m && t < l);
        assert_eq!(m, 0.0);
        assert_eq!(l, 1.0);
    }

    #[test]
    fn worse_picks_lower_score() {
        let w = Fidelity::Lossless.worse(Fidelity::Rounded { digits: 1 });
        assert_eq!(w, Fidelity::Rounded { digits: 1 });
    }

    #[test]
    fn missing_measure_has_no_value() {
        let m = Measure::missing();
        assert_eq!(m.get(), None);
        assert_eq!(m.or(7.0), 7.0);
        assert!(Measure::lossless(1.0).ratio(&m).is_missing());
        // missing == missing (the NaN payload must not leak into eq)
        assert_eq!(Measure::missing(), Measure::missing());
        assert_ne!(Measure::missing(), Measure::lossless(1.0));
    }

    #[test]
    fn ratio_carries_worse_fidelity() {
        let n = Measure::rounded(30.0, 1);
        let d = Measure::lossless(100.0);
        let r = n.ratio(&d);
        assert!((r.or(0.0) - 0.3).abs() < 1e-12);
        assert_eq!(r.fidelity, Fidelity::Rounded { digits: 1 });
    }

    #[test]
    fn hottest_prefers_preserved_time_then_pressure() {
        let mut ev = Evidence {
            frontend: "test",
            total_us: Measure::lossless(10.0),
            launch_overhead_us: Measure::lossless(1.0),
            busy_fraction: Measure::lossless(0.9),
            kernels: vec![kernel("a", 2.0), kernel("b", 5.0)],
        };
        assert_eq!(ev.hottest().unwrap().name, "b");
        ev.kernels[0].time_us = Measure::missing();
        ev.kernels[0].mem_utilization = Measure::lossless(0.99);
        // "a" has no time; its heat falls back to mem pressure (0.99),
        // which loses to b's 5us of preserved time
        assert_eq!(ev.hottest().unwrap().name, "b");
    }

    #[test]
    fn fidelity_score_ranks_lossless_above_degraded() {
        let clean = Evidence {
            frontend: "clean",
            total_us: Measure::lossless(10.0),
            launch_overhead_us: Measure::lossless(1.0),
            busy_fraction: Measure::lossless(0.9),
            kernels: vec![kernel("a", 2.0)],
        };
        let mut rough = clean.clone();
        rough.total_us = Measure::rounded(10.0, 1);
        rough.kernels[0].time_us = Measure::missing();
        rough.kernels[0].name_fidelity = Fidelity::Truncated { chars: 20 };
        assert!(clean.fidelity_score() > rough.fidelity_score());
        assert!(clean.fidelity_score() > 0.99);
    }

    #[test]
    fn kernel_free_evidence_scores_zero_everywhere() {
        // no kernel rows ⇒ no basis for a recommendation, even when
        // the global counters themselves arrived lossless
        let empty = Evidence {
            frontend: "clean",
            total_us: Measure::lossless(10.0),
            launch_overhead_us: Measure::lossless(9.0),
            busy_fraction: Measure::lossless(0.1),
            kernels: vec![],
        };
        assert_eq!(empty.fidelity_score(), 0.0);
    }
}
