//! PJRT execution of AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Interchange is HLO *text* (the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos with
//! 64-bit instruction ids; the text parser reassigns ids).
//! Executables are compiled once and cached per artifact key.
//!
//! The `xla` crate (and its native xla_extension library) is not
//! vendorable in the offline build, so the real implementation sits
//! behind the `pjrt` cargo feature.  Without it, [`PjrtRuntime`] keeps
//! the identical API but errors at construction — callers (CLI
//! `serve`, the e2e example, the PJRT integration tests) degrade with
//! a clear message instead of failing to link.

#[cfg(feature = "pjrt")]
mod real {
    use super::super::registry::{ArtifactEntry, Registry};
    use crate::tensor::{Shape, Tensor};
    use crate::util::rng::Pcg;
    use anyhow::{bail, Context, Result};
    use std::cell::RefCell;
    use std::collections::HashMap;

    /// A PJRT runtime with a compile-once executable cache.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        registry: Registry,
        cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl PjrtRuntime {
        /// Create a CPU-backed runtime over an artifact registry.
        pub fn new(registry: Registry) -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime {
                client,
                registry,
                cache: RefCell::new(HashMap::new()),
            })
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch the cached executable for) an artifact.
        fn executable(&self, entry: &ArtifactEntry) -> Result<()> {
            let mut cache = self.cache.borrow_mut();
            if cache.contains_key(&entry.key) {
                return Ok(());
            }
            let path = entry
                .path
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", entry.path))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.key))?;
            cache.insert(entry.key.clone(), exe);
            Ok(())
        }

        /// Execute an artifact by key with the given inputs.
        pub fn execute(&self, key: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let entry = self
                .registry
                .get(key)
                .with_context(|| format!("unknown artifact {key}"))?
                .clone();
            if inputs.len() != entry.input_shapes.len() {
                bail!(
                    "{key}: expected {} inputs, got {}",
                    entry.input_shapes.len(),
                    inputs.len()
                );
            }
            for (i, (t, dims)) in inputs.iter().zip(&entry.input_shapes).enumerate() {
                if t.shape.dims() != dims.as_slice() {
                    bail!("{key}: input {i} shape {} != expected {dims:?}", t.shape);
                }
            }
            self.executable(&entry)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> = t.shape.dims().iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .context("reshaping input literal")
                })
                .collect::<Result<_>>()?;
            let cache = self.cache.borrow();
            let exe = cache.get(key).expect("just compiled");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {key}"))?;
            let out_lit = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // aot.py lowers with return_tuple=True: decompose the tuple
            let elements = out_lit.to_tuple().context("decomposing tuple")?;
            let mut outputs = Vec::with_capacity(elements.len());
            for el in elements {
                let shape = el.array_shape().context("result shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = el.to_vec::<f32>().context("reading f32 result")?;
                outputs.push(Tensor::new(Shape(dims), data));
            }
            Ok(outputs)
        }

        /// Generate seeded inputs matching an artifact's declared shapes.
        pub fn seeded_inputs(&self, key: &str, seed: u64) -> Result<Vec<Tensor>> {
            let entry = self
                .registry
                .get(key)
                .with_context(|| format!("unknown artifact {key}"))?;
            let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(key.as_bytes()));
            Ok(entry
                .input_shapes
                .iter()
                .map(|dims| Tensor::randn(Shape(dims.clone()), &mut rng, 0.5))
                .collect())
        }

        /// Time `runs` executions (after `warmup`) of an artifact with the
        /// given inputs; returns per-run seconds.
        pub fn bench(
            &self,
            key: &str,
            inputs: &[Tensor],
            warmup: usize,
            runs: usize,
        ) -> Result<Vec<f64>> {
            for _ in 0..warmup {
                self.execute(key, inputs)?;
            }
            let mut samples = Vec::with_capacity(runs);
            for _ in 0..runs {
                let t0 = std::time::Instant::now();
                self.execute(key, inputs)?;
                samples.push(t0.elapsed().as_secs_f64());
            }
            Ok(samples)
        }

        /// Number of compiled executables held in the cache.
        pub fn cache_len(&self) -> usize {
            self.cache.borrow().len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::super::registry::Registry;
    use crate::tensor::{Shape, Tensor};
    use crate::util::rng::Pcg;
    use anyhow::{bail, Context, Result};

    const DISABLED: &str = "PJRT support not compiled in: add the `xla` crate to \
         [dependencies] and rebuild with `--features pjrt` (the dependency is not \
         vendored in the offline build)";

    /// API-compatible stand-in used when the `pjrt` feature is off, so
    /// callers (CLI `serve`, the e2e example, the integration tests)
    /// compile unchanged.  Construction always fails with a clear
    /// message; the remaining methods exist only to keep those call
    /// sites type-checking.
    pub struct PjrtRuntime {
        registry: Registry,
    }

    impl PjrtRuntime {
        pub fn new(_registry: Registry) -> Result<PjrtRuntime> {
            bail!("{DISABLED}")
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn execute(&self, key: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("cannot execute {key}: {DISABLED}")
        }

        pub fn seeded_inputs(&self, key: &str, seed: u64) -> Result<Vec<Tensor>> {
            let entry = self
                .registry
                .get(key)
                .with_context(|| format!("unknown artifact {key}"))?;
            let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(key.as_bytes()));
            Ok(entry
                .input_shapes
                .iter()
                .map(|dims| Tensor::randn(Shape(dims.clone()), &mut rng, 0.5))
                .collect())
        }

        pub fn bench(
            &self,
            key: &str,
            _inputs: &[Tensor],
            _warmup: usize,
            _runs: usize,
        ) -> Result<Vec<f64>> {
            bail!("cannot bench {key}: {DISABLED}")
        }

        pub fn cache_len(&self) -> usize {
            0
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

// Tests requiring real artifacts live in rust/tests/pjrt_integration.rs
// (they need `make artifacts` to have run and the `pjrt` feature).
