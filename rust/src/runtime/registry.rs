//! Artifact registry: parses the AOT manifest and resolves
//! (workload, variant, batch) keys to HLO files and input specs.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub key: String,
    pub workload: String,
    pub variant: String,
    pub batch: usize,
    pub path: PathBuf,
    /// Input shapes (all f32).
    pub input_shapes: Vec<Vec<usize>>,
    pub is_reference: bool,
}

/// The parsed registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub entries: Vec<ArtifactEntry>,
    pub root: PathBuf,
}

impl Registry {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Registry> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        Self::parse(&text, root)
    }

    /// Parse a manifest document.
    pub fn parse(text: &str, root: PathBuf) -> Result<Registry> {
        let doc = json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries")?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("entry missing {k}"))?
                    .to_string())
            };
            let mut input_shapes = Vec::new();
            for inp in e.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let dims: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_i64().map(|v| v as usize))
                    .collect();
                let dtype = inp.get("dtype").and_then(Json::as_str).unwrap_or("float32");
                if dtype != "float32" {
                    bail!("unsupported dtype {dtype}");
                }
                input_shapes.push(dims);
            }
            entries.push(ArtifactEntry {
                key: get_str("key")?,
                workload: get_str("workload")?,
                variant: get_str("variant")?,
                batch: e.get("batch").and_then(Json::as_i64).unwrap_or(0) as usize,
                path: root.join(get_str("path")?),
                input_shapes,
                is_reference: e.get("is_reference").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        Ok(Registry { entries, root })
    }

    pub fn get(&self, key: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.key == key)
    }

    /// All variants of a workload at a batch size.
    pub fn variants(&self, workload: &str, batch: usize) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.workload == workload && e.batch == batch)
            .collect()
    }

    /// The reference variant of a workload at a batch size.
    pub fn reference(&self, workload: &str, batch: usize) -> Option<&ArtifactEntry> {
        self.variants(workload, batch)
            .into_iter()
            .find(|e| e.is_reference)
    }

    pub fn workloads(&self) -> Vec<String> {
        let mut names: Vec<String> = self.entries.iter().map(|e| e.workload.clone()).collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1,
 "entries": [
  {"key": "swish__naive__b16", "workload": "swish", "variant": "naive",
   "batch": 16, "path": "swish__naive__b16.hlo.txt",
   "inputs": [{"shape": [16, 16384], "dtype": "float32"}],
   "is_reference": true, "sha256": "ab"},
  {"key": "swish__ept8__b16", "workload": "swish", "variant": "ept8",
   "batch": 16, "path": "swish__ept8__b16.hlo.txt",
   "inputs": [{"shape": [16, 16384], "dtype": "float32"}],
   "is_reference": false, "sha256": "cd"}
 ]
}"#;

    #[test]
    fn parses_sample_manifest() {
        let r = Registry::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(r.entries.len(), 2);
        assert_eq!(r.workloads(), vec!["swish"]);
        let e = r.get("swish__ept8__b16").unwrap();
        assert_eq!(e.input_shapes, vec![vec![16, 16384]]);
        assert_eq!(e.path, PathBuf::from("/tmp/a/swish__ept8__b16.hlo.txt"));
    }

    #[test]
    fn reference_lookup() {
        let r = Registry::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(r.reference("swish", 16).unwrap().variant, "naive");
        assert!(r.reference("swish", 99).is_none());
        assert_eq!(r.variants("swish", 16).len(), 2);
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Registry::parse(r#"{"version": 2, "entries": []}"#, PathBuf::new()).is_err());
    }
}
