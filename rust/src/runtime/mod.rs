//! PJRT runtime: the real execution path.
//!
//! Loads the HLO-text artifacts the Python AOT pipeline emitted
//! (`artifacts/*.hlo.txt` + `manifest.json`), compiles each once on
//! the PJRT CPU client, and executes them with concrete inputs from
//! the coordinator's request loop.  Python is never on this path.
//!
//! Real execution needs the `xla` crate and sits behind the `pjrt`
//! cargo feature; the default (offline) build ships an API-compatible
//! stub that errors at construction.

pub mod registry;
pub mod pjrt;

pub use pjrt::PjrtRuntime;
pub use registry::{ArtifactEntry, Registry};
