//! PJRT runtime: the real execution path.
//!
//! Loads the HLO-text artifacts the Python AOT pipeline emitted
//! (`artifacts/*.hlo.txt` + `manifest.json`), compiles each once on
//! the PJRT CPU client, and executes them with concrete inputs from
//! the coordinator's request loop.  Python is never on this path.

pub mod registry;
pub mod pjrt;

pub use pjrt::PjrtRuntime;
pub use registry::{ArtifactEntry, Registry};
