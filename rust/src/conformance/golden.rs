//! Golden-artifact storage: bless and check against `goldens/`.
//!
//! Layout: one `<artifact-name>.txt` per rendered artifact, byte-exact.
//! `bless` makes the directory mirror the render set (stale files are
//! removed); `check` reports missing, drifted and stale artifacts —
//! all three fail, because a stale golden is how a silently deleted
//! artifact hides.

use super::diff;
use crate::harness::Artifact;
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// Default golden directory, relative to the repo root.
pub const DEFAULT_DIR: &str = "goldens";

/// A drifted artifact: name plus the cell-level report.
#[derive(Debug, Clone)]
pub struct Drift {
    pub name: String,
    pub report: String,
}

/// Outcome of a conformance check.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Artifacts compared (rendered set size).
    pub checked: usize,
    /// Rendered artifacts with no committed golden.
    pub missing: Vec<String>,
    /// Artifacts whose golden differs from the fresh render.
    pub drifted: Vec<Drift>,
    /// Golden files no rendered artifact claims (deleted artifact or
    /// typo'd name — either way a rot vector).
    pub stale: Vec<String>,
}

impl Report {
    pub fn passed(&self) -> bool {
        self.checked > 0
            && self.missing.is_empty()
            && self.drifted.is_empty()
            && self.stale.is_empty()
    }

    /// Was the golden directory simply never blessed?  (Distinct from
    /// drift: the fix is `--bless` + commit, not a code review.)
    pub fn unblessed(&self) -> bool {
        self.missing.len() == self.checked && self.drifted.is_empty()
    }

    pub fn summary(&self) -> String {
        if self.passed() {
            return format!("conformance OK: {} artifacts match their goldens", self.checked);
        }
        let mut out = format!(
            "conformance FAILED: {} checked, {} missing, {} drifted, {} stale\n",
            self.checked,
            self.missing.len(),
            self.drifted.len(),
            self.stale.len()
        );
        if !self.missing.is_empty() {
            out.push_str(&format!("  missing goldens: {}\n", self.missing.join(", ")));
        }
        for d in &self.drifted {
            out.push_str(&format!("  drifted: {}\n", d.name));
        }
        if !self.stale.is_empty() {
            out.push_str(&format!("  stale goldens: {}\n", self.stale.join(", ")));
        }
        if self.unblessed() {
            out.push_str("  (no goldens committed yet — run `kforge conformance --bless` and commit goldens/)\n");
        }
        out
    }

    /// Every drift report concatenated (written to `--out` for CI
    /// artifact upload).
    pub fn full_diff(&self) -> String {
        self.drifted
            .iter()
            .map(|d| d.report.as_str())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

fn golden_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.txt"))
}

/// Write the rendered artifacts into `dir` (shared by bless and the
/// CI `--out` capture).  Does not remove anything.
pub fn write_artifacts(dir: &Path, arts: &[Artifact]) -> Result<()> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    for a in arts {
        let path = golden_path(dir, &a.name);
        fs::write(&path, &a.text).with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(())
}

/// Golden `.txt` files present in `dir`, by artifact name.
fn present(dir: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(entries) = fs::read_dir(dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) == Some("txt") {
                if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                    names.push(stem.to_string());
                }
            }
        }
    }
    names.sort();
    names
}

/// Bless: make `dir` mirror `arts` exactly.  Returns the blessed
/// names; stale files are removed so a deleted artifact cannot leave a
/// zombie golden behind.
pub fn bless_with(dir: &Path, arts: &[Artifact]) -> Result<Vec<String>> {
    write_artifacts(dir, arts)?;
    let rendered: Vec<&str> = arts.iter().map(|a| a.name.as_str()).collect();
    for stale in present(dir).iter().filter(|n| !rendered.contains(&n.as_str())) {
        let path = golden_path(dir, stale);
        fs::remove_file(&path).with_context(|| format!("removing stale {}", path.display()))?;
    }
    Ok(arts.iter().map(|a| a.name.clone()).collect())
}

/// Check `arts` against the goldens in `dir`.
pub fn check_against(dir: &Path, arts: &[Artifact]) -> Result<Report> {
    let mut report = Report {
        checked: arts.len(),
        ..Report::default()
    };
    for a in arts {
        let path = golden_path(dir, &a.name);
        match fs::read_to_string(&path) {
            Err(_) => report.missing.push(a.name.clone()),
            Ok(golden) => {
                if let Some(d) = diff::cell_diff(&a.name, &golden, &a.text) {
                    report.drifted.push(Drift {
                        name: a.name.clone(),
                        report: d,
                    });
                }
            }
        }
    }
    let rendered: Vec<&str> = arts.iter().map(|a| a.name.as_str()).collect();
    report.stale = present(dir)
        .into_iter()
        .filter(|n| !rendered.contains(&n.as_str()))
        .collect();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art(name: &str, text: &str) -> Artifact {
        Artifact::new(name, text.to_string())
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kforge_golden_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn bless_check_round_trip_and_drift() {
        let dir = tmp("rt");
        let arts = vec![art("a", "x  1\n"), art("b", "y  2\n")];
        bless_with(&dir, &arts).unwrap();
        let ok = check_against(&dir, &arts).unwrap();
        assert!(ok.passed(), "{}", ok.summary());

        let drifted = vec![art("a", "x  9\n"), art("b", "y  2\n")];
        let bad = check_against(&dir, &drifted).unwrap();
        assert!(!bad.passed());
        assert_eq!(bad.drifted.len(), 1);
        assert_eq!(bad.drifted[0].name, "a");
        assert!(bad.drifted[0].report.contains("\"1\" -> \"9\""), "{}", bad.drifted[0].report);
        assert!(bad.summary().contains("drifted: a"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_and_stale_goldens_fail() {
        let dir = tmp("ms");
        let arts = vec![art("a", "1\n"), art("b", "2\n")];
        bless_with(&dir, &arts).unwrap();
        // a new artifact appears → missing
        let grown = vec![art("a", "1\n"), art("b", "2\n"), art("c", "3\n")];
        let r = check_against(&dir, &grown).unwrap();
        assert_eq!(r.missing, vec!["c".to_string()]);
        assert!(!r.passed());
        // an artifact disappears → its golden is stale
        let shrunk = vec![art("a", "1\n")];
        let r = check_against(&dir, &shrunk).unwrap();
        assert_eq!(r.stale, vec!["b".to_string()]);
        assert!(!r.passed());
        // bless with the shrunk set removes the zombie
        bless_with(&dir, &shrunk).unwrap();
        assert!(check_against(&dir, &shrunk).unwrap().passed());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unblessed_directory_is_distinguished() {
        let dir = tmp("ub");
        let arts = vec![art("a", "1\n")];
        let r = check_against(&dir, &arts).unwrap();
        assert!(!r.passed());
        assert!(r.unblessed());
        assert!(r.summary().contains("--bless"), "{}", r.summary());
    }

    #[test]
    fn empty_render_set_never_passes() {
        let dir = tmp("er");
        let r = check_against(&dir, &[]).unwrap();
        assert!(!r.passed());
    }
}
