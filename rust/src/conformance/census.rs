//! Per-platform census artifact: the data-driven facts a platform
//! contributes to the system, rendered deterministically.
//!
//! One of these exists per registered platform, so the golden set
//! notices when a platform's spec, suite filter, profiler frontend or
//! persona calibration drifts — the facts every paper artifact is
//! downstream of, caught before they smear into campaign numbers.

use crate::agents::persona::PERSONAS;
use crate::harness::Artifact;
use crate::platform::Platform;
use crate::workloads::{Level, Suite};

/// The census artifact for one platform (`census_<name>`).
pub fn artifact(platform: &dyn Platform) -> Artifact {
    Artifact::new(format!("census_{}", platform.name()), render(platform))
}

/// Render the census text for one platform.
pub fn render(platform: &dyn Platform) -> String {
    let spec = platform.spec();
    let full = Suite::full();
    let filtered = full.supported_on(spec);
    let frontend = platform.profiler_frontend();
    let mut out = format!("== Census: {} ({}) ==\n", platform.name(), spec.name);
    out.push_str(&format!("language: {}\n", platform.language()));
    out.push_str(&format!(
        "aliases: {}\n",
        if platform.aliases().is_empty() {
            "(none)".to_string()
        } else {
            platform.aliases().join(", ")
        }
    ));
    out.push_str(&format!(
        "simd width: {} | max threadgroup: {} | cores: {} | unified memory: {}\n",
        spec.simd_width, spec.max_threadgroup, spec.num_cores, spec.unified_memory
    ));
    out.push_str(&format!(
        "mem bandwidth: {:.0} GB/s | onchip: {} KiB | default workers: {}\n",
        spec.mem_bw / 1e9,
        spec.onchip_bytes / 1024,
        platform.default_workers()
    ));
    let levels = Level::ALL
        .iter()
        .zip(filtered.distribution())
        .map(|(l, n)| format!("{}={n}", l.tag()))
        .collect::<Vec<_>>()
        .join(" ");
    out.push_str(&format!(
        "suite: {levels} (supported {}/{})\n",
        filtered.len(),
        full.len()
    ));
    out.push_str(&format!(
        "unsupported ops: {}\n",
        if spec.unsupported_ops.is_empty() {
            "(none)".to_string()
        } else {
            spec.unsupported_ops.join(", ")
        }
    ));
    out.push_str(&format!(
        "profiler frontend: {}{}\n",
        frontend.name(),
        if frontend.lossless() { "" } else { " (lossy)" }
    ));
    out.push_str(&format!(
        "reference transfer: {} | calibration fallback: {} x{:.2}\n",
        platform.reference_transfer(),
        platform.calibration_fallback().0,
        platform.calibration_fallback().1
    ));
    // calibration rows are measured for L1–L3; L4 clamps to the L3
    // bucket (Level::calibration_bucket), so three columns stay honest
    out.push_str("single-shot priors (L1/L2/L3; L4 uses the L3 bucket):\n");
    for persona in PERSONAS {
        let row = persona.single_shot(platform);
        out.push_str(&format!(
            "  {:<18} {:.2}/{:.2}/{:.2}\n",
            persona.name, row[0], row[1], row[2]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry;

    #[test]
    fn census_is_deterministic_and_names_the_platform() {
        for platform in registry().platforms() {
            let a = render(&**platform);
            let b = render(&**platform);
            assert_eq!(a, b);
            assert!(a.contains(platform.name()));
            assert!(a.contains(platform.language()));
            assert!(a.contains("single-shot priors"));
        }
    }

    #[test]
    fn census_reflects_the_suite_filter() {
        let metal = crate::platform::by_name("metal").unwrap();
        let text = render(&*metal);
        // the Table-2 Metal numbers, via the platform's own filter
        assert!(text.contains("L1=91 L2=79 L3=50 L4=8"), "{text}");
        assert!(text.contains("conv3d_transpose"), "{text}");
    }

    #[test]
    fn census_has_a_row_per_persona() {
        let cuda = crate::platform::by_name("cuda").unwrap();
        let text = render(&*cuda);
        for persona in PERSONAS {
            assert!(text.contains(persona.name), "{} missing", persona.name);
        }
    }
}
