//! Cell-level golden diffing.
//!
//! Harness artifacts are fixed-width tables (two-space column gutters,
//! see `harness::render`), so a drifted artifact is best reported as
//! *which cell moved*, with the full golden/current lines as context —
//! not as an opaque byte mismatch.

/// Maximum drifted lines detailed per artifact before eliding.
const MAX_DETAILED_LINES: usize = 8;

/// Split a rendered table line into cells on the two-space gutter.
/// Cells may contain single spaces ("Level 1"); gutters are always at
/// least two.
fn cells(line: &str) -> Vec<String> {
    line.split("  ")
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect()
}

/// Compare a golden artifact against its re-render.  `None` means
/// byte-identical; otherwise a human-readable drift report naming every
/// drifted cell with line context.
pub fn cell_diff(name: &str, golden: &str, current: &str) -> Option<String> {
    if golden == current {
        return None;
    }
    let gl: Vec<&str> = golden.lines().collect();
    let cl: Vec<&str> = current.lines().collect();
    let mut out = format!("artifact {name}: drift from golden\n");
    if gl.len() != cl.len() {
        out.push_str(&format!(
            "  line count: golden {} vs current {}\n",
            gl.len(),
            cl.len()
        ));
    }
    let mut detailed = 0;
    let mut drifted_lines = 0;
    for (i, (g, c)) in gl.iter().zip(&cl).enumerate() {
        if g == c {
            continue;
        }
        drifted_lines += 1;
        if detailed >= MAX_DETAILED_LINES {
            continue;
        }
        detailed += 1;
        out.push_str(&format!("  line {}:\n", i + 1));
        out.push_str(&format!("    golden  | {g}\n"));
        out.push_str(&format!("    current | {c}\n"));
        let gc = cells(g);
        let cc = cells(c);
        if gc.len() != cc.len() {
            out.push_str(&format!(
                "    cell count: golden {} vs current {}\n",
                gc.len(),
                cc.len()
            ));
        }
        for (col, (a, b)) in gc.iter().zip(&cc).enumerate() {
            if a != b {
                out.push_str(&format!("    cell {col}: {a:?} -> {b:?}\n"));
            }
        }
    }
    if drifted_lines > detailed {
        out.push_str(&format!(
            "  … {} further drifted lines elided\n",
            drifted_lines - detailed
        ));
    }
    // lines present on only one side
    let common = gl.len().min(cl.len());
    for (label, side) in [("golden only", &gl), ("current only", &cl)] {
        for (k, line) in side.iter().enumerate().skip(common).take(3) {
            out.push_str(&format!("  line {} ({label}): {line}\n", k + 1));
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_text_is_no_drift() {
        assert!(cell_diff("t", "a  b\nc  d\n", "a  b\nc  d\n").is_none());
    }

    #[test]
    fn single_cell_drift_names_line_column_and_values() {
        let golden = "== T ==\nModel  L1  L2\ngpt  0.90  0.80\n";
        let current = "== T ==\nModel  L1  L2\ngpt  0.90  0.75\n";
        let report = cell_diff("t", golden, current).unwrap();
        assert!(report.contains("line 3"), "{report}");
        assert!(report.contains("cell 2"), "{report}");
        assert!(report.contains("\"0.80\" -> \"0.75\""), "{report}");
        assert!(report.contains("golden  | gpt  0.90  0.80"), "{report}");
    }

    #[test]
    fn cells_keep_single_spaces() {
        assert_eq!(cells("Benchmark  Level 1  Level 2"), vec!["Benchmark", "Level 1", "Level 2"]);
        assert_eq!(cells("a     b"), vec!["a", "b"]);
    }

    #[test]
    fn extra_lines_are_reported() {
        let report = cell_diff("t", "a\n", "a\nb\nc\n").unwrap();
        assert!(report.contains("line count: golden 1 vs current 3"), "{report}");
        assert!(report.contains("current only"), "{report}");
    }

    #[test]
    fn long_drifts_are_elided() {
        let golden: String = (0..40).map(|i| format!("row {i}  x\n")).collect();
        let current: String = (0..40).map(|i| format!("row {i}  y\n")).collect();
        let report = cell_diff("t", &golden, &current).unwrap();
        assert!(report.contains("further drifted lines elided"), "{report}");
    }
}
