//! The conformance subsystem: what the system computes, pinned.
//!
//! Two PRs of open plugin APIs (platforms, profiler frontends) made the
//! codebase easy to refactor aggressively — and nothing pinned the
//! results those refactors must preserve.  This module is that pin,
//! with three legs:
//!
//! - **Golden paper artifacts** ([`golden`], [`diff`], [`census`]):
//!   every paper table/figure (via the harness modules' `artifact`
//!   hooks) plus, per registered platform, a census and a
//!   `search_frontier` artifact ([`crate::search::frontier`]) are
//!   rendered to canonical text and compared cell-by-cell against the
//!   committed `goldens/` directory.  `kforge conformance` checks;
//!   `kforge conformance --bless` regenerates.
//! - **Differential KIR fuzzing** ([`crate::kir::fuzz`]): thousands of
//!   seeded random graphs assert that every rewrite pass (and the full
//!   pipeline in any order) preserves interpreter semantics and
//!   validator invariants — see `rust/tests/conformance.rs`.
//! - **Synthetic workloads** ([`crate::workloads::synth`]):
//!   `Suite::synthetic(seed, n)` promotes the fuzz generator into an
//!   unbounded campaign source.
//!
//! Every later scale/speed refactor in the ROADMAP lands against this
//! gate instead of vibes.
//!
//! The gate also pins the result store (`crate::store`): rendering
//! against a warm `--cache-dir` must produce byte-identical artifacts
//! to a cold render (CI's `cache-smoke` job renders twice against one
//! shared store and asserts nonzero hits with zero golden drift).

pub mod census;
pub mod diff;
pub mod golden;

use crate::harness::{self, Artifact, Scale};
use crate::platform::registry;

/// The scale golden artifacts are rendered at.  Small enough that a
/// bless/check cycle is a CI-friendly minute, large enough that every
/// campaign-driven artifact carries real rows.  Changing this constant
/// changes every golden — re-bless deliberately.
pub const SCALE: Scale = Scale::Quick(4);

/// Render the full golden artifact set at `scale`, in a stable order:
/// a manifest, the nine paper artifacts, then one census and one
/// search-frontier artifact per registered platform.  Registering a
/// new platform (or search strategy) therefore *adds* or reshapes a
/// golden — the check fails until the new artifact is blessed, which
/// is exactly the review moment the conformance gate exists to force.
///
/// The manifest records the render scale, so goldens blessed at one
/// `--quick` scale and checked at another fail on a single explicit
/// `scale:` cell instead of a wall of spurious numeric drift.
pub fn render_all(scale: Scale) -> Vec<Artifact> {
    let mut arts = harness::artifacts(scale);
    for platform in registry().platforms() {
        arts.push(census::artifact(&**platform));
    }
    for platform in registry().platforms() {
        arts.push(crate::search::frontier::artifact(platform, scale));
    }
    let mut manifest = format!("scale: {scale:?}\nartifacts: {}\n", arts.len() + 1);
    for a in &arts {
        manifest.push_str(&format!("- {}\n", a.name));
    }
    arts.insert(0, Artifact::new("manifest", manifest));
    arts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_artifacts_cover_every_registered_platform() {
        // census rendering is cheap (no campaigns), so run it directly
        let names: Vec<String> = registry()
            .platforms()
            .iter()
            .map(|p| census::artifact(&**p).name)
            .collect();
        for p in registry().platforms() {
            assert!(names.contains(&format!("census_{}", p.name())));
        }
    }

    #[test]
    fn scale_constant_is_quick() {
        // the golden set must never silently run at Full scale (hours)
        assert!(matches!(SCALE, Scale::Quick(n) if n >= 2));
    }

    #[test]
    fn manifest_leads_and_records_the_scale() {
        // cheap structural check without campaign artifacts: the
        // manifest text is derived, not rendered, so exercise its
        // format against a hand-built artifact list
        let arts = vec![
            Artifact::new("a", "1".into()),
            Artifact::new("b", "2".into()),
        ];
        let mut manifest = format!("scale: {:?}\nartifacts: {}\n", SCALE, arts.len() + 1);
        for a in &arts {
            manifest.push_str(&format!("- {}\n", a.name));
        }
        assert!(manifest.contains("scale: Quick(4)"), "{manifest}");
        assert!(manifest.contains("- a\n- b\n"));
    }
}
