//! Dense row-major f32 tensor.

use super::shape::Shape;
use crate::util::rng::Pcg;
use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Shape, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.numel(), data.len(), "shape {shape} != data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Shape) -> Tensor {
        let n = shape.numel();
        Tensor::new(shape, vec![0.0; n])
    }

    pub fn full(shape: Shape, v: f32) -> Tensor {
        let n = shape.numel();
        Tensor::new(shape, vec![v; n])
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::new(Shape::scalar(), vec![v])
    }

    /// Standard-normal random tensor from a seeded stream.
    pub fn randn(shape: Shape, rng: &mut Pcg, scale: f32) -> Tensor {
        let mut data = vec![0.0f32; shape.numel()];
        rng.fill_normal_f32(&mut data, scale);
        Tensor::new(shape, data)
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    /// Reshape without moving data.
    pub fn reshape(&self, shape: Shape) -> Tensor {
        assert_eq!(shape.numel(), self.numel(), "reshape {} -> {shape}", self.shape);
        Tensor {
            shape,
            data: self.data.clone(),
        }
    }

    /// Value at a multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        let strides = self.shape.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    /// Max |a-b| between two tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// allclose with rtol/atol semantics (numpy style).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs() && a.is_finite() == b.is_finite())
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f32> {
        self.data.iter()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let preview: Vec<String> = self.data.iter().take(6).map(|v| format!("{v:.4}")).collect();
        write!(
            f,
            "Tensor{}[{}{}]",
            self.shape,
            preview.join(", "),
            if self.numel() > 6 { ", …" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_at() {
        let t = Tensor::new(Shape::of(&[2, 3]), (0..6).map(|i| i as f32).collect());
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(Shape::of(&[2, 2]), vec![1.0; 3]);
    }

    #[test]
    fn allclose_tolerates_small_noise() {
        let a = Tensor::full(Shape::of(&[4]), 1.0);
        let mut b = a.clone();
        b.data[0] = 1.0 + 1e-6;
        assert!(a.allclose(&b, 1e-4, 1e-5));
        b.data[0] = 1.1;
        assert!(!a.allclose(&b, 1e-4, 1e-5));
    }

    #[test]
    fn allclose_rejects_nan() {
        let a = Tensor::full(Shape::of(&[2]), 1.0);
        let mut b = a.clone();
        b.data[1] = f32::NAN;
        assert!(!a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Pcg::seed(5);
        let mut r2 = Pcg::seed(5);
        let a = Tensor::randn(Shape::of(&[16]), &mut r1, 1.0);
        let b = Tensor::randn(Shape::of(&[16]), &mut r2, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(Shape::of(&[2, 3]), (0..6).map(|i| i as f32).collect());
        let r = t.reshape(Shape::of(&[3, 2]));
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, Shape::of(&[3, 2]));
    }
}
