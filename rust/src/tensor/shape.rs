//! Tensor shapes: dimension lists with helpers for strides, broadcasting
//! and element counts.

use std::fmt;

/// A dense row-major shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn scalar() -> Shape {
        Shape(vec![])
    }

    pub fn of(dims: &[usize]) -> Shape {
        Shape(dims.to_vec())
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    pub fn dim(&self, i: usize) -> usize {
        self.0[i]
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Numpy-style broadcast of two shapes.
    pub fn broadcast(&self, other: &Shape) -> Option<Shape> {
        let r = self.rank().max(other.rank());
        let mut out = vec![0usize; r];
        for i in 0..r {
            let a = if i < r - self.rank() { 1 } else { self.0[i - (r - self.rank())] };
            let b = if i < r - other.rank() { 1 } else { other.0[i - (r - other.rank())] };
            out[i] = match (a, b) {
                (x, y) if x == y => x,
                (1, y) => y,
                (x, 1) => x,
                _ => return None,
            };
        }
        Some(Shape(out))
    }

    /// Bytes at f32.
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}]",
            self.0.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        )
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Shape {
        Shape(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        let s = Shape::of(&[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(Shape::scalar().numel(), 1);
    }

    #[test]
    fn broadcast_rules() {
        let a = Shape::of(&[4, 1, 3]);
        let b = Shape::of(&[2, 3]);
        assert_eq!(a.broadcast(&b), Some(Shape::of(&[4, 2, 3])));
        assert_eq!(Shape::of(&[3]).broadcast(&Shape::of(&[4])), None);
        assert_eq!(Shape::scalar().broadcast(&a), Some(a.clone()));
    }

    #[test]
    fn display() {
        assert_eq!(Shape::of(&[2, 3]).to_string(), "[2, 3]");
    }
}
