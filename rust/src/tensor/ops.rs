//! Reference CPU kernels over dense f32 tensors.
//!
//! Ground truth for the KIR interpreter.  `matmul` uses ikj loop order
//! (cache-friendly, auto-vectorizable) because verification evaluates
//! hundreds of thousands of candidate programs per campaign.

use super::{Shape, Tensor};

// ---------------------------------------------------------------------------
// elementwise
// ---------------------------------------------------------------------------

/// Apply a unary function elementwise.
pub fn map(x: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(x.shape.clone(), x.data.iter().map(|&v| f(v)).collect())
}

pub fn relu(x: &Tensor) -> Tensor {
    map(x, |v| v.max(0.0))
}

pub fn sigmoid(x: &Tensor) -> Tensor {
    map(x, |v| 1.0 / (1.0 + (-v).exp()))
}

pub fn swish(x: &Tensor) -> Tensor {
    map(x, |v| v / (1.0 + (-v).exp()))
}

pub fn gelu(x: &Tensor) -> Tensor {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    map(x, |v| 0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh()))
}

pub fn tanh(x: &Tensor) -> Tensor {
    map(x, f32::tanh)
}

pub fn exp(x: &Tensor) -> Tensor {
    map(x, f32::exp)
}

pub fn neg(x: &Tensor) -> Tensor {
    map(x, |v| -v)
}

pub fn square(x: &Tensor) -> Tensor {
    map(x, |v| v * v)
}

pub fn sqrt(x: &Tensor) -> Tensor {
    map(x, f32::sqrt)
}

pub fn scale(x: &Tensor, s: f32) -> Tensor {
    map(x, |v| v * s)
}

pub fn add_scalar(x: &Tensor, s: f32) -> Tensor {
    map(x, |v| v + s)
}

pub fn clamp(x: &Tensor, lo: f32, hi: f32) -> Tensor {
    map(x, |v| v.clamp(lo, hi))
}

/// Binary elementwise with numpy broadcasting.
pub fn zip(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.shape == b.shape {
        return Tensor::new(
            a.shape.clone(),
            a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect(),
        );
    }
    let out_shape = a
        .shape
        .broadcast(&b.shape)
        .unwrap_or_else(|| panic!("broadcast {} vs {}", a.shape, b.shape));
    let r = out_shape.rank();
    let strides = out_shape.strides();
    let a_map = bcast_strides(&a.shape, &out_shape);
    let b_map = bcast_strides(&b.shape, &out_shape);
    let n = out_shape.numel();
    let mut out = Vec::with_capacity(n);
    let mut idx = vec![0usize; r];
    for lin in 0..n {
        let mut rem = lin;
        let mut ao = 0usize;
        let mut bo = 0usize;
        for d in 0..r {
            idx[d] = rem / strides[d];
            rem %= strides[d];
            ao += idx[d] * a_map[d];
            bo += idx[d] * b_map[d];
        }
        out.push(f(a.data[ao], b.data[bo]));
    }
    Tensor::new(out_shape, out)
}

/// Per-dim stride of `small` when broadcast against `out` (0 where dim=1).
fn bcast_strides(small: &Shape, out: &Shape) -> Vec<usize> {
    let r = out.rank();
    let offset = r - small.rank();
    let s_str = small.strides();
    (0..r)
        .map(|d| {
            if d < offset || small.dim(d - offset) == 1 {
                0
            } else {
                s_str[d - offset]
            }
        })
        .collect()
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x + y)
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x - y)
}

pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x * y)
}

pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x / y)
}

pub fn maximum(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, f32::max)
}

// ---------------------------------------------------------------------------
// matmul
// ---------------------------------------------------------------------------

/// [m,k] @ [k,n] -> [m,n], ikj order with a zeroed accumulator row.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2, "matmul lhs rank {}", a.rank());
    assert_eq!(b.rank(), 2, "matmul rhs rank {}", b.rank());
    let (m, k) = (a.shape.dim(0), a.shape.dim(1));
    let (k2, n) = (b.shape.dim(0), b.shape.dim(1));
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor::new(Shape::of(&[m, n]), out)
}

/// Transpose a 2-D tensor.
pub fn transpose2(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 2);
    let (m, n) = (x.shape.dim(0), x.shape.dim(1));
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = x.data[i * n + j];
        }
    }
    Tensor::new(Shape::of(&[n, m]), out)
}

// ---------------------------------------------------------------------------
// reductions
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    Sum,
    Max,
    Mean,
    LogSumExp,
}

/// Reduce along `axis`, keeping the dim as size 1 (keepdims=true).
pub fn reduce(x: &Tensor, axis: usize, kind: Reduce) -> Tensor {
    assert!(axis < x.rank(), "axis {axis} rank {}", x.rank());
    let dims = x.shape.dims();
    let outer: usize = dims[..axis].iter().product();
    let rdim = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out_shape = dims.to_vec();
    out_shape[axis] = 1;
    let mut out = vec![0.0f32; outer * inner];
    for o in 0..outer {
        for i in 0..inner {
            let base = o * rdim * inner + i;
            let vals = (0..rdim).map(|r| x.data[base + r * inner]);
            out[o * inner + i] = match kind {
                Reduce::Sum => vals.sum(),
                Reduce::Max => vals.fold(f32::NEG_INFINITY, f32::max),
                Reduce::Mean => vals.sum::<f32>() / rdim as f32,
                Reduce::LogSumExp => {
                    let m = (0..rdim)
                        .map(|r| x.data[base + r * inner])
                        .fold(f32::NEG_INFINITY, f32::max);
                    let s: f32 = (0..rdim).map(|r| (x.data[base + r * inner] - m).exp()).sum();
                    m + s.ln()
                }
            };
        }
    }
    Tensor::new(Shape(out_shape), out)
}

/// Softmax along the last axis.
pub fn softmax(x: &Tensor) -> Tensor {
    let axis = x.rank() - 1;
    let m = reduce(x, axis, Reduce::Max);
    let e = exp(&sub(x, &m));
    let s = reduce(&e, axis, Reduce::Sum);
    div(&e, &s)
}

/// LayerNorm along the last axis with per-feature gamma/beta.
pub fn layernorm(x: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let axis = x.rank() - 1;
    let mu = reduce(x, axis, Reduce::Mean);
    let centered = sub(x, &mu);
    let var = reduce(&square(&centered), axis, Reduce::Mean);
    let inv = map(&add_scalar(&var, eps), |v| 1.0 / v.sqrt());
    add(&mul(&mul(&centered, &inv), gamma), beta)
}

// ---------------------------------------------------------------------------
// convolution / pooling (NCHW)
// ---------------------------------------------------------------------------

/// NCHW ⊛ OIHW conv2d.
pub fn conv2d(x: &Tensor, w: &Tensor, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.rank(), 4, "conv2d input rank");
    assert_eq!(w.rank(), 4, "conv2d weight rank");
    let (n, c, h, wd) = dims4(x);
    let (o, ci, kh, kw) = dims4(w);
    assert_eq!(c, ci, "conv2d channels {c} vs {ci}");
    let oh = (h + 2 * padding - kh) / stride + 1;
    let ow = (wd + 2 * padding - kw) / stride + 1;
    let mut out = vec![0.0f32; n * o * oh * ow];
    for b in 0..n {
        for oc in 0..o {
            for y in 0..oh {
                for xx in 0..ow {
                    let mut acc = 0.0f32;
                    for ic in 0..c {
                        for ky in 0..kh {
                            let iy = (y * stride + ky) as isize - padding as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (xx * stride + kx) as isize - padding as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                let xi = ((b * c + ic) * h + iy as usize) * wd + ix as usize;
                                let wi = ((oc * c + ic) * kh + ky) * kw + kx;
                                acc += x.data[xi] * w.data[wi];
                            }
                        }
                    }
                    out[((b * o + oc) * oh + y) * ow + xx] = acc;
                }
            }
        }
    }
    Tensor::new(Shape::of(&[n, o, oh, ow]), out)
}

/// Depthwise conv2d (one filter per channel), weights [C,1,KH,KW].
pub fn depthwise_conv2d(x: &Tensor, w: &Tensor, stride: usize, padding: usize) -> Tensor {
    let (n, c, h, wd) = dims4(x);
    let (cw, one, kh, kw) = dims4(w);
    assert_eq!(c, cw);
    assert_eq!(one, 1, "depthwise weight dim1 must be 1");
    let oh = (h + 2 * padding - kh) / stride + 1;
    let ow = (wd + 2 * padding - kw) / stride + 1;
    let mut out = vec![0.0f32; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for y in 0..oh {
                for xx in 0..ow {
                    let mut acc = 0.0f32;
                    for ky in 0..kh {
                        let iy = (y * stride + ky) as isize - padding as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (xx * stride + kx) as isize - padding as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let xi = ((b * c + ch) * h + iy as usize) * wd + ix as usize;
                            let wi = (ch * kh + ky) * kw + kx;
                            acc += x.data[xi] * w.data[wi];
                        }
                    }
                    out[((b * c + ch) * oh + y) * ow + xx] = acc;
                }
            }
        }
    }
    Tensor::new(Shape::of(&[n, c, oh, ow]), out)
}

/// 2-D max pooling.
pub fn maxpool2d(x: &Tensor, k: usize, stride: usize) -> Tensor {
    pool2d(x, k, stride, true)
}

/// 2-D average pooling.
pub fn avgpool2d(x: &Tensor, k: usize, stride: usize) -> Tensor {
    pool2d(x, k, stride, false)
}

fn pool2d(x: &Tensor, k: usize, stride: usize, is_max: bool) -> Tensor {
    let (n, c, h, w) = dims4(x);
    assert!(k <= h && k <= w, "pool window {k} exceeds input {h}x{w}");
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = vec![0.0f32; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for y in 0..oh {
                for xx in 0..ow {
                    let mut acc = if is_max { f32::NEG_INFINITY } else { 0.0 };
                    for ky in 0..k {
                        for kx in 0..k {
                            let v = x.data[((b * c + ch) * h + y * stride + ky) * w + xx * stride + kx];
                            if is_max {
                                acc = acc.max(v);
                            } else {
                                acc += v;
                            }
                        }
                    }
                    out[((b * c + ch) * oh + y) * ow + xx] =
                        if is_max { acc } else { acc / (k * k) as f32 };
                }
            }
        }
    }
    Tensor::new(Shape::of(&[n, c, oh, ow]), out)
}

/// Concatenate along `axis`.
pub fn concat(xs: &[&Tensor], axis: usize) -> Tensor {
    assert!(!xs.is_empty());
    let r = xs[0].rank();
    assert!(axis < r);
    let mut out_dims = xs[0].shape.dims().to_vec();
    out_dims[axis] = xs.iter().map(|t| t.shape.dim(axis)).sum();
    for t in xs {
        for d in 0..r {
            if d != axis {
                assert_eq!(t.shape.dim(d), xs[0].shape.dim(d), "concat dim {d}");
            }
        }
    }
    let outer: usize = out_dims[..axis].iter().product();
    let inner: usize = out_dims[axis + 1..].iter().product();
    let mut out = Vec::with_capacity(out_dims.iter().product());
    for o in 0..outer {
        for t in xs {
            let ad = t.shape.dim(axis);
            let start = o * ad * inner;
            out.extend_from_slice(&t.data[start..start + ad * inner]);
        }
    }
    Tensor::new(Shape(out_dims), out)
}

/// Global average pool over H,W: [N,C,H,W] -> [N,C,1,1].
pub fn global_avgpool(x: &Tensor) -> Tensor {
    let (n, c, h, w) = dims4(x);
    let mut out = vec![0.0f32; n * c];
    for b in 0..n {
        for ch in 0..c {
            let base = (b * c + ch) * h * w;
            out[b * c + ch] = x.data[base..base + h * w].iter().sum::<f32>() / (h * w) as f32;
        }
    }
    Tensor::new(Shape::of(&[n, c, 1, 1]), out)
}

fn dims4(x: &Tensor) -> (usize, usize, usize, usize) {
    assert_eq!(x.rank(), 4);
    (
        x.shape.dim(0),
        x.shape.dim(1),
        x.shape.dim(2),
        x.shape.dim(3),
    )
}

/// Single-head attention: q,k,v [s,d].
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.shape.dim(1) as f32;
    let logits = scale(&matmul(q, &transpose2(k)), 1.0 / d.sqrt());
    matmul(&softmax(&logits), v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randt(dims: &[usize], seed: u64) -> Tensor {
        let mut r = Pcg::seed(seed);
        Tensor::randn(Shape::of(dims), &mut r, 1.0)
    }

    #[test]
    fn matmul_identity() {
        let x = randt(&[3, 3], 1);
        let mut eye = Tensor::zeros(Shape::of(&[3, 3]));
        for i in 0..3 {
            eye.data[i * 3 + i] = 1.0;
        }
        assert!(matmul(&x, &eye).allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(Shape::of(&[2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::full(Shape::of(&[2, 2]), 1.0);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_associates_with_transpose() {
        let a = randt(&[4, 6], 2);
        let b = randt(&[6, 5], 3);
        let c = matmul(&a, &b);
        let ct = matmul(&transpose2(&b), &transpose2(&a));
        assert!(transpose2(&c).allclose(&ct, 1e-5, 1e-5));
    }

    #[test]
    fn broadcast_add_bias() {
        let x = randt(&[4, 3], 4);
        let b = Tensor::new(Shape::of(&[3]), vec![1.0, 2.0, 3.0]);
        let y = add(&x, &b);
        for i in 0..4 {
            for j in 0..3 {
                assert!((y.at(&[i, j]) - x.at(&[i, j]) - b.data[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = randt(&[5, 7], 5);
        let s = softmax(&x);
        let sums = reduce(&s, 1, Reduce::Sum);
        assert!(sums.allclose(&Tensor::full(Shape::of(&[5, 1]), 1.0), 1e-5, 1e-6));
    }

    #[test]
    fn softmax_stable_at_extremes() {
        let x = Tensor::new(Shape::of(&[1, 3]), vec![1e4, 0.0, -1e4]);
        let s = softmax(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.data[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn reduce_kinds() {
        let x = Tensor::new(Shape::of(&[2, 3]), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(reduce(&x, 1, Reduce::Sum).data, vec![6.0, 15.0]);
        assert_eq!(reduce(&x, 1, Reduce::Max).data, vec![3.0, 6.0]);
        assert_eq!(reduce(&x, 1, Reduce::Mean).data, vec![2.0, 5.0]);
        assert_eq!(reduce(&x, 0, Reduce::Sum).data, vec![5.0, 7.0, 9.0]);
        let lse = reduce(&x, 1, Reduce::LogSumExp);
        let want = (1f32.exp() + 2f32.exp() + 3f32.exp()).ln();
        assert!((lse.data[0] - want).abs() < 1e-5);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = randt(&[6, 32], 6);
        let g = Tensor::full(Shape::of(&[32]), 1.0);
        let b = Tensor::zeros(Shape::of(&[32]));
        let y = layernorm(&x, &g, &b, 1e-5);
        let mu = reduce(&y, 1, Reduce::Mean);
        assert!(mu.allclose(&Tensor::zeros(Shape::of(&[6, 1])), 1e-4, 1e-4));
    }

    #[test]
    fn conv2d_identity_kernel() {
        let x = randt(&[1, 1, 5, 5], 7);
        let w = Tensor::new(Shape::of(&[1, 1, 1, 1]), vec![1.0]);
        assert!(conv2d(&x, &w, 1, 0).allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn conv2d_shapes_with_stride_padding() {
        let x = randt(&[2, 3, 9, 9], 8);
        let w = randt(&[4, 3, 3, 3], 9);
        let y = conv2d(&x, &w, 2, 1);
        assert_eq!(y.shape, Shape::of(&[2, 4, 5, 5]));
    }

    #[test]
    fn conv2d_sum_kernel_equals_window_sum() {
        let x = Tensor::full(Shape::of(&[1, 1, 4, 4]), 1.0);
        let w = Tensor::full(Shape::of(&[1, 1, 2, 2]), 1.0);
        let y = conv2d(&x, &w, 1, 0);
        assert!(y.allclose(&Tensor::full(Shape::of(&[1, 1, 3, 3]), 4.0), 1e-6, 1e-6));
    }

    #[test]
    fn depthwise_matches_grouped_full_conv() {
        let x = randt(&[1, 2, 5, 5], 10);
        let w = randt(&[2, 1, 3, 3], 11);
        let y = depthwise_conv2d(&x, &w, 1, 1);
        assert_eq!(y.shape, Shape::of(&[1, 2, 5, 5]));
        // channel 0 of output must equal conv of channel 0 alone
        let x0 = Tensor::new(Shape::of(&[1, 1, 5, 5]), x.data[..25].to_vec());
        let w0 = Tensor::new(Shape::of(&[1, 1, 3, 3]), w.data[..9].to_vec());
        let y0 = conv2d(&x0, &w0, 1, 1);
        assert!((0..25).all(|i| (y.data[i] - y0.data[i]).abs() < 1e-5));
    }

    #[test]
    fn pooling() {
        let x = Tensor::new(
            Shape::of(&[1, 1, 2, 2]),
            vec![1.0, 2.0, 3.0, 4.0],
        );
        assert_eq!(maxpool2d(&x, 2, 1).data, vec![4.0]);
        assert_eq!(avgpool2d(&x, 2, 1).data, vec![2.5]);
    }

    #[test]
    fn concat_axis1() {
        let a = Tensor::new(Shape::of(&[2, 1]), vec![1.0, 2.0]);
        let b = Tensor::new(Shape::of(&[2, 2]), vec![3.0, 4.0, 5.0, 6.0]);
        let c = concat(&[&a, &b], 1);
        assert_eq!(c.shape, Shape::of(&[2, 3]));
        assert_eq!(c.data, vec![1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn global_avgpool_matches_mean() {
        let x = randt(&[2, 3, 4, 4], 12);
        let y = global_avgpool(&x);
        let want = x.data[..16].iter().sum::<f32>() / 16.0;
        assert!((y.data[0] - want).abs() < 1e-5);
    }

    #[test]
    fn attention_uniform_when_keys_identical() {
        // identical keys -> uniform weights -> output = mean of V rows
        let q = randt(&[2, 4], 13);
        let k = Tensor::full(Shape::of(&[3, 4]), 0.5);
        let v = Tensor::new(
            Shape::of(&[3, 2]),
            vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0],
        );
        let out = attention(&q, &k, &v);
        assert!((out.at(&[0, 0]) - 2.0).abs() < 1e-5);
        assert!((out.at(&[1, 1]) - 20.0).abs() < 1e-5);
    }

    #[test]
    fn swish_matches_definition() {
        let x = randt(&[64], 14);
        let y = swish(&x);
        for (a, b) in x.data.iter().zip(&y.data) {
            assert!((b - a / (1.0 + (-a).exp())).abs() < 1e-6);
        }
    }
}
