//! Dense f32 tensors + reference CPU kernels.
//!
//! This is the numerical ground truth for the KIR interpreter: every
//! candidate program's output is checked against the reference graph
//! evaluated with these ops.  Correctness over speed — though the hot
//! ops (matmul) are written cache-consciously because the verification
//! pipeline runs hundreds of thousands of evaluations per campaign.

pub mod shape;
pub mod tensorimpl;
pub mod ops;

pub use shape::Shape;
pub use tensorimpl::Tensor;
