//! Self-profiling: structured spans, counters and leveled logging.
//!
//! KForge's thesis is that profiling evidence should drive optimization
//! — so the repo profiles *itself* with the same machinery it points at
//! GPU kernels.  This module is a zero-dependency tracer: a process-wide
//! [`Tracer`] records scoped spans (RAII guards, nested parent ids),
//! instant events, integer counters and f64 gauges into an in-memory
//! buffer, and [`export`] renders the buffer as chrome-trace JSON that
//! [`crate::profiler::rocprof::RocprofFrontend::interpret`] can read
//! back into [`crate::profiler::evidence::Evidence`] — the
//! platform-agnostic analysis path applied to KForge's own execution.
//!
//! ## The two-clock rule
//!
//! Every event carries two kinds of information:
//!
//! - **logical identity** — phase, class, name, lane, span id, parent
//!   id, counter value: a pure function of the work performed;
//! - **environmental detail** — wall-clock nanoseconds and the worker
//!   thread id (`tid`): properties of one particular execution.
//!
//! The repo's bit-identity guarantees (campaigns, tune runs and serve
//! scenarios are bit-identical across worker counts and warm vs cold
//! store) extend to traces through the event **class**:
//!
//! - [`Class::Logical`] events are emitted only where the *event stream
//!   itself* is deterministic — post-hoc from pinned result values, or
//!   from single-threaded seeded loops (the serve virtual phase).  The
//!   [`Snapshot::canon`] digest covers exactly these, excluding wall
//!   and tid by construction, and is compared across worker counts
//!   *and* warm vs cold store.
//! - [`Class::Exec`] events mark real execution (phase timings, store
//!   traffic, oracle evaluations).  They exist only where work actually
//!   ran, so a warm run legitimately has fewer of them; the
//!   [`Snapshot::canon_exec`] digest (wall/tid stripped, counters
//!   summed) is still pinned across worker counts on cold runs.
//!
//! ## Lanes, span ids and threads
//!
//! Events are grouped into **lanes** — deterministic scope strings
//! ("main", "job:cuda:expert:gemm_256", "serve") established with
//! [`lane`] guards at points where a stable domain *identity* is in
//! hand (the per-job closures, not the worker pool).  Span ids count up
//! from 0 per (lane, class), assigned under the buffer lock, so they
//! are deterministic as long as a lane is driven by one thread at a
//! time — which identity naming guarantees (one job is executed by one
//! worker; the serve virtual loop is single-threaded).  Worker threads
//! are numbered by [`alloc_tid`]/[`set_tid`] in
//! [`crate::coordinator::worker::run_jobs`]; tid 0 is the main thread.
//!
//! A disabled tracer (the default — nothing in the library enables it;
//! only the CLI `--trace` flag does) is a no-op: every entry point
//! checks one relaxed atomic load and returns before allocating or
//! formatting anything, and [`recorded_total`] deltas stay zero.
//!
//! STORE_SCHEMA deliberately does **not** bump for this subsystem:
//! tracing is purely observational — it reads results, it never feeds
//! a fingerprinted input — so cached entries stay valid (pinned in
//! `rust/tests/trace.rs`).

pub mod export;
pub mod log;
pub mod summary;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Lane 0, the default scope for events outside any [`lane`] guard.
pub const ROOT_LANE: &str = "main";

/// Sentinel parent/span id: "none".
pub const NO_ID: u64 = u64::MAX;

/// Determinism class of one event — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    /// Deterministic function of the work; in [`Snapshot::canon`].
    Logical,
    /// Real execution detail; in [`Snapshot::canon_exec`] only.
    Exec,
}

/// Event shape, mirroring the chrome-trace `ph` vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventPhase {
    /// Span open (`ph: B`).
    Begin,
    /// Span close (`ph: E`).
    End,
    /// Point-in-time marker (`ph: i`).
    Instant,
    /// Monotonic integer delta, summed per (lane, name) (`ph: C`).
    Counter,
    /// Sampled f64 level (`ph: C`).
    Gauge,
}

/// One recorded event.  `wall_ns` and `tid` are the environmental
/// half of the two-clock design; everything else is logical identity.
#[derive(Debug, Clone)]
pub struct Event {
    pub phase: EventPhase,
    pub class: Class,
    /// Event name; empty on `End` (the span id identifies it).
    pub name: String,
    /// Interned lane id — resolve with [`Snapshot::lane_name`].
    pub lane: u32,
    /// Span id within the lane (`Begin`/`End`), else [`NO_ID`].
    pub span: u64,
    /// Enclosing span id within the lane, or [`NO_ID`] at root.
    pub parent: u64,
    /// Worker index (0 = main thread).  Environmental.
    pub tid: u32,
    /// Nanoseconds since [`enable`].  Environmental.
    pub wall_ns: u64,
    /// Counter delta or gauge level; 0.0 otherwise.
    pub value: f64,
}

struct Inner {
    lanes: Vec<String>,
    lane_ids: BTreeMap<String, u32>,
    /// Next span id per (lane, class).  The two classes count
    /// independently so logical span ids stay warm/cold invariant no
    /// matter how many exec spans the cold run opened in the lane.
    next_span: BTreeMap<(u32, u8), u64>,
    events: Vec<Event>,
    epoch: Option<Instant>,
}

fn class_idx(class: Class) -> u8 {
    match class {
        Class::Logical => 0,
        Class::Exec => 1,
    }
}

/// The process-wide trace collector.  All access goes through the
/// module-level free functions; the struct is public only so its
/// existence is documented.
pub struct Tracer {
    enabled: AtomicBool,
    /// Monotonic count of events ever recorded — the no-op-overhead
    /// smoke asserts this does not move while disabled.
    recorded: AtomicU64,
    /// Next thread id for [`alloc_tid`] (0 is the main thread).
    next_tid: AtomicU32,
    inner: Mutex<Inner>,
}

static TRACER: Tracer = Tracer {
    enabled: AtomicBool::new(false),
    recorded: AtomicU64::new(0),
    next_tid: AtomicU32::new(1),
    inner: Mutex::new(Inner {
        lanes: Vec::new(),
        lane_ids: BTreeMap::new(),
        next_span: BTreeMap::new(),
        events: Vec::new(),
        epoch: None,
    }),
};

struct Ctx {
    tid: u32,
    lane: u32,
    /// Open span ids in this thread (innermost last).
    stack: Vec<u64>,
}

thread_local! {
    static CTX: RefCell<Ctx> = const { RefCell::new(Ctx { tid: 0, lane: 0, stack: Vec::new() }) };
}

/// Survive lock poisoning: a panicking traced job (the worker pool
/// catches unwinds) must not take the whole tracer down with it.
fn lock() -> MutexGuard<'static, Inner> {
    TRACER.inner.lock().unwrap_or_else(|e| e.into_inner())
}

fn intern(inner: &mut Inner, name: &str) -> u32 {
    if inner.lanes.is_empty() {
        inner.lanes.push(ROOT_LANE.to_string());
        inner.lane_ids.insert(ROOT_LANE.to_string(), 0);
    }
    if let Some(&id) = inner.lane_ids.get(name) {
        return id;
    }
    let id = inner.lanes.len() as u32;
    inner.lanes.push(name.to_string());
    inner.lane_ids.insert(name.to_string(), id);
    id
}

fn wall_ns(inner: &Inner) -> u64 {
    inner.epoch.map(|e| e.elapsed().as_nanos() as u64).unwrap_or(0)
}

/// Is the tracer recording?  One relaxed load — callers building
/// dynamic event names should gate the formatting on this.
#[inline]
pub fn enabled() -> bool {
    TRACER.enabled.load(Ordering::Relaxed)
}

/// Start recording.  The wall-clock epoch is set on the first enable
/// after a [`reset`] and then sticks, so a disable/enable toggle (the
/// bench overhead probe does this) keeps timestamps monotonic within
/// one buffer.  Does not clear the buffer (pair with [`reset`] for a
/// fresh trace).
pub fn enable() {
    let mut inner = lock();
    if inner.epoch.is_none() {
        inner.epoch = Some(Instant::now());
    }
    drop(inner);
    TRACER.enabled.store(true, Ordering::Relaxed);
}

/// Stop recording (buffer kept for [`snapshot`]).
pub fn disable() {
    TRACER.enabled.store(false, Ordering::Relaxed);
}

/// Clear the buffer, lanes and span counters.  [`recorded_total`] is
/// monotonic and deliberately unaffected.
pub fn reset() {
    let mut inner = lock();
    inner.events.clear();
    inner.lanes.clear();
    inner.lane_ids.clear();
    inner.next_span.clear();
    inner.epoch = None;
    drop(inner);
    TRACER.next_tid.store(1, Ordering::Relaxed);
}

/// Total events ever recorded by this process — a delta of zero across
/// a region proves the disabled tracer stayed a no-op.
pub fn recorded_total() -> u64 {
    TRACER.recorded.load(Ordering::Relaxed)
}

/// Number this thread for trace attribution (0 = main thread; the
/// worker pool uses 1-based worker indices).  No-op while disabled.
pub fn set_tid(tid: u32) {
    if !enabled() {
        return;
    }
    CTX.with(|c| c.borrow_mut().tid = tid);
}

/// Allocate a process-unique thread id for a worker about to spawn.
/// The top-level pool spawns sequentially, so its workers get 1..=N —
/// exactly the worker index; nested pools (the serve execution fan
/// runs whole single-job campaigns per worker) draw further ids so no
/// two live OS threads ever share a tid, which is what keeps per-tid
/// begin/end matching in the exported chrome trace well-formed.  Tid is
/// environmental (stripped from both canon digests), so allocation
/// order racing between concurrent nested pools is harmless.  Returns 0
/// while disabled.
pub fn alloc_tid() -> u32 {
    if !enabled() {
        return 0;
    }
    TRACER.next_tid.fetch_add(1, Ordering::Relaxed)
}

/// Scope guard restoring the previous lane (and its open-span stack)
/// on drop.
pub struct LaneGuard {
    prev: Option<(u32, Vec<u64>)>,
}

/// Enter a lane — a named, deterministic event scope ("job:3",
/// "serve").  Spans opened inside nest under this lane with their own
/// id sequence; the previous lane's open spans are shelved until drop.
pub fn lane(name: &str) -> LaneGuard {
    if !enabled() {
        return LaneGuard { prev: None };
    }
    let id = intern(&mut lock(), name);
    let prev = CTX.with(|c| {
        let mut c = c.borrow_mut();
        let prev = (c.lane, std::mem::take(&mut c.stack));
        c.lane = id;
        prev
    });
    LaneGuard { prev: Some(prev) }
}

/// Enter the per-job lane `job:<platform>:<persona>:<problem>` — the
/// deterministic scope campaign and serve fan-outs attribute work to.
/// Lanes are named by job *identity* (not dispatch index) so that
/// concurrent single-job campaigns — the serve execution fan runs one
/// per worker — land in distinct lanes and per-lane span ids stay
/// deterministic.  The name is formatted only when the tracer is live.
pub fn job_lane(platform: &str, persona: &str, problem: &str) -> LaneGuard {
    if !enabled() {
        return LaneGuard { prev: None };
    }
    lane(&format!("job:{platform}:{persona}:{problem}"))
}

impl Drop for LaneGuard {
    fn drop(&mut self) {
        if let Some((lane, stack)) = self.prev.take() {
            CTX.with(|c| {
                let mut c = c.borrow_mut();
                c.lane = lane;
                c.stack = stack;
            });
        }
    }
}

/// Scope guard closing its span on drop.
pub struct SpanGuard {
    open: Option<(u32, u64, Class)>,
}

fn begin_span(name: &str, class: Class) -> SpanGuard {
    if !enabled() {
        return SpanGuard { open: None };
    }
    let (lane, tid, parent) = CTX.with(|c| {
        let c = c.borrow();
        (c.lane, c.tid, c.stack.last().copied().unwrap_or(NO_ID))
    });
    let id = {
        let mut inner = lock();
        if inner.lanes.is_empty() {
            intern(&mut inner, ROOT_LANE);
        }
        let slot = inner.next_span.entry((lane, class_idx(class))).or_insert(0);
        let id = *slot;
        *slot += 1;
        let wall = wall_ns(&inner);
        inner.events.push(Event {
            phase: EventPhase::Begin,
            class,
            name: name.to_string(),
            lane,
            span: id,
            parent,
            tid,
            wall_ns: wall,
            value: 0.0,
        });
        id
    };
    TRACER.recorded.fetch_add(1, Ordering::Relaxed);
    CTX.with(|c| c.borrow_mut().stack.push(id));
    SpanGuard { open: Some((lane, id, class)) }
}

/// Open an [`Class::Exec`] span timing real work.
pub fn span(name: &str) -> SpanGuard {
    begin_span(name, Class::Exec)
}

/// Open a [`Class::Logical`] span (structure pinned warm and cold).
pub fn logical_span(name: &str) -> SpanGuard {
    begin_span(name, Class::Logical)
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((lane, id, class)) = self.open.take() else {
            return;
        };
        let tid = CTX.with(|c| {
            let mut c = c.borrow_mut();
            if c.stack.last() == Some(&id) {
                c.stack.pop();
            }
            c.tid
        });
        if !enabled() {
            return;
        }
        let mut inner = lock();
        let wall = wall_ns(&inner);
        inner.events.push(Event {
            phase: EventPhase::End,
            class,
            name: String::new(),
            lane,
            span: id,
            parent: NO_ID,
            tid,
            wall_ns: wall,
            value: 0.0,
        });
        drop(inner);
        TRACER.recorded.fetch_add(1, Ordering::Relaxed);
    }
}

fn point(phase: EventPhase, class: Class, name: &str, value: f64) {
    if !enabled() {
        return;
    }
    let (lane, tid, parent) = CTX.with(|c| {
        let c = c.borrow();
        (c.lane, c.tid, c.stack.last().copied().unwrap_or(NO_ID))
    });
    let mut inner = lock();
    if inner.lanes.is_empty() {
        intern(&mut inner, ROOT_LANE);
    }
    let wall = wall_ns(&inner);
    inner.events.push(Event {
        phase,
        class,
        name: name.to_string(),
        lane,
        span: NO_ID,
        parent,
        tid,
        wall_ns: wall,
        value,
    });
    drop(inner);
    TRACER.recorded.fetch_add(1, Ordering::Relaxed);
}

/// Exec instant event (admission decisions, cache hits, ...).
pub fn instant(name: &str) {
    point(EventPhase::Instant, Class::Exec, name, 0.0);
}

/// Logical instant event.
pub fn logical_instant(name: &str) {
    point(EventPhase::Instant, Class::Logical, name, 0.0);
}

/// Bump an exec counter.  Counters are integer-valued so per-(lane,
/// name) sums are exact and order-independent across threads.
pub fn counter(name: &str, delta: u64) {
    point(EventPhase::Counter, Class::Exec, name, delta as f64);
}

/// Bump a logical counter.
pub fn logical_counter(name: &str, delta: u64) {
    point(EventPhase::Counter, Class::Logical, name, delta as f64);
}

/// Sample an exec gauge level (in-flight requests, queue depth).
pub fn gauge(name: &str, value: f64) {
    point(EventPhase::Gauge, Class::Exec, name, value);
}

/// Sample a logical gauge (bit-exact values only — it lands in the
/// canon digest verbatim).
pub fn logical_gauge(name: &str, value: f64) {
    point(EventPhase::Gauge, Class::Logical, name, value);
}

/// Copy the current buffer out.
pub fn snapshot() -> Snapshot {
    let inner = lock();
    Snapshot { lanes: inner.lanes.clone(), events: inner.events.clone() }
}

/// An owned copy of the trace buffer, with the canon digests.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub lanes: Vec<String>,
    pub events: Vec<Event>,
}

fn fmt_id(id: u64) -> String {
    if id == NO_ID {
        "-".to_string()
    } else {
        id.to_string()
    }
}

impl Snapshot {
    pub fn lane_name(&self, id: u32) -> &str {
        self.lanes.get(id as usize).map(|s| s.as_str()).unwrap_or(ROOT_LANE)
    }

    /// Events of one class, in record order.
    pub fn of_class(&self, class: Class) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.class == class)
    }

    /// Sum of a counter across all lanes (both classes).
    pub fn counter_total(&self, name: &str) -> u64 {
        self.events
            .iter()
            .filter(|e| e.phase == EventPhase::Counter && e.name == name)
            .map(|e| e.value as u64)
            .sum()
    }

    /// The logical-determinism digest: every [`Class::Logical`] event's
    /// identity, grouped per lane (lanes sorted by name, events in
    /// record order, counters summed).  Wall-clock and tid are excluded
    /// by construction — this string is compared bit-for-bit across
    /// worker counts and warm vs cold store.
    pub fn canon(&self) -> String {
        self.digest(Class::Logical, "kforge-trace-canon v1 logical")
    }

    /// The exec-determinism digest: [`Class::Exec`] identities with
    /// wall/tid stripped and counters summed.  Pinned across worker
    /// counts for cold runs (warm runs legitimately skip exec work).
    pub fn canon_exec(&self) -> String {
        self.digest(Class::Exec, "kforge-trace-canon v1 exec")
    }

    fn digest(&self, class: Class, header: &str) -> String {
        // per lane: identity lines in record order + summed counters.
        // counter sums are exact: values are integers, so addition is
        // associative and thread interleaving cannot change the total.
        let mut by_lane: BTreeMap<&str, (Vec<String>, BTreeMap<&str, f64>)> = BTreeMap::new();
        for e in &self.events {
            if e.class != class {
                continue;
            }
            let slot = by_lane.entry(self.lane_name(e.lane)).or_default();
            match e.phase {
                EventPhase::Counter => {
                    *slot.1.entry(e.name.as_str()).or_insert(0.0) += e.value;
                }
                EventPhase::Begin => slot.0.push(format!(
                    "begin {} parent={} {}",
                    e.span,
                    fmt_id(e.parent),
                    e.name
                )),
                EventPhase::End => slot.0.push(format!("end {}", e.span)),
                EventPhase::Instant => {
                    slot.0.push(format!("inst parent={} {}", fmt_id(e.parent), e.name))
                }
                EventPhase::Gauge => slot.0.push(format!(
                    "gauge parent={} {} = {:016x}",
                    fmt_id(e.parent),
                    e.name,
                    e.value.to_bits()
                )),
            }
        }
        let mut out = String::with_capacity(64 + 32 * self.events.len());
        out.push_str(header);
        out.push('\n');
        for (lane, (lines, counters)) in by_lane {
            out.push_str("lane ");
            out.push_str(lane);
            out.push('\n');
            for line in lines {
                out.push_str("  ");
                out.push_str(&line);
                out.push('\n');
            }
            for (name, total) in counters {
                out.push_str(&format!("  counter {name} = {}\n", total as u64));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tracer is process-global and the lib test binary runs tests
    // concurrently: every test here that enables it takes this lock
    // and asserts only on its own uniquely-named lanes/counters, so a
    // concurrently-running instrumented test cannot perturb it.  The
    // full-system determinism suite lives in rust/tests/trace.rs
    // (its own process).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _g = locked();
        disable();
        let before = recorded_total();
        let _lane = lane("obs-test-noop");
        let _span = span("obs.noop.phase");
        instant("obs.noop.instant");
        counter("obs.noop.counter", 7);
        gauge("obs.noop.gauge", 1.5);
        drop(_span);
        assert_eq!(recorded_total(), before, "disabled tracer recorded events");
    }

    #[test]
    fn spans_nest_and_ids_count_per_lane() {
        let _g = locked();
        reset();
        enable();
        {
            let _l = lane("obs-test-nest");
            let _outer = span("obs.nest.outer");
            {
                let _inner = logical_span("obs.nest.inner");
                counter("obs.nest.hits", 2);
            }
        }
        disable();
        let snap = snapshot();
        let mine: Vec<&Event> = snap
            .events
            .iter()
            .filter(|e| snap.lane_name(e.lane) == "obs-test-nest")
            .collect();
        assert_eq!(mine.len(), 5, "{mine:?}");
        assert_eq!(mine[0].phase, EventPhase::Begin);
        assert_eq!(mine[0].span, 0);
        assert_eq!(mine[0].parent, NO_ID);
        // span ids count per (lane, class): the logical inner span is
        // logical-id 0 even though exec-id 0 is already taken
        assert_eq!(mine[1].span, 0);
        assert_eq!(mine[1].parent, 0, "inner span must parent on outer");
        assert_eq!(mine[1].class, Class::Logical);
        assert_eq!(mine[2].phase, EventPhase::Counter);
        assert_eq!(mine[2].parent, 0, "counter must attach to innermost span");
        assert_eq!(mine[3].phase, EventPhase::End);
        assert_eq!(mine[3].class, Class::Logical);
        assert_eq!(mine[4].class, Class::Exec);
        assert_eq!(mine[4].span, 0);
        reset();
    }

    #[test]
    fn lane_guard_restores_previous_scope() {
        let _g = locked();
        reset();
        enable();
        let _outer = lane("obs-test-outer");
        let _s = span("obs.outer.span");
        {
            let _inner = lane("obs-test-inner");
            // fresh lane: no inherited parent, ids restart at 0
            let _t = span("obs.inner.span");
        }
        instant("obs.outer.after");
        disable();
        let snap = snapshot();
        let inner: Vec<&Event> = snap
            .events
            .iter()
            .filter(|e| snap.lane_name(e.lane) == "obs-test-inner")
            .collect();
        assert_eq!(inner[0].span, 0);
        assert_eq!(inner[0].parent, NO_ID);
        let after = snap
            .events
            .iter()
            .find(|e| e.name == "obs.outer.after")
            .expect("instant after lane pop");
        assert_eq!(snap.lane_name(after.lane), "obs-test-outer");
        assert_eq!(after.parent, 0, "outer span must be open again");
        reset();
    }

    #[test]
    fn canon_excludes_wall_tid_and_exec_class() {
        let _g = locked();
        reset();
        enable();
        {
            let _l = lane("obs-test-canon");
            let _exec = span("obs.canon.exec");
            logical_counter("obs.canon.count", 3);
            logical_counter("obs.canon.count", 4);
            logical_gauge("obs.canon.level", 2.5);
        }
        disable();
        let snap = snapshot();
        let canon = snap.canon();
        assert!(canon.contains("lane obs-test-canon"), "{canon}");
        assert!(canon.contains("counter obs.canon.count = 7"), "{canon}");
        // the gauge sits inside the (exec) span, so its parent is that
        // span's id — identity only, no wall/tid anywhere in the digest
        assert!(
            canon.contains(&format!("gauge parent=0 obs.canon.level = {:016x}", 2.5f64.to_bits())),
            "{canon}"
        );
        assert!(!canon.contains("obs.canon.exec"), "exec event leaked into canon: {canon}");
        let exec = snap.canon_exec();
        assert!(exec.contains("begin 0 parent=- obs.canon.exec"), "{exec}");
        assert!(!exec.contains("obs.canon.count"), "logical event leaked into exec: {exec}");
        reset();
    }

    #[test]
    fn counter_total_sums_across_lanes() {
        let _g = locked();
        reset();
        enable();
        {
            let _a = lane("obs-test-sum-a");
            counter("obs.sum.n", 5);
        }
        {
            let _b = lane("obs-test-sum-b");
            counter("obs.sum.n", 6);
        }
        disable();
        assert_eq!(snapshot().counter_total("obs.sum.n"), 11);
        reset();
    }
}
