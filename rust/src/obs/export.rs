//! Chrome-trace export of a trace [`Snapshot`] — readable by the
//! `chrome://tracing` / Perfetto UI *and* by KForge's own rocprof
//! frontend.
//!
//! The file carries two views of the same run:
//!
//! - the raw event stream as standard `ph: B/E/i/C` records (tid =
//!   worker index, ts = microseconds since [`super::enable`]), for
//!   humans with a trace viewer;
//! - appended `ph: X` **phase-aggregate** rows plus an `otherData`
//!   header in exactly the rocprof dialect
//!   ([`crate::profiler::rocprof`]): one row per distinct exec span
//!   name carrying `BeginNs`/`EndNs`/`DurationNs` (total self-time,
//!   laid end-to-end on a CPU-time axis behind one leading gap of
//!   unattributed time) and the rocprof counter vocabulary reused for
//!   phase shares.  `RocprofFrontend::interpret` skips everything but
//!   the X rows, so the emitted file round-trips into
//!   [`Evidence`] unmodified — KForge's analysis agent reading
//!   KForge's own execution.
//!
//! The X-row field mapping (the "self-profile" dialect):
//!
//! - `DurationNs` — total self-time of the phase (child spans
//!   excluded), summed across all occurrences and threads;
//! - `VALUBusyPct` — the phase's share of all attributed self-time;
//! - `MemUnitBusyPct` — the phase's share of the span *count*;
//! - `WaveOccupancyPct` — the share of lanes in which the phase ran;
//! - `BoundBy` — `MEM` for store/journal phases, `VALU` otherwise;
//! - `otherData.TotalDurationNs` — attributed + unattributed CPU time;
//! - `otherData.GpuBusyPct` — the attributed share (so
//!   `Evidence::launch_fraction` reports untraced time).

use super::{Class, Event, EventPhase, Snapshot, NO_ID};
use crate::profiler::evidence::Evidence;
use crate::profiler::frontend::{ArtifactKind, ArtifactPart, ProfileArtifact, ProfilerFrontend};
use crate::profiler::rocprof::RocprofFrontend;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

fn round1(v: f64) -> f64 {
    (v * 10.0).round() / 10.0
}

fn class_str(c: Class) -> &'static str {
    match c {
        Class::Logical => "logical",
        Class::Exec => "exec",
    }
}

fn id_i64(id: u64) -> i64 {
    if id == NO_ID {
        -1
    } else {
        id as i64
    }
}

/// Per-phase aggregate over the exec spans of a snapshot.
#[derive(Debug, Default, Clone)]
struct PhaseAgg {
    count: u64,
    self_ns: u64,
    lanes: BTreeSet<u32>,
}

/// Aggregates: (per-name phase stats, attributed ns, unattributed ns,
/// lanes that ran any exec span).
fn aggregate_exec_spans(snap: &Snapshot) -> (BTreeMap<String, PhaseAgg>, u64, u64, usize) {
    // per-tid replay: events reach the buffer in per-thread
    // chronological order, so a stack walk per tid reconstructs
    // nesting and self-times exactly.
    struct Open {
        name: String,
        lane: u32,
        begin_ns: u64,
        child_ns: u64,
    }
    let mut stacks: BTreeMap<u32, Vec<Open>> = BTreeMap::new();
    // per-tid root-span intervals + observed extent, for coverage
    let mut roots: BTreeMap<u32, Vec<(u64, u64)>> = BTreeMap::new();
    let mut extent: BTreeMap<u32, (u64, u64)> = BTreeMap::new();
    let mut phases: BTreeMap<String, PhaseAgg> = BTreeMap::new();
    let mut exec_lanes: BTreeSet<u32> = BTreeSet::new();

    for e in snap.events.iter() {
        if e.class != Class::Exec {
            continue;
        }
        match e.phase {
            EventPhase::Begin => {
                let ext = extent.entry(e.tid).or_insert((e.wall_ns, e.wall_ns));
                ext.0 = ext.0.min(e.wall_ns);
                ext.1 = ext.1.max(e.wall_ns);
                exec_lanes.insert(e.lane);
                stacks.entry(e.tid).or_default().push(Open {
                    name: e.name.clone(),
                    lane: e.lane,
                    begin_ns: e.wall_ns,
                    child_ns: 0,
                });
            }
            EventPhase::End => {
                let ext = extent.entry(e.tid).or_insert((e.wall_ns, e.wall_ns));
                ext.0 = ext.0.min(e.wall_ns);
                ext.1 = ext.1.max(e.wall_ns);
                let stack = stacks.entry(e.tid).or_default();
                // unmatched Ends (disabled mid-span) are dropped
                let Some(open) = stack.pop() else { continue };
                let dur = e.wall_ns.saturating_sub(open.begin_ns);
                let agg = phases.entry(open.name).or_default();
                agg.count += 1;
                agg.self_ns += dur.saturating_sub(open.child_ns);
                agg.lanes.insert(open.lane);
                if let Some(parent) = stack.last_mut() {
                    parent.child_ns += dur;
                } else {
                    roots.entry(e.tid).or_default().push((open.begin_ns, e.wall_ns));
                }
            }
            _ => {}
        }
    }

    // attributed = per-tid union of root intervals; unattributed = the
    // rest of each tid's observed extent (both CPU-time, so threads sum)
    let mut attributed: u64 = 0;
    let mut unattributed: u64 = 0;
    for (tid, mut intervals) in roots {
        intervals.sort_unstable();
        let mut covered: u64 = 0;
        let mut cursor: u64 = 0;
        let mut first = true;
        for (b, e) in intervals {
            if first || b > cursor {
                covered += e.saturating_sub(b);
                cursor = e;
                first = false;
            } else if e > cursor {
                covered += e - cursor;
                cursor = e;
            }
        }
        attributed += covered;
        if let Some((lo, hi)) = extent.get(&tid) {
            unattributed += (hi - lo).saturating_sub(covered);
        }
    }
    (phases, attributed, unattributed, exec_lanes.len())
}

/// Render a snapshot as chrome-trace JSON (see the module docs).
pub fn chrome_trace(snap: &Snapshot, workload: &str) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(snap.events.len() + 16);
    for e in snap.events.iter() {
        let ts = e.wall_ns as f64 / 1e3;
        let lane = snap.lane_name(e.lane);
        let ev = match e.phase {
            EventPhase::Begin => Json::obj()
                .set("ph", "B")
                .set("name", e.name.clone())
                .set(
                    "args",
                    Json::obj()
                        .set("lane", lane)
                        .set("class", class_str(e.class))
                        .set("span", id_i64(e.span))
                        .set("parent", id_i64(e.parent)),
                ),
            EventPhase::End => Json::obj().set("ph", "E").set(
                "args",
                Json::obj().set("lane", lane).set("span", id_i64(e.span)),
            ),
            EventPhase::Instant => Json::obj()
                .set("ph", "i")
                .set("s", "t")
                .set("name", e.name.clone())
                .set(
                    "args",
                    Json::obj().set("lane", lane).set("class", class_str(e.class)),
                ),
            EventPhase::Counter | EventPhase::Gauge => Json::obj()
                .set("ph", "C")
                .set("name", e.name.clone())
                .set(
                    "args",
                    Json::obj()
                        .set("value", e.value)
                        .set(
                            "kind",
                            if e.phase == EventPhase::Gauge { "gauge" } else { "counter" },
                        )
                        .set("lane", lane)
                        .set("class", class_str(e.class)),
                ),
        };
        events.push(ev.set("pid", 0i64).set("tid", i64::from(e.tid)).set("ts", ts));
    }

    // appended rocprof-dialect X rows: one per exec phase name, laid
    // end-to-end on a CPU-time axis behind a single leading gap of
    // unattributed time (which interpret() reads back as launch
    // overhead, i.e. untraced time)
    let (phases, attributed, unattributed, n_lanes) = aggregate_exec_spans(snap);
    let total_self: u64 = phases.values().map(|a| a.self_ns).sum();
    let total_count: u64 = phases.values().map(|a| a.count).sum();
    let total_ns = attributed + unattributed;
    let mut rows: Vec<(&String, &PhaseAgg)> = phases.iter().collect();
    rows.sort_by(|a, b| b.1.self_ns.cmp(&a.1.self_ns).then_with(|| a.0.cmp(b.0)));
    let mut cursor = unattributed;
    for (name, agg) in rows {
        let begin = cursor;
        let end = begin + agg.self_ns;
        cursor = end;
        let share = |num: u64, den: u64| {
            if den == 0 {
                0.0
            } else {
                round1(100.0 * num as f64 / den as f64)
            }
        };
        let bound_by = if name.starts_with("store") || name.starts_with("journal") {
            "MEM"
        } else {
            "VALU"
        };
        events.push(
            Json::obj()
                .set("ph", "X")
                .set("pid", 0i64)
                .set("tid", 0i64)
                .set("name", name.clone())
                .set(
                    "args",
                    Json::obj()
                        .set("BeginNs", begin as i64)
                        .set("EndNs", end as i64)
                        .set("DurationNs", agg.self_ns as i64)
                        .set("Calls", agg.count as i64)
                        .set("VALUBusyPct", share(agg.self_ns, total_self))
                        .set("MemUnitBusyPct", share(agg.count, total_count))
                        .set("WaveOccupancyPct", share(agg.lanes.len() as u64, n_lanes as u64))
                        .set("BoundBy", bound_by),
                ),
        );
    }

    let busy_pct = if total_ns == 0 {
        0.0
    } else {
        round1(100.0 * attributed as f64 / total_ns as f64)
    };
    let other = Json::obj()
        .set("Device", "kforge-self")
        .set("Workload", workload)
        .set("TotalDurationNs", total_ns as i64)
        .set("GpuBusyPct", busy_pct);
    Json::obj()
        .set("otherData", other)
        .set("traceEvents", Json::Arr(events))
        .to_string()
}

/// Wrap an emitted trace as the rocprof artifact shape — the whole
/// file *is* the `kernel_trace_json` part (interpret reads only the X
/// rows and `otherData`).
pub fn self_artifact(trace_json: String) -> ProfileArtifact {
    ProfileArtifact {
        frontend: "rocprof",
        kind: ArtifactKind::TraceJson,
        parts: vec![ArtifactPart { name: "kernel_trace_json", content: trace_json }],
    }
}

/// Feed a trace through the rocprof frontend: the self-profile
/// [`Evidence`] the analysis pipeline already knows how to read.
pub fn self_evidence(trace_json: &str) -> Result<Evidence> {
    RocprofFrontend.interpret(&self_artifact(trace_json.to_string()))
}

/// Snapshot the global tracer and write the chrome-trace file.
pub fn write_trace(path: &Path, workload: &str) -> Result<()> {
    let snap = super::snapshot();
    std::fs::write(path, chrome_trace(&snap, workload))
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    /// A deterministic hand-built snapshot: two tids, nested spans,
    /// one logical instant, one counter.
    fn sample_snapshot() -> Snapshot {
        let ev = |phase, class, name: &str, lane, span, parent, tid, wall_ns, value| Event {
            phase,
            class,
            name: name.to_string(),
            lane,
            span,
            parent,
            tid,
            wall_ns,
            value,
        };
        use Class::{Exec, Logical};
        use EventPhase::{Begin, Counter, End, Instant};
        Snapshot {
            lanes: vec!["main".into(), "job:0".into()],
            events: vec![
                ev(Begin, Exec, "campaign", 0, 0, NO_ID, 0, 0, 0.0),
                ev(Begin, Exec, "verify", 1, 0, NO_ID, 1, 100, 0.0),
                ev(Counter, Exec, "store.bytes", 1, NO_ID, 0, 1, 150, 64.0),
                ev(End, Exec, "", 1, 0, NO_ID, 1, 700, 0.0),
                ev(Instant, Logical, "task.correct", 1, NO_ID, NO_ID, 0, 800, 0.0),
                ev(End, Exec, "", 0, 0, NO_ID, 0, 1000, 0.0),
            ],
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_matched_begin_end() {
        let text = chrome_trace(&sample_snapshot(), "unit");
        let doc = json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let b = events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("B")).count();
        let e = events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("E")).count();
        assert_eq!(b, 2);
        assert_eq!(b, e);
        assert_eq!(
            doc.get("otherData").unwrap().get("Device").and_then(Json::as_str),
            Some("kforge-self")
        );
    }

    #[test]
    fn x_rows_report_self_time_and_interpret_roundtrips() {
        let text = chrome_trace(&sample_snapshot(), "unit");
        let doc = json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let xs: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("X")).collect();
        assert_eq!(xs.len(), 2, "one X row per exec phase name");
        // campaign ran 1000ns total but verify (600ns) is a separate
        // tid root: campaign self = 1000, verify self = 600
        let by_name = |n: &str| {
            xs.iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(n))
                .unwrap()
                .get("args")
                .unwrap()
                .clone()
        };
        assert_eq!(by_name("campaign").get("DurationNs").and_then(Json::as_i64), Some(1000));
        assert_eq!(by_name("verify").get("DurationNs").and_then(Json::as_i64), Some(600));

        let ev = self_evidence(&text).unwrap();
        assert_eq!(ev.frontend, "rocprof");
        assert_eq!(ev.n_kernels(), 2);
        assert!(ev.fidelity_score() > 0.0, "{}", ev.fidelity_score());
        // both tids fully covered by roots => no unattributed time
        assert!((ev.busy_fraction.or(0.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn empty_snapshot_exports_cleanly() {
        let text = chrome_trace(&Snapshot::default(), "unit");
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("traceEvents").and_then(Json::as_arr).unwrap().len(), 0);
        let ev = self_evidence(&text).unwrap();
        assert_eq!(ev.n_kernels(), 0);
        assert_eq!(ev.fidelity_score(), 0.0);
    }
}
