//! Leveled stderr diagnostics gated by `KFORGE_LOG`.
//!
//! The repo's scattered `eprintln!` diagnostics route through here so
//! noisy paths are silenceable (or verbose paths audible) with one env
//! var instead of another round of call-site edits:
//!
//! ```text
//! KFORGE_LOG=error   only hard failures
//! KFORGE_LOG=warn    (default) degraded-but-continuing paths
//! KFORGE_LOG=info    progress lines
//! KFORGE_LOG=debug   everything
//! ```
//!
//! Use through the crate-root macros:
//!
//! ```ignore
//! crate::kf_warn!("[store] journal append failed for job {i} ({e:#})");
//! ```
//!
//! Output goes to stderr as `kforge[<level>] ...`, never stdout — the
//! golden-pinned CLI surfaces stay byte-identical.  The filter is read
//! once per process ([`std::sync::OnceLock`]); the pure
//! [`Level::from_env_str`] is separated out so tests never race on the
//! process environment.

use std::fmt;
use std::sync::OnceLock;

/// Diagnostic severity, ordered most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `KFORGE_LOG` value.  Unset, empty and unrecognized all
    /// fall back to the `warn` default — a typo must never silence
    /// error reporting entirely.
    pub fn from_env_str(raw: Option<&str>) -> Level {
        match raw.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
            Some("error") => Level::Error,
            Some("info") => Level::Info,
            Some("debug") => Level::Debug,
            _ => Level::Warn,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// The process-wide filter: everything at or above this level prints.
pub fn max_level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| Level::from_env_str(std::env::var("KFORGE_LOG").ok().as_deref()))
}

/// Macro backend — call through `kf_error!`/`kf_warn!`/`kf_info!`/
/// `kf_debug!`, which defer the formatting into `fmt::Arguments` so a
/// filtered-out line never allocates.
pub fn log(level: Level, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("kforge[{}] {}", level.tag(), args);
    }
}

/// Log a hard failure (always printed unless someone filters to a
/// level that does not exist).
#[macro_export]
macro_rules! kf_error {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log a degraded-but-continuing condition (printed by default).
#[macro_export]
macro_rules! kf_warn {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log a progress line (silent by default).
#[macro_export]
macro_rules! kf_info {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log a firehose detail (silent by default).
#[macro_export]
macro_rules! kf_debug {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_parse_covers_all_levels_and_defaults_to_warn() {
        assert_eq!(Level::from_env_str(Some("error")), Level::Error);
        assert_eq!(Level::from_env_str(Some("WARN")), Level::Warn);
        assert_eq!(Level::from_env_str(Some(" Info ")), Level::Info);
        assert_eq!(Level::from_env_str(Some("debug")), Level::Debug);
        assert_eq!(Level::from_env_str(None), Level::Warn);
        assert_eq!(Level::from_env_str(Some("")), Level::Warn);
        assert_eq!(Level::from_env_str(Some("verbose")), Level::Warn);
    }

    #[test]
    fn severity_ordering_matches_filtering() {
        // `level <= max` prints: error always, debug only at debug
        assert!(Level::Error <= Level::Warn);
        assert!(Level::Warn <= Level::Warn);
        assert!(Level::Info > Level::Warn);
        assert!(Level::Debug > Level::Info);
    }

    #[test]
    fn macros_expand_without_panicking() {
        // smoke: format args with captures, through the crate paths
        let job = 3;
        crate::kf_debug!("probe line for job {job} ({})", "detail");
        crate::kf_info!("probe info");
    }
}
