//! `kforge trace summarize` — per-phase breakdown of an emitted
//! chrome-trace file, closed with the rocprof self-profile.
//!
//! The input is a file written by [`super::export::chrome_trace`]:
//! the raw `B`/`E` events are replayed per tid (file order preserves
//! per-thread chronology) into per-phase call counts, total and
//! self-times, and a **coverage** figure — the share of traced wall
//! time (summed per-thread extents, so a CPU-time axis) attributed to
//! named phases.  The CI smoke asserts coverage ≥ 95% on a cold
//! campaign.  The same bytes are then fed through
//! [`super::export::self_evidence`] — the rocprof frontend's
//! `interpret` — and the resulting [`Evidence`] drives a
//! "self-profile" recommendation line: the analysis path the paper
//! applies to GPU traces, applied to KForge's own run.

use super::export::self_evidence;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Default, Clone)]
struct PhaseRow {
    calls: u64,
    total_us: f64,
    self_us: f64,
}

struct Open {
    name: String,
    exec: bool,
    begin_us: f64,
    child_us: f64,
}

/// Render the human summary of a chrome-trace file's contents.
pub fn summarize(trace_json: &str) -> Result<String> {
    let doc = json::parse(trace_json).context("parsing chrome-trace JSON")?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace has no traceEvents array")?;
    let workload = doc
        .get("otherData")
        .and_then(|o| o.get("Workload"))
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string();

    let mut phases: BTreeMap<String, PhaseRow> = BTreeMap::new();
    let mut counters: BTreeMap<String, f64> = BTreeMap::new();
    let mut stacks: BTreeMap<i64, Vec<Open>> = BTreeMap::new();
    // per-tid observed extent and attributed (exec-root) time
    let mut extent: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    let mut attributed: BTreeMap<i64, f64> = BTreeMap::new();
    let (mut n_spans, mut n_instants, mut n_counts, mut n_aggregates) = (0u64, 0u64, 0u64, 0u64);

    for (i, e) in events.iter().enumerate() {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        let tid = e.get("tid").and_then(Json::as_i64).unwrap_or(0);
        let ts = e.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        let args = e.get("args");
        let arg_str = |k: &str| args.and_then(|a| a.get(k)).and_then(Json::as_str);
        match ph {
            "B" => {
                n_spans += 1;
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("B event {i} has no name"))?
                    .to_string();
                let exec = arg_str("class") == Some("exec");
                if exec {
                    let ext = extent.entry(tid).or_insert((ts, ts));
                    ext.0 = ext.0.min(ts);
                    ext.1 = ext.1.max(ts);
                }
                stacks.entry(tid).or_default().push(Open {
                    name,
                    exec,
                    begin_us: ts,
                    child_us: 0.0,
                });
            }
            "E" => {
                let stack = stacks.entry(tid).or_default();
                let open = stack
                    .pop()
                    .with_context(|| format!("E event {i} on tid {tid} has no open span"))?;
                if !open.exec {
                    continue;
                }
                let ext = extent.entry(tid).or_insert((ts, ts));
                ext.0 = ext.0.min(ts);
                ext.1 = ext.1.max(ts);
                let dur = (ts - open.begin_us).max(0.0);
                let row = phases.entry(open.name).or_default();
                row.calls += 1;
                row.total_us += dur;
                row.self_us += (dur - open.child_us).max(0.0);
                // charge the nearest exec ancestor; at exec root the
                // whole interval counts as attributed thread time
                match stack.iter_mut().rev().find(|o| o.exec) {
                    Some(parent) => parent.child_us += dur,
                    None => *attributed.entry(tid).or_insert(0.0) += dur,
                }
            }
            "i" => n_instants += 1,
            "C" => {
                n_counts += 1;
                if arg_str("kind") != Some("gauge") {
                    let name = e.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
                    let v = args.and_then(|a| a.get("value")).and_then(Json::as_f64).unwrap_or(0.0);
                    *counters.entry(name).or_insert(0.0) += v;
                }
            }
            "X" => n_aggregates += 1,
            _ => {}
        }
    }

    let traced_us: f64 = extent.values().map(|(lo, hi)| hi - lo).sum();
    let attributed_us: f64 = attributed.values().sum();
    let total_self: f64 = phases.values().map(|r| r.self_us).sum();

    let mut out = String::new();
    let _ = writeln!(out, "kforge trace summary (workload: {workload})");
    let _ = writeln!(
        out,
        "events: {}  spans: {n_spans}  instants: {n_instants}  counters: {n_counts}  aggregates: {n_aggregates}",
        events.len()
    );
    let _ = writeln!(
        out,
        "threads: {}  traced wall: {:.3} s (summed per-thread extents)",
        extent.len().max(1),
        traced_us / 1e6
    );

    if phases.is_empty() {
        let _ = writeln!(out, "no timed exec spans (fully warm run, or tracing was off)");
        let _ = writeln!(out, "coverage: n/a");
    } else {
        let mut rows: Vec<(&String, &PhaseRow)> = phases.iter().collect();
        rows.sort_by(|a, b| {
            b.1.self_us.total_cmp(&a.1.self_us).then_with(|| a.0.cmp(b.0))
        });
        let _ = writeln!(
            out,
            "{:<32} {:>7} {:>10} {:>10} {:>7}",
            "phase", "calls", "total_s", "self_s", "share"
        );
        for (name, row) in rows {
            let share = if total_self > 0.0 { 100.0 * row.self_us / total_self } else { 0.0 };
            let _ = writeln!(
                out,
                "{:<32} {:>7} {:>10.3} {:>10.3} {:>6.1}%",
                name,
                row.calls,
                row.total_us / 1e6,
                row.self_us / 1e6,
                share
            );
        }
        let coverage =
            if traced_us > 0.0 { 100.0 * attributed_us / traced_us } else { 100.0 };
        let _ = writeln!(
            out,
            "coverage: {:.1}% of traced wall time attributed to named phases",
            coverage.min(100.0)
        );
    }

    if !counters.is_empty() {
        let _ = writeln!(out, "counters:");
        for (name, total) in &counters {
            let _ = writeln!(out, "  {:<32} {}", name, *total as u64);
        }
    }

    // close the loop: the emitted trace through the rocprof frontend
    match self_evidence(trace_json) {
        Ok(ev) if ev.n_kernels() > 0 => {
            let hottest = ev
                .kernels
                .iter()
                .max_by(|a, b| a.time_us.or(0.0).total_cmp(&b.time_us.or(0.0)))
                .expect("n_kernels > 0");
            let total = ev.kernels.iter().map(|k| k.time_us.or(0.0)).sum::<f64>();
            let hot_pct =
                if total > 0.0 { 100.0 * hottest.time_us.or(0.0) / total } else { 0.0 };
            let _ = writeln!(
                out,
                "self-profile [rocprof]: hottest phase '{}' ({:.1}% of attributed time), untraced {:.1}%, fidelity {:.2}",
                hottest.name,
                hot_pct,
                100.0 * ev.launch_fraction().or(0.0),
                ev.fidelity_score()
            );
        }
        Ok(_) => {
            let _ = writeln!(out, "self-profile [rocprof]: no exec phases to interpret");
        }
        Err(e) => {
            let _ = writeln!(out, "self-profile [rocprof]: interpretation failed ({e:#})");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::chrome_trace;
    use crate::obs::{Class, Event, EventPhase, Snapshot, NO_ID};

    fn sample_snapshot() -> Snapshot {
        let ev = |phase, class, name: &str, lane, span, parent, tid, wall_ns, value| Event {
            phase,
            class,
            name: name.to_string(),
            lane,
            span,
            parent,
            tid,
            wall_ns,
            value,
        };
        use Class::{Exec, Logical};
        use EventPhase::{Begin, Counter, End, Instant};
        Snapshot {
            lanes: vec!["main".into(), "job:0".into()],
            events: vec![
                ev(Begin, Exec, "campaign", 0, 0, NO_ID, 0, 0, 0.0),
                ev(Begin, Exec, "verify", 0, 1, 0, 0, 200_000, 0.0),
                ev(Counter, Exec, "oracle.evaluations", 0, NO_ID, 1, 0, 300_000, 12.0),
                ev(End, Exec, "", 0, 1, NO_ID, 0, 800_000, 0.0),
                ev(Instant, Logical, "task.correct", 1, NO_ID, NO_ID, 0, 900_000, 0.0),
                ev(End, Exec, "", 0, 0, NO_ID, 0, 1_000_000, 0.0),
            ],
        }
    }

    #[test]
    fn summary_attributes_self_time_and_full_coverage() {
        let text = chrome_trace(&sample_snapshot(), "unit");
        let s = summarize(&text).unwrap();
        assert!(s.contains("workload: unit"), "{s}");
        // campaign: total 1ms, self 0.4ms after the 0.6ms verify child
        assert!(s.contains("verify"), "{s}");
        assert!(s.contains("coverage: 100.0%"), "{s}");
        assert!(s.contains("oracle.evaluations"), "{s}");
        assert!(s.contains("12"), "{s}");
        assert!(s.contains("self-profile [rocprof]: hottest phase 'verify'"), "{s}");
    }

    #[test]
    fn summary_of_spanless_trace_degrades_gracefully() {
        let text = chrome_trace(&Snapshot::default(), "unit");
        let s = summarize(&text).unwrap();
        assert!(s.contains("coverage: n/a"), "{s}");
        assert!(s.contains("no exec phases to interpret"), "{s}");
    }

    #[test]
    fn malformed_input_is_an_error_not_a_panic() {
        assert!(summarize("{").is_err());
        assert!(summarize("{\"no\": \"traceEvents\"}").is_err());
        // an E with no open span is a structural error the CI check
        // should surface, not silently ignore
        let bad = r#"{"otherData":{},"traceEvents":[{"ph":"E","tid":0,"ts":1.0}]}"#;
        assert!(summarize(bad).is_err());
    }
}
