//! ROCm platform: AMD Instinct MI300X constants (CDNA3).
//!
//! This module is the proof that the platform API is open: a third
//! accelerator landed **entirely here** — spec + `Platform` impl +
//! one registration line in [`super::registry`] — with no match arms
//! or special cases anywhere else in the codebase.
//!
//! The interesting contrasts with the built-in pair:
//! - discrete HBM3 memory like CUDA, but 64-wide wavefronts (CDNA)
//!   instead of 32-wide warps — the legality checks and schedule
//!   samplers pick this up from `simd_width` alone;
//! - **its own profiler frontend**: `rocprof` chrome-trace JSON
//!   (`profiler/rocprof.rs`) with rocprof field names and ns units —
//!   programmatic and recommendation-grade like nsys, but a genuinely
//!   different artifact dialect, registered via the one
//!   [`Platform::profiler_frontend`] hook below;
//! - hipGraph launch amortization (the HIP port of CUDA graphs) with a
//!   slightly heavier per-node replay;
//! - its own unsupported-op list (MIOpen's transposed-3D-conv gap);
//! - named MI300X persona calibration rows in `agents/persona.rs`
//!   (measured single-shot rates; before those landed, personas rode
//!   the declared CUDA-fallback prior below, which remains the path
//!   for platforms newer than their calibration).

use super::spec::{LaunchAmortization, PlatformSpec};
use super::Platform;
use crate::profiler::ProfilerFrontendRef;
use crate::sched::schedule::Tile;
use std::sync::Arc;

/// MI300X (304 CU, 192GB HBM3) device model.
pub fn mi300x() -> PlatformSpec {
    PlatformSpec {
        platform_id: "rocm",
        language: "HIP",
        name: "AMD Instinct MI300X 192GB",
        // 304 CUs * 128 fp32 lanes * 2 flop * ~2.1GHz ≈ 163 TFLOP/s
        peak_flops_f32: 163e12,
        // matrix-core TF32 throughput (dense) ≈ 654 TFLOP/s
        peak_flops_mm: 654e12,
        // 5.3 TB/s HBM3
        mem_bw: 5.3e12,
        // HIP kernel launch runs a little heavier than CUDA's
        launch_overhead: 6.0e-6,
        dispatch_overhead: 2.0e-6,
        // 64 KB LDS per workgroup
        onchip_bytes: 64 * 1024,
        max_threadgroup: 1024,
        // CDNA wavefront
        simd_width: 64,
        num_cores: 304,
        unified_memory: false,
        // PCIe Gen5 x16 host staging
        h2d_bw: 64e9,
        // hipGraph: CUDA-graphs port, slightly costlier replay
        launch_amortization: LaunchAmortization::DeviceGraphs {
            replay_per_node_s: 0.5e-6,
        },
        tile_sweet_spot: 128.0,
        // 64 KB LDS caps the tile below the H100 point: 64x64x64 is
        // the largest Tile::CHOICES entry that fits (48 KB)
        expert_tile: Tile { bm: 64, bn: 64, bk: 64 },
        stock_tile: Tile { bm: 64, bn: 64, bk: 32 },
        inductor_tile: Tile { bm: 64, bn: 64, bk: 32 },
        noise_sigma: 0.05,
        // MIOpen gap: transposed 3-D convolution falls back to host
        unsupported_ops: &["conv3d_transpose"],
    }
}

/// The ROCm platform plugin.
#[derive(Debug)]
pub struct RocmPlatform {
    spec: PlatformSpec,
}

impl RocmPlatform {
    pub fn new() -> RocmPlatform {
        RocmPlatform { spec: mi300x() }
    }
}

impl Default for RocmPlatform {
    fn default() -> Self {
        RocmPlatform::new()
    }
}

impl Platform for RocmPlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["hip", "mi300"]
    }

    /// rocprof chrome-trace JSON — the frontend defined in
    /// `profiler/rocprof.rs`; this hook is its entire registration.
    fn profiler_frontend(&self) -> ProfilerFrontendRef {
        static ROCPROF: std::sync::OnceLock<ProfilerFrontendRef> = std::sync::OnceLock::new();
        ROCPROF
            .get_or_init(|| Arc::new(crate::profiler::rocprof::RocprofFrontend))
            .clone()
    }

    /// One 8-GPU MI300X node, one kernel per GPU at a time.
    fn default_workers(&self) -> usize {
        8
    }

    /// HIP is close enough to CUDA that persona priors transfer with a
    /// mild haircut: same row, failure rate inflated 15%.
    fn calibration_fallback(&self) -> (&'static str, f64) {
        ("cuda", 1.15)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{by_name, cuda};

    #[test]
    fn mi300x_headlines() {
        let s = mi300x();
        assert_eq!(s.platform_id, "rocm");
        assert_eq!(s.simd_width, 64);
        assert!(!s.unified_memory);
        assert!(s.mem_bw > cuda::h100().mem_bw);
        assert!(!s.supports("conv3d_transpose"));
        assert!(s.supports("maxpool3d"));
    }

    #[test]
    fn profiles_through_rocprof_not_nsys() {
        let f = RocmPlatform::new().profiler_frontend();
        assert_eq!(f.name(), "rocprof");
        assert!(f.lossless());
        assert!(f.part_names().contains(&"kernel_trace_json"));
    }

    #[test]
    fn expert_tile_fits_lds() {
        let s = mi300x();
        assert!(s.expert_tile.onchip_bytes() <= s.onchip_bytes);
    }

    #[test]
    fn registered_with_aliases() {
        assert_eq!(by_name("hip").unwrap().name(), "rocm");
        assert_eq!(by_name("mi300").unwrap().name(), "rocm");
    }

    #[test]
    fn falls_back_to_cuda_calibration() {
        let (fallback, factor) = RocmPlatform::new().calibration_fallback();
        assert_eq!(fallback, "cuda");
        assert!(factor > 1.0);
    }
}
