//! Name → platform lookup.
//!
//! The process-wide [`registry`] holds the built-in platforms (cuda,
//! metal, rocm).  [`PlatformRegistry`] is also constructible standalone
//! so embedders (and tests) can register additional targets without
//! touching the built-in set.

use super::{Platform, PlatformRef};
use anyhow::{bail, Result};
use std::sync::{Arc, OnceLock};

/// An ordered collection of platforms, addressable by name or alias.
#[derive(Debug, Default)]
pub struct PlatformRegistry {
    platforms: Vec<PlatformRef>,
}

impl PlatformRegistry {
    /// An empty registry.
    pub fn new() -> PlatformRegistry {
        PlatformRegistry::default()
    }

    /// Register a platform.  Names and aliases must not collide with
    /// anything already registered.
    pub fn register(&mut self, platform: PlatformRef) -> Result<()> {
        for taken in self.platforms.iter() {
            let mut claimed = vec![taken.name()];
            claimed.extend(taken.aliases());
            for id in std::iter::once(platform.name()).chain(platform.aliases().iter().copied()) {
                if claimed.contains(&id) {
                    bail!(
                        "platform name {id:?} already registered (by {:?})",
                        taken.name()
                    );
                }
            }
        }
        self.platforms.push(platform);
        Ok(())
    }

    /// Look up a platform by name or alias.  Unknown names are an
    /// error (never a panic) listing everything registered.
    pub fn get(&self, name: &str) -> Result<PlatformRef> {
        for p in &self.platforms {
            if p.name() == name || p.aliases().contains(&name) {
                return Ok(p.clone());
            }
        }
        bail!(
            "unknown platform {name:?}; registered platforms: {}",
            self.describe()
        )
    }

    /// All registered platforms, in registration order.
    pub fn platforms(&self) -> &[PlatformRef] {
        &self.platforms
    }

    /// Registered primary names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.platforms.iter().map(|p| p.name()).collect()
    }

    pub fn len(&self) -> usize {
        self.platforms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.platforms.is_empty()
    }

    /// Human-readable listing: `cuda, metal (aka mps), rocm (aka hip)`.
    pub fn describe(&self) -> String {
        self.platforms
            .iter()
            .map(|p| {
                if p.aliases().is_empty() {
                    p.name().to_string()
                } else {
                    format!("{} (aka {})", p.name(), p.aliases().join(", "))
                }
            })
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// The process-wide registry of built-in platforms.
pub fn registry() -> &'static PlatformRegistry {
    static REGISTRY: OnceLock<PlatformRegistry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut r = PlatformRegistry::new();
        r.register(Arc::new(super::cuda::CudaPlatform::new()))
            .expect("builtin cuda registers");
        r.register(Arc::new(super::metal::MetalPlatform::new()))
            .expect("builtin metal registers");
        r.register(Arc::new(super::rocm::RocmPlatform::new()))
            .expect("builtin rocm registers");
        r
    })
}

/// Look up a built-in platform by name or alias.
pub fn by_name(name: &str) -> Result<PlatformRef> {
    registry().get(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformSpec;

    #[test]
    fn builtins_resolve_by_name_and_alias() {
        assert_eq!(by_name("cuda").unwrap().name(), "cuda");
        assert_eq!(by_name("metal").unwrap().name(), "metal");
        assert_eq!(by_name("mps").unwrap().name(), "metal");
        assert_eq!(by_name("rocm").unwrap().name(), "rocm");
        assert_eq!(by_name("hip").unwrap().name(), "rocm");
        assert!(registry().len() >= 3);
    }

    #[test]
    fn unknown_platform_is_error_not_panic() {
        let err = by_name("tpu").unwrap_err().to_string();
        assert!(err.contains("unknown platform"), "{err}");
        assert!(err.contains("cuda"), "error should list platforms: {err}");
        assert!(err.contains("rocm"), "error should list platforms: {err}");
    }

    #[derive(Debug)]
    struct FakePlatform {
        spec: PlatformSpec,
    }

    impl crate::platform::Platform for FakePlatform {
        fn spec(&self) -> &PlatformSpec {
            &self.spec
        }

        fn aliases(&self) -> &'static [&'static str] {
            &["fake2"]
        }
    }

    fn fake(id: &'static str) -> PlatformRef {
        let mut spec = crate::platform::cuda::h100();
        spec.platform_id = id;
        Arc::new(FakePlatform { spec })
    }

    #[test]
    fn standalone_registry_registers_and_rejects_duplicates() {
        let mut r = PlatformRegistry::new();
        r.register(fake("fake")).unwrap();
        assert_eq!(r.get("fake").unwrap().name(), "fake");
        assert_eq!(r.get("fake2").unwrap().name(), "fake");
        // same name again → error
        assert!(r.register(fake("fake")).is_err());
        // alias collision → error
        assert!(r.register(fake("fake2")).is_err());
        assert_eq!(r.names(), vec!["fake"]);
    }
}
