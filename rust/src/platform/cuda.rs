//! CUDA platform: NVIDIA H100 SXM5 constants (the paper's testbed:
//! 4× H100 SXM5, 80GB HBM3, 3.35 TB/s — §4.3).

use super::spec::{LaunchAmortization, PlatformSpec};
use super::Platform;
use crate::profiler::ProfilerFrontendRef;
use crate::sched::schedule::Tile;
use std::sync::Arc;

/// H100 SXM5 device model.
pub fn h100() -> PlatformSpec {
    PlatformSpec {
        platform_id: "cuda",
        language: "CUDA",
        name: "NVIDIA H100 SXM5 80GB",
        // 132 SMs * 128 fp32 lanes * 2 flop * ~1.8GHz ≈ 60 TFLOP/s
        peak_flops_f32: 60e12,
        // TF32 tensor core throughput (dense) ≈ 495 TFLOP/s; we model
        // f32 matmul on the MM engine at TF32 rate.
        peak_flops_mm: 495e12,
        mem_bw: 3.35e12,
        // CUDA kernel launch ≈ 4 µs end-to-end at small sizes
        launch_overhead: 4.0e-6,
        dispatch_overhead: 1.5e-6,
        // 228 KB shared memory per SM (227 usable per block)
        onchip_bytes: 227 * 1024,
        max_threadgroup: 1024,
        simd_width: 32,
        num_cores: 132,
        unified_memory: false,
        // PCIe Gen5 x16 ≈ 64 GB/s (SXM uses NVLink to peers, but host
        // staging still crosses PCIe)
        h2d_bw: 64e9,
        // CUDA graphs: one launch + tiny per-node replay cost
        launch_amortization: LaunchAmortization::DeviceGraphs {
            replay_per_node_s: 0.3e-6,
        },
        tile_sweet_spot: 128.0,
        expert_tile: Tile { bm: 128, bn: 128, bk: 64 },
        stock_tile: Tile { bm: 128, bn: 128, bk: 32 },
        inductor_tile: Tile { bm: 64, bn: 64, bk: 32 },
        noise_sigma: 0.04,
        unsupported_ops: &[],
    }
}

/// The CUDA platform plugin.
#[derive(Debug)]
pub struct CudaPlatform {
    spec: PlatformSpec,
}

impl CudaPlatform {
    pub fn new() -> CudaPlatform {
        CudaPlatform { spec: h100() }
    }
}

impl Default for CudaPlatform {
    fn default() -> Self {
        CudaPlatform::new()
    }
}

impl Platform for CudaPlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    /// `nsys stats` CSV reports (§5.2) — the trait default, stated
    /// explicitly for the paper's primary platform.
    fn profiler_frontend(&self) -> ProfilerFrontendRef {
        static NSYS: std::sync::OnceLock<ProfilerFrontendRef> = std::sync::OnceLock::new();
        NSYS.get_or_init(|| Arc::new(crate::profiler::nsys::NsysFrontend))
            .clone()
    }

    /// The paper's CUDA testbed: 4 H100s, one kernel per GPU at a time.
    fn default_workers(&self) -> usize {
        4
    }

    /// On CUDA the reference corpus *is* CUDA code — providing it is
    /// not a cross-platform transfer, so no ref-effect applies (§6.2).
    fn reference_transfer(&self) -> bool {
        false
    }

    fn calibration_fallback(&self) -> (&'static str, f64) {
        ("cuda", 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_headlines() {
        let s = h100();
        assert_eq!(s.platform_id, "cuda");
        assert!((s.mem_bw - 3.35e12).abs() < 1e9);
        assert!(s.peak_flops_mm > s.peak_flops_f32);
        assert_eq!(s.max_threadgroup, 1024);
    }

    #[test]
    fn cuda_reference_is_not_a_transfer() {
        assert!(!CudaPlatform::new().reference_transfer());
    }
}
