//! Simulated accelerator platforms.
//!
//! Two fundamentally different targets, as in the paper (§4.3):
//! a CUDA-like discrete GPU modeled on the H100 SXM5 testbed, and a
//! Metal-like unified-memory GPU modeled on the Apple M4 Max Mac
//! Studios.  The constants drive the `perfsim` roofline model; the
//! *profiling asymmetry* (programmatic CSV vs GUI screenshots) lives in
//! `profiler`.

pub mod spec;
pub mod cuda;
pub mod metal;

pub use spec::{PlatformKind, PlatformSpec, ProfilerAccess};
