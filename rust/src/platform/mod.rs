//! Simulated accelerator platforms — the open platform plugin API.
//!
//! The paper's headline claim is that the two-agent loop is
//! *platform-agnostic*: "requires only a single-shot example to target
//! new platforms".  This module makes that claim structural:
//!
//! - [`PlatformSpec`] (in [`spec`]) carries every device constant and
//!   behavioral knob — roofline rates, launch amortization model,
//!   baseline/expert tiles, prompt language, the unsupported-op list —
//!   as plain data;
//! - the [`Platform`] trait bundles the spec with the few behavioral
//!   hooks that are per-platform policy rather than constants (the
//!   profiler frontend, expert schedule, worker-pool sizing,
//!   persona-calibration fallback, whether a CUDA reference acts as
//!   cross-platform transfer);
//! - [`PlatformRegistry`] (in [`registry`]) maps names and aliases to
//!   [`PlatformRef`] handles; the CLI, coordinator, agents, baselines
//!   and harness all resolve platforms through it.
//!
//! **Adding a new accelerator is a one-module change**: write
//! `platform/<name>.rs` with a spec + a `Platform` impl, register it in
//! [`registry::registry`], done.  No other module branches on the
//! concrete platform — [`rocm`] (an MI300X-like CDNA target) was landed
//! exactly this way and is the living proof.
//!
//! The built-in targets, as in the paper (§4.3) plus the ROCm
//! extension:
//! - [`cuda`] — discrete H100 SXM5, programmatic `nsys` CSV profiling;
//! - [`metal`] — unified-memory Apple M4 Max, GUI-screenshot profiling;
//! - [`rocm`] — discrete MI300X, `rocprof` chrome-trace JSON profiling
//!   (its own frontend in `profiler/rocprof.rs`), 64-wide wavefronts,
//!   its own unsupported-op list.

pub mod spec;
pub mod registry;
pub mod cuda;
pub mod metal;
pub mod rocm;

pub use registry::{by_name, registry, PlatformRegistry};
pub use spec::{LaunchAmortization, PlatformSpec};

use crate::profiler::ProfilerFrontendRef;
use crate::sched::Schedule;
use std::fmt;
use std::sync::Arc;

/// Shared handle to a registered platform.
pub type PlatformRef = Arc<dyn Platform>;

/// A hardware target.  Most behavior derives from [`PlatformSpec`]
/// data via the default methods; a platform module overrides only what
/// is genuinely policy (worker counts, calibration fallback, reference
/// semantics).
pub trait Platform: fmt::Debug + Send + Sync {
    /// The device constants driving the simulator, legality checks,
    /// cost model and baselines.
    fn spec(&self) -> &PlatformSpec;

    /// Stable lowercase identifier used by the CLI, registry, persona
    /// calibration and run logs.
    fn name(&self) -> &'static str {
        self.spec().platform_id
    }

    /// Alternate names accepted by CLI parsing (e.g. "mps" for metal).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// The accelerator-language name used in prompts.
    fn language(&self) -> &'static str {
        self.spec().language
    }

    /// The profiling tool this platform exposes — how raw profiles
    /// become [`crate::profiler::Evidence`] for the analysis agent
    /// (§6.3's asymmetry: programmatic reports on CUDA/ROCm, scraped
    /// GUI screenshots on Metal).  Defaults to the nsys CSV frontend,
    /// the least surprising choice for a programmatically profiled
    /// accelerator; platforms with their own tooling override this
    /// (see `profiler/rocprof.rs` for the one-module recipe).
    ///
    /// Called once per optimization iteration, so implementations
    /// should hand out a cached `Arc` (frontends are stateless) rather
    /// than allocating per call.
    fn profiler_frontend(&self) -> ProfilerFrontendRef {
        static NSYS: std::sync::OnceLock<ProfilerFrontendRef> = std::sync::OnceLock::new();
        NSYS.get_or_init(|| Arc::new(crate::profiler::nsys::NsysFrontend))
            .clone()
    }

    /// The schedule point an expert (or a converged refinement loop)
    /// lands on for this device.
    fn expert_schedule(&self) -> Schedule {
        Schedule::expert_for(self.spec())
    }

    /// Worker threads (devices) a default campaign uses — the paper's
    /// testbed sizing (4 H100s, 5 Mac Studios).
    fn default_workers(&self) -> usize {
        4
    }

    /// Does a CUDA reference implementation act as a *cross-platform*
    /// transfer aid here (§6.2)?  False on CUDA itself — there the
    /// reference is the same language and carries no transfer effect.
    fn reference_transfer(&self) -> bool {
        true
    }

    /// Persona-calibration fallback for platforms without a dedicated
    /// calibration row: the name of the calibrated platform this one
    /// most resembles, plus a failure-rate inflation applied on top
    /// (>1.0 = harder than the fallback; the single-shot-example story
    /// means an unseen platform costs a bounded correctness haircut,
    /// not a rewrite).
    fn calibration_fallback(&self) -> (&'static str, f64) {
        ("cuda", 1.25)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::legal;

    #[test]
    fn every_registered_platform_expert_schedule_is_legal_on_itself() {
        for p in registry().platforms() {
            let sched = p.expert_schedule();
            legal::check(&sched, p.spec())
                .unwrap_or_else(|e| panic!("{}: expert schedule illegal: {e}", p.name()));
        }
    }

    #[test]
    fn profiler_asymmetry_via_frontends() {
        // the paper's §6.3 asymmetry, now expressed as frontend choice:
        // CUDA and ROCm expose lossless programmatic tools, Metal only
        // a lossy rendered-screen scrape — and the tools are distinct
        let f = |name: &str| by_name(name).unwrap().profiler_frontend();
        assert_eq!(f("cuda").name(), "nsys");
        assert_eq!(f("metal").name(), "xcode");
        assert_eq!(f("rocm").name(), "rocprof");
        assert!(f("cuda").lossless() && f("rocm").lossless());
        assert!(!f("metal").lossless());
        for p in registry().platforms() {
            assert!(!p.profiler_frontend().part_names().is_empty(), "{}", p.name());
        }
    }

    #[test]
    fn names_and_languages_are_distinct_and_nonempty() {
        let distinct = |mut v: Vec<&str>| {
            let n = v.len();
            v.sort();
            v.dedup();
            v.len() == n
        };
        let platforms = registry().platforms();
        assert!(
            distinct(platforms.iter().map(|p| p.name()).collect()),
            "duplicate platform names"
        );
        // languages key the per-platform census rows (harness::table2),
        // so they must be unique too; a same-language second device
        // needs a distinct label there before it can register
        assert!(
            distinct(platforms.iter().map(|p| p.language()).collect()),
            "duplicate platform languages"
        );
        for p in platforms {
            assert!(!p.name().is_empty());
            assert!(!p.language().is_empty());
            assert!(!p.spec().name.is_empty());
        }
    }
}
