//! Metal platform: Apple M4 Max constants (the paper's testbed:
//! 5× Mac Studio, 14-core CPU / 32-core GPU / 36GB unified — §4.3).

use super::spec::{LaunchAmortization, PlatformSpec};
use super::Platform;
use crate::profiler::ProfilerFrontendRef;
use crate::sched::schedule::Tile;
use std::sync::Arc;

/// M4 Max (32-core GPU) device model.
pub fn m4_max() -> PlatformSpec {
    PlatformSpec {
        platform_id: "metal",
        language: "Metal",
        name: "Apple M4 Max (32-core GPU)",
        // 32 cores * 128 ALUs * 2 flop * ~1.6GHz ≈ 13 TFLOP/s fp32
        peak_flops_f32: 13e12,
        // simdgroup_matrix throughput ≈ 2× vector fp32 on M-series
        peak_flops_mm: 26e12,
        // 546 GB/s unified memory bandwidth
        mem_bw: 546e9,
        // Metal command-buffer dispatch is heavier than CUDA launch:
        // ~15 µs per encoder round trip observed at small sizes (the
        // §7.2 listing's thread-local pipeline caching attacks this).
        launch_overhead: 15.0e-6,
        dispatch_overhead: 5.0e-6,
        // 32 KB threadgroup memory
        onchip_bytes: 32 * 1024,
        max_threadgroup: 1024,
        simd_width: 32,
        num_cores: 32,
        unified_memory: true,
        h2d_bw: f64::INFINITY,
        // no command graphs on Metal: the launch-amortization lever is
        // cached pipeline state + command-queue reuse (§7.2's listing)
        launch_amortization: LaunchAmortization::PipelineCache {
            dispatch_factor: 0.35,
        },
        tile_sweet_spot: 64.0,
        expert_tile: Tile { bm: 64, bn: 64, bk: 32 },
        stock_tile: Tile { bm: 64, bn: 64, bk: 32 },
        inductor_tile: Tile { bm: 32, bn: 32, bk: 32 },
        // the paper reports higher variance on MPS measurements
        noise_sigma: 0.07,
        // PyTorch 2.7 MPS gaps (§4.1): Conv3D-transpose, 3-D pooling
        unsupported_ops: &["conv3d_transpose", "avgpool3d", "maxpool3d"],
    }
}

/// The Metal platform plugin.
#[derive(Debug)]
pub struct MetalPlatform {
    spec: PlatformSpec,
}

impl MetalPlatform {
    pub fn new() -> MetalPlatform {
        MetalPlatform { spec: m4_max() }
    }
}

impl Default for MetalPlatform {
    fn default() -> Self {
        MetalPlatform::new()
    }
}

impl Platform for MetalPlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["mps"]
    }

    /// macOS exposes no programmatic GPU-profiling API: the only
    /// profiling artifact is rendered Xcode-Instruments screens that
    /// must be scraped back (§6.3's cliclick pipeline).
    fn profiler_frontend(&self) -> ProfilerFrontendRef {
        static XCODE: std::sync::OnceLock<ProfilerFrontendRef> = std::sync::OnceLock::new();
        XCODE
            .get_or_init(|| Arc::new(crate::profiler::xcode::XcodeFrontend))
            .clone()
    }

    /// The paper's Metal testbed: 5 Mac Studio nodes.
    fn default_workers(&self) -> usize {
        5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m4_headlines() {
        let s = m4_max();
        assert_eq!(s.platform_id, "metal");
        assert!(s.unified_memory);
        assert!(s.launch_overhead > 1e-5);
        assert_eq!(s.unsupported_ops.len(), 3);
    }

    #[test]
    fn metal_slower_than_cuda_on_paper() {
        let m = m4_max();
        let c = crate::platform::cuda::h100();
        assert!(m.mem_bw < c.mem_bw);
        assert!(m.peak_flops_mm < c.peak_flops_mm);
    }
}
