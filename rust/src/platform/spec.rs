//! Platform specification: the device constants the simulator and the
//! legality checks consume.

/// Which platform family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    Cuda,
    Metal,
}

impl PlatformKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformKind::Cuda => "cuda",
            PlatformKind::Metal => "metal",
        }
    }

    /// The accelerator-language name used in prompts (Listing 1's
    /// `{{ accelerator }}` substitution).
    pub fn language(&self) -> &'static str {
        match self {
            PlatformKind::Cuda => "CUDA",
            PlatformKind::Metal => "Metal",
        }
    }
}

/// How profiling data can be obtained on this platform — the central
/// asymmetry of the paper (§6.3): CUDA has programmatic APIs (nsys
/// stats → CSV), Metal only exposes Xcode's GUI, which the paper drove
/// with cliclick and screenshots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfilerAccess {
    /// Structured CSV reports, machine-readable.
    ProgrammaticCsv,
    /// Rendered screenshots of GUI views; must be parsed visually.
    GuiScreenshot,
}

/// Device constants.  All rates in SI (bytes/s, flop/s, seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub kind: PlatformKind,
    pub name: &'static str,
    /// Peak f32 compute (FLOP/s) through the vector units.
    pub peak_flops_f32: f64,
    /// Peak matmul-engine compute (FLOP/s) — tensor core / simdgroup-mm.
    pub peak_flops_mm: f64,
    /// HBM / unified-memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Per-kernel launch overhead (s) — dominates small-batch problems
    /// (§5.1's T_o >> T_m discussion, Table 6's small-batch regime).
    pub launch_overhead: f64,
    /// Extra per-dispatch overhead the runtime pays when the command
    /// stream isn't consolidated (graphs amortize this on CUDA).
    pub dispatch_overhead: f64,
    /// On-chip memory per threadgroup (shared mem / threadgroup mem).
    pub onchip_bytes: usize,
    /// Max threads per threadgroup.
    pub max_threadgroup: usize,
    /// Execution-unit width (warp = 32 on CUDA, SIMD-group = 32 on Metal).
    pub simd_width: usize,
    /// Number of SMs / GPU cores (occupancy granularity).
    pub num_cores: usize,
    /// Unified memory (no explicit H2D/D2H transfer cost).
    pub unified_memory: bool,
    /// Host-device transfer bandwidth (bytes/s); unused when unified.
    pub h2d_bw: f64,
    /// How profiles are accessed on this platform.
    pub profiler: ProfilerAccess,
    /// Measurement noise sigma (log-space) for simulated timings; the
    /// paper notes small-shape measurements carry irreducible noise.
    pub noise_sigma: f64,
    /// Ops with no native implementation (problems containing them are
    /// excluded on this platform — Table 2's 30 exclusions on Metal).
    pub unsupported_ops: &'static [&'static str],
}

impl PlatformSpec {
    /// Is an op (by mnemonic family) supported natively?
    pub fn supports(&self, op_family: &str) -> bool {
        !self.unsupported_ops.contains(&op_family)
    }

    /// Ideal time lower bound for a workload of `flops` and `bytes`
    /// at perfect utilization (roofline).
    pub fn roofline_seconds(&self, flops: f64, bytes: f64, on_mm_engine: bool) -> f64 {
        let peak = if on_mm_engine {
            self.peak_flops_mm
        } else {
            self.peak_flops_f32
        };
        (flops / peak).max(bytes / self.mem_bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{cuda, metal};

    #[test]
    fn roofline_picks_binding_constraint() {
        let spec = cuda::h100();
        // tiny flops, huge bytes -> memory bound
        let t = spec.roofline_seconds(1e3, 1e9, true);
        assert!((t - 1e9 / spec.mem_bw).abs() / t < 1e-9);
        // huge flops, tiny bytes -> compute bound
        let t2 = spec.roofline_seconds(1e15, 1.0, true);
        assert!((t2 - 1e15 / spec.peak_flops_mm).abs() / t2 < 1e-9);
    }

    #[test]
    fn metal_is_unified_cuda_is_not() {
        assert!(metal::m4_max().unified_memory);
        assert!(!cuda::h100().unified_memory);
    }

    #[test]
    fn profiler_asymmetry() {
        assert_eq!(cuda::h100().profiler, ProfilerAccess::ProgrammaticCsv);
        assert_eq!(metal::m4_max().profiler, ProfilerAccess::GuiScreenshot);
    }

    #[test]
    fn metal_excludes_3d_ops() {
        let m = metal::m4_max();
        assert!(!m.supports("conv3d_transpose"));
        assert!(m.supports("matmul"));
        assert!(cuda::h100().supports("conv3d_transpose"));
    }
}
