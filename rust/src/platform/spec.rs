//! Platform specification: the device constants the simulator, the
//! legality checks, the baselines and the cost model consume.
//!
//! A [`PlatformSpec`] is fully data-driven: everything that used to be
//! pattern-matched on a closed platform enum (tile sweet spots, launch
//! amortization behavior, baseline tiles, prompt language) is a field
//! here, so a new accelerator is described entirely by its own module
//! (see [`super::rocm`]) with no match arms anywhere else.

use crate::sched::schedule::Tile;

/// How launch overhead amortizes when the schedule's launch-
/// consolidation lever (`Schedule::use_graphs`) is on.  This is the
/// platform-specific mechanism behind the §5.1 / §7.2 optimizations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LaunchAmortization {
    /// Device command graphs (CUDA graphs, hipGraph): the whole kernel
    /// sequence is captured and replayed with one launch plus a tiny
    /// per-node replay cost.
    DeviceGraphs {
        /// Per-node replay cost (seconds) inside a captured graph.
        replay_per_node_s: f64,
    },
    /// Cached pipeline state / command-queue reuse (Metal, §7.2's
    /// thread-local caching listing): encoder setup drops away and each
    /// dispatch pays a fraction of the full launch overhead.
    PipelineCache {
        /// Fraction of `launch_overhead` still paid per dispatch.
        dispatch_factor: f64,
    },
}

/// Device constants.  All rates in SI (bytes/s, flop/s, seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// Stable lowercase identifier ("cuda", "metal", "rocm", …) used by
    /// the CLI, the registry, persona calibration rows and run logs.
    pub platform_id: &'static str,
    /// The accelerator-language name used in prompts (Listing 1's
    /// `{{ accelerator }}` substitution).
    pub language: &'static str,
    /// Human-readable device name.
    pub name: &'static str,
    /// Peak f32 compute (FLOP/s) through the vector units.
    pub peak_flops_f32: f64,
    /// Peak matmul-engine compute (FLOP/s) — tensor core / simdgroup-mm
    /// / matrix core.
    pub peak_flops_mm: f64,
    /// HBM / unified-memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Per-kernel launch overhead (s) — dominates small-batch problems
    /// (§5.1's T_o >> T_m discussion, Table 6's small-batch regime).
    pub launch_overhead: f64,
    /// Extra per-dispatch overhead the runtime pays when the command
    /// stream isn't consolidated (graphs amortize this on CUDA).
    pub dispatch_overhead: f64,
    /// On-chip memory per threadgroup (shared mem / threadgroup mem /
    /// LDS).
    pub onchip_bytes: usize,
    /// Max threads per threadgroup.
    pub max_threadgroup: usize,
    /// Execution-unit width (warp = 32 on CUDA, SIMD-group = 32 on
    /// Metal, wavefront = 64 on CDNA).
    pub simd_width: usize,
    /// Number of SMs / GPU cores / CUs (occupancy granularity).
    pub num_cores: usize,
    /// Unified memory (no explicit H2D/D2H transfer cost).
    pub unified_memory: bool,
    /// Host-device transfer bandwidth (bytes/s); unused when unified.
    pub h2d_bw: f64,
    /// How launch overhead amortizes under the `use_graphs` lever.
    pub launch_amortization: LaunchAmortization,
    /// Matmul tile edge (elements) at which the MM engine saturates —
    /// the cost model's tile-utilization sweet spot.
    pub tile_sweet_spot: f64,
    /// The tile an expert (or a converged refinement loop) lands on;
    /// must fit `onchip_bytes`.
    pub expert_tile: Tile,
    /// The tile stock vendor kernels effectively run with (cuBLAS /
    /// MPS / rocBLAS are well tuned per kernel).
    pub stock_tile: Tile,
    /// The generic tile an inductor-style compiler backend emits.
    pub inductor_tile: Tile,
    /// Measurement noise sigma (log-space) for simulated timings; the
    /// paper notes small-shape measurements carry irreducible noise.
    pub noise_sigma: f64,
    /// Ops with no native implementation (problems containing them are
    /// excluded on this platform — Table 2's 30 exclusions on Metal).
    pub unsupported_ops: &'static [&'static str],
}

impl PlatformSpec {
    /// Is an op (by mnemonic family) supported natively?
    pub fn supports(&self, op_family: &str) -> bool {
        !self.unsupported_ops.contains(&op_family)
    }

    /// Ideal time lower bound for a workload of `flops` and `bytes`
    /// at perfect utilization (roofline).
    pub fn roofline_seconds(&self, flops: f64, bytes: f64, on_mm_engine: bool) -> f64 {
        let peak = if on_mm_engine {
            self.peak_flops_mm
        } else {
            self.peak_flops_f32
        };
        (flops / peak).max(bytes / self.mem_bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{cuda, metal};

    #[test]
    fn roofline_picks_binding_constraint() {
        let spec = cuda::h100();
        // tiny flops, huge bytes -> memory bound
        let t = spec.roofline_seconds(1e3, 1e9, true);
        assert!((t - 1e9 / spec.mem_bw).abs() / t < 1e-9);
        // huge flops, tiny bytes -> compute bound
        let t2 = spec.roofline_seconds(1e15, 1.0, true);
        assert!((t2 - 1e15 / spec.peak_flops_mm).abs() / t2 < 1e-9);
    }

    #[test]
    fn metal_is_unified_cuda_is_not() {
        assert!(metal::m4_max().unified_memory);
        assert!(!cuda::h100().unified_memory);
    }

    #[test]
    fn metal_excludes_3d_ops() {
        let m = metal::m4_max();
        assert!(!m.supports("conv3d_transpose"));
        assert!(m.supports("matmul"));
        assert!(cuda::h100().supports("conv3d_transpose"));
    }

    #[test]
    fn expert_tiles_fit_onchip_memory() {
        for spec in [cuda::h100(), metal::m4_max(), crate::platform::rocm::mi300x()] {
            assert!(
                spec.expert_tile.onchip_bytes() <= spec.onchip_bytes,
                "{}: expert tile overflows on-chip memory",
                spec.platform_id
            );
            assert!(spec.stock_tile.onchip_bytes() <= spec.onchip_bytes);
            assert!(spec.inductor_tile.onchip_bytes() <= spec.onchip_bytes);
        }
    }

}
