//! Distributed campaigns: multi-shard execution over one shared store.
//!
//! The campaign grid (persona × problem) is embarrassingly shardable:
//! every job is a pure function of its [`crate::store::JobKey`] (the
//! PR 3 worker-invariance property), so any partition of the job index
//! space, executed by any set of processes against one shared
//! `--cache-dir`, folds back into a result bit-identical to the
//! 1-process run.  This module makes that operational:
//!
//! - **shard planner + work-stealing splitter** ([`plan_chunks`],
//!   [`run_shard`]): the job list is cut into contiguous chunks,
//!   oversubscribed ~4× the shard count.  Shards claim chunks
//!   one-at-a-time through persistent claim files under the shared
//!   cache dir (`store::lease::claim` — the create-new winner owns the
//!   chunk forever), so fast shards steal work from slow ones and two
//!   shards can never compute the same chunk.  Each shard appends to
//!   its own journal, keyed by *global* job index against the full
//!   campaign key list — crash-resume of any single shard is the plain
//!   journal-resume path, and re-running a dead shard recomputes
//!   exactly its missing jobs (its claims persist).
//! - **merge/verify** ([`merge_shards`], [`assert_bit_identical`]):
//!   fold every shard journal back into one
//!   [`CampaignResult`], first-wins by job index, erroring if any job
//!   is missing.  Because each job result is a pure function of its
//!   key, the merged result is bit-identical (every `TaskResult`
//!   field, f64s by bit pattern) to the 1-process run — CI gates this.
//! - **in-process chunk pool** ([`exec_pool`]): the same
//!   chunk-claiming discipline as an in-process execution pool
//!   (atomic chunk cursor instead of claim files), used by the serve
//!   tier's `--exec-shards` to shard its execution phase.
//! - **subprocess driver** ([`spawn_shards`]): `kforge dist spawn`
//!   forks N `kforge run --shards N --shard-id K` workers of the
//!   current binary and waits for them; the CLI then merges.
//!
//! While a shard runs it holds a liveness lease
//! ([`crate::store::Lease`]), so `kforge cache gc` racing the campaign
//! never evicts an object a shard journal already references.

use crate::coordinator::experiment::{job_list, run_task, CampaignResult, ExperimentConfig};
use crate::coordinator::job::TaskResult;
use crate::coordinator::worker::{self, run_sparse};
use crate::obs;
use crate::store::journal::campaign_digest;
use crate::store::{lease, CacheStats, JobKey, Journal, KeyScope, Lease, Store};
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::Suite;
use anyhow::{Context, Result};
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Chunk oversubscription factor: more chunks than shards so a fast
/// shard steals work instead of idling behind a static split.
const CHUNKS_PER_SHARD: usize = 4;

/// Partition `n_jobs` into contiguous, balanced chunks — about
/// [`CHUNKS_PER_SHARD`] per shard, never more chunks than jobs, sizes
/// differing by at most one.  The chunk list is a pure function of
/// (n_jobs, shards), so every shard of a campaign computes the same
/// plan independently.
pub fn plan_chunks(n_jobs: usize, shards: usize) -> Vec<Range<usize>> {
    if n_jobs == 0 {
        return Vec::new();
    }
    let target = (shards.max(1) * CHUNKS_PER_SHARD).min(n_jobs);
    let base = n_jobs / target;
    let extra = n_jobs % target;
    let mut out = Vec::with_capacity(target);
    let mut start = 0;
    for i in 0..target {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// What one shard run did (the CLI prints this; merge does not need it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardReport {
    pub shard_id: usize,
    pub shards: usize,
    /// Total jobs in the campaign (across all shards).
    pub jobs_total: usize,
    /// Chunks this run owned (claimed now or reclaimed after a crash).
    pub chunks_owned: usize,
    /// Jobs restored from this shard's journal (a prior run's work).
    pub restored: usize,
    /// Jobs answered by the shared store inside owned chunks.
    pub store_hits: usize,
    /// Jobs actually computed by this run.
    pub computed: usize,
    /// Bytes appended to the shared object store.
    pub bytes_written: u64,
}

impl ShardReport {
    /// One-line summary (what `kforge run --shards` prints).
    pub fn summary(&self) -> String {
        format!(
            "shard {}/{}: {} chunk(s) owned, {} restored, {} store hit(s), {} computed of {} total",
            self.shard_id,
            self.shards,
            self.chunks_owned,
            self.restored,
            self.store_hits,
            self.computed,
            self.jobs_total,
        )
    }
}

fn shard_keys<'a>(
    cfg: &ExperimentConfig,
    filtered: &'a Suite,
    corpus: Option<&'a RefCorpus>,
) -> (
    Vec<(&'static crate::agents::Persona, &'a crate::workloads::Problem, Option<&'a crate::agents::Program>)>,
    Vec<JobKey>,
) {
    let spec = cfg.spec();
    let jobs = job_list(cfg, filtered, corpus);
    let scope = KeyScope::new(cfg, &spec);
    let keys = jobs.iter().map(|(p, pr, r)| scope.key(p, pr, *r)).collect();
    (jobs, keys)
}

/// Execute shard `shard_id` of an `shards`-way campaign against a
/// shared disk-backed store.  Claims chunks one at a time (work
/// stealing), consults the store before computing, and journals every
/// completion by global job index.  Always resumes its own journal:
/// chunk claims persist across crashes, so a rerun that started a
/// fresh journal would skip its claimed chunks and lose their results.
pub fn run_shard(
    store: &Store,
    suite: &Suite,
    corpus: Option<&RefCorpus>,
    cfg: &ExperimentConfig,
    shards: usize,
    shard_id: usize,
) -> Result<ShardReport> {
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    anyhow::ensure!(shard_id < shards, "--shard-id {shard_id} out of range for {shards} shard(s)");
    let root = store
        .shared_dir()
        .context("sharded execution needs a disk-backed store (--cache-dir)")?
        .to_path_buf();
    let spec = cfg.spec();
    let filtered = suite.supported_on(&spec);
    let (jobs, keys) = shard_keys(cfg, &filtered, corpus);
    let digest = campaign_digest(&cfg.name, &keys);
    let owner = format!("shard{shard_id}of{shards}");
    let _shard_span = obs::span("dist.shard");

    // liveness lease for gc protection, pid-suffixed so a crashed
    // predecessor's stale file never blocks this run (it only widens
    // the gc floor, which is the safe direction)
    let _lease = match Lease::acquire(
        &root,
        &format!("{digest:016x}-shard{shard_id}-{}", std::process::id()),
        &owner,
    ) {
        Ok(l) => Some(l),
        Err(e) => {
            crate::kf_warn!("[dist] could not take the shard lease ({e:#}); gc protection off");
            None
        }
    };

    let journal_path = store
        .shard_journal_path(&cfg.name, &keys, shards, shard_id)
        .context("store has no journal directory")?;
    let (journal, restored_recs) = Journal::resume(&journal_path, &cfg.name, &keys)?;
    let mut done = vec![false; jobs.len()];
    let restored = restored_recs.len();
    for (i, r) in restored_recs {
        store.record_resumed();
        store.put(&keys[i], &r); // backfill objects a gc may have taken
        done[i] = true;
    }

    let chunks = plan_chunks(jobs.len(), shards);
    let workers = cfg.workers.max(1);
    let mut processed = vec![false; chunks.len()];
    let mut chunks_owned = 0usize;
    let mut store_hits = 0usize;
    let mut computed = 0usize;
    let bytes_written = AtomicU64::new(0);

    loop {
        // claim the next chunk that is unclaimed, or was claimed by a
        // previous (crashed) run of this same shard
        let mut mine = None;
        for ci in 0..chunks.len() {
            if processed[ci] {
                continue;
            }
            let name = format!("{digest:016x}-c{ci:04}");
            let ours = match lease::claim(&root, &name, &owner) {
                Ok(true) => true,
                Ok(false) => lease::claim_owner(&root, &name).as_deref() == Some(owner.as_str()),
                Err(e) => {
                    crate::kf_warn!("[dist] chunk claim failed ({e:#}); skipping chunk {ci}");
                    false
                }
            };
            if ours {
                mine = Some(ci);
                break;
            }
        }
        let Some(ci) = mine else { break };
        processed[ci] = true;
        chunks_owned += 1;
        obs::counter("dist.chunks_claimed", 1);

        // store consult first: hits are backfilled into the shard
        // journal so merge sees a complete record without the store
        let mut pending = Vec::new();
        for i in chunks[ci].clone() {
            if done[i] {
                continue;
            }
            if let Some((r, _bytes)) = store.get(&keys[i]) {
                store_hits += 1;
                done[i] = true;
                if let Err(e) = journal.append(i, &keys[i], &r) {
                    crate::kf_warn!("[dist] journal backfill failed for job {i} ({e:#})");
                }
            } else {
                pending.push(i);
            }
        }
        let _chunk_span = obs::span("dist.chunk");
        let results = run_sparse(workers, &pending, |i| {
            let (persona, problem, reference) = jobs[i];
            let _lane = obs::job_lane(spec.name, persona.name, &problem.id);
            let r = run_task(cfg, &spec, persona, problem, reference);
            bytes_written.fetch_add(store.put(&keys[i], &r), Ordering::Relaxed);
            if let Err(e) = journal.append(i, &keys[i], &r) {
                crate::kf_warn!("[dist] journal append failed for job {i} ({e:#})");
            }
            r
        });
        computed += results.len();
        for i in pending {
            done[i] = true;
        }
    }

    Ok(ShardReport {
        shard_id,
        shards,
        jobs_total: jobs.len(),
        chunks_owned,
        restored,
        store_hits,
        computed,
        bytes_written: bytes_written.into_inner(),
    })
}

/// Fold every shard journal of an `shards`-way campaign back into one
/// [`CampaignResult`], first-wins by global job index.  Errors if no
/// shard journal exists or any job is missing (a shard died and was
/// never re-run) — re-running the dead shard completes the set.
///
/// The merged `results` are bit-identical to the 1-process run's: each
/// record was produced by [`run_task`] on the same key, and the fold
/// only rearranges complete records into index order.  Cache counters
/// are *not* comparable to a live run's (every job here is restored),
/// so `cache.resumed` carries the job count and the rest stay zero.
pub fn merge_shards(
    store: &Store,
    suite: &Suite,
    corpus: Option<&RefCorpus>,
    cfg: &ExperimentConfig,
    shards: usize,
) -> Result<CampaignResult> {
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    let spec = cfg.spec();
    let filtered = suite.supported_on(&spec);
    let (jobs, keys) = shard_keys(cfg, &filtered, corpus);
    let _merge_span = obs::span("dist.merge");
    let mut slots: Vec<Option<TaskResult>> = vec![None; jobs.len()];
    let mut journals_found = 0usize;
    for shard_id in 0..shards {
        let path = store
            .shard_journal_path(&cfg.name, &keys, shards, shard_id)
            .context("store has no journal directory")?;
        if !path.exists() {
            continue;
        }
        let (_j, restored) = Journal::resume(&path, &cfg.name, &keys)?;
        journals_found += 1;
        for (i, r) in restored {
            // duplicates across shards are bit-identical by
            // construction (pure function of the key); first wins
            if slots[i].is_none() {
                slots[i] = Some(r);
            }
        }
    }
    anyhow::ensure!(
        journals_found > 0,
        "no shard journals found for campaign {:?} ({} shard(s)); run the shards first",
        cfg.name,
        shards
    );
    let missing = slots.iter().filter(|s| s.is_none()).count();
    anyhow::ensure!(
        missing == 0,
        "{missing} of {} job(s) missing from {journals_found} shard journal(s); re-run the incomplete shard(s)",
        jobs.len()
    );
    let results: Vec<TaskResult> = slots.into_iter().map(|s| s.expect("checked")).collect();
    let cache = CacheStats { resumed: results.len() as u64, ..Default::default() };
    Ok(CampaignResult { config_name: cfg.name.clone(), results, cache })
}

/// Verify two campaign results are bit-identical: same job order,
/// every `TaskResult` field equal, f64s compared by bit pattern.  This
/// is the merge/verify phase's proof obligation (`kforge dist merge
/// --verify` runs it against a store-answered 1-process run).
pub fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult) -> Result<()> {
    anyhow::ensure!(
        a.results.len() == b.results.len(),
        "job count mismatch: {} vs {}",
        a.results.len(),
        b.results.len()
    );
    for (i, (x, y)) in a.results.iter().zip(&b.results).enumerate() {
        let ok = x.problem_id == y.problem_id
            && x.level == y.level
            && x.persona == y.persona
            && x.state_history == y.state_history
            && x.outcome.correct == y.outcome.correct
            && x.outcome.speedup.to_bits() == y.outcome.speedup.to_bits()
            && x.best_iteration == y.best_iteration
            && x.baseline_s.to_bits() == y.baseline_s.to_bits()
            && x.best_candidate_s.map(f64::to_bits) == y.best_candidate_s.map(f64::to_bits);
        anyhow::ensure!(ok, "job {i} ({}) differs between runs", x.problem_id);
    }
    Ok(())
}

/// In-process chunk-claiming execution pool: the shard discipline with
/// an atomic cursor standing in for claim files.  Results come back in
/// job order; a panicking job is re-raised naming the smallest failing
/// job index, mirroring [`crate::coordinator::worker::run_jobs`].
/// Pool width never changes results — jobs are independent and order
/// is restored — which is what lets serve's `--exec-shards` keep the
/// scenario bit-identity guarantee.
pub fn exec_pool<J, R, F>(shards: usize, jobs: &[J], f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let chunks = plan_chunks(jobs.len(), shards);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<std::thread::Result<R>>>> =
        (0..jobs.len()).map(|_| Mutex::new(None)).collect();
    let pool = shards.clamp(1, chunks.len());
    std::thread::scope(|scope| {
        for _ in 0..pool {
            let (next, results, f, chunks) = (&next, &results, &f, &chunks);
            let tid = obs::alloc_tid();
            scope.spawn(move || {
                obs::set_tid(tid);
                loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    if ci >= chunks.len() {
                        break;
                    }
                    for i in chunks[ci].clone() {
                        let r = catch_unwind(AssertUnwindSafe(|| f(&jobs[i])));
                        *results[i].lock().unwrap() = Some(r);
                    }
                }
            });
        }
    });
    let mut out = Vec::with_capacity(jobs.len());
    for (i, slot) in results.into_iter().enumerate() {
        match slot.into_inner().unwrap() {
            Some(Ok(r)) => out.push(r),
            Some(Err(payload)) => {
                panic!("job {i} panicked: {}", worker::payload_text(&*payload))
            }
            None => unreachable!("job {i} slot empty after scope join"),
        }
    }
    out
}

/// Fork `shards` worker subprocesses of the current binary, each
/// running `run --shards N --shard-id K` plus `forward`ed flags, and
/// wait for all of them.  Returns the per-shard exit successes; the
/// caller (the `dist spawn` CLI verb) merges afterwards.
pub fn spawn_shards(shards: usize, forward: &[String]) -> Result<Vec<bool>> {
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    let exe = std::env::current_exe().context("locating the kforge binary")?;
    let mut children = Vec::with_capacity(shards);
    for shard_id in 0..shards {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("run")
            .arg("--shards")
            .arg(shards.to_string())
            .arg("--shard-id")
            .arg(shard_id.to_string())
            .args(forward);
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning shard {shard_id}/{shards}"))?;
        children.push(child);
    }
    let mut ok = Vec::with_capacity(shards);
    for (shard_id, mut child) in children.into_iter().enumerate() {
        let status = child
            .wait()
            .with_context(|| format!("waiting for shard {shard_id}/{shards}"))?;
        if !status.success() {
            crate::kf_error!("[dist] shard {shard_id}/{shards} exited with {status}");
        }
        ok.push(status.success());
    }
    Ok(ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_plan_is_balanced_and_covers_exactly() {
        for (n, shards) in [(0usize, 4usize), (1, 4), (7, 2), (18, 4), (258, 4), (5, 16)] {
            let chunks = plan_chunks(n, shards);
            if n == 0 {
                assert!(chunks.is_empty());
                continue;
            }
            assert!(chunks.len() <= n, "more chunks than jobs for n={n}");
            assert!(chunks.len() <= shards * CHUNKS_PER_SHARD);
            // exact, gapless, ordered coverage
            let mut cursor = 0;
            for c in &chunks {
                assert_eq!(c.start, cursor, "gap before chunk in n={n} shards={shards}");
                assert!(c.end > c.start, "empty chunk");
                cursor = c.end;
            }
            assert_eq!(cursor, n);
            // balanced: sizes differ by at most one
            let sizes: Vec<usize> = chunks.iter().map(|c| c.end - c.start).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced plan for n={n} shards={shards}: {sizes:?}");
            // the plan is shared: every shard computes the same one
            assert_eq!(chunks, plan_chunks(n, shards));
        }
    }

    #[test]
    fn exec_pool_preserves_order_across_widths() {
        let jobs: Vec<usize> = (0..97).collect();
        let serial = exec_pool(1, &jobs, |&j| j * 3 + 1);
        assert_eq!(serial, (0..97).map(|j| j * 3 + 1).collect::<Vec<_>>());
        for shards in [2usize, 4, 16] {
            assert_eq!(exec_pool(shards, &jobs, |&j| j * 3 + 1), serial, "width {shards}");
        }
        let empty: Vec<usize> = exec_pool(4, &[] as &[usize], |&j| j);
        assert!(empty.is_empty());
    }

    #[test]
    fn exec_pool_runs_every_job_exactly_once() {
        let count = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..200).collect();
        exec_pool(7, &jobs, |_| count.fetch_add(1, Ordering::Relaxed));
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }

    #[test]
    #[should_panic(expected = "job 5 panicked: boom 5")]
    fn exec_pool_reraises_naming_the_job() {
        let jobs: Vec<usize> = (0..8).collect();
        exec_pool(3, &jobs, |&j| {
            if j == 5 {
                panic!("boom {j}");
            }
            j
        });
    }
}
