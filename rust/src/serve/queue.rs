//! Bounded two-lane MPMC request queue.
//!
//! The serve path's front door: producers (`Service::submit`, the
//! scenario engine) push admitted requests, worker threads pop them.
//! Two priority lanes — [`Priority::Interactive`] always dequeues
//! before [`Priority::Batch`] — and each lane is strictly FIFO, a
//! property the load-test suite asserts from the recorded pop order.
//!
//! The queue never blocks a producer: `try_push` returns the item to
//! the caller when the queue is full (admission control turns that
//! into a typed `Rejected` outcome instead of backpressure), and a
//! closed queue keeps draining what it holds but accepts nothing new.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Priority class of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// User-facing traffic: dequeued before any batch request.
    Interactive,
    /// Background traffic: served only when no interactive request waits.
    Batch,
}

impl Priority {
    pub fn label(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Why a push was refused; carries the item back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at capacity.
    Full(T),
    /// The queue was closed; no further requests are accepted.
    Closed(T),
}

struct Lanes<T> {
    interactive: VecDeque<T>,
    batch: VecDeque<T>,
    closed: bool,
}

impl<T> Lanes<T> {
    fn depth(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    fn pop(&mut self) -> Option<(Priority, T)> {
        if let Some(x) = self.interactive.pop_front() {
            return Some((Priority::Interactive, x));
        }
        self.batch.pop_front().map(|x| (Priority::Batch, x))
    }
}

/// Bounded MPMC queue with two FIFO priority lanes.  The capacity
/// bounds the two lanes together.
pub struct BoundedQueue<T> {
    lanes: Mutex<Lanes<T>>,
    ready: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        assert!(capacity > 0, "queue capacity must be at least 1");
        BoundedQueue {
            lanes: Mutex::new(Lanes {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently queued (both lanes).
    pub fn depth(&self) -> usize {
        self.lanes.lock().unwrap().depth()
    }

    pub fn is_closed(&self) -> bool {
        self.lanes.lock().unwrap().closed
    }

    /// Enqueue without blocking; a full or closed queue hands the item
    /// straight back so the caller can shed it.
    pub fn try_push(&self, priority: Priority, item: T) -> Result<(), PushError<T>> {
        let mut lanes = self.lanes.lock().unwrap();
        if lanes.closed {
            return Err(PushError::Closed(item));
        }
        if lanes.depth() >= self.capacity {
            return Err(PushError::Full(item));
        }
        match priority {
            Priority::Interactive => lanes.interactive.push_back(item),
            Priority::Batch => lanes.batch.push_back(item),
        }
        drop(lanes);
        self.ready.notify_one();
        Ok(())
    }

    /// Dequeue without blocking: the oldest interactive request, else
    /// the oldest batch request, else `None`.
    pub fn try_pop(&self) -> Option<(Priority, T)> {
        self.lanes.lock().unwrap().pop()
    }

    /// Dequeue, waiting for an item.  Returns `None` only once the
    /// queue is closed *and* drained — queued requests are always
    /// served (or deadline-expired by the consumer), never dropped.
    pub fn pop_blocking(&self) -> Option<(Priority, T)> {
        let mut lanes = self.lanes.lock().unwrap();
        loop {
            if let Some(x) = lanes.pop() {
                return Some(x);
            }
            if lanes.closed {
                return None;
            }
            lanes = self.ready.wait(lanes).unwrap();
        }
    }

    /// Stop accepting new requests and wake every waiting consumer.
    /// Already-queued items remain poppable.
    pub fn close(&self) {
        self.lanes.lock().unwrap().closed = true;
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_a_lane() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.try_push(Priority::Batch, i).unwrap();
        }
        let popped: Vec<i32> = (0..5).map(|_| q.try_pop().unwrap().1).collect();
        assert_eq!(popped, vec![0, 1, 2, 3, 4]);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn interactive_precedes_batch() {
        let q = BoundedQueue::new(8);
        q.try_push(Priority::Batch, "b0").unwrap();
        q.try_push(Priority::Interactive, "i0").unwrap();
        q.try_push(Priority::Batch, "b1").unwrap();
        q.try_push(Priority::Interactive, "i1").unwrap();
        let order: Vec<&str> = (0..4).map(|_| q.try_pop().unwrap().1).collect();
        assert_eq!(order, vec!["i0", "i1", "b0", "b1"]);
    }

    #[test]
    fn full_queue_returns_the_item() {
        let q = BoundedQueue::new(2);
        q.try_push(Priority::Interactive, 1).unwrap();
        q.try_push(Priority::Batch, 2).unwrap();
        assert_eq!(q.depth(), 2);
        match q.try_push(Priority::Interactive, 3) {
            Err(PushError::Full(x)) => assert_eq!(x, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        // capacity is shared across lanes
        match q.try_push(Priority::Batch, 4) {
            Err(PushError::Full(x)) => assert_eq!(x, 4),
            other => panic!("expected Full, got {other:?}"),
        }
    }

    #[test]
    fn closed_queue_rejects_new_but_drains_old() {
        let q = BoundedQueue::new(4);
        q.try_push(Priority::Batch, 1).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.try_push(Priority::Batch, 2) {
            Err(PushError::Closed(x)) => assert_eq!(x, 2),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.pop_blocking(), Some((Priority::Batch, 1)));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let q = BoundedQueue::new(1024);
        let popped = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for p in 0..4 {
                let q = &q;
                s.spawn(move || {
                    for i in 0..100usize {
                        let prio = if i % 3 == 0 { Priority::Interactive } else { Priority::Batch };
                        q.try_push(prio, p * 100 + i).unwrap();
                    }
                });
            }
            for _ in 0..3 {
                let (q, popped, sum) = (&q, &popped, &sum);
                s.spawn(move || {
                    while let Some((_, x)) = q.pop_blocking() {
                        popped.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(x, Ordering::Relaxed);
                    }
                });
            }
            // close once every producer has finished; consumers then
            // drain the remainder and exit on None
            std::thread::sleep(std::time::Duration::from_millis(50));
            while q.depth() < 400 - popped.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            q.close();
        });
        assert_eq!(popped.load(Ordering::Relaxed), 400);
        assert_eq!(sum.load(Ordering::Relaxed), (0..4).map(|p| (0..100).map(|i| p * 100 + i).sum::<usize>()).sum());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_panics() {
        BoundedQueue::<u8>::new(0);
    }
}
