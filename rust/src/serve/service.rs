//! The real-time service front end: admission-controlled submission,
//! a worker pool draining the bounded queue, and ticket-based results.
//!
//! This is the wall-clock sibling of the virtual-time scenario engine
//! — same queue, same admission policy, same typed [`Outcome`]s, but
//! driven by real threads and measured with [`Instant`].  `kforge
//! serve --artifacts` replays compiled artifacts through it, and
//! `examples/e2e_serve.rs` demos it; the deterministic load tests live
//! on the scenario side where timing is virtual.
//!
//! Usage pattern: `submit` every request (each returns a [`Ticket`]
//! immediately — shed requests come back pre-resolved), then [`close`]
//! the intake, then [`run`] a worker pool (or [`drain_inline`] for
//! handlers that are not `Sync`, like the PJRT runtime) until the
//! queue is empty.  Every submitted ticket is resolved by the time
//! `run`/`drain_inline` returns; `Ticket::wait` before that may block.

use super::admission::{deadline_expired, AdmissionPolicy, Decision, Outcome, ShedReason};
use super::queue::{BoundedQueue, Priority, PushError};
use crate::coordinator::worker::run_jobs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One request's eventual resolution: the typed outcome, plus the
/// handler's value when it completed.
pub type Resolution<R> = (Outcome, Option<R>);

struct TicketCell<R> {
    slot: Mutex<Option<Resolution<R>>>,
    ready: Condvar,
}

impl<R> TicketCell<R> {
    fn resolve(&self, outcome: Outcome, value: Option<R>) {
        let mut slot = self.slot.lock().unwrap();
        debug_assert!(slot.is_none(), "a ticket resolves exactly once");
        *slot = Some((outcome, value));
        self.ready.notify_all();
    }
}

/// Handle to one submitted request.  Shed requests are resolved before
/// `submit` even returns; admitted ones resolve as the pool processes
/// them.
pub struct Ticket<R>(Arc<TicketCell<R>>);

impl<R> Ticket<R> {
    /// Block until resolved.  Call only after `run`/`drain_inline` has
    /// returned (or from another thread while the pool runs).
    pub fn wait(self) -> Resolution<R> {
        let mut slot = self.0.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.0.ready.wait(slot).unwrap();
        }
    }

    /// Non-blocking check; `None` while the request is still queued or
    /// in flight.
    pub fn try_take(&self) -> Option<Resolution<R>> {
        self.0.slot.lock().unwrap().take()
    }
}

struct Request<T, R> {
    payload: T,
    deadline_ms: Option<f64>,
    enqueued: Instant,
    ticket: Arc<TicketCell<R>>,
}

/// Monotonic service counters (a snapshot, not a live view).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceCounts {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub failed: u64,
    pub in_flight: u64,
    pub depth: usize,
}

/// Admission-controlled request service over payloads `T` resolving to
/// handler results `R`.
pub struct Service<T, R> {
    queue: BoundedQueue<Request<T, R>>,
    policy: AdmissionPolicy,
    started: Instant,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    expired: AtomicU64,
    failed: AtomicU64,
    in_flight: AtomicU64,
}

impl<T, R> Service<T, R> {
    pub fn new(policy: AdmissionPolicy) -> Service<T, R> {
        Service {
            queue: BoundedQueue::new(policy.queue_capacity),
            policy,
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
        }
    }

    fn shed(&self, reason: ShedReason) -> Ticket<R> {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        if crate::obs::enabled() {
            crate::obs::instant(&format!("serve.shed.{}", reason.label()));
        }
        let cell = Arc::new(TicketCell { slot: Mutex::new(None), ready: Condvar::new() });
        cell.resolve(Outcome::Rejected { reason }, None);
        Ticket(cell)
    }

    /// Submit a request.  Never blocks: a shed request's ticket comes
    /// back already resolved as [`Outcome::Rejected`].
    pub fn submit(&self, priority: Priority, deadline_ms: Option<f64>, payload: T) -> Ticket<R> {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if let Decision::Shed(reason) = self.policy.decide(self.queue.depth()) {
            return self.shed(reason);
        }
        let cell = Arc::new(TicketCell { slot: Mutex::new(None), ready: Condvar::new() });
        let req = Request {
            payload,
            deadline_ms,
            enqueued: Instant::now(),
            ticket: Arc::clone(&cell),
        };
        match self.queue.try_push(priority, req) {
            Ok(()) => Ticket(cell),
            // decide() raced another producer — shed, don't block
            Err(PushError::Full(_)) => self.shed(ShedReason::QueueFull),
            Err(PushError::Closed(_)) => self.shed(ShedReason::Closed),
        }
    }

    /// Stop accepting requests; already-queued ones still drain.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Resolve one queued request with `handler`; false once the queue
    /// is closed and drained.
    fn serve_one<F>(&self, handler: &F) -> bool
    where
        F: Fn(&T) -> anyhow::Result<R>,
    {
        let Some((_, req)) = self.queue.pop_blocking() else {
            return false;
        };
        let waited_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
        if deadline_expired(req.deadline_ms, waited_ms) {
            self.expired.fetch_add(1, Ordering::Relaxed);
            crate::obs::instant("serve.expired");
            req.ticket.resolve(Outcome::DeadlineExceeded { waited_ms }, None);
            return true;
        }
        // real-time measurements: exec class only, never in the canon
        // digests (this front end is wall-clock by nature)
        crate::obs::gauge("serve.queue_wait_ms", waited_ms);
        let live = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        crate::obs::gauge("serve.in_flight", live as f64);
        let request_span = crate::obs::span("serve.request");
        let t = Instant::now();
        let result = handler(&req.payload);
        let service_ms = t.elapsed().as_secs_f64() * 1e3;
        drop(request_span);
        let live = self.in_flight.fetch_sub(1, Ordering::Relaxed) - 1;
        crate::obs::gauge("serve.in_flight", live as f64);
        match result {
            Ok(value) => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                req.ticket
                    .resolve(Outcome::Completed { queue_ms: waited_ms, service_ms }, Some(value));
            }
            Err(e) => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                req.ticket.resolve(Outcome::Failed { error: format!("{e:#}") }, None);
            }
        }
        true
    }

    /// Drain the queue with a pool of `workers` threads.  Returns once
    /// the queue is closed and empty; every admitted ticket is resolved.
    pub fn run<F>(&self, workers: usize, handler: F)
    where
        T: Send,
        R: Send,
        F: Fn(&T) -> anyhow::Result<R> + Sync,
    {
        let lanes: Vec<usize> = (0..workers.max(1)).collect();
        run_jobs(workers.max(1), &lanes, |_| while self.serve_one(&handler) {});
    }

    /// Drain the queue on the calling thread.  For handlers that are
    /// not `Sync` (the PJRT runtime's executable cache, say); otherwise
    /// identical to `run(1, ..)`.
    pub fn drain_inline<F>(&self, handler: F)
    where
        F: Fn(&T) -> anyhow::Result<R>,
    {
        while self.serve_one(&handler) {}
    }

    pub fn counts(&self) -> ServiceCounts {
        ServiceCounts {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            depth: self.queue.depth(),
        }
    }

    /// One greppable live-stats line.
    pub fn stats_line(&self) -> String {
        let c = self.counts();
        format!(
            "serve: uptime={:.1}s depth={} in_flight={} submitted={} completed={} rejected={} expired={} failed={}",
            self.started.elapsed().as_secs_f64(),
            c.depth,
            c.in_flight,
            c.submitted,
            c.completed,
            c.rejected,
            c.expired,
            c.failed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(capacity: usize) -> Service<u32, u32> {
        Service::new(AdmissionPolicy::new(capacity))
    }

    #[test]
    fn submit_close_drain_resolves_every_ticket() {
        let svc = service(16);
        let tickets: Vec<Ticket<u32>> =
            (0..8).map(|i| svc.submit(Priority::Batch, None, i)).collect();
        svc.close();
        svc.drain_inline(|&x| Ok(x * 2));
        for (i, t) in tickets.into_iter().enumerate() {
            let (outcome, value) = t.wait();
            assert!(outcome.is_completed(), "{outcome:?}");
            assert_eq!(value, Some(i as u32 * 2));
        }
        let c = svc.counts();
        assert_eq!((c.submitted, c.completed, c.rejected), (8, 8, 0));
        assert_eq!((c.depth, c.in_flight), (0, 0));
    }

    #[test]
    fn overload_sheds_with_queue_full() {
        let svc = service(2);
        let tickets: Vec<Ticket<u32>> =
            (0..5).map(|i| svc.submit(Priority::Interactive, None, i)).collect();
        // no worker ran yet: 2 queued, 3 shed pre-resolved
        let shed: Vec<bool> = tickets.iter().map(|t| t.try_take().is_some()).collect();
        assert_eq!(shed, vec![false, false, true, true, true]);
        assert_eq!(svc.counts().rejected, 3);
        svc.close();
        svc.drain_inline(|&x| Ok(x));
        assert_eq!(svc.counts().completed, 2);
    }

    #[test]
    fn expired_deadline_skips_the_handler() {
        let svc = service(4);
        let t = svc.submit(Priority::Interactive, Some(0.0), 7u32);
        std::thread::sleep(std::time::Duration::from_millis(2));
        svc.close();
        let ran = std::sync::atomic::AtomicU64::new(0);
        svc.drain_inline(|&x| {
            ran.fetch_add(1, Ordering::Relaxed);
            Ok(x)
        });
        let (outcome, value) = t.wait();
        assert_eq!(outcome.label(), "deadline_exceeded");
        assert_eq!(value, None);
        assert_eq!(ran.load(Ordering::Relaxed), 0, "expired request must not execute");
        assert_eq!(svc.counts().expired, 1);
    }

    #[test]
    fn handler_errors_become_failed_outcomes() {
        let svc = service(4);
        let ok = svc.submit(Priority::Batch, None, 1u32);
        let bad = svc.submit(Priority::Batch, None, 13u32);
        svc.close();
        svc.drain_inline(|&x| {
            if x == 13 {
                anyhow::bail!("unlucky")
            }
            Ok(x)
        });
        assert!(ok.wait().0.is_completed());
        let (outcome, _) = bad.wait();
        match outcome {
            Outcome::Failed { error } => assert!(error.contains("unlucky"), "{error}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        let c = svc.counts();
        assert_eq!((c.completed, c.failed), (1, 1));
    }

    #[test]
    fn closed_service_sheds_new_submissions() {
        let svc = service(4);
        svc.close();
        let t = svc.submit(Priority::Batch, None, 1u32);
        match t.wait().0 {
            Outcome::Rejected { reason } => assert_eq!(reason.label(), "closed"),
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn concurrent_pool_conserves_requests() {
        let svc = service(256);
        let tickets: Vec<Ticket<u32>> =
            (0..100).map(|i| svc.submit(Priority::Batch, None, i)).collect();
        svc.close();
        svc.run(4, |&x| Ok(x + 1));
        let mut sum = 0u64;
        for t in tickets {
            let (outcome, value) = t.wait();
            assert!(outcome.is_completed());
            sum += u64::from(value.unwrap());
        }
        assert_eq!(sum, (1..=100).sum::<u64>());
        let c = svc.counts();
        assert_eq!((c.submitted, c.completed, c.failed, c.rejected), (100, 100, 0, 0));
    }
}
