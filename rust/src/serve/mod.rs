//! Production serving tier: concurrent synthesis service with
//! admission control, deadlines, and a load-test harness.
//!
//! The sixth subsystem.  A bounded two-lane MPMC queue ([`queue`])
//! feeds the coordinator's worker pool; admission control ([`admission`])
//! sheds load at the door and expires overdue requests at dequeue, so
//! every request resolves to a typed [`Outcome`] — the service never
//! blocks a producer and never drops a request silently.  Concurrent
//! campaign requests multiplex over the crash-safe result store, with
//! the hottest job keys warmed at startup.
//!
//! Two execution modes share the machinery:
//!
//! - **`kforge serve --synthetic`** drives the seeded bursty load
//!   generator ([`loadgen`]) through the virtual-time scenario engine
//!   ([`scenario`]): deterministic admission/shed/deadline outcomes
//!   and latency percentiles given a seed, plus real concurrent
//!   execution of every distinct admitted job through the store.  This
//!   is the load-test harness; its p99 and shed-rate are gated against
//!   the declared budgets in tests and in CI.  Level-4 (whole-model)
//!   requests may arrive as *streaming* requests: the virtual phase
//!   prices them as pulsed per-chunk service under a per-chunk latency
//!   budget, and the execution phase verifies each distinct streaming
//!   job's chunked evaluation bit-identical to whole-graph
//!   ([`crate::model::stream_eval`]).
//! - **`kforge serve --artifacts`** replays compiled artifacts through
//!   the real-time [`Service`] front end ([`service`], [`replay`]).
//!
//! Observability: a periodic greppable stats line while serving, and a
//! machine-readable summary under the [`SERVE_SCHEMA`] id (the
//! `kforge-bench-v1` convention), rendered by [`ServeSummary`].

pub mod admission;
pub mod loadgen;
pub mod queue;
pub mod replay;
pub mod scenario;
pub mod service;

pub use admission::{deadline_expired, AdmissionPolicy, Decision, Outcome, ShedReason};
pub use loadgen::{generate, LoadgenConfig, RequestSpec};
pub use queue::{BoundedQueue, Priority, PushError};
pub use replay::{key_for_request, replay_keys};
pub use scenario::{
    execute_job, run_scenario, run_virtual, RequestReport, ScenarioConfig, ScenarioReport,
    VirtualOutcome, SERVE_JOB_SEED,
};
pub use service::{Service, ServiceCounts, Ticket};

use crate::metrics::LatencyHistogram;
use crate::store::CacheStats;
use crate::util::json::Json;
use crate::util::stats::{self, Summary};

/// Schema id stamped into every `kforge serve --json` summary.
pub const SERVE_SCHEMA: &str = "kforge-serve-v1";

/// Aggregated view of one scenario run: outcome census, admission and
/// queue behavior, virtual latency distribution, store counters, and
/// the measured (wall-clock) execution figures.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub requests: usize,
    pub completed: usize,
    pub rejected: usize,
    pub expired: usize,
    pub failed: usize,
    pub queue_capacity: usize,
    pub shed_depth: usize,
    pub max_depth: usize,
    pub workers: usize,
    pub exec_workers: usize,
    pub seed: u64,
    pub makespan_ms: f64,
    /// Virtual end-to-end latency of completed requests (None when
    /// nothing completed).
    pub latency: Option<Summary>,
    pub hist: LatencyHistogram,
    /// Requests the simulation modeled as store hits.
    pub virtual_hits: usize,
    pub warmed: Vec<String>,
    pub distinct_jobs: usize,
    pub exec_total_ms: f64,
    pub wall_s: f64,
    pub cache: CacheStats,
    pub p99_budget_ms: f64,
    pub shed_budget: f64,
    /// Requests served as pulsed (chunked) streaming misses.
    pub streaming_requests: usize,
    /// Total modeled chunks across those requests.
    pub chunks: usize,
    /// Distribution of modeled per-chunk service times (None when the
    /// scenario drew no streaming traffic).
    pub chunk_latency: Option<Summary>,
    pub chunk_budget_ms: f64,
    /// Modeled chunks over the per-chunk budget.
    pub chunks_over_budget: usize,
    /// Distinct streaming jobs verified bit-identical pulsed vs whole.
    pub stream_checked: usize,
    /// Streaming jobs whose pulsed execution diverged (must be 0).
    pub stream_mismatches: usize,
}

/// Fold a scenario run into its summary.
pub fn summarize(cfg: &ScenarioConfig, report: &ScenarioReport) -> ServeSummary {
    let latencies = report.virtual_latencies_ms();
    let mut hist = LatencyHistogram::default_serve();
    for &ms in &latencies {
        hist.record(ms);
    }
    let chunk_ms = report.chunk_latencies_ms();
    ServeSummary {
        requests: report.requests.len(),
        completed: report.count("completed"),
        rejected: report.count("rejected"),
        expired: report.count("deadline_exceeded"),
        failed: report.count("failed"),
        queue_capacity: cfg.queue_capacity,
        shed_depth: cfg.shed_depth.min(cfg.queue_capacity),
        max_depth: report.max_depth,
        workers: cfg.workers,
        exec_workers: cfg.exec_workers.unwrap_or(cfg.workers).max(1),
        seed: cfg.load.seed,
        makespan_ms: report.makespan_ms,
        latency: if latencies.is_empty() { None } else { Some(stats::summarize(&latencies)) },
        hist,
        virtual_hits: report.requests.iter().filter(|r| r.virtual_hit).count(),
        warmed: report.warmed.clone(),
        distinct_jobs: report.results.len(),
        exec_total_ms: report.exec_wall_ms.iter().sum(),
        wall_s: report.wall_s,
        cache: report.cache,
        p99_budget_ms: cfg.p99_budget_ms,
        shed_budget: cfg.shed_budget,
        streaming_requests: report.requests.iter().filter(|r| !r.chunk_ms.is_empty()).count(),
        chunks: chunk_ms.len(),
        chunk_latency: if chunk_ms.is_empty() { None } else { Some(stats::summarize(&chunk_ms)) },
        chunk_budget_ms: cfg.chunk_budget_ms,
        chunks_over_budget: chunk_ms.iter().filter(|&&ms| ms > cfg.chunk_budget_ms).count(),
        stream_checked: report.stream_checked,
        stream_mismatches: report.stream_mismatches,
    }
}

impl ServeSummary {
    /// Fraction of requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.requests as f64
    }

    /// Virtual p99 within the declared budget (vacuously true when
    /// nothing completed).
    pub fn within_latency_budget(&self) -> bool {
        self.latency.map_or(true, |s| s.p99 <= self.p99_budget_ms)
    }

    pub fn within_shed_budget(&self) -> bool {
        self.shed_rate() <= self.shed_budget
    }

    /// Streaming p99 within the per-chunk budget and zero pulsed-vs-
    /// whole mismatches (vacuously true without streaming traffic).
    pub fn within_chunk_budget(&self) -> bool {
        self.stream_mismatches == 0
            && self.chunk_latency.map_or(true, |s| s.p99 <= self.chunk_budget_ms)
    }

    pub fn within_budgets(&self) -> bool {
        self.within_latency_budget() && self.within_shed_budget() && self.within_chunk_budget()
    }

    /// The greppable multi-line text report.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "serve: requests={} completed={} rejected={} expired={} failed={}\n",
            self.requests, self.completed, self.rejected, self.expired, self.failed
        ));
        out.push_str(&format!(
            "admission: shed_rate={:.1}% capacity={} shed_depth={} max_depth={}\n",
            self.shed_rate() * 100.0,
            self.queue_capacity,
            self.shed_depth,
            self.max_depth
        ));
        out.push_str(&format!(
            "queue: workers={} makespan_ms={:.2} distinct_jobs={} warmed={}\n",
            self.workers,
            self.makespan_ms,
            self.distinct_jobs,
            self.warmed.len()
        ));
        match &self.latency {
            Some(s) => out.push_str(&format!(
                "latency(virtual) ms: p50={:.2} p95={:.2} p99={:.2} max={:.2} budget_p99={:.1}\n",
                s.p50, s.p95, s.p99, s.max, self.p99_budget_ms
            )),
            None => out.push_str("latency(virtual) ms: no completed requests\n"),
        }
        out.push_str(&format!("hist(virtual): {}\n", self.hist.render()));
        match &self.chunk_latency {
            Some(s) => out.push_str(&format!(
                "streaming: requests={} chunks={} chunk_p99_ms={:.2} budget_ms={:.1} over_budget={} verified={} mismatches={}\n",
                self.streaming_requests,
                self.chunks,
                s.p99,
                self.chunk_budget_ms,
                self.chunks_over_budget,
                self.stream_checked,
                self.stream_mismatches
            )),
            None => out.push_str("streaming: no streaming requests\n"),
        }
        out.push_str(&format!("store: {} virtual_hits={}\n", self.cache, self.virtual_hits));
        out.push_str(&format!(
            "measured: exec_workers={} exec_total_ms={:.1} wall={:.2}s\n",
            self.exec_workers, self.exec_total_ms, self.wall_s
        ));
        out
    }

    /// The `kforge-serve-v1` machine-readable summary.
    pub fn to_json(&self, mode: &str) -> Json {
        let latency = match &self.latency {
            Some(s) => Json::obj()
                .set("p50", s.p50)
                .set("p95", s.p95)
                .set("p99", s.p99)
                .set("max", s.max)
                .set("mean", s.mean),
            None => Json::Null,
        };
        let hist: Vec<Json> = self
            .hist
            .cumulative()
            .iter()
            .map(|(le, n)| Json::obj().set("le", *le).set("count", *n as i64))
            .collect();
        Json::obj()
            .set("schema", SERVE_SCHEMA)
            .set("mode", mode)
            .set("seed", self.seed as i64)
            .set("workers", self.workers)
            .set("exec_workers", self.exec_workers)
            .set(
                "requests",
                Json::obj()
                    .set("total", self.requests)
                    .set("completed", self.completed)
                    .set("rejected", self.rejected)
                    .set("expired", self.expired)
                    .set("failed", self.failed),
            )
            .set(
                "admission",
                Json::obj()
                    .set("queue_capacity", self.queue_capacity)
                    .set("shed_depth", self.shed_depth)
                    .set("max_depth", self.max_depth)
                    .set("shed_rate", self.shed_rate()),
            )
            .set("latency_virtual_ms", latency)
            .set(
                "streaming",
                Json::obj()
                    .set("requests", self.streaming_requests)
                    .set("chunks", self.chunks)
                    .set(
                        "chunk_p99_ms",
                        match &self.chunk_latency {
                            Some(s) => Json::from(s.p99),
                            None => Json::Null,
                        },
                    )
                    .set("chunk_budget_ms", self.chunk_budget_ms)
                    .set("chunks_over_budget", self.chunks_over_budget)
                    .set("stream_checked", self.stream_checked)
                    .set("stream_mismatches", self.stream_mismatches),
            )
            .set(
                "histogram_virtual_ms",
                Json::obj().set("cumulative", hist).set("overflow", self.hist.overflow() as i64),
            )
            .set(
                "store",
                Json::obj()
                    .set("hits", self.cache.hits as i64)
                    .set("misses", self.cache.misses as i64)
                    .set("resumed", self.cache.resumed as i64)
                    .set("evictions", self.cache.evictions as i64)
                    .set("bytes_read", self.cache.bytes_read as i64)
                    .set("bytes_written", self.cache.bytes_written as i64)
                    .set("hit_rate", self.cache.hit_rate())
                    .set("virtual_hits", self.virtual_hits),
            )
            .set(
                "measured",
                Json::obj()
                    .set("distinct_jobs", self.distinct_jobs)
                    .set("exec_total_ms", self.exec_total_ms)
                    .set("wall_s", self.wall_s),
            )
            .set("warmed", Json::Arr(self.warmed.iter().map(|w| Json::from(w.as_str())).collect()))
            .set(
                "budgets",
                Json::obj()
                    .set("p99_ms", self.p99_budget_ms)
                    .set("shed", self.shed_budget)
                    .set("within", self.within_budgets()),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServeSummary {
        let mut hist = LatencyHistogram::default_serve();
        for ms in [1.0, 2.0, 40.0] {
            hist.record(ms);
        }
        ServeSummary {
            requests: 8,
            completed: 3,
            rejected: 4,
            expired: 1,
            failed: 0,
            queue_capacity: 4,
            shed_depth: 4,
            max_depth: 4,
            workers: 2,
            exec_workers: 2,
            seed: 9,
            makespan_ms: 50.0,
            latency: Some(stats::summarize(&[1.0, 2.0, 40.0])),
            hist,
            virtual_hits: 1,
            warmed: vec!["cuda::expert::p1".into()],
            distinct_jobs: 3,
            exec_total_ms: 12.5,
            wall_s: 0.2,
            cache: CacheStats { hits: 2, misses: 3, ..Default::default() },
            p99_budget_ms: 250.0,
            shed_budget: 0.6,
            streaming_requests: 2,
            chunks: 8,
            chunk_latency: Some(stats::summarize(&[1.0, 2.0, 3.0, 4.0])),
            chunk_budget_ms: 8.0,
            chunks_over_budget: 0,
            stream_checked: 2,
            stream_mismatches: 0,
        }
    }

    #[test]
    fn budgets_and_shed_rate() {
        let mut s = sample();
        assert!((s.shed_rate() - 0.5).abs() < 1e-12);
        assert!(s.within_budgets());
        s.shed_budget = 0.4;
        assert!(!s.within_shed_budget());
        s.shed_budget = 0.6;
        s.p99_budget_ms = 10.0;
        assert!(!s.within_latency_budget());
    }

    #[test]
    fn chunk_budget_gates_streaming_and_is_vacuous_without_it() {
        let mut s = sample();
        assert!(s.within_chunk_budget());
        s.chunk_budget_ms = 2.0;
        assert!(!s.within_chunk_budget(), "chunk p99 3.97 must bust a 2.0 budget");
        assert!(!s.within_budgets());
        s.chunk_budget_ms = 8.0;
        s.stream_mismatches = 1;
        assert!(!s.within_chunk_budget(), "a pulsed-vs-whole mismatch busts the budget");
        s.stream_mismatches = 0;
        s.chunk_latency = None;
        s.chunk_budget_ms = 0.0;
        assert!(s.within_chunk_budget(), "vacuous without streaming traffic");
        assert!(s.render_text().contains("streaming: no streaming requests"));
    }

    #[test]
    fn text_is_greppable() {
        let text = sample().render_text();
        assert!(text.contains("serve: requests=8 completed=3 rejected=4 expired=1 failed=0"));
        assert!(text.contains("admission: shed_rate=50.0%"));
        assert!(text.contains("hist(virtual): le0.25=0"));
        assert!(text.contains("virtual_hits=1"));
        assert!(text.contains(
            "streaming: requests=2 chunks=8 chunk_p99_ms=3.97 budget_ms=8.0 over_budget=0 verified=2 mismatches=0"
        ));
    }

    #[test]
    fn json_schema_and_counters() {
        let j = sample().to_json("synthetic");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SERVE_SCHEMA));
        assert_eq!(j.get("mode").and_then(Json::as_str), Some("synthetic"));
        let reqs = j.get("requests").unwrap();
        assert_eq!(reqs.get("failed").and_then(Json::as_i64), Some(0));
        assert_eq!(reqs.get("rejected").and_then(Json::as_i64), Some(4));
        let store = j.get("store").unwrap();
        assert_eq!(store.get("hits").and_then(Json::as_i64), Some(2));
        assert_eq!(store.get("virtual_hits").and_then(Json::as_i64), Some(1));
        let streaming = j.get("streaming").unwrap();
        assert_eq!(streaming.get("chunks").and_then(Json::as_i64), Some(8));
        assert_eq!(streaming.get("stream_mismatches").and_then(Json::as_i64), Some(0));
        // the CI smoke job greps the pretty rendering for these
        let text = j.to_pretty();
        assert!(text.contains("\"schema\": \"kforge-serve-v1\""), "{text}");
        assert!(text.contains("\"failed\": 0"), "{text}");
        assert!(text.contains("\"hits\": 2"), "{text}");
        // round-trips through the parser
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SERVE_SCHEMA));
    }

    #[test]
    fn empty_latency_is_null_and_vacuously_in_budget() {
        let mut s = sample();
        s.latency = None;
        s.completed = 0;
        assert!(s.within_latency_budget());
        let j = s.to_json("synthetic");
        assert!(matches!(j.get("latency_virtual_ms"), Some(Json::Null)));
        assert!(s.render_text().contains("no completed requests"));
    }
}
