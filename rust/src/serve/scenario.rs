//! The load-test scenario engine: a deterministic virtual-time
//! simulation of the service front end, followed by real concurrent
//! execution of every admitted distinct job through the result store.
//!
//! The split is what reconciles "real concurrent service" with
//! "deterministic scenario outcome given a seed":
//!
//! 1. **Virtual phase** — a discrete-event simulation drives the real
//!    [`BoundedQueue`] and [`AdmissionPolicy`] with the seeded arrival
//!    sequence from [`loadgen`].  `workers` virtual servers pull from
//!    the queue; service times are modeled per request (per-level base
//!    cost, persona factor, seeded lognormal noise for store misses; a
//!    small constant for hits), deadlines are checked at dequeue, and
//!    every request resolves to a typed [`Outcome`].  Everything here
//!    — admissions, sheds, deadline misses, pop order, latency
//!    percentiles, makespan — is bit-reproducible from the seed.
//! 2. **Execution phase** — the hottest job keys are warmed into the
//!    store, then every *distinct* job that virtually completed runs
//!    for real, fanned over [`crate::coordinator::worker::run_jobs`]
//!    as single-job campaigns through [`run_campaign_with`] against
//!    the shared store.  Results are bit-identical regardless of the
//!    execution pool width (the PR 3/4 property), so only wall-clock
//!    measurements vary run to run.
//!
//! Executing each distinct job exactly once (instead of one campaign
//! per request) is also what keeps the crash-safe journals sound: two
//! concurrent campaigns over the same key list would share a journal
//! path.  Duplicate requests are resolved from the first execution —
//! exactly what the store would do anyway, minus the file races.

use super::admission::{deadline_expired, AdmissionPolicy, Decision, Outcome, ShedReason};
use super::loadgen::{self, LoadgenConfig, RequestSpec};
use super::queue::{BoundedQueue, Priority, PushError};
use crate::coordinator::{run_campaign_with, BaselineKind, ExperimentConfig, TaskResult};
use crate::obs;
use crate::store::{CacheStats, Store};
use crate::util::rng::{fnv1a, Pcg};
use crate::workloads::{Level, Suite};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet};
use std::sync::Arc;

/// The fixed seed of every serve-path campaign.  Part of each job's
/// store key: keeping it constant (rather than deriving it from the
/// scenario seed) is what lets different traffic scenarios share
/// cached results for overlapping jobs — the whole point of a cache.
pub const SERVE_JOB_SEED: u64 = 0x5E12;

/// Iterations per serve-path synthesis job (cheaper than the paper's 5
/// — a serving tier trades refinement depth for latency).
pub const SERVE_JOB_ITERATIONS: usize = 3;

#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub load: LoadgenConfig,
    /// Service capacity: virtual servers in the simulation, and the
    /// default execution pool width.
    pub workers: usize,
    pub queue_capacity: usize,
    pub shed_depth: usize,
    /// Warm the K hottest job keys into the store before serving.
    pub warm_hottest: usize,
    /// Execution pool width override.  The virtual scenario (and thus
    /// every deterministic outcome) is a function of `workers` only;
    /// this knob varies real parallelism without touching it — the
    /// worker-count bit-identity tests pivot on exactly that.
    pub exec_workers: Option<usize>,
    /// Route the execution phase through the distributed chunk-claiming
    /// pool ([`crate::dist::exec_pool`]) with this many in-process
    /// shards instead of the flat worker pool.  Like `exec_workers`
    /// this varies real parallelism only — results stay bit-identical
    /// to the unsharded path (pool-shape invariance is test-pinned).
    pub exec_shards: Option<usize>,
    /// Apply store-eviction pressure after the warm phase: gc the disk
    /// tier down to this many bytes.
    pub gc_max_bytes: Option<u64>,
    /// Declared latency budget gated by `kforge serve` and the tests.
    pub p99_budget_ms: f64,
    /// Declared shed-rate budget (rejected / total).
    pub shed_budget: f64,
    /// Declared per-chunk latency budget for streaming (level-4)
    /// requests: the p99 of modeled chunk service times must stay
    /// under it.  Vacuous when the scenario draws no streaming traffic.
    pub chunk_budget_ms: f64,
    /// Print a stats line every N processed arrivals (0 = silent).
    pub progress_every: usize,
}

impl ScenarioConfig {
    pub fn new(seed: u64, requests: usize, workers: usize) -> ScenarioConfig {
        let workers = workers.max(1);
        ScenarioConfig {
            load: LoadgenConfig::new(seed, requests),
            workers,
            queue_capacity: 2 * workers + 8,
            shed_depth: 2 * workers + 8,
            warm_hottest: 4,
            exec_workers: None,
            exec_shards: None,
            gc_max_bytes: None,
            p99_budget_ms: 250.0,
            shed_budget: 0.5,
            // a pulsed L4 chunk models at (miss/chunks)·noise ≈ 4–7.5 ms
            // worst-case (reasoning persona, upper noise tail); the
            // budget sits above that but well below a one-shot L4 miss
            chunk_budget_ms: 12.0,
            progress_every: 0,
        }
    }
}

/// One request's resolution.
#[derive(Debug, Clone)]
pub struct RequestReport {
    pub id: usize,
    pub priority: Priority,
    pub job: String,
    pub outcome: Outcome,
    /// Virtual service start (None for shed / expired requests).
    pub started_ms: Option<f64>,
    /// Whether the simulation modeled this request as a store hit.
    pub virtual_hit: bool,
    /// Per-chunk modeled service times for a streaming request served
    /// as a miss (sums to the request's `service_ms`).  Empty for
    /// one-shot requests and for streaming hits, which answer from the
    /// cache in one piece.
    pub chunk_ms: Vec<f64>,
}

/// Everything a scenario run produces.  All fields except `wall_s`,
/// `exec_wall_ms` and the byte counters inside `cache` are
/// deterministic given the seed and config (with a fresh store, the
/// hit/miss counters are too).
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub requests: Vec<RequestReport>,
    /// (priority, request id) in virtual dequeue order — the FIFO
    /// evidence the load tests assert on.
    pub pop_order: Vec<(Priority, usize)>,
    pub max_depth: usize,
    /// Virtual time of the last completion.
    pub makespan_ms: f64,
    /// Job ids warmed into the store before serving, hottest first.
    pub warmed: Vec<String>,
    /// Synthesized results for every distinct job that completed
    /// virtually, in first-virtual-start order.
    pub results: Vec<(String, TaskResult)>,
    /// Measured wall time per executed job (ms), same order.
    pub exec_wall_ms: Vec<f64>,
    /// Measured wall time of the whole execution phase (warm + gc +
    /// serve), seconds.
    pub wall_s: f64,
    /// Store counter delta across the execution phase.
    pub cache: CacheStats,
    /// Distinct streaming jobs whose pulsed execution was verified
    /// bit-identical to whole-graph evaluation in the real phase.
    pub stream_checked: usize,
    /// Streaming jobs whose pulsed execution diverged (must be 0).
    pub stream_mismatches: usize,
}

impl ScenarioReport {
    pub fn count(&self, label: &str) -> usize {
        self.requests.iter().filter(|r| r.outcome.label() == label).count()
    }

    /// Virtual end-to-end latencies of completed requests, request order.
    pub fn virtual_latencies_ms(&self) -> Vec<f64> {
        self.requests.iter().filter_map(|r| r.outcome.latency_ms()).collect()
    }

    /// Every modeled chunk service time, request order then chunk order.
    pub fn chunk_latencies_ms(&self) -> Vec<f64> {
        self.requests.iter().flat_map(|r| r.chunk_ms.iter().copied()).collect()
    }
}

/// Modeled per-level miss cost bases, aligned with [`Level::ALL`]
/// (whole-model level-4 jobs are the most expensive tier).
const MISS_BASE_MS: [f64; Level::COUNT] = [4.0, 6.5, 10.0, 16.0];

/// Modeled service cost for a store miss: per-level base cost times a
/// persona factor times seeded lognormal noise.
fn miss_cost_ms(spec: &RequestSpec, rng: &mut Pcg) -> f64 {
    let base = MISS_BASE_MS[spec.problem.level.index()];
    let factor = if spec.persona.reasoning { 1.25 } else { 1.0 };
    base * factor * rng.lognormal_noise(0.12)
}

/// Modeled service cost for a store hit (lookup + deserialize).
fn hit_cost_ms(rng: &mut Pcg) -> f64 {
    0.4 * rng.lognormal_noise(0.08)
}

/// Pre-drawn modeled costs for one request.  Draw order inside the
/// request's fork is load-bearing: miss, then hit, then (for streaming
/// requests only) the per-chunk noise — so non-streaming scenarios
/// price identically to the pre-streaming engine.
#[derive(Debug, Clone)]
struct ReqCost {
    miss_ms: f64,
    hit_ms: f64,
    /// Per-chunk costs for a streaming request (empty otherwise); the
    /// streaming miss's total service time is their sum.
    chunk_ms: Vec<f64>,
}

fn request_cost(spec: &RequestSpec, svc_root: &Pcg) -> ReqCost {
    let mut r = svc_root.fork(&format!("req-{}", spec.id));
    let miss_ms = miss_cost_ms(spec, &mut r);
    let hit_ms = hit_cost_ms(&mut r);
    let chunk_ms: Vec<f64> = (0..spec.chunks)
        .map(|_| (miss_ms / spec.chunks as f64) * r.lognormal_noise(0.10))
        .collect();
    ReqCost { miss_ms, hit_ms, chunk_ms }
}

/// The campaign config a request's job runs under.  Fixed name and
/// seed: the store key covers both, so every serve scenario (and every
/// serve process) shares one key space.
fn job_config(spec: &RequestSpec) -> ExperimentConfig {
    ExperimentConfig {
        name: "serve".into(),
        platform: spec.platform.clone(),
        personas: vec![spec.persona],
        iterations: SERVE_JOB_ITERATIONS,
        use_profiling: false,
        use_reference: false,
        baseline: BaselineKind::Eager,
        seed: SERVE_JOB_SEED,
        workers: 1,
    }
}

/// Execute one request's job as a single-problem campaign through the
/// store (the `kforge run --problem` idiom).  Public so integration
/// tests can reproduce a serve-path result independently.
pub fn execute_job(store: &Store, spec: &RequestSpec) -> TaskResult {
    let cfg = job_config(spec);
    let single = Suite { problems: Arc::new(vec![spec.problem.clone()]) };
    let campaign = run_campaign_with(store, &single, None, &cfg);
    campaign.results.into_iter().next().expect("single-job campaign yields one result")
}

/// f64 virtual-time heap key with a total order.
#[derive(PartialEq)]
struct Ms(f64);
impl Eq for Ms {}
impl PartialOrd for Ms {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ms {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Mutable state of the virtual-time simulation.
struct Engine<'a> {
    specs: &'a [RequestSpec],
    /// Pre-drawn costs per request — drawn up front so the noise
    /// stream never depends on event interleaving.
    costs: Vec<ReqCost>,
    warm_set: HashSet<String>,
    /// Model store hits at all?  False for a disabled store.
    model_hits: bool,
    queue: BoundedQueue<usize>,
    idle: usize,
    /// (finish time, request idx); min-heap with a total f64 order and
    /// the request id as a deterministic tie-break.
    completions: BinaryHeap<Reverse<(Ms, usize)>>,
    /// Job id → earliest virtual completion (inserted only once the
    /// simulation clock has passed it, so membership ⇒ done by `now`).
    job_done: HashSet<String>,
    reports: Vec<Option<RequestReport>>,
    pop_order: Vec<(Priority, usize)>,
    max_depth: usize,
    makespan_ms: f64,
    completed: usize,
    expired: usize,
}

impl Engine<'_> {
    /// Process every completion at or before `t`, starting queued
    /// requests as servers free up (at the completion's own time, not
    /// at `t` — a freed server never idles while work waits).
    fn drain_until(&mut self, t: f64) {
        while let Some(Reverse((Ms(ct), _))) = self.completions.peek() {
            if *ct > t {
                break;
            }
            let Reverse((Ms(ct), idx)) = self.completions.pop().expect("peeked");
            self.idle += 1;
            self.completed += 1;
            self.makespan_ms = if ct > self.makespan_ms { ct } else { self.makespan_ms };
            self.job_done.insert(self.specs[idx].job_id());
            self.start_ready(ct);
        }
    }

    /// Hand queued requests to idle servers at virtual time `now`.
    /// Expired requests are resolved without consuming a server.
    fn start_ready(&mut self, now: f64) {
        while self.idle > 0 {
            let Some((priority, idx)) = self.queue.try_pop() else {
                break;
            };
            self.pop_order.push((priority, idx));
            let spec = &self.specs[idx];
            let waited = now - spec.at_ms;
            let job = spec.job_id();
            if deadline_expired(spec.deadline_ms, waited) {
                self.reports[idx] = Some(RequestReport {
                    id: idx,
                    priority,
                    job,
                    outcome: Outcome::DeadlineExceeded { waited_ms: waited },
                    started_ms: None,
                    virtual_hit: false,
                    chunk_ms: Vec::new(),
                });
                self.expired += 1;
                continue;
            }
            let hit = self.model_hits
                && (self.warm_set.contains(&job) || self.job_done.contains(&job));
            let cost = &self.costs[idx];
            // a streaming miss is served chunk by chunk; a streaming
            // hit answers from the cache in one piece
            let streaming_miss = !hit && !cost.chunk_ms.is_empty();
            let service_ms = if hit {
                cost.hit_ms
            } else if streaming_miss {
                cost.chunk_ms.iter().sum()
            } else {
                cost.miss_ms
            };
            let chunk_ms = if streaming_miss { cost.chunk_ms.clone() } else { Vec::new() };
            self.idle -= 1;
            self.completions.push(Reverse((Ms(now + service_ms), idx)));
            self.reports[idx] = Some(RequestReport {
                id: idx,
                priority,
                job,
                outcome: Outcome::Completed { queue_ms: waited, service_ms },
                started_ms: Some(now),
                virtual_hit: hit,
                chunk_ms,
            });
        }
    }
}

/// The deterministic product of the virtual phase: everything the
/// simulation decides before any real job executes.  Public so
/// `kforge bench` can price a streaming scenario (chunk percentiles
/// included) without paying for real synthesis.
pub struct VirtualOutcome {
    pub specs: Vec<RequestSpec>,
    pub requests: Vec<RequestReport>,
    pub pop_order: Vec<(Priority, usize)>,
    pub max_depth: usize,
    pub makespan_ms: f64,
    /// Job ids that would be warmed, hottest first (empty when the
    /// store is disabled).
    pub warmed: Vec<String>,
}

/// Run just the virtual phase.  `store_enabled` selects whether the
/// simulation models warm-up and store hits (it must match the store
/// the execution phase will use for the phases to agree).
pub fn run_virtual(cfg: &ScenarioConfig, store_enabled: bool) -> VirtualOutcome {
    let specs = loadgen::generate(&cfg.load);

    // hottest job keys: by request frequency, job id as the tie-break
    let mut freq: BTreeMap<String, usize> = BTreeMap::new();
    for s in &specs {
        *freq.entry(s.job_id()).or_insert(0) += 1;
    }
    let mut hottest: Vec<(&String, &usize)> = freq.iter().collect();
    hottest.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
    let warm_n = if store_enabled { cfg.warm_hottest } else { 0 };
    let warmed: Vec<String> = hottest.iter().take(warm_n).map(|(k, _)| (*k).clone()).collect();

    // pre-draw modeled service costs (independent of event order)
    let svc_root = Pcg::new(cfg.load.seed, fnv1a(b"serve-service"));
    let costs: Vec<ReqCost> = specs.iter().map(|s| request_cost(s, &svc_root)).collect();

    // ---- virtual phase -------------------------------------------------
    let policy = AdmissionPolicy {
        queue_capacity: cfg.queue_capacity,
        shed_depth: cfg.shed_depth.min(cfg.queue_capacity),
    };
    let mut eng = Engine {
        specs: &specs,
        costs,
        warm_set: warmed.iter().cloned().collect(),
        model_hits: store_enabled,
        queue: BoundedQueue::new(cfg.queue_capacity),
        idle: cfg.workers.max(1),
        completions: BinaryHeap::new(),
        job_done: HashSet::new(),
        reports: specs.iter().map(|_| None).collect(),
        pop_order: Vec::new(),
        max_depth: 0,
        makespan_ms: 0.0,
        completed: 0,
        expired: 0,
    };
    let mut rejected = 0usize;
    for (idx, spec) in specs.iter().enumerate() {
        eng.drain_until(spec.at_ms);
        match policy.decide(eng.queue.depth()) {
            Decision::Shed(reason) => {
                eng.reports[idx] = Some(RequestReport {
                    id: idx,
                    priority: spec.priority,
                    job: spec.job_id(),
                    outcome: Outcome::Rejected { reason },
                    started_ms: None,
                    virtual_hit: false,
                    chunk_ms: Vec::new(),
                });
                rejected += 1;
            }
            Decision::Admit => match eng.queue.try_push(spec.priority, idx) {
                Ok(()) => {
                    let depth = eng.queue.depth();
                    if depth > eng.max_depth {
                        eng.max_depth = depth;
                    }
                    eng.start_ready(spec.at_ms);
                }
                Err(PushError::Full(_)) | Err(PushError::Closed(_)) => {
                    // decide() admits only below capacity and nothing
                    // closes this queue, but shed rather than panic if
                    // the policy and queue ever disagree
                    eng.reports[idx] = Some(RequestReport {
                        id: idx,
                        priority: spec.priority,
                        job: spec.job_id(),
                        outcome: Outcome::Rejected { reason: ShedReason::QueueFull },
                        started_ms: None,
                        virtual_hit: false,
                        chunk_ms: Vec::new(),
                    });
                    rejected += 1;
                }
            },
        }
        if obs::enabled() {
            // the virtual loop is single-threaded and seeded, so these
            // live samples are part of the deterministic (logical) trace
            let _l = obs::lane("serve");
            obs::logical_gauge("serve.queue_depth", eng.queue.depth() as f64);
            obs::logical_gauge(
                "serve.in_flight",
                (cfg.workers.max(1) - eng.idle) as f64,
            );
        }
        if cfg.progress_every > 0 && (idx + 1) % cfg.progress_every == 0 {
            println!(
                "[serve] t={:.1}ms arrived={} depth={} in_flight={} completed={} rejected={} expired={}",
                spec.at_ms,
                idx + 1,
                eng.queue.depth(),
                cfg.workers.max(1) - eng.idle,
                eng.completed,
                rejected,
                eng.expired
            );
        }
    }
    eng.drain_until(f64::INFINITY);
    debug_assert_eq!(eng.queue.depth(), 0, "virtual queue fully drained");
    let requests: Vec<RequestReport> = eng
        .reports
        .into_iter()
        .map(|r| r.expect("every request resolves to exactly one outcome"))
        .collect();

    let out = VirtualOutcome {
        specs,
        requests,
        pop_order: eng.pop_order,
        max_depth: eng.max_depth,
        makespan_ms: eng.makespan_ms,
        warmed,
    };
    trace_virtual(&out);
    out
}

/// Emit the logical trace of a virtual run: one admission decision
/// instant per request (arrival order), queue-wait gauges in the
/// priority lanes, and the scenario summary.  Everything comes from the
/// assembled [`VirtualOutcome`], which is a pure function of (seed,
/// config, store-enabled) — so the stream lands in `Snapshot::canon`
/// and is compared bit-for-bit across execution worker counts and warm
/// vs cold store.
fn trace_virtual(v: &VirtualOutcome) {
    if !obs::enabled() {
        return;
    }
    let _lane = obs::lane("serve");
    let _span = obs::logical_span("serve.virtual");
    for r in &v.requests {
        match &r.outcome {
            Outcome::Completed { queue_ms, .. } => {
                obs::logical_instant("serve.admit");
                let _p = obs::lane(&format!("serve:{}", r.priority.label()));
                obs::logical_counter("serve.completed", 1);
                obs::logical_gauge("serve.queue_wait_ms", *queue_ms);
            }
            Outcome::Rejected { reason } => {
                obs::logical_instant(&format!("serve.shed.{}", reason.label()));
            }
            Outcome::DeadlineExceeded { waited_ms } => {
                obs::logical_instant("serve.admit");
                let _p = obs::lane(&format!("serve:{}", r.priority.label()));
                obs::logical_counter("serve.expired", 1);
                obs::logical_gauge("serve.queue_wait_ms", *waited_ms);
            }
            Outcome::Failed { .. } => obs::logical_instant("serve.failed"),
        }
    }
    obs::logical_counter("serve.requests", v.requests.len() as u64);
    obs::logical_counter("serve.warmed", v.warmed.len() as u64);
    obs::logical_gauge("serve.max_depth", v.max_depth as f64);
    obs::logical_gauge("serve.makespan_ms", v.makespan_ms);
}

/// Run the full scenario: the virtual phase, then real execution of
/// every distinct virtually-completed job through the store, then —
/// for the streaming jobs among them — a pulsed-execution verification
/// pass (chunked evaluation must be bit-identical to whole-graph).
pub fn run_scenario(store: &Store, cfg: &ScenarioConfig) -> ScenarioReport {
    let VirtualOutcome { specs, requests, pop_order, max_depth, makespan_ms, warmed } =
        run_virtual(cfg, store.enabled());

    // ---- execution phase -----------------------------------------------
    let _exec_lane = obs::lane("serve");
    let t0 = std::time::Instant::now();
    let snap0 = store.snapshot();
    let mut first_spec: HashMap<String, usize> = HashMap::new();
    for (i, s) in specs.iter().enumerate() {
        first_spec.entry(s.job_id()).or_insert(i);
    }
    // cache warming: the hottest keys, before any traffic executes
    {
        let _s = obs::span("serve.warm");
        for job in &warmed {
            let _ = execute_job(store, &specs[first_spec[job]]);
        }
    }
    // optional eviction pressure on the disk tier between warm and serve
    if let Some(max_bytes) = cfg.gc_max_bytes {
        let _s = obs::span("serve.gc");
        if let Err(e) = store.cache().gc(max_bytes) {
            crate::kf_warn!("[serve] gc failed ({e:#}); continuing");
        }
    }
    // distinct jobs that virtually completed, in first-start order,
    // fanned over the real worker pool as single-job campaigns
    let mut started: Vec<&RequestReport> =
        requests.iter().filter(|r| r.started_ms.is_some()).collect();
    started.sort_by(|a, b| {
        a.started_ms
            .expect("filtered on started")
            .total_cmp(&b.started_ms.expect("filtered on started"))
            .then(a.id.cmp(&b.id))
    });
    let mut seen = HashSet::new();
    let exec_jobs: Vec<(String, usize)> = started
        .iter()
        .filter(|r| seen.insert(r.job.clone()))
        .map(|r| (r.job.clone(), first_spec[&r.job]))
        .collect();
    let exec_workers = cfg.exec_workers.unwrap_or(cfg.workers).max(1);
    let exec_span = obs::span("serve.exec");
    let run_one = |(_, spec_idx): &(String, usize)| {
        let t = std::time::Instant::now();
        let r = execute_job(store, &specs[*spec_idx]);
        (r, t.elapsed().as_secs_f64() * 1e3)
    };
    let timed: Vec<(TaskResult, f64)> = match cfg.exec_shards {
        // shard-backed pool: self-claiming chunks instead of a flat
        // queue — same results, different scheduling shape
        Some(shards) => crate::dist::exec_pool(shards.max(1), &exec_jobs, run_one),
        None => crate::coordinator::worker::run_jobs(exec_workers, &exec_jobs, run_one),
    };
    drop(exec_span);
    let results: Vec<(String, TaskResult)> = exec_jobs
        .iter()
        .zip(&timed)
        .map(|((job, _), (r, _))| (job.clone(), r.clone()))
        .collect();
    let exec_wall_ms: Vec<f64> = timed.iter().map(|(_, ms)| *ms).collect();

    // ---- streaming verification ------------------------------------------
    // every distinct streaming job that started must deliver the same
    // bits pulsed (chunked) as whole-graph — the serve-tier face of the
    // model-layer determinism property
    let stream_span = obs::span("serve.stream_verify");
    let mut stream_checked = 0usize;
    let mut stream_mismatches = 0usize;
    let mut stream_seen: HashSet<&str> = HashSet::new();
    for (i, s) in specs.iter().enumerate() {
        if s.chunks == 0 || requests[i].started_ms.is_none() {
            continue;
        }
        let job = &requests[i].job;
        if !stream_seen.insert(job.as_str()) {
            continue;
        }
        if !crate::model::is_streamable(&s.problem.eval_graph) {
            continue;
        }
        let ins = s.problem.eval_inputs(SERVE_JOB_SEED);
        let whole = crate::kir::interp::eval(&s.problem.eval_graph, &ins);
        let pulsed =
            crate::model::stream_eval(&s.problem.eval_graph, &ins, cfg.load.chunk_rows);
        let same = match (&whole, &pulsed) {
            (Ok(w), Ok(p)) => {
                w.len() == p.len()
                    && w.iter().zip(p).all(|(a, b)| {
                        a.shape == b.shape
                            && a.data.len() == b.data.len()
                            && a.data
                                .iter()
                                .zip(&b.data)
                                .all(|(x, y)| x.to_bits() == y.to_bits())
                    })
            }
            _ => false,
        };
        if same {
            stream_checked += 1;
        } else {
            stream_mismatches += 1;
            crate::kf_error!("[serve] streaming mismatch on job {job}");
        }
    }
    drop(stream_span);
    // pulsed-vs-whole agreement is a pure function of the specs, so the
    // counts belong to the logical (determinism-pinned) trace
    obs::logical_counter("serve.stream_checked", stream_checked as u64);
    obs::logical_counter("serve.stream_mismatches", stream_mismatches as u64);

    ScenarioReport {
        requests,
        pop_order,
        max_depth,
        makespan_ms,
        warmed,
        results,
        exec_wall_ms,
        wall_s: t0.elapsed().as_secs_f64(),
        cache: store.snapshot().since(&snap0),
        stream_checked,
        stream_mismatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_orders_totally_with_ties_broken_by_index() {
        let mut h: BinaryHeap<Reverse<(Ms, usize)>> = BinaryHeap::new();
        h.push(Reverse((Ms(2.0), 1)));
        h.push(Reverse((Ms(1.0), 9)));
        h.push(Reverse((Ms(2.0), 0)));
        let order: Vec<usize> = std::iter::from_fn(|| h.pop().map(|Reverse((_, i))| i)).collect();
        assert_eq!(order, vec![9, 0, 1]);
    }

    #[test]
    fn modeled_costs_are_positive_and_hit_is_cheaper() {
        let specs = loadgen::generate(&LoadgenConfig::new(3, 32));
        let root = Pcg::new(3, fnv1a(b"serve-service"));
        for s in &specs {
            let mut r = root.fork(&format!("req-{}", s.id));
            let miss = miss_cost_ms(s, &mut r);
            let hit = hit_cost_ms(&mut r);
            assert!(miss > 0.0 && hit > 0.0);
            assert!(hit < miss, "hit {hit} must undercut miss {miss}");
        }
    }

    #[test]
    fn per_chunk_costs_sum_to_the_streaming_service_time() {
        let specs = loadgen::generate(&LoadgenConfig::new(0x57, 256));
        let root = Pcg::new(0x57, fnv1a(b"serve-service"));
        let mut streaming = 0usize;
        for s in &specs {
            let c = request_cost(s, &root);
            let c2 = request_cost(s, &root);
            assert_eq!(c.miss_ms.to_bits(), c2.miss_ms.to_bits(), "request_cost must be pure");
            assert_eq!(c.chunk_ms.len(), s.chunks);
            if s.chunks > 0 {
                streaming += 1;
                let sum: f64 = c.chunk_ms.iter().sum();
                assert!(c.chunk_ms.iter().all(|&m| m > 0.0));
                // each chunk is miss/chunks × lognormal(0.10); the sum
                // stays in a tight band around the one-shot miss cost
                assert!(
                    sum > 0.5 * c.miss_ms && sum < 2.0 * c.miss_ms,
                    "chunk sum {sum} vs miss {}",
                    c.miss_ms
                );
            }
        }
        assert!(streaming > 0, "no streaming request drawn");
    }

    #[test]
    fn miss_costs_rise_with_level_and_cover_every_level() {
        // the table is indexed by Level::index(); a new level without a
        // base cost fails to compile, an out-of-order one fails here
        for w in MISS_BASE_MS.windows(2) {
            assert!(w[1] > w[0], "miss base costs must rise with level: {MISS_BASE_MS:?}");
        }
        assert_eq!(MISS_BASE_MS.len(), Level::ALL.len());
    }

    #[test]
    fn virtual_phase_reports_chunked_streaming_misses() {
        let mut cfg = ScenarioConfig::new(0x57, 256, 4);
        cfg.load.synthetic_problems = 16; // guarantees L4 problems in the pool
        let v = run_virtual(&cfg, true);
        let mut streamed_miss = 0usize;
        for r in &v.requests {
            if r.chunk_ms.is_empty() {
                continue;
            }
            streamed_miss += 1;
            let spec = &v.specs[r.id];
            assert_eq!(r.chunk_ms.len(), spec.chunks);
            assert!(!r.virtual_hit, "streaming hits answer in one piece");
            let service = match r.outcome {
                Outcome::Completed { service_ms, .. } => service_ms,
                ref o => panic!("chunked request resolved as {o:?}"),
            };
            let sum: f64 = r.chunk_ms.iter().sum();
            assert_eq!(sum.to_bits(), service.to_bits(), "chunks must sum to service time");
        }
        assert!(streamed_miss > 0, "no streaming miss surfaced in the virtual phase");
        // the virtual phase is bit-reproducible
        let v2 = run_virtual(&cfg, true);
        for (a, b) in v.requests.iter().zip(&v2.requests) {
            assert_eq!(a.chunk_ms.len(), b.chunk_ms.len());
            for (x, y) in a.chunk_ms.iter().zip(&b.chunk_ms) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn serve_job_config_is_stable() {
        let specs = loadgen::generate(&LoadgenConfig::new(5, 4));
        let cfg = job_config(&specs[0]);
        assert_eq!(cfg.name, "serve");
        assert_eq!(cfg.seed, SERVE_JOB_SEED);
        assert_eq!(cfg.iterations, SERVE_JOB_ITERATIONS);
        // a different scenario seed must not perturb the job identity
        let other = loadgen::generate(&LoadgenConfig::new(6, 4));
        let cfg2 = job_config(&other[0]);
        assert_eq!(cfg.name, cfg2.name);
        assert_eq!(cfg.seed, cfg2.seed);
    }
}
