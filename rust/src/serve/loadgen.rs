//! Seeded load generator: bursty Poisson-ish arrivals over mixed
//! platforms and personas.
//!
//! Traffic alternates calm and burst phases; within a phase,
//! inter-arrival gaps are exponential around the phase's mean, drawn
//! from a forked [`Pcg`] stream — so a seed pins the entire arrival
//! process, and the scenario engine's outcomes (admissions, sheds,
//! deadline misses, latency percentiles) are bit-reproducible.  Each
//! request pairs a registered platform with a synthetic problem that
//! platform supports and one of the calibrated personas; interactive
//! requests carry a deadline, batch requests do not.

use super::queue::Priority;
use crate::agents::persona::{Persona, PERSONAS};
use crate::platform::{registry, PlatformRef};
use crate::util::rng::{fnv1a, Pcg};
use crate::workloads::{Problem, Suite};

/// Traffic shape knobs.  `LoadgenConfig::new` gives the default
/// scenario: 70% interactive with a 120 ms deadline, ~8 ms calm gaps,
/// 0.5 ms burst gaps, bursts of up to 12 requests.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub seed: u64,
    pub requests: usize,
    /// Size of the synthetic problem pool requests draw from.
    pub synthetic_problems: usize,
    /// Fraction of requests in the interactive priority class.
    pub interactive_fraction: f64,
    /// Deadline attached to interactive requests (virtual ms).
    pub deadline_ms: f64,
    /// Mean inter-arrival gap in a calm phase (ms).
    pub calm_gap_ms: f64,
    /// Mean inter-arrival gap in a burst phase (ms).
    pub burst_gap_ms: f64,
    /// Upper bound on requests per phase (each phase's length is drawn
    /// uniformly from 2..=burst_len).
    pub burst_len: usize,
    /// Fraction of level-4 (whole-model) requests that arrive as
    /// streaming requests: the model is executed in pulsed row chunks
    /// under a per-chunk latency budget instead of one synthesis pass.
    pub streaming_fraction: f64,
    /// Rows per chunk for streaming requests (chunk count is derived
    /// from the model's batch axis).
    pub chunk_rows: usize,
}

impl LoadgenConfig {
    pub fn new(seed: u64, requests: usize) -> LoadgenConfig {
        LoadgenConfig {
            seed,
            requests,
            synthetic_problems: 12,
            interactive_fraction: 0.7,
            deadline_ms: 120.0,
            calm_gap_ms: 8.0,
            burst_gap_ms: 0.5,
            burst_len: 12,
            streaming_fraction: 0.35,
            chunk_rows: 2,
        }
    }
}

/// One generated request.
#[derive(Clone)]
pub struct RequestSpec {
    /// Arrival-order index (ids are assigned in arrival order).
    pub id: usize,
    /// Virtual arrival time.
    pub at_ms: f64,
    pub priority: Priority,
    /// Virtual deadline budget, measured from arrival.
    pub deadline_ms: Option<f64>,
    pub platform: PlatformRef,
    pub persona: &'static Persona,
    pub problem: Problem,
    /// Streaming request: the whole-model answer is delivered in this
    /// many pulsed row chunks (0 = ordinary one-shot synthesis).  Only
    /// level-4 problems stream.
    pub chunks: usize,
}

impl RequestSpec {
    /// The request's job identity: requests with equal job ids resolve
    /// to the same synthesized result (and the same store `JobKey`).
    pub fn job_id(&self) -> String {
        format!("{}::{}::{}", self.platform.name(), self.persona.name, self.problem.id)
    }
}

impl std::fmt::Debug for RequestSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestSpec")
            .field("id", &self.id)
            .field("at_ms", &self.at_ms)
            .field("priority", &self.priority)
            .field("deadline_ms", &self.deadline_ms)
            .field("job", &self.job_id())
            .field("chunks", &self.chunks)
            .finish()
    }
}

/// Generate the arrival sequence for a scenario.
pub fn generate(cfg: &LoadgenConfig) -> Vec<RequestSpec> {
    let base = Suite::synthetic(cfg.seed, cfg.synthetic_problems.max(1));
    // per-platform pools of supported problems (platform filters are
    // real: a synthetic problem tagged with an unsupported op family
    // never pairs with that platform)
    let pools: Vec<(PlatformRef, Vec<Problem>)> = registry()
        .platforms()
        .iter()
        .map(|p| {
            let supported: Vec<Problem> =
                base.supported_on(p.spec()).problems.iter().cloned().collect();
            (p.clone(), supported)
        })
        .filter(|(_, pool)| !pool.is_empty())
        .collect();
    assert!(!pools.is_empty(), "no platform supports any synthetic problem");

    let root = Pcg::new(cfg.seed, fnv1a(b"serve-loadgen"));
    let mut arrivals = root.fork("arrivals");
    let mut mix = root.fork("mix");
    // a dedicated stream for streaming decisions, so adding the request
    // kind leaves the arrival/mix draws (and every pre-existing golden
    // scenario) bit-identical
    let mut streaming = root.fork("streaming");
    let mut out = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    let mut in_burst = false;
    let mut phase_left = 0usize;
    for id in 0..cfg.requests {
        if phase_left == 0 {
            in_burst = !in_burst;
            phase_left = arrivals.range_i64(2, cfg.burst_len.max(2) as i64) as usize;
        }
        phase_left -= 1;
        let gap = if in_burst { cfg.burst_gap_ms } else { cfg.calm_gap_ms };
        // exponential inter-arrival with mean `gap`
        t += -gap * (1.0 - arrivals.uniform()).max(1e-12).ln();
        let (platform, pool) = &pools[mix.below(pools.len() as u32) as usize];
        let problem = mix.choose(pool).clone();
        let persona = mix.choose(PERSONAS);
        let (priority, deadline_ms) = if mix.chance(cfg.interactive_fraction) {
            (Priority::Interactive, Some(cfg.deadline_ms))
        } else {
            (Priority::Batch, None)
        };
        // whole-model problems may stream: chunk count derives from the
        // model's batch axis, so it is a property of the problem, not a
        // random draw
        let chunks = if problem.level == crate::workloads::Level::L4
            && streaming.chance(cfg.streaming_fraction)
        {
            let batch = problem
                .eval_graph
                .input_shapes
                .first()
                .map(|s| s.dim(0))
                .unwrap_or(1);
            batch.div_ceil(cfg.chunk_rows.max(1))
        } else {
            0
        };
        out.push(RequestSpec {
            id,
            at_ms: t,
            priority,
            deadline_ms,
            platform: platform.clone(),
            persona,
            problem,
            chunks,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let cfg = LoadgenConfig::new(0xFEED, 64);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.at_ms.to_bits(), y.at_ms.to_bits());
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.deadline_ms.map(f64::to_bits), y.deadline_ms.map(f64::to_bits));
            assert_eq!(x.job_id(), y.job_id());
            assert_eq!(x.chunks, y.chunks);
        }
        // a different seed reshapes the arrival process
        let c = generate(&LoadgenConfig::new(0xFEED + 1, 64));
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_ms.to_bits() != y.at_ms.to_bits()));
    }

    #[test]
    fn arrivals_are_ordered_and_bursty() {
        let reqs = generate(&LoadgenConfig::new(7, 128));
        for w in reqs.windows(2) {
            assert!(w[1].at_ms >= w[0].at_ms, "arrivals must be time-ordered");
        }
        let gaps: Vec<f64> = reqs.windows(2).map(|w| w[1].at_ms - w[0].at_ms).collect();
        let tight = gaps.iter().filter(|&&g| g < 2.0).count();
        let loose = gaps.iter().filter(|&&g| g > 4.0).count();
        assert!(tight > 10, "bursts missing: {tight} tight gaps");
        assert!(loose > 10, "calm phases missing: {loose} loose gaps");
    }

    #[test]
    fn platform_problem_pairings_are_supported() {
        let reqs = generate(&LoadgenConfig::new(11, 96));
        for r in &reqs {
            assert!(
                r.problem.supported_on(r.platform.spec()),
                "{} paired with unsupported problem {}",
                r.platform.name(),
                r.problem.id
            );
        }
        // the mix spans platforms and personas
        let platforms: std::collections::HashSet<&str> =
            reqs.iter().map(|r| r.platform.name()).collect();
        let personas: std::collections::HashSet<&str> =
            reqs.iter().map(|r| r.persona.name).collect();
        assert!(platforms.len() > 1, "only {platforms:?}");
        assert!(personas.len() > 2, "only {personas:?}");
    }

    #[test]
    fn streaming_rides_level4_requests_only() {
        use crate::workloads::Level;
        let reqs = generate(&LoadgenConfig::new(0x57, 256));
        let mut streamed = 0usize;
        let mut l4 = 0usize;
        for r in &reqs {
            if r.problem.level == Level::L4 {
                l4 += 1;
            }
            if r.chunks > 0 {
                streamed += 1;
                assert_eq!(r.problem.level, Level::L4, "req {} streams a non-L4 problem", r.id);
                // batch 8, chunk_rows 2 => 4 chunks for the default
                // synthetic model config
                assert_eq!(r.chunks, 4, "req {}", r.id);
            }
        }
        assert!(l4 > 0, "no level-4 requests drawn");
        assert!(streamed > 0, "streaming fraction never fired over {l4} L4 requests");
        assert!(streamed < l4, "every L4 request streamed — fraction ignored");

        // the streaming knob does not perturb arrivals or the mix
        let mut quiet = LoadgenConfig::new(0x57, 256);
        quiet.streaming_fraction = 0.0;
        let base = generate(&quiet);
        for (a, b) in reqs.iter().zip(&base) {
            assert_eq!(a.at_ms.to_bits(), b.at_ms.to_bits());
            assert_eq!(a.job_id(), b.job_id());
            assert_eq!(b.chunks, 0);
        }
    }

    #[test]
    fn deadlines_ride_interactive_requests_only() {
        let reqs = generate(&LoadgenConfig::new(13, 128));
        let mut interactive = 0;
        for r in &reqs {
            match r.priority {
                Priority::Interactive => {
                    interactive += 1;
                    assert_eq!(r.deadline_ms, Some(120.0));
                }
                Priority::Batch => assert_eq!(r.deadline_ms, None),
            }
        }
        assert!(interactive > 64, "interactive fraction off: {interactive}/128");
        assert!(interactive < 128, "batch class never drawn");
    }
}
