//! Admission control: load-shedding and per-request deadlines.
//!
//! A request is admitted or shed *at the door*, before it consumes
//! queue space — the service never blocks a producer and never lets
//! the queue grow past its bound.  Two shed triggers exist: the
//! physical queue capacity ([`ShedReason::QueueFull`]) and an optional
//! earlier policy threshold ([`ShedReason::DepthLimit`], for shedding
//! batch-shaped load before the queue is literally full).  Admitted
//! requests may still time out waiting: a consumer checks the
//! request's deadline at dequeue and resolves it as
//! [`Outcome::DeadlineExceeded`] without executing it.
//!
//! Every request resolves to exactly one typed [`Outcome`]; nothing
//! blocks indefinitely and nothing is silently dropped.

/// Admission policy over the current queue depth.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// The queue's physical bound.
    pub queue_capacity: usize,
    /// Shed once this many requests are already queued (≤ capacity;
    /// equal by default, i.e. shed only when the queue is full).
    pub shed_depth: usize,
}

impl AdmissionPolicy {
    pub fn new(queue_capacity: usize) -> AdmissionPolicy {
        AdmissionPolicy { queue_capacity, shed_depth: queue_capacity }
    }

    /// Decide admission for a request arriving at `depth` queued.
    pub fn decide(&self, depth: usize) -> Decision {
        if depth >= self.queue_capacity {
            Decision::Shed(ShedReason::QueueFull)
        } else if depth >= self.shed_depth {
            Decision::Shed(ShedReason::DepthLimit)
        } else {
            Decision::Admit
        }
    }
}

/// The admission verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Admit,
    Shed(ShedReason),
}

/// Why a request was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue is at its physical capacity.
    QueueFull,
    /// The policy's shed threshold (below capacity) was reached.
    DepthLimit,
    /// The service stopped accepting requests.
    Closed,
}

impl ShedReason {
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::DepthLimit => "depth_limit",
            ShedReason::Closed => "closed",
        }
    }
}

/// True when a request that has waited `waited_ms` has overrun its
/// deadline (requests without a deadline never expire).
pub fn deadline_expired(deadline_ms: Option<f64>, waited_ms: f64) -> bool {
    matches!(deadline_ms, Some(d) if waited_ms > d)
}

/// The typed resolution every request ends in.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Served: waited `queue_ms`, then executed for `service_ms`.
    Completed { queue_ms: f64, service_ms: f64 },
    /// Shed at admission; never entered the queue.
    Rejected { reason: ShedReason },
    /// Admitted, but its deadline passed before a worker reached it.
    DeadlineExceeded { waited_ms: f64 },
    /// The handler returned an error (CI gates this count to zero for
    /// synthetic traffic — synthesis jobs are infallible).
    Failed { error: String },
}

impl Outcome {
    /// End-to-end latency for completed requests (queue wait + service).
    pub fn latency_ms(&self) -> Option<f64> {
        match self {
            Outcome::Completed { queue_ms, service_ms } => Some(queue_ms + service_ms),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Outcome::Completed { .. } => "completed",
            Outcome::Rejected { .. } => "rejected",
            Outcome::DeadlineExceeded { .. } => "deadline_exceeded",
            Outcome::Failed { .. } => "failed",
        }
    }

    pub fn is_completed(&self) -> bool {
        matches!(self, Outcome::Completed { .. })
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, Outcome::Rejected { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_thresholds() {
        let p = AdmissionPolicy { queue_capacity: 8, shed_depth: 6 };
        assert_eq!(p.decide(0), Decision::Admit);
        assert_eq!(p.decide(5), Decision::Admit);
        assert_eq!(p.decide(6), Decision::Shed(ShedReason::DepthLimit));
        assert_eq!(p.decide(7), Decision::Shed(ShedReason::DepthLimit));
        assert_eq!(p.decide(8), Decision::Shed(ShedReason::QueueFull));
        assert_eq!(p.decide(100), Decision::Shed(ShedReason::QueueFull));
    }

    #[test]
    fn default_policy_sheds_only_at_capacity() {
        let p = AdmissionPolicy::new(4);
        assert_eq!(p.decide(3), Decision::Admit);
        assert_eq!(p.decide(4), Decision::Shed(ShedReason::QueueFull));
    }

    #[test]
    fn deadlines() {
        assert!(!deadline_expired(None, 1e9));
        assert!(!deadline_expired(Some(10.0), 10.0)); // exactly on time
        assert!(deadline_expired(Some(10.0), 10.001));
    }

    #[test]
    fn outcome_latency_and_labels() {
        let done = Outcome::Completed { queue_ms: 2.0, service_ms: 5.0 };
        assert_eq!(done.latency_ms(), Some(7.0));
        assert!(done.is_completed());
        assert_eq!(done.label(), "completed");
        let shed = Outcome::Rejected { reason: ShedReason::QueueFull };
        assert_eq!(shed.latency_ms(), None);
        assert!(shed.is_rejected());
        assert_eq!(shed.label(), "rejected");
        assert_eq!(Outcome::DeadlineExceeded { waited_ms: 3.0 }.label(), "deadline_exceeded");
        assert_eq!(Outcome::Failed { error: "x".into() }.label(), "failed");
    }
}
