//! Artifact-replay request planning for `kforge serve --artifacts`.
//!
//! The replay path cycles compiled artifacts through the PJRT runtime
//! via the [`super::Service`] front end.  Its request plan is derived
//! here — in particular the guard for the empty-registry case, which
//! previously reached `keys[i % keys.len()]` in `main.rs` and died on
//! a division by zero instead of explaining itself.

use crate::runtime::Registry;
use anyhow::{bail, Result};

/// The artifact keys a replay session cycles through, in manifest
/// order.  An empty registry is a usage error (the artifacts were
/// never built), reported as such rather than as a modulo panic.
pub fn replay_keys(registry: &Registry) -> Result<Vec<String>> {
    if registry.entries.is_empty() {
        bail!("no artifacts in {} (run `make artifacts`)", registry.root.display());
    }
    Ok(registry.entries.iter().map(|e| e.key.clone()).collect())
}

/// Round-robin assignment of request `i` to a key.  Total function on
/// any non-empty key list — `replay_keys` guarantees non-emptiness.
pub fn key_for_request(keys: &[String], i: usize) -> &str {
    &keys[i % keys.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    const EMPTY: &str = r#"{"version": 1, "entries": []}"#;
    const TWO: &str = r#"{
 "version": 1,
 "entries": [
  {"key": "a__naive__b1", "workload": "a", "variant": "naive", "batch": 1,
   "path": "a.hlo.txt", "inputs": [], "is_reference": true},
  {"key": "a__fast__b1", "workload": "a", "variant": "fast", "batch": 1,
   "path": "b.hlo.txt", "inputs": [], "is_reference": false}
 ]
}"#;

    #[test]
    fn empty_registry_is_a_usage_error_not_a_panic() {
        let reg = Registry::parse(EMPTY, PathBuf::from("/tmp/arts")).unwrap();
        let err = replay_keys(&reg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no artifacts in /tmp/arts"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn keys_cycle_in_manifest_order() {
        let reg = Registry::parse(TWO, PathBuf::from("/x")).unwrap();
        let keys = replay_keys(&reg).unwrap();
        assert_eq!(keys, vec!["a__naive__b1", "a__fast__b1"]);
        assert_eq!(key_for_request(&keys, 0), "a__naive__b1");
        assert_eq!(key_for_request(&keys, 1), "a__fast__b1");
        assert_eq!(key_for_request(&keys, 2), "a__naive__b1");
        assert_eq!(key_for_request(&keys, 5), "a__fast__b1");
    }
}
