//! Level 3: 50 architecture problems (KernelBench L3 analog).
//!
//! Includes the three Table-6 case-study architectures as
//! batch-parameterized constructors so the harness can sweep batch
//! sizes 8–128: `squeezenet_fire`, `mobilenetv2_block`, `mingpt_block`.

use super::spec::{Level, Problem};
use crate::kir::graph::{Graph, GraphBuilder, NodeId};
use crate::kir::op::{BinaryKind, Op, UnaryKind};
use crate::tensor::Shape;

fn conv_bias_relu(b: &mut GraphBuilder, x: NodeId, c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize) -> NodeId {
    let w = b.input(Shape::of(&[c_out, c_in, k, k]));
    let bias = b.input(Shape::of(&[1, c_out, 1, 1]));
    let cv = b.conv2d(x, w, stride, pad);
    let a = b.add(cv, bias);
    b.unary(UnaryKind::Relu, a)
}

/// SqueezeNet Fire module (§7.1 / Table 6): squeeze 1×1 → expand 1×1 ‖ 3×3.
pub fn squeezenet_fire(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("squeezenet_fire");
    let (c, hw, sq, ex) = (96usize, 55usize, 16usize, 64usize);
    let x = b.input(Shape::of(&[batch, c, hw, hw]));
    let s = conv_bias_relu(&mut b, x, c, sq, 1, 1, 0);
    let e1 = conv_bias_relu(&mut b, s, sq, ex, 1, 1, 0);
    let e3 = conv_bias_relu(&mut b, s, sq, ex, 3, 1, 1);
    let out = b.push(Op::Concat { inputs: vec![e1, e3], axis: 1 });
    b.finish(vec![out])
}

fn fire_small(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("fire_small");
    let x = b.input(Shape::of(&[batch, 4, 8, 8]));
    let s = conv_bias_relu(&mut b, x, 4, 2, 1, 1, 0);
    let e1 = conv_bias_relu(&mut b, s, 2, 4, 1, 1, 0);
    let e3 = conv_bias_relu(&mut b, s, 2, 4, 3, 1, 1);
    let out = b.push(Op::Concat { inputs: vec![e1, e3], axis: 1 });
    b.finish(vec![out])
}

/// MobileNetV2 inverted residual (Table 6): expand 1×1 → depthwise 3×3
/// → project 1×1 → residual add.
pub fn mobilenetv2_block(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("mobilenetv2_block");
    let (c, hw, t) = (32usize, 28usize, 6usize);
    let x = b.input(Shape::of(&[batch, c, hw, hw]));
    let h = conv_bias_relu(&mut b, x, c, c * t, 1, 1, 0);
    let dw_w = b.input(Shape::of(&[c * t, 1, 3, 3]));
    let dw = b.push(Op::DepthwiseConv2d { input: h, weight: dw_w, stride: 1, padding: 1 });
    let dwr = b.unary(UnaryKind::Relu, dw);
    let pw = b.input(Shape::of(&[c, c * t, 1, 1]));
    let proj = b.conv2d(dwr, pw, 1, 0);
    let out = b.add(proj, x);
    b.finish(vec![out])
}

fn mbv2_small(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("mbv2_small");
    let (c, hw, t) = (4usize, 8usize, 2usize);
    let x = b.input(Shape::of(&[batch, c, hw, hw]));
    let h = conv_bias_relu(&mut b, x, c, c * t, 1, 1, 0);
    let dw_w = b.input(Shape::of(&[c * t, 1, 3, 3]));
    let dw = b.push(Op::DepthwiseConv2d { input: h, weight: dw_w, stride: 1, padding: 1 });
    let dwr = b.unary(UnaryKind::Relu, dw);
    let pw = b.input(Shape::of(&[c, c * t, 1, 1]));
    let proj = b.conv2d(dwr, pw, 1, 0);
    let out = b.add(proj, x);
    b.finish(vec![out])
}

fn transformer_block_inner(b: &mut GraphBuilder, x0: NodeId, s: usize, d: usize, f: usize) -> NodeId {
    let g1 = b.input(Shape::of(&[d]));
    let be1 = b.input(Shape::of(&[d]));
    let h = b.push(Op::Layernorm { input: x0, gamma: g1, beta: be1 });
    let wq = b.input(Shape::of(&[d, d]));
    let wk = b.input(Shape::of(&[d, d]));
    let wv = b.input(Shape::of(&[d, d]));
    let wo = b.input(Shape::of(&[d, d]));
    let q = b.matmul(h, wq);
    let k = b.matmul(h, wk);
    let v = b.matmul(h, wv);
    let at = b.push(Op::Attention { q, k, v });
    let o = b.matmul(at, wo);
    let x1 = b.add(x0, o);
    let g2 = b.input(Shape::of(&[d]));
    let be2 = b.input(Shape::of(&[d]));
    let h2 = b.push(Op::Layernorm { input: x1, gamma: g2, beta: be2 });
    let w1 = b.input(Shape::of(&[d, f]));
    let bb1 = b.input(Shape::of(&[f]));
    let m1 = b.matmul(h2, w1);
    let a1 = b.add(m1, bb1);
    let gl = b.unary(UnaryKind::Gelu, a1);
    let w2 = b.input(Shape::of(&[f, d]));
    let bb2 = b.input(Shape::of(&[d]));
    let m2 = b.matmul(gl, w2);
    let a2 = b.add(m2, bb2);
    let _ = s;
    b.add(x1, a2)
}

/// MinGPT block (Table 6): LN → attention → residual → LN → MLP →
/// residual.  `batch` scales the sequence length (tokens processed).
pub fn mingpt_block(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("mingpt_block");
    let (s, d, f) = (8 * batch, 384usize, 1536usize);
    let x = b.input(Shape::of(&[s, d]));
    let out = transformer_block_inner(&mut b, x, s, d, f);
    b.finish(vec![out])
}

fn mingpt_small(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("mingpt_small");
    let (s, d, f) = (4 * batch.max(1), 16usize, 32usize);
    let x = b.input(Shape::of(&[s, d]));
    let out = transformer_block_inner(&mut b, x, s, d, f);
    b.finish(vec![out])
}

fn mlp_stack(name: &str, m: usize, dims: &[usize], act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[m, dims[0]]));
    for w in dims.windows(2) {
        let wt = b.input(Shape::of(&[w[0], w[1]]));
        let bias = b.input(Shape::of(&[w[1]]));
        let mm = b.matmul(x, wt);
        let a = b.add(mm, bias);
        x = b.unary(act, a);
    }
    b.finish(vec![x])
}

fn vgg_stage(name: &str, batch: usize, c_in: usize, c_out: usize, hw: usize, convs: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[batch, c_in, hw, hw]));
    let mut c = c_in;
    for _ in 0..convs {
        x = conv_bias_relu(&mut b, x, c, c_out, 3, 1, 1);
        c = c_out;
    }
    let p = b.push(Op::MaxPool2d { input: x, k: 2, stride: 2 });
    b.finish(vec![p])
}

fn attention_stack(name: &str, s: usize, d: usize, layers: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[s, d]));
    for _ in 0..layers {
        let wq = b.input(Shape::of(&[d, d]));
        let wk = b.input(Shape::of(&[d, d]));
        let wv = b.input(Shape::of(&[d, d]));
        let q = b.matmul(x, wq);
        let k = b.matmul(x, wk);
        let v = b.matmul(x, wv);
        let at = b.push(Op::Attention { q, k, v });
        x = b.add(at, x);
    }
    b.finish(vec![x])
}

fn alexnet_head(name: &str, batch: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[batch, 3, 64, 64]));
    let c1 = conv_bias_relu(&mut b, x, 3, 16, 5, 2, 2);
    let p1 = b.push(Op::MaxPool2d { input: c1, k: 2, stride: 2 });
    let c2 = conv_bias_relu(&mut b, p1, 16, 32, 3, 1, 1);
    let p2 = b.push(Op::MaxPool2d { input: c2, k: 2, stride: 2 });
    let g = b.push(Op::GlobalAvgPool { input: p2 });
    let r = b.push(Op::Reshape { input: g, shape: Shape::of(&[batch, 32]) });
    let w = b.input(Shape::of(&[32, 10]));
    let out = b.matmul(r, w);
    b.finish(vec![out])
}

fn residual_mlp(name: &str, m: usize, d: usize, layers: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[m, d]));
    for _ in 0..layers {
        let w = b.input(Shape::of(&[d, d]));
        let bias = b.input(Shape::of(&[d]));
        let mm = b.matmul(x, w);
        let a = b.add(mm, bias);
        let g = b.unary(UnaryKind::Gelu, a);
        x = b.binary(BinaryKind::Add, g, x);
    }
    b.finish(vec![x])
}

struct Def {
    id: String,
    eval: Graph,
    perf: Graph,
    families: Vec<&'static str>,
}

/// All 50 Level-3 problems.
pub fn problems() -> Vec<Problem> {
    let mut defs: Vec<Def> = Vec::with_capacity(50);

    // -- the three Table-6 architectures (ids match the case study) -----
    defs.push(Def {
        id: "l3_020_mobilenetv2".into(),
        eval: mbv2_small(1),
        perf: mobilenetv2_block(16),
        families: vec!["conv2d", "dwconv2d"],
    });
    defs.push(Def {
        id: "l3_043_mingpt".into(),
        eval: mingpt_small(1),
        perf: mingpt_block(16),
        families: vec!["matmul", "attention", "layernorm", "gelu"],
    });
    defs.push(Def {
        id: "l3_squeezenet_fire".into(),
        eval: fire_small(1),
        perf: squeezenet_fire(16),
        families: vec!["conv2d", "concat"],
    });

    // -- fire variants: 4 more ------------------------------------------
    for (i, batch) in [8usize, 32, 64, 128].iter().enumerate() {
        let id = format!("l3_fire_b{batch}");
        let _ = i;
        defs.push(Def {
            eval: fire_small(1),
            perf: squeezenet_fire(*batch),
            id,
            families: vec!["conv2d", "concat"],
        });
    }

    // -- mobilenet variants: 4 more ----------------------------------------
    for batch in [8usize, 32, 64, 128] {
        let id = format!("l3_mbv2_b{batch}");
        defs.push(Def {
            eval: mbv2_small(1),
            perf: mobilenetv2_block(batch),
            id,
            families: vec!["conv2d", "dwconv2d"],
        });
    }

    // -- mingpt variants: 4 more ---------------------------------------------
    for batch in [8usize, 32, 64, 128] {
        let id = format!("l3_mingpt_b{batch}");
        defs.push(Def {
            eval: mingpt_small(1),
            perf: mingpt_block(batch),
            id,
            families: vec!["matmul", "attention", "layernorm", "gelu"],
        });
    }

    // -- MLP stacks: 8 ----------------------------------------------------------
    let mlp_cfgs: [(&[usize], UnaryKind, &'static str); 8] = [
        (&[784, 512, 256, 10], UnaryKind::Relu, "relu"),
        (&[784, 1024, 1024, 10], UnaryKind::Gelu, "gelu"),
        (&[256, 256, 256, 256, 256], UnaryKind::Swish, "swish"),
        (&[512, 2048, 512], UnaryKind::Relu, "relu"),
        (&[1024, 4096, 1024], UnaryKind::Gelu, "gelu"),
        (&[128, 128, 128, 128, 128, 128], UnaryKind::Tanh, "tanh"),
        (&[2048, 512, 128, 32], UnaryKind::Relu, "relu"),
        (&[64, 1024, 64], UnaryKind::Sigmoid, "sigmoid"),
    ];
    for (i, (dims, act, an)) in mlp_cfgs.iter().enumerate() {
        let id = format!("l3_mlp_{i:02}");
        let small: Vec<usize> = dims.iter().map(|d| (*d / 32).clamp(4, 16)).collect();
        defs.push(Def {
            eval: mlp_stack(&id, 4, &small, *act),
            perf: mlp_stack(&id, 16, dims, *act),
            id,
            families: vec!["matmul", an],
        });
    }

    // -- VGG-ish conv stages: 10 ---------------------------------------------------
    let vgg_cfgs = [
        (16usize, 3usize, 32usize, 32usize, 2usize),
        (16, 32, 64, 16, 2),
        (16, 64, 128, 8, 3),
        (8, 3, 64, 64, 2),
        (8, 64, 128, 32, 2),
        (8, 128, 256, 16, 3),
        (32, 3, 16, 32, 2),
        (32, 16, 32, 16, 2),
        (4, 128, 256, 28, 3),
        (4, 256, 512, 14, 3),
    ];
    for (i, (n, ci, co, hw, convs)) in vgg_cfgs.iter().enumerate() {
        let id = format!("l3_vgg_{i:02}");
        defs.push(Def {
            eval: vgg_stage(&id, 1, 3, 4, 8, 2),
            perf: vgg_stage(&id, *n, *ci, *co, *hw, *convs),
            id,
            families: vec!["conv2d", "maxpool2d"],
        });
    }

    // -- attention stacks: 5 -----------------------------------------------------------
    for (i, (s, d, layers)) in [
        (128usize, 256usize, 2usize),
        (256, 384, 2),
        (512, 256, 3),
        (64, 512, 4),
        (1024, 128, 2),
    ]
    .iter()
    .enumerate()
    {
        let id = format!("l3_attnstack_{i:02}");
        defs.push(Def {
            eval: attention_stack(&id, 8, 16, 2),
            perf: attention_stack(&id, *s, *d, *layers),
            id,
            families: vec!["matmul", "attention"],
        });
    }

    // -- AlexNet-ish heads: 4 -------------------------------------------------------------
    for batch in [4usize, 16, 32, 64] {
        let id = format!("l3_alexnet_b{batch}");
        defs.push(Def {
            eval: alexnet_head(&id, 1),
            perf: alexnet_head(&id, batch),
            id,
            families: vec!["conv2d", "maxpool2d", "matmul"],
        });
    }

    // -- residual MLPs: 8 -------------------------------------------------------------------
    for (i, (m, d, layers)) in [
        (16usize, 512usize, 4usize),
        (64, 256, 6),
        (16, 1024, 3),
        (128, 128, 8),
        (32, 768, 4),
        (16, 256, 12),
        (8, 2048, 2),
        (256, 64, 10),
    ]
    .iter()
    .enumerate()
    {
        let id = format!("l3_resmlp_{i:02}");
        defs.push(Def {
            eval: residual_mlp(&id, 4, 16, 2),
            perf: residual_mlp(&id, *m, *d, *layers),
            id,
            families: vec!["matmul", "gelu"],
        });
    }

    assert_eq!(defs.len(), 50, "level 3 must have exactly 50 problems, got {}", defs.len());
    defs.into_iter()
        .map(|d| Problem {
            id: d.id,
            level: Level::L3,
            eval_graph: d.eval,
            perf_graph: d.perf,
            op_families: d.families,
            constant_output: false,
            reducible: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp::eval;
    use crate::kir::validate::validate;
    use crate::platform::metal;

    #[test]
    fn exactly_50_problems() {
        assert_eq!(problems().len(), 50);
    }

    #[test]
    fn all_supported_on_metal() {
        // Table 2: all 50 L3 problems remain in KernelBench-Metal
        let m = metal::m4_max();
        assert!(problems().iter().all(|p| p.supported_on(&m)));
    }

    #[test]
    fn all_graphs_validate_and_run() {
        for p in problems() {
            validate(&p.eval_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            validate(&p.perf_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            let ins = p.eval_inputs(0);
            eval(&p.eval_graph, &ins).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        }
    }

    #[test]
    fn table6_ctors_scale_with_batch() {
        let f8 = squeezenet_fire(8);
        let f128 = squeezenet_fire(128);
        assert!(f128.total_flops() > 10.0 * f8.total_flops());
        let m8 = mingpt_block(8);
        let m128 = mingpt_block(128);
        assert!(m128.total_flops() > 10.0 * m8.total_flops());
    }

    #[test]
    fn deep_graphs_have_many_ops() {
        // L3 problems must be architecture-scale (many launches eager)
        for p in problems() {
            assert!(p.perf_graph.len() >= 8, "{} too small", p.id);
        }
    }
}
