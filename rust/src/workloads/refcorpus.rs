//! The CUDA reference corpus (§6.2).
//!
//! The paper reuses correct CUDA programs from KernelBench-samples
//! (12,600 programs over 245 tasks) and, for reproducibility, picks the
//! *first correct implementation per task* as the Metal-transfer
//! reference.  Our corpus has the same provenance: it is built by
//! running a CUDA synthesis campaign and retaining, per problem, the
//! first correct program.

use crate::agents::{GenerationAgent, Program};
use crate::platform::cuda;
use crate::util::rng::Pcg;
use crate::verify;
use crate::workloads::Suite;
use std::collections::HashMap;

/// The reference corpus: problem id → first correct CUDA program.
#[derive(Debug, Clone, Default)]
pub struct RefCorpus {
    pub programs: HashMap<String, Program>,
}

impl RefCorpus {
    /// Build by running `attempts_per_problem` CUDA generations per
    /// problem with a strong persona and keeping the first correct one.
    pub fn build(suite: &Suite, attempts_per_problem: usize, seed: u64) -> RefCorpus {
        let spec = cuda::h100();
        let persona = crate::agents::persona::by_name("openai-gpt-5").unwrap();
        let agent =
            GenerationAgent::new(persona, crate::platform::by_name("cuda").expect("builtin cuda"));
        let mut programs = HashMap::new();
        for problem in suite.problems.iter() {
            let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(problem.id.as_bytes()));
            for _ in 0..attempts_per_problem {
                let Some(prog) = agent.synthesize(problem, None, &mut rng) else {
                    continue;
                };
                let out = verify::verify(&spec, problem, Some(&prog), &mut rng);
                if out.state.is_correct() {
                    programs.insert(problem.id.clone(), prog);
                    break;
                }
            }
        }
        RefCorpus { programs }
    }

    pub fn get(&self, problem_id: &str) -> Option<&Program> {
        self.programs.get(problem_id)
    }

    pub fn coverage(&self, suite: &Suite) -> f64 {
        let covered = suite
            .problems
            .iter()
            .filter(|p| self.programs.contains_key(&p.id))
            .count();
        covered as f64 / suite.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_builds_with_good_coverage() {
        let suite = Suite::sample(4);
        let corpus = RefCorpus::build(&suite, 6, 7);
        // gpt-5 with 6 attempts covers most problems
        assert!(corpus.coverage(&suite) > 0.7, "coverage {}", corpus.coverage(&suite));
    }

    #[test]
    fn corpus_programs_are_cuda_correct() {
        let suite = Suite::sample(2);
        let corpus = RefCorpus::build(&suite, 6, 7);
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        for (id, prog) in &corpus.programs {
            let p = suite.problems.iter().find(|p| &p.id == id).unwrap();
            let out = verify::verify(&spec, p, Some(prog), &mut rng);
            assert!(out.state.is_correct(), "{id}: {:?}", out.state);
        }
    }

    #[test]
    fn corpus_deterministic() {
        let suite = Suite::sample(2);
        let a = RefCorpus::build(&suite, 3, 9);
        let b = RefCorpus::build(&suite, 3, 9);
        assert_eq!(a.programs.len(), b.programs.len());
        for (k, v) in &a.programs {
            assert_eq!(b.programs[k].schedule, v.schedule);
        }
    }
}
