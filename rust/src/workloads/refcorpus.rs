//! The CUDA reference corpus (§6.2).
//!
//! The paper reuses correct CUDA programs from KernelBench-samples
//! (12,600 programs over 245 tasks) and, for reproducibility, picks the
//! *first correct implementation per task* as the Metal-transfer
//! reference.  Our corpus has the same provenance: it is built by
//! running a CUDA synthesis campaign and retaining, per problem, the
//! first correct program.

use crate::agents::{GenerationAgent, Program};
use crate::platform::cuda;
use crate::util::rng::Pcg;
use crate::verify;
use crate::workloads::Suite;
use std::collections::HashMap;

/// The reference corpus: problem id → first correct CUDA program.
#[derive(Debug, Clone, Default)]
pub struct RefCorpus {
    pub programs: HashMap<String, Program>,
}

impl RefCorpus {
    /// Build by running `attempts_per_problem` CUDA generations per
    /// problem with a strong persona and keeping the first correct one.
    pub fn build(suite: &Suite, attempts_per_problem: usize, seed: u64) -> RefCorpus {
        let spec = cuda::h100();
        let persona = crate::agents::persona::by_name("openai-gpt-5").unwrap();
        let agent =
            GenerationAgent::new(persona, crate::platform::by_name("cuda").expect("builtin cuda"));
        let mut programs = HashMap::new();
        for problem in suite.problems.iter() {
            let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(problem.id.as_bytes()));
            for _ in 0..attempts_per_problem {
                let Some(prog) = agent.synthesize(problem, None, &mut rng) else {
                    continue;
                };
                let out = verify::verify(&spec, problem, Some(&prog), &mut rng);
                if out.state.is_correct() {
                    programs.insert(problem.id.clone(), prog);
                    break;
                }
            }
        }
        RefCorpus { programs }
    }

    pub fn get(&self, problem_id: &str) -> Option<&Program> {
        self.programs.get(problem_id)
    }

    pub fn coverage(&self, suite: &Suite) -> f64 {
        let covered = suite
            .problems
            .iter()
            .filter(|p| self.programs.contains_key(&p.id))
            .count();
        covered as f64 / suite.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_campaign, BaselineKind, CampaignResult, ExperimentConfig};

    /// Single-shot config; the *same* name and seed for the with- and
    /// without-reference runs so each (persona, problem) job draws the
    /// identical RNG stream in both (see `experiment::run_task`).
    fn single_shot_cfg(platform: &str, use_reference: bool) -> ExperimentConfig {
        ExperimentConfig {
            name: "refcorpus_transfer_prop".into(),
            platform: crate::platform::by_name(platform).unwrap(),
            personas: vec![crate::agents::persona::by_name("claude-opus-4").unwrap()],
            iterations: 1,
            use_profiling: false,
            use_reference,
            baseline: BaselineKind::Eager,
            seed: 0x6_2,
            workers: 4,
        }
    }

    fn assert_results_identical(a: &CampaignResult, b: &CampaignResult) {
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.problem_id, y.problem_id);
            assert_eq!(x.state_history, y.state_history);
            assert_eq!(x.outcome.correct, y.outcome.correct);
            assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits());
            assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits());
        }
    }

    #[test]
    fn cuda_reference_never_lowers_single_shot_on_transfer_platforms() {
        // §6.2 for the big-gainer persona (claude-opus-4, ref_effect <
        // 1 at every level): with aligned RNG streams, a job's
        // correctness draw compares the same uniform against p_base vs
        // p_ref ≥ p_base, so the with-reference run can never flip a
        // correct job to incorrect — per job, not just on average —
        // and that must hold on every transfer platform
        let suite = Suite::sample(8); // 24 problems
        let corpus = RefCorpus::build(&suite, 6, 0xC0DE);
        assert!(corpus.coverage(&suite) > 0.5);
        for platform in ["metal", "rocm"] {
            assert!(
                crate::platform::by_name(platform).unwrap().reference_transfer(),
                "{platform} should treat the CUDA corpus as cross-platform transfer"
            );
            let without = run_campaign(&suite, None, &single_shot_cfg(platform, false));
            let with = run_campaign(&suite, Some(&corpus), &single_shot_cfg(platform, true));
            assert_eq!(without.results.len(), with.results.len());
            for (base, refd) in without.results.iter().zip(&with.results) {
                assert_eq!(base.problem_id, refd.problem_id);
                assert!(
                    !(base.outcome.correct && !refd.outcome.correct),
                    "{platform}/{}: CUDA reference lowered single-shot correctness",
                    base.problem_id
                );
                // a problem the corpus does not cover must be untouched
                if corpus.get(&base.problem_id).is_none() {
                    assert_eq!(base.state_history, refd.state_history, "{}", base.problem_id);
                    assert_eq!(base.outcome.correct, refd.outcome.correct);
                }
            }
            let rate = |c: &CampaignResult| {
                crate::metrics::correctness_rate(
                    &c.results.iter().map(|r| r.outcome).collect::<Vec<_>>(),
                )
            };
            assert!(
                rate(&with) >= rate(&without),
                "{platform}: with-ref rate {} below baseline {}",
                rate(&with),
                rate(&without)
            );
        }
    }

    #[test]
    fn corpus_get_misses_fall_back_cleanly() {
        // an empty corpus with use_reference on must be bit-identical
        // to no corpus at all: every `get` miss falls through to the
        // reference-free synthesis path
        let suite = Suite::sample(4);
        let empty = RefCorpus::default();
        assert!(empty.get("l1_act_swish_0").is_none());
        assert_eq!(empty.coverage(&suite), 0.0);
        let without = run_campaign(&suite, None, &single_shot_cfg("metal", false));
        let with_empty = run_campaign(&suite, Some(&empty), &single_shot_cfg("metal", true));
        assert_results_identical(&without, &with_empty);
        // and use_reference without any corpus handle at all is the
        // same degenerate path
        let with_none = run_campaign(&suite, None, &single_shot_cfg("metal", true));
        assert_results_identical(&without, &with_none);
    }

    #[test]
    fn corpus_builds_with_good_coverage() {
        let suite = Suite::sample(4);
        let corpus = RefCorpus::build(&suite, 6, 7);
        // gpt-5 with 6 attempts covers most problems
        assert!(corpus.coverage(&suite) > 0.7, "coverage {}", corpus.coverage(&suite));
    }

    #[test]
    fn corpus_programs_are_cuda_correct() {
        let suite = Suite::sample(2);
        let corpus = RefCorpus::build(&suite, 6, 7);
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        for (id, prog) in &corpus.programs {
            let p = suite.problems.iter().find(|p| &p.id == id).unwrap();
            let out = verify::verify(&spec, p, Some(prog), &mut rng);
            assert!(out.state.is_correct(), "{id}: {:?}", out.state);
        }
    }

    #[test]
    fn corpus_deterministic() {
        let suite = Suite::sample(2);
        let a = RefCorpus::build(&suite, 3, 9);
        let b = RefCorpus::build(&suite, 3, 9);
        assert_eq!(a.programs.len(), b.programs.len());
        for (k, v) in &a.programs {
            assert_eq!(b.programs[k].schedule, v.schedule);
        }
    }
}
