//! The assembled suite: 250 kernel problems plus the level-4
//! whole-model tier, Metal filtering, Table-2 counts.

use super::spec::{Level, Problem};
use super::{level1, level2, level3, level4};
use crate::platform::PlatformSpec;
use std::sync::{Arc, OnceLock};

/// The full suite (constructed once; problems are immutable).
#[derive(Debug, Clone)]
pub struct Suite {
    pub problems: Arc<Vec<Problem>>,
}

fn full_suite() -> &'static Arc<Vec<Problem>> {
    static SUITE: OnceLock<Arc<Vec<Problem>>> = OnceLock::new();
    SUITE.get_or_init(|| {
        let mut ps = level1::problems();
        ps.extend(level2::problems());
        ps.extend(level3::problems());
        ps.extend(level4::problems());
        Arc::new(ps)
    })
}

impl Suite {
    /// The full KernelBench-KIR suite (cached): 250 kernel problems
    /// (L1–L3) plus the level-4 whole-model tier.
    pub fn full() -> Suite {
        Suite {
            problems: full_suite().clone(),
        }
    }

    /// An unbounded synthetic suite: `n` deterministic fuzz-generated
    /// problems from `seed` (see [`super::synth`]).  Not cached — every
    /// `(seed, n)` pair is a fresh suite, opening scenario diversity
    /// beyond the fixed L1–L3 levels.
    pub fn synthetic(seed: u64, n: usize) -> Suite {
        Suite {
            problems: Arc::new(super::synth::problems(seed, n)),
        }
    }

    /// A deterministic subset (first `n` of each level) for fast tests.
    pub fn sample(per_level: usize) -> Suite {
        let full = Suite::full();
        let mut out = Vec::new();
        for level in Level::ALL {
            out.extend(
                full.problems
                    .iter()
                    .filter(|p| p.level == level)
                    .take(per_level)
                    .cloned(),
            );
        }
        Suite {
            problems: Arc::new(out),
        }
    }

    pub fn len(&self) -> usize {
        self.problems.len()
    }

    pub fn is_empty(&self) -> bool {
        self.problems.is_empty()
    }

    pub fn by_level(&self, level: Level) -> Vec<&Problem> {
        self.problems.iter().filter(|p| p.level == level).collect()
    }

    /// Problems runnable on a platform (Metal drops 30 → 220).
    pub fn supported_on(&self, spec: &PlatformSpec) -> Suite {
        Suite {
            problems: Arc::new(
                self.problems
                    .iter()
                    .filter(|p| p.supported_on(spec))
                    .cloned()
                    .collect(),
            ),
        }
    }

    /// Per-level counts aligned with [`Level::ALL`] — the Table 2 row.
    pub fn distribution(&self) -> Vec<usize> {
        Level::ALL.iter().map(|&l| self.by_level(l).len()).collect()
    }

    pub fn get(&self, id: &str) -> Option<&Problem> {
        self.problems.iter().find(|p| p.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{cuda, metal};

    #[test]
    fn table2_distribution() {
        let full = Suite::full();
        assert_eq!(full.distribution(), vec![100, 100, 50, 8]);
        let metal_suite = full.supported_on(&metal::m4_max());
        // level-4 models stitch only universally supported kernel
        // families, so every platform keeps the whole tier
        assert_eq!(metal_suite.distribution(), vec![91, 79, 50, 8]);
        assert_eq!(metal_suite.len(), 228);
        assert_eq!(full.supported_on(&cuda::h100()).len(), 258);
        // rocm excludes only its transposed-3D-conv family: strictly
        // between the Metal subset and the full suite
        let rocm_len = full.supported_on(&crate::platform::rocm::mi300x()).len();
        assert!(rocm_len > 228 && rocm_len < 258, "rocm suite: {rocm_len}");
    }

    #[test]
    fn sample_subsets() {
        let s = Suite::sample(3);
        assert_eq!(s.len(), 3 * Level::ALL.len());
        for level in Level::ALL {
            assert_eq!(s.by_level(level).len(), 3, "{}", level.tag());
        }
    }

    #[test]
    fn lookup_by_id() {
        let s = Suite::full();
        assert!(s.get("l3_043_mingpt").is_some());
        assert!(s.get("nonexistent").is_none());
    }

    #[test]
    fn synthetic_suite_is_deterministic_and_filterable() {
        let a = Suite::synthetic(0x5EED, 15);
        let b = Suite::synthetic(0x5EED, 15);
        assert_eq!(a.len(), 15);
        for (x, y) in a.problems.iter().zip(b.problems.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.eval_graph, y.eval_graph);
        }
        // platforms with unsupported ops must filter something out of a
        // tagged synthetic suite; platforms without keep everything
        for p in crate::platform::registry().platforms() {
            let kept = a.supported_on(p.spec()).len();
            if p.spec().unsupported_ops.is_empty() {
                assert_eq!(kept, a.len(), "{} filtered a fully supported suite", p.name());
            } else {
                assert!(kept < a.len(), "{} filter never exercised", p.name());
                assert!(kept > 0, "{} filtered everything", p.name());
            }
        }
    }

    #[test]
    fn full_is_cached() {
        let a = Suite::full();
        let b = Suite::full();
        assert!(Arc::ptr_eq(&a.problems, &b.problems));
    }
}
