//! Problem specification.

use crate::kir::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// KernelBench difficulty level, extended with the whole-model tier.
///
/// The level set is a *registry*: everything that iterates or labels
/// levels derives from [`Level::ALL`] / [`Level::tag`] / [`Level::index`]
/// rather than hand-written `1..=3` ranges, so adding a tier is a local
/// edit here plus the tier's own module — not a scatter of match arms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    L3,
    /// Whole-model workloads: multi-kernel DAGs stitched from L1–L3
    /// kernels (see `crate::model` and [`super::level4`]).
    L4,
}

impl Level {
    pub const ALL: [Level; 4] = [Level::L1, Level::L2, Level::L3, Level::L4];

    /// Number of registered levels (`ALL.len()` usable in const context).
    pub const COUNT: usize = Level::ALL.len();

    /// Position in [`Level::ALL`] — the canonical index for per-level
    /// tables (`[T; Level::COUNT]`).
    pub fn index(&self) -> usize {
        *self as usize
    }

    /// Calibration bucket for the paper-derived per-level rate tables,
    /// which are measured for L1–L3 only.  L4 has no published priors;
    /// whole-model jobs clamp to the hardest measured bucket (L3).
    pub fn calibration_bucket(&self) -> usize {
        self.index().min(2)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Level::L1 => "Level 1",
            Level::L2 => "Level 2",
            Level::L3 => "Level 3",
            Level::L4 => "Level 4",
        }
    }

    /// Short stable tag ("L1".."L4") — used in store serialization,
    /// census lines, and CLI `--level` filters.
    pub fn tag(&self) -> &'static str {
        match self {
            Level::L1 => "L1",
            Level::L2 => "L2",
            Level::L3 => "L3",
            Level::L4 => "L4",
        }
    }

    /// Inverse of [`Level::tag`]; also accepts the bare digit ("4").
    pub fn from_tag(tag: &str) -> Option<Level> {
        Level::ALL
            .iter()
            .copied()
            .find(|l| l.tag() == tag || l.tag()[1..] == *tag)
    }
}

/// One benchmark problem.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Stable id, e.g. "l1_025_swish".
    pub id: String,
    pub level: Level,
    /// Reference graph at evaluation (small) shapes — numerics ground
    /// truth runs here.
    pub eval_graph: Graph,
    /// Reference graph at paper-scale shapes — the simulator prices
    /// this one (batch sizes etc. match the paper's regime).
    pub perf_graph: Graph,
    /// Op families used (Metal-support filtering).
    pub op_families: Vec<&'static str>,
    /// True if the problem's output is input-independent (§7.3 class).
    pub constant_output: bool,
    /// True if the §7.4 algebraic reduction applies.
    pub reducible: bool,
}

impl Problem {
    /// Seeded evaluation inputs for the numerics check.
    pub fn eval_inputs(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(self.id.as_bytes()));
        self.eval_graph
            .input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.5))
            .collect()
    }

    /// Is this problem runnable on a platform (all op families present)?
    pub fn supported_on(&self, spec: &crate::platform::PlatformSpec) -> bool {
        self.op_families.iter().all(|f| spec.supports(f))
    }
}

/// Helper: batch-parameterized problem constructor used by the levels.
pub type ProblemCtor = fn(batch: usize) -> Graph;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::tensor::Shape;

    fn trivial(name: &str) -> Graph {
        let mut b = GraphBuilder::new(name);
        let x = b.input(Shape::of(&[4]));
        let r = b.unary(crate::kir::op::UnaryKind::Relu, x);
        b.finish(vec![r])
    }

    #[test]
    fn eval_inputs_deterministic_per_problem() {
        let p = Problem {
            id: "t".into(),
            level: Level::L1,
            eval_graph: trivial("t"),
            perf_graph: trivial("t"),
            op_families: vec!["relu"],
            constant_output: false,
            reducible: false,
        };
        assert_eq!(p.eval_inputs(1), p.eval_inputs(1));
        assert_ne!(p.eval_inputs(1)[0].data, p.eval_inputs(2)[0].data);
    }

    #[test]
    fn level_registry_round_trips() {
        assert_eq!(Level::ALL.len(), Level::COUNT);
        for (i, level) in Level::ALL.iter().enumerate() {
            assert_eq!(level.index(), i);
            assert_eq!(Level::from_tag(level.tag()), Some(*level));
            assert_eq!(Level::from_tag(&level.tag()[1..]), Some(*level));
        }
        assert_eq!(Level::from_tag("L9"), None);
        assert_eq!(Level::L4.calibration_bucket(), Level::L3.calibration_bucket());
        assert_eq!(Level::L1.calibration_bucket(), 0);
    }

    #[test]
    fn different_problems_different_inputs() {
        let mk = |id: &str| Problem {
            id: id.into(),
            level: Level::L1,
            eval_graph: trivial(id),
            perf_graph: trivial(id),
            op_families: vec![],
            constant_output: false,
            reducible: false,
        };
        assert_ne!(mk("a").eval_inputs(1)[0].data, mk("b").eval_inputs(1)[0].data);
    }
}
