//! Problem specification.

use crate::kir::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// KernelBench difficulty level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    L3,
}

impl Level {
    pub const ALL: [Level; 3] = [Level::L1, Level::L2, Level::L3];

    pub fn name(&self) -> &'static str {
        match self {
            Level::L1 => "Level 1",
            Level::L2 => "Level 2",
            Level::L3 => "Level 3",
        }
    }
}

/// One benchmark problem.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Stable id, e.g. "l1_025_swish".
    pub id: String,
    pub level: Level,
    /// Reference graph at evaluation (small) shapes — numerics ground
    /// truth runs here.
    pub eval_graph: Graph,
    /// Reference graph at paper-scale shapes — the simulator prices
    /// this one (batch sizes etc. match the paper's regime).
    pub perf_graph: Graph,
    /// Op families used (Metal-support filtering).
    pub op_families: Vec<&'static str>,
    /// True if the problem's output is input-independent (§7.3 class).
    pub constant_output: bool,
    /// True if the §7.4 algebraic reduction applies.
    pub reducible: bool,
}

impl Problem {
    /// Seeded evaluation inputs for the numerics check.
    pub fn eval_inputs(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(self.id.as_bytes()));
        self.eval_graph
            .input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.5))
            .collect()
    }

    /// Is this problem runnable on a platform (all op families present)?
    pub fn supported_on(&self, spec: &crate::platform::PlatformSpec) -> bool {
        self.op_families.iter().all(|f| spec.supports(f))
    }
}

/// Helper: batch-parameterized problem constructor used by the levels.
pub type ProblemCtor = fn(batch: usize) -> Graph;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::tensor::Shape;

    fn trivial(name: &str) -> Graph {
        let mut b = GraphBuilder::new(name);
        let x = b.input(Shape::of(&[4]));
        let r = b.unary(crate::kir::op::UnaryKind::Relu, x);
        b.finish(vec![r])
    }

    #[test]
    fn eval_inputs_deterministic_per_problem() {
        let p = Problem {
            id: "t".into(),
            level: Level::L1,
            eval_graph: trivial("t"),
            perf_graph: trivial("t"),
            op_families: vec!["relu"],
            constant_output: false,
            reducible: false,
        };
        assert_eq!(p.eval_inputs(1), p.eval_inputs(1));
        assert_ne!(p.eval_inputs(1)[0].data, p.eval_inputs(2)[0].data);
    }

    #[test]
    fn different_problems_different_inputs() {
        let mk = |id: &str| Problem {
            id: id.into(),
            level: Level::L1,
            eval_graph: trivial(id),
            perf_graph: trivial(id),
            op_families: vec![],
            constant_output: false,
            reducible: false,
        };
        assert_ne!(mk("a").eval_inputs(1)[0].data, mk("b").eval_inputs(1)[0].data);
    }
}
