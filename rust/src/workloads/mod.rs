//! The KernelBench-KIR workload suite.
//!
//! 258 problems: the 250 mirroring the KernelBench distribution
//! (Table 2) plus the level-4 whole-model tier:
//! - **Level 1** (100): single primitives — activations, matmuls,
//!   convolutions, reductions, normalizations;
//! - **Level 2** (100): operator sequences with fusion potential —
//!   GEMM+epilogue chains, conv+norm+act blocks, reduction chains
//!   (including the §7.3 constant-output and §7.4 reducible problems);
//! - **Level 3** (50): architectures — Fire modules, MobileNetV2-style
//!   inverted residuals, MinGPT-style transformer blocks, MLP stacks,
//!   VGG/AlexNet-style stages;
//! - **Level 4** (8): whole-model workloads — multi-kernel DAGs from
//!   [`crate::model`] (generated + a committed NNEF fixture), most of
//!   them streamable under the serve tier's pulsed execution.
//!
//! Each problem carries two shape sets: `eval` (small; ground-truth
//! numerics run on the CPU reference executor) and `perf` (paper-scale;
//! priced by the device simulator).  30 problems contain ops missing on
//! Metal (9 L1 + 21 L2) and are excluded there, leaving 228
//! (KernelBench-Metal + the level-4 tier, Table 2).

pub mod spec;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod level4;
pub mod suite;
pub mod refcorpus;
pub mod synth;

pub use spec::{Level, Problem};
pub use suite::Suite;
