//! Level 2: 100 operator-sequence problems with fusion potential
//! (KernelBench L2 analog).
//!
//! Includes the two case-study classes:
//! - `l2_012_reduction_chain` — the §7.4 reducible linear→sum→max→mean→
//!   lse→lse problem (matmul collapses to matvec);
//! - `l2_023_convnorm_mean` / `l2_080_gemm_max_sub_gelu` — the §7.3
//!   constant-output problems (~1% of L1+L2, as the paper reports).
//!
//! 21 problems carry 3-D pooling analogs excluded on Metal (Table 2:
//! 79 of 100 remain).

use super::spec::{Level, Problem};
use crate::kir::graph::{Graph, GraphBuilder};
use crate::kir::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::Shape;

fn gemm_bias_act(name: &str, m: usize, k: usize, n: usize, act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let bias = b.input(Shape::of(&[n]));
    let mm = b.matmul(x, w);
    let a = b.add(mm, bias);
    let r = b.unary(act, a);
    b.finish(vec![r])
}

fn gemm_bias_act_scale(name: &str, m: usize, k: usize, n: usize, act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let bias = b.input(Shape::of(&[n]));
    let scale = b.input(Shape::of(&[n]));
    let mm = b.matmul(x, w);
    let a = b.add(mm, bias);
    let r = b.unary(act, a);
    let s = b.binary(BinaryKind::Mul, r, scale);
    b.finish(vec![s])
}

fn conv_bias_act(name: &str, n: usize, c: usize, hw: usize, o: usize, k: usize, act: UnaryKind, pool3d: bool) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[n, c, hw, hw]));
    let w = b.input(Shape::of(&[o, c, k, k]));
    let bias = b.input(Shape::of(&[1, o, 1, 1]));
    let cv = b.conv2d(x, w, 1, k / 2);
    let a = b.add(cv, bias);
    let r = b.unary(act, a);
    let out = if pool3d {
        // the 3-D pooling analog (2-D stand-in, metal-unsupported family)
        b.push(Op::MaxPool2d { input: r, k: 2, stride: 2 })
    } else {
        r
    };
    b.finish(vec![out])
}

fn elementwise_chain(name: &str, m: usize, n: usize, kinds: &[UnaryKind]) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[m, n]));
    for &k in kinds {
        x = b.unary(k, x);
    }
    b.finish(vec![x])
}

fn gemm_layernorm_act(name: &str, m: usize, k: usize, n: usize, act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let g = b.input(Shape::of(&[n]));
    let be = b.input(Shape::of(&[n]));
    let mm = b.matmul(x, w);
    let ln = b.push(Op::Layernorm { input: mm, gamma: g, beta: be });
    let r = b.unary(act, ln);
    b.finish(vec![r])
}

fn gemm_softmax(name: &str, m: usize, k: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let mm = b.matmul(x, w);
    let sm = b.push(Op::Softmax { input: mm });
    b.finish(vec![sm])
}

/// §7.4: linear → sum(1) → max(1) → mean(1) → lse(1) → lse(1).
fn reduction_chain(name: &str, m: usize, k: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let bias = b.input(Shape::of(&[n]));
    let mm = b.matmul(x, w);
    let lin = b.add(mm, bias);
    let s = b.reduce(ReduceKind::Sum, 1, lin);
    let mx = b.reduce(ReduceKind::Max, 1, s);
    let mean = b.reduce(ReduceKind::Mean, 1, mx);
    let l1 = b.reduce(ReduceKind::LogSumExp, 1, mean);
    let l2 = b.reduce(ReduceKind::LogSumExp, 1, l1);
    b.finish(vec![l2])
}

/// §7.3 / C.3: linear → max(1) → subtract mean(1) → gelu ≡ zeros.
fn gemm_max_sub_gelu(name: &str, m: usize, k: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let bias = b.input(Shape::of(&[n]));
    let mm = b.matmul(x, w);
    let y = b.add(mm, bias);
    let mx = b.reduce(ReduceKind::Max, 1, y);
    let mean = b.reduce(ReduceKind::Mean, 1, mx);
    let sub = b.binary(BinaryKind::Sub, mx, mean);
    let out = b.unary(UnaryKind::Gelu, sub);
    b.finish(vec![out])
}

/// §7.3 / C.2 analog: conv → groupnorm-bias-mean ≡ constant.  Modeled
/// as conv → (x - mean over singleton) → mul-by-zero epilogue whose
/// output provably constant-folds.
fn convnorm_mean_const(name: &str, n: usize, c: usize, hw: usize, o: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[n, c, hw, hw]));
    let w = b.input(Shape::of(&[o, c, 3, 3]));
    let cv = b.conv2d(x, w, 1, 1);
    let gp = b.push(Op::GlobalAvgPool { input: cv }); // [n,o,1,1]
    let m1 = b.reduce(ReduceKind::Mean, 2, gp); // singleton -> identity
    let sub = b.binary(BinaryKind::Sub, gp, m1); // != 0 in general...
    // ...but the chain multiplies by (mean-over-singleton - itself) = 0:
    let zero = b.binary(BinaryKind::Sub, m1, gp);
    let add = b.add(sub, zero); // sub + (-sub) == 0 elementwise? no — keep explicit:
    let out = b.binary(BinaryKind::Mul, add, zero);
    b.finish(vec![out])
}

fn gemm_chain(name: &str, m: usize, k: usize, depth: usize, act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let mut x = b.input(Shape::of(&[m, k]));
    for _ in 0..depth {
        let w = b.input(Shape::of(&[k, k]));
        let mm = b.matmul(x, w);
        x = b.unary(act, mm);
    }
    b.finish(vec![x])
}

fn scale_residual(name: &str, m: usize, n: usize, act: UnaryKind) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let s = b.input(Shape::of(&[n]));
    let h = b.binary(BinaryKind::Mul, x, s);
    let a = b.unary(act, h);
    let r = b.add(a, x);
    b.finish(vec![r])
}

struct Def {
    id: String,
    eval: Graph,
    perf: Graph,
    families: Vec<&'static str>,
    constant_output: bool,
    reducible: bool,
}

/// All 100 Level-2 problems.
pub fn problems() -> Vec<Problem> {
    let mut defs: Vec<Def> = Vec::with_capacity(100);
    let acts = [
        (UnaryKind::Relu, "relu"),
        (UnaryKind::Swish, "swish"),
        (UnaryKind::Gelu, "gelu"),
        (UnaryKind::Sigmoid, "sigmoid"),
        (UnaryKind::Tanh, "tanh"),
    ];

    // -- gemm+bias+act: 5 acts × 3 shapes = 15 ---------------------------
    let gemm_shapes = [(16usize, 1024usize, 1024usize), (128, 512, 512), (16, 4096, 256)];
    for (act, an) in acts {
        for (si, (m, k, n)) in gemm_shapes.iter().enumerate() {
            let id = format!("l2_gemm_bias_{an}_{si}");
            defs.push(Def {
                eval: gemm_bias_act(&id, 8, 32, 24, act),
                perf: gemm_bias_act(&id, *m, *k, *n, act),
                id,
                families: vec!["matmul", an],
                constant_output: false,
                reducible: false,
            });
        }
    }

    // -- gemm+bias+act+scale: 5 -------------------------------------------
    for (act, an) in acts {
        let id = format!("l2_gemm_scale_{an}");
        defs.push(Def {
            eval: gemm_bias_act_scale(&id, 8, 32, 24, act),
            perf: gemm_bias_act_scale(&id, 64, 512, 512, act),
            id,
            families: vec!["matmul", an],
            constant_output: false,
            reducible: false,
        });
    }

    // -- conv+bias+act (plain): 14 ------------------------------------------
    let conv_defs: [(usize, usize, usize, usize, usize); 7] = [
        (16, 16, 32, 32, 3),
        (16, 32, 28, 64, 3),
        (8, 64, 14, 64, 3),
        (16, 3, 64, 16, 5),
        (16, 8, 56, 16, 1),
        (8, 48, 28, 48, 3),
        (16, 24, 32, 24, 3),
    ];
    for (ci, (n, c, hw, o, k)) in conv_defs.iter().enumerate() {
        for (act, an) in [(UnaryKind::Relu, "relu"), (UnaryKind::Swish, "swish")] {
            let id = format!("l2_conv_bias_{an}_{ci}");
            defs.push(Def {
                eval: conv_bias_act(&id, 1, 4, 10, 4, 3, act, false),
                perf: conv_bias_act(&id, *n, *c, *hw, *o, *k, act, false),
                id,
                families: vec!["conv2d", an],
                constant_output: false,
                reducible: false,
            });
        }
    }

    // -- conv+act+3dpool analogs: 21 (metal-unsupported) ---------------------
    for i in 0..21 {
        let (act, an) = acts[i % 5];
        let id = format!("l2_conv_pool3d_{i:02}");
        defs.push(Def {
            eval: conv_bias_act(&id, 1, 4, 12, 4, 3, act, true),
            perf: conv_bias_act(&id, 16, 16 + (i % 4) * 16, 32, 32, 3, act, true),
            id,
            families: vec!["conv2d", an, if i % 2 == 0 { "maxpool3d" } else { "avgpool3d" }],
            constant_output: false,
            reducible: false,
        });
    }

    // -- elementwise chains: 10 ----------------------------------------------
    let chains: [&[UnaryKind]; 5] = [
        &[UnaryKind::Swish, UnaryKind::Relu],
        &[UnaryKind::Sigmoid, UnaryKind::Square, UnaryKind::Neg],
        &[UnaryKind::Gelu, UnaryKind::Tanh],
        &[UnaryKind::Relu, UnaryKind::Sqrt, UnaryKind::Sigmoid],
        &[UnaryKind::Swish, UnaryKind::Swish, UnaryKind::Swish],
    ];
    for (i, ch) in chains.iter().enumerate() {
        for (si, (m, n)) in [(16usize, 16384usize), (256, 2048)].iter().enumerate() {
            let id = format!("l2_ewchain_{i}_{si}");
            defs.push(Def {
                eval: elementwise_chain(&id, 4, 64, ch),
                perf: elementwise_chain(&id, *m, *n, ch),
                id,
                families: vec!["elementwise"],
                constant_output: false,
                reducible: false,
            });
        }
    }

    // -- gemm+layernorm+act: 10 ------------------------------------------------
    for (act, an) in acts {
        for (si, (m, k, n)) in [(16usize, 512usize, 512usize), (128, 768, 768)].iter().enumerate() {
            let id = format!("l2_gemm_ln_{an}_{si}");
            defs.push(Def {
                eval: gemm_layernorm_act(&id, 8, 32, 24, act),
                perf: gemm_layernorm_act(&id, *m, *k, *n, act),
                id,
                families: vec!["matmul", "layernorm", an],
                constant_output: false,
                reducible: false,
            });
        }
    }

    // -- gemm+softmax: 6 ----------------------------------------------------------
    for (i, (m, k, n)) in [
        (16usize, 512usize, 512usize),
        (64, 64, 4096),
        (128, 256, 1024),
        (16, 1024, 128),
        (256, 128, 256),
        (32, 2048, 512),
    ]
    .iter()
    .enumerate()
    {
        let id = format!("l2_gemm_softmax_{i}");
        defs.push(Def {
            eval: gemm_softmax(&id, 6, 24, 20),
            perf: gemm_softmax(&id, *m, *k, *n),
            id,
            families: vec!["matmul", "softmax"],
            constant_output: false,
            reducible: false,
        });
    }

    // -- reduction chains (§7.4 class): 5, all reducible ---------------------------
    for (i, (m, k, n)) in [
        (128usize, 8192usize, 1024usize), // the paper's problem-12 geometry
        (64, 4096, 512),
        (16, 2048, 2048),
        (256, 1024, 256),
        (32, 512, 4096),
    ]
    .iter()
    .enumerate()
    {
        let id = if i == 0 { "l2_012_reduction_chain".to_string() } else { format!("l2_redchain_{i}") };
        defs.push(Def {
            eval: reduction_chain(&id, 8, 32, 24),
            perf: reduction_chain(&id, *m, *k, *n),
            id,
            families: vec!["matmul", "reduce"],
            constant_output: false,
            reducible: true,
        });
    }

    // -- constant-output problems (§7.3 class): 2 (~1% of L1+L2) --------------------
    {
        let id = "l2_080_gemm_max_sub_gelu".to_string();
        defs.push(Def {
            eval: gemm_max_sub_gelu(&id, 8, 32, 24),
            perf: gemm_max_sub_gelu(&id, 128, 512, 1024),
            id,
            families: vec!["matmul", "reduce", "gelu"],
            constant_output: true,
            reducible: false,
        });
        let id = "l2_023_convnorm_mean".to_string();
        defs.push(Def {
            eval: convnorm_mean_const(&id, 1, 3, 8, 4),
            perf: convnorm_mean_const(&id, 128, 3, 16, 16),
            id,
            families: vec!["conv2d", "reduce"],
            constant_output: true,
            reducible: false,
        });
    }

    // -- gemm chains: 7 ---------------------------------------------------------------
    for (i, (m, k, depth)) in [
        (16usize, 256usize, 3usize),
        (64, 512, 2),
        (16, 128, 4),
        (128, 256, 2),
        (32, 1024, 2),
        (16, 64, 6),
        (8, 512, 3),
    ]
    .iter()
    .enumerate()
    {
        let (act, an) = acts[i % 5];
        let id = format!("l2_gemmchain_{i}");
        defs.push(Def {
            eval: gemm_chain(&id, 8, 24, (*depth).min(3), act),
            perf: gemm_chain(&id, *m, *k, *depth, act),
            id,
            families: vec!["matmul", an],
            constant_output: false,
            reducible: false,
        });
    }

    // -- scale+residual: 5 --------------------------------------------------------------
    for (act, an) in acts {
        let id = format!("l2_scaleres_{an}");
        defs.push(Def {
            eval: scale_residual(&id, 4, 64, act),
            perf: scale_residual(&id, 16, 8192, act),
            id,
            families: vec!["elementwise", an],
            constant_output: false,
            reducible: false,
        });
    }

    assert_eq!(defs.len(), 100, "level 2 must have exactly 100 problems, got {}", defs.len());
    defs.into_iter()
        .map(|d| Problem {
            id: d.id,
            level: Level::L2,
            eval_graph: d.eval,
            perf_graph: d.perf,
            op_families: d.families,
            constant_output: d.constant_output,
            reducible: d.reducible,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp::eval;
    use crate::kir::rewrite::constant_fold;
    use crate::kir::validate::validate;
    use crate::platform::metal;

    #[test]
    fn exactly_100_problems() {
        assert_eq!(problems().len(), 100);
    }

    #[test]
    fn twenty_one_metal_exclusions() {
        let m = metal::m4_max();
        let excluded = problems().iter().filter(|p| !p.supported_on(&m)).count();
        assert_eq!(excluded, 21);
    }

    #[test]
    fn all_graphs_validate_and_run() {
        for p in problems() {
            validate(&p.eval_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            validate(&p.perf_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            let ins = p.eval_inputs(0);
            eval(&p.eval_graph, &ins).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        }
    }

    #[test]
    fn constant_output_problems_detected_by_folding() {
        for p in problems().iter().filter(|p| p.constant_output) {
            assert!(
                constant_fold::output_is_constant(&p.eval_graph),
                "{} should constant-fold",
                p.id
            );
        }
    }

    #[test]
    fn constant_flags_are_one_percent_class() {
        let n = problems().iter().filter(|p| p.constant_output).count();
        assert_eq!(n, 2); // ~1% of L1+L2, as §7.3 reports
    }

    #[test]
    fn reducible_problems_actually_reduce() {
        use crate::kir::rewrite::algebraic;
        for p in problems().iter().filter(|p| p.reducible) {
            assert!(
                algebraic::count_opportunities(&p.eval_graph) > 0,
                "{} should be reducible",
                p.id
            );
        }
    }
}
