//! Level 4: whole-model workloads (the tier above KernelBench).
//!
//! Eight multi-kernel model DAGs from [`crate::model`]: one lowered
//! from the committed NNEF fixture (`rust/fixtures/model/tiny_mlp.nnef`)
//! and seven stitched by the seeded generator.  Metadata is computed,
//! never guessed — the same honesty contract as the synthetic tier —
//! and most models are streamable (pulsed execution under serve);
//! one deliberately is not, so the streaming boundary stays exercised.
//!
//! Evaluation graphs run at toy scale (batch 8, narrow widths); perf
//! graphs carry paper-scale batch and width so speedup accounting is
//! meaningful.  All eight stay inside the universally supported op
//! families, so every registered platform keeps the full tier
//! (Table 2: +8 in every column).

use super::spec::{Level, Problem};
use super::synth::family_of;
use crate::kir::graph::Graph;
use crate::kir::rewrite::{algebraic, constant_fold};
use crate::model::{generate, parse_nnef, with_batch, ModelConfig};

/// The committed NNEF fixture, as source text.
pub const TINY_MLP_NNEF: &str = include_str!("../../fixtures/model/tiny_mlp.nnef");

fn families(g: &Graph) -> Vec<&'static str> {
    let mut out = Vec::new();
    for node in &g.nodes {
        if let Some(fam) = family_of(&node.op) {
            if !out.contains(&fam) {
                out.push(fam);
            }
        }
    }
    out
}

fn problem(id: String, eval: Graph, perf: Graph) -> Problem {
    let op_families = families(&perf);
    let constant_output = constant_fold::output_is_constant(&eval);
    let reducible = algebraic::count_opportunities(&eval) > 0;
    Problem {
        id,
        level: Level::L4,
        eval_graph: eval,
        perf_graph: perf,
        op_families,
        constant_output,
        reducible,
    }
}

/// Generated models: (seed, blocks, attention head, global head, name,
/// perf batch, perf d_model).  The global-head entry is the one
/// deliberately non-streamable model.
const GEN: [(u64, usize, bool, bool, &str, usize, usize); 7] = [
    (0x41, 4, false, false, "mlp_chain", 64, 128),
    (0x42, 5, true, false, "attn_mix", 64, 96),
    (0x43, 3, false, false, "shallow", 128, 64),
    (0x44, 6, true, false, "deep_attn", 48, 128),
    (0x45, 4, true, false, "gated_attn", 96, 96),
    (0x46, 5, false, false, "wide", 64, 192),
    (0x47, 4, false, true, "global_mean", 64, 128),
];

/// All 8 Level-4 problems.
pub fn problems() -> Vec<Problem> {
    let mut out = Vec::with_capacity(8);

    // -- the committed NNEF fixture ------------------------------------
    let fixture = parse_nnef(TINY_MLP_NNEF)
        .expect("committed fixture must parse (rust/fixtures/model/tiny_mlp.nnef)");
    let perf = with_batch(&fixture.graph, 128)
        .expect("fixture must re-infer at paper batch");
    out.push(problem("l4_000_tiny_mlp".into(), fixture.graph, perf));

    // -- seven stitched models -----------------------------------------
    for (i, &(seed, blocks, attention, global, name, pb, pd)) in GEN.iter().enumerate() {
        let cfg = ModelConfig {
            batch: 8,
            d_model: 8,
            blocks,
            allow_attention: attention,
            allow_global: global,
        };
        let eval = generate(seed, &cfg);
        let perf = generate(seed, &cfg.scaled(pb, pd));
        out.push(problem(format!("l4_{:03}_{name}", i + 1), eval.graph, perf.graph));
    }

    assert_eq!(out.len(), 8, "level 4 must have exactly 8 problems");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp::eval;
    use crate::kir::validate::validate;
    use crate::model::is_streamable;
    use crate::platform::registry;

    #[test]
    fn exactly_8_problems_with_l4_ids() {
        let ps = problems();
        assert_eq!(ps.len(), 8);
        for p in &ps {
            assert!(p.id.starts_with("l4_"), "{}", p.id);
            assert_eq!(p.level, Level::L4);
        }
    }

    #[test]
    fn all_graphs_validate_and_run() {
        for p in problems() {
            validate(&p.eval_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            validate(&p.perf_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            let out = eval(&p.eval_graph, &p.eval_inputs(0))
                .unwrap_or_else(|e| panic!("{}: {e}", p.id));
            assert!(
                out.iter().all(|t| t.data.iter().all(|v| v.is_finite())),
                "{}: non-finite reference output",
                p.id
            );
        }
    }

    #[test]
    fn supported_on_every_registered_platform() {
        // Table 2: the level-4 column reads 8 for every benchmark row
        for platform in registry().platforms() {
            for p in problems() {
                assert!(
                    p.supported_on(platform.spec()),
                    "{} unsupported on {}",
                    p.id,
                    platform.name()
                );
            }
        }
    }

    #[test]
    fn metadata_is_computed_not_guessed() {
        for p in problems() {
            assert_eq!(
                p.constant_output,
                crate::kir::rewrite::constant_fold::output_is_constant(&p.eval_graph),
                "{}",
                p.id
            );
            assert_eq!(
                p.reducible,
                crate::kir::rewrite::algebraic::count_opportunities(&p.eval_graph) > 0,
                "{}",
                p.id
            );
            assert!(!p.op_families.is_empty(), "{}", p.id);
            assert!(p.op_families.contains(&"matmul"), "{}: no matmul family", p.id);
        }
    }

    #[test]
    fn perf_graphs_are_paper_scale() {
        for p in problems() {
            assert!(
                p.perf_graph.total_flops() > 8.0 * p.eval_graph.total_flops(),
                "{}: perf {} vs eval {}",
                p.id,
                p.perf_graph.total_flops(),
                p.eval_graph.total_flops()
            );
            assert!(p.perf_graph.len() >= 10, "{}: not a whole model", p.id);
        }
    }

    #[test]
    fn streaming_boundary_is_exercised() {
        let ps = problems();
        let streamable = ps.iter().filter(|p| is_streamable(&p.eval_graph)).count();
        assert!(streamable >= 6, "only {streamable}/8 streamable");
        assert!(streamable < ps.len(), "need one non-streamable model");
        // the fixture streams, the global-head model does not
        assert!(is_streamable(&ps[0].eval_graph));
        let global = ps.iter().find(|p| p.id.ends_with("global_mean")).unwrap();
        assert!(!is_streamable(&global.eval_graph));
        // streamability agrees between eval and perf scales
        for p in &ps {
            assert_eq!(
                is_streamable(&p.eval_graph),
                is_streamable(&p.perf_graph),
                "{}",
                p.id
            );
        }
    }
}
