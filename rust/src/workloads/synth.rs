//! Synthetic workload generation: the KIR fuzz generator promoted into
//! an unbounded problem source.
//!
//! The fixed KernelBench-style suite covers 250 hand-written problems;
//! [`Suite::synthetic`](crate::workloads::Suite::synthetic) opens the
//! scenario space beyond it: any `(seed, n)` yields `n` deterministic,
//! well-typed problems drawn from the full op vocabulary, so campaigns
//! (and the conformance gate) can sweep suites no one hand-wrote.
//!
//! Honesty of the problem metadata matters for the §7.3 / §7.4 paths:
//! `constant_output` and `reducible` are *computed* from the generated
//! graph (via `constant_fold::output_is_constant` and
//! `algebraic::count_opportunities`), never guessed, so the generation
//! agent's rewrite discovery probabilities act on synthetic problems
//! exactly as they do on the curated ones.  A slice of problems is also
//! tagged with platform-unsupported op families (drawn from the
//! registry's union) so every platform's suite filter is exercised by
//! any reasonably sized synthetic suite.

use super::spec::{Level, Problem};
use crate::kir::fuzz::{self, FuzzConfig};
use crate::kir::op::Op;
use crate::kir::rewrite::{algebraic, constant_fold};

/// Static family label for an op (Problem.op_families is `&'static str`
/// — these mirror the curated levels' labels where they overlap).
/// Shared with the level-4 whole-model tier, which computes families
/// from its stitched graphs the same way.
pub(crate) fn family_of(op: &Op) -> Option<&'static str> {
    Some(match op {
        Op::Input { .. } | Op::ConstFill { .. } | Op::Reshape { .. } => return None,
        Op::Unary { .. } => "activation",
        Op::Binary { .. } => "binary",
        Op::Matmul { .. } => "matmul",
        Op::Transpose2 { .. } => "transpose",
        Op::Reduce { .. } => "reduce",
        Op::Softmax { .. } => "softmax",
        Op::Layernorm { .. } => "layernorm",
        Op::Attention { .. } => "attention",
        Op::Conv2d { .. } => "conv2d",
        Op::DepthwiseConv2d { .. } => "dwconv2d",
        Op::MaxPool2d { .. } => "maxpool2d",
        Op::AvgPool2d { .. } => "avgpool2d",
        Op::GlobalAvgPool { .. } => "gavgpool",
        Op::Concat { .. } => "concat",
    })
}

/// Union of every registered platform's unsupported-op families, in
/// registration-then-declaration order (deterministic).
fn unsupported_families() -> Vec<&'static str> {
    let mut out: Vec<&'static str> = Vec::new();
    for platform in crate::platform::registry().platforms() {
        for &fam in platform.spec().unsupported_ops {
            if !out.contains(&fam) {
                out.push(fam);
            }
        }
    }
    out
}

/// Every `TAG_STRIDE`-th synthetic problem carries one rotating
/// platform-unsupported family tag, so platform filters always have
/// something to exclude on suites of a dozen problems or more.
const TAG_STRIDE: usize = 5;

/// Generate `n` deterministic synthetic problems from `seed`.
pub fn problems(seed: u64, n: usize) -> Vec<Problem> {
    let cfg = FuzzConfig::default();
    let hard_tags = unsupported_families();
    (0..n)
        .map(|i| {
            let gseed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let level = Level::ALL[i % Level::ALL.len()];
            // L4 slots are whole-model workloads: multi-kernel DAGs
            // from the model stitcher rather than single fuzz kernels,
            // so synthetic suites exercise the level-4 paths (streaming
            // serve requests included) exactly like the curated tier
            let graph = if level == Level::L4 {
                let mcfg = crate::model::ModelConfig {
                    allow_attention: gseed % 2 == 0,
                    ..Default::default()
                };
                crate::model::generate(gseed, &mcfg).graph
            } else {
                fuzz::graph_with(gseed, &cfg)
            };
            let mut op_families: Vec<&'static str> = Vec::new();
            for node in graph.nodes.iter() {
                if let Some(fam) = family_of(&node.op) {
                    if !op_families.contains(&fam) {
                        op_families.push(fam);
                    }
                }
            }
            if !hard_tags.is_empty() && i % TAG_STRIDE == TAG_STRIDE - 1 {
                op_families.push(hard_tags[(i / TAG_STRIDE) % hard_tags.len()]);
            }
            let constant_output = constant_fold::output_is_constant(&graph);
            let reducible = algebraic::count_opportunities(&graph) > 0;
            Problem {
                id: format!("synth_{seed:x}_{i:04}"),
                // nominal difficulty bucket: synthetic problems are not
                // calibrated to KernelBench levels, but campaigns and
                // metrics slice by level, so assign them round-robin
                level,
                perf_graph: graph.clone(),
                eval_graph: graph,
                op_families,
                constant_output,
                reducible,
            }
        })
        .collect()
}

/// Rename helper used by the suite constructor so problem ids (and the
/// per-problem input streams derived from them) never collide with the
/// curated suite.
pub fn is_synthetic_id(id: &str) -> bool {
    id.starts_with("synth_")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp;
    use crate::kir::validate::validate;

    #[test]
    fn problems_are_deterministic_and_valid() {
        let a = problems(0xFEED, 20);
        let b = problems(0xFEED, 20);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.eval_graph, y.eval_graph);
            assert_eq!(x.op_families, y.op_families);
            validate(&x.eval_graph).unwrap();
            assert!(is_synthetic_id(&x.id));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = problems(1, 4);
        let b = problems(2, 4);
        assert!(a.iter().zip(&b).any(|(x, y)| x.eval_graph != y.eval_graph));
    }

    #[test]
    fn all_levels_populated() {
        let ps = problems(3, 9);
        for level in Level::ALL {
            assert!(ps.iter().any(|p| p.level == level), "{level:?} missing");
        }
    }

    #[test]
    fn constness_and_reducibility_tags_are_honest() {
        let ps = problems(0xC0, 40);
        for p in &ps {
            assert_eq!(
                p.constant_output,
                crate::kir::rewrite::constant_fold::output_is_constant(&p.eval_graph),
                "{}",
                p.id
            );
            assert_eq!(
                p.reducible,
                crate::kir::rewrite::algebraic::count_opportunities(&p.eval_graph) > 0,
                "{}",
                p.id
            );
        }
        // the motif injection makes both classes non-empty over 40 problems
        assert!(ps.iter().any(|p| p.reducible), "no reducible synthetic problem");
    }

    #[test]
    fn l4_slots_are_whole_model_graphs() {
        let ps = problems(0x77, 16);
        let l4: Vec<_> = ps.iter().filter(|p| p.level == Level::L4).collect();
        assert_eq!(l4.len(), 4);
        for p in l4 {
            assert!(
                p.eval_graph.name.starts_with("model_"),
                "{}: expected a stitched model graph, got {}",
                p.id,
                p.eval_graph.name
            );
            // whole-model: a multi-kernel DAG with at least one
            // compute anchor, not a single fuzz kernel
            assert!(p.eval_graph.len() >= 10, "{}: too small", p.id);
            assert!(
                p.eval_graph.nodes.iter().any(|n| n.op.is_compute_anchor()),
                "{}: no compute anchor",
                p.id
            );
        }
    }

    #[test]
    fn eval_inputs_flow_through_problem_seeding() {
        let ps = problems(9, 3);
        let p = &ps[0];
        // the Problem::eval_inputs contract (deterministic per id) holds
        assert_eq!(p.eval_inputs(4)[0].data, p.eval_inputs(4)[0].data);
        let out = interp::eval(&p.eval_graph, &p.eval_inputs(4));
        assert!(out.is_ok(), "synthetic reference graph must evaluate");
    }

    #[test]
    fn unsupported_tags_rotate_through_the_registry_union() {
        let ps = problems(0xAB, 30);
        let union = unsupported_families();
        assert!(!union.is_empty(), "registry declares no unsupported ops");
        let tagged: Vec<_> = ps
            .iter()
            .filter(|p| p.op_families.iter().any(|f| union.contains(f)))
            .collect();
        assert_eq!(tagged.len(), 30 / TAG_STRIDE);
        // every family in the union appears on some problem of a
        // 30-problem suite (union is currently 3 families)
        for fam in &union {
            assert!(
                tagged.iter().any(|p| p.op_families.contains(fam)),
                "family {fam} never tagged"
            );
        }
    }
}
