//! Level 1: 100 single-primitive problems (KernelBench L1 analog).
//!
//! Families: activations, matmuls, 2-D convolutions, depthwise convs,
//! reductions, softmax, layernorm, pooling, transpose, binary ops.
//! Nine problems carry op families absent from the MPS backend
//! (conv3d-transpose / 3-D pooling analogs) and are excluded on Metal
//! (Table 2: 91 of 100 remain).

use super::spec::{Level, Problem};
use crate::kir::graph::{Graph, GraphBuilder};
use crate::kir::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::Shape;

fn act_graph(name: &str, kind: UnaryKind, rows: usize, cols: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[rows, cols]));
    // KernelBench's Swish problem is written as `x * torch.sigmoid(x)`
    // — two eager kernels (this is what the §7.2 fused kernel beats).
    let r = if kind == UnaryKind::Swish {
        let s = b.unary(UnaryKind::Sigmoid, x);
        b.binary(BinaryKind::Mul, x, s)
    } else {
        b.unary(kind, x)
    };
    b.finish(vec![r])
}

fn matmul_graph(name: &str, m: usize, k: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, k]));
    let w = b.input(Shape::of(&[k, n]));
    let y = b.matmul(x, w);
    b.finish(vec![y])
}

fn conv_graph(name: &str, n: usize, c: usize, hw: usize, o: usize, k: usize, stride: usize, pad: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[n, c, hw, hw]));
    let w = b.input(Shape::of(&[o, c, k, k]));
    let y = b.conv2d(x, w, stride, pad);
    b.finish(vec![y])
}

fn dwconv_graph(name: &str, n: usize, c: usize, hw: usize, k: usize, stride: usize, pad: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[n, c, hw, hw]));
    let w = b.input(Shape::of(&[c, 1, k, k]));
    let y = b.push(Op::DepthwiseConv2d { input: x, weight: w, stride, padding: pad });
    b.finish(vec![y])
}

fn reduce_graph(name: &str, m: usize, n: usize, kind: ReduceKind, axis: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let y = b.reduce(kind, axis, x);
    b.finish(vec![y])
}

fn softmax_graph(name: &str, m: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let y = b.push(Op::Softmax { input: x });
    b.finish(vec![y])
}

fn layernorm_graph(name: &str, m: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let g = b.input(Shape::of(&[n]));
    let be = b.input(Shape::of(&[n]));
    let y = b.push(Op::Layernorm { input: x, gamma: g, beta: be });
    b.finish(vec![y])
}

fn pool_graph(name: &str, n: usize, c: usize, hw: usize, k: usize, stride: usize, is_max: bool) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[n, c, hw, hw]));
    let y = if is_max {
        b.push(Op::MaxPool2d { input: x, k, stride })
    } else {
        b.push(Op::AvgPool2d { input: x, k, stride })
    };
    b.finish(vec![y])
}

fn binary_graph(name: &str, kind: BinaryKind, m: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let y = b.input(Shape::of(&[m, n]));
    let z = b.binary(kind, x, y);
    b.finish(vec![z])
}

fn transpose_graph(name: &str, m: usize, n: usize) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(Shape::of(&[m, n]));
    let y = b.push(Op::Transpose2 { input: x });
    b.finish(vec![y])
}

struct Def {
    id: &'static str,
    eval: Graph,
    perf: Graph,
    families: Vec<&'static str>,
}

/// All 100 Level-1 problems.
pub fn problems() -> Vec<Problem> {
    let mut defs: Vec<Def> = Vec::with_capacity(100);

    // -- activations: 5 kinds × 4 shapes = 20 ----------------------------
    let acts = [
        (UnaryKind::Relu, "relu"),
        (UnaryKind::Sigmoid, "sigmoid"),
        (UnaryKind::Swish, "swish"),
        (UnaryKind::Gelu, "gelu"),
        (UnaryKind::Tanh, "tanh"),
    ];
    // (rows, cols) perf shapes: the paper's L1 problems use modest batch
    let act_shapes = [(16usize, 16384usize), (128, 4096), (16, 256), (1024, 1024)];
    for (kind, kname) in acts {
        for (si, (r, c)) in act_shapes.iter().enumerate() {
            let id = Box::leak(format!("l1_act_{kname}_{si}").into_boxed_str());
            defs.push(Def {
                id,
                eval: act_graph(id, kind, 4, 64),
                perf: act_graph(id, kind, *r, *c),
                families: vec![kname],
            });
        }
    }

    // -- matmuls: 15 ------------------------------------------------------
    let mm_shapes = [
        (256usize, 256usize, 256usize),
        (1024, 1024, 1024),
        (16, 4096, 4096),
        (4096, 16, 4096),
        (4096, 4096, 16),
        (128, 512, 256),
        (64, 64, 64),
        (2048, 128, 2048),
        (512, 2048, 512),
        (32, 32, 8192),
        (8192, 32, 32),
        (1, 4096, 4096),
        (4096, 4096, 1),
        (768, 768, 768),
        (16, 16, 16),
    ];
    for (i, (m, k, n)) in mm_shapes.iter().enumerate() {
        let id = Box::leak(format!("l1_matmul_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: matmul_graph(id, (m / 64).clamp(1, 8) * 8, (k / 64).clamp(1, 8) * 8, (n / 64).clamp(1, 8) * 8),
            perf: matmul_graph(id, *m, *k, *n),
            families: vec!["matmul"],
        });
    }

    // -- conv2d: 17 + 3 "conv3d_transpose" analogs (metal-unsupported) ----
    let conv_shapes: [(usize, usize, usize, usize, usize, usize, usize); 17] = [
        (16, 3, 224, 64, 7, 2, 3),
        (16, 64, 56, 64, 3, 1, 1),
        (16, 64, 56, 128, 3, 2, 1),
        (16, 128, 28, 128, 3, 1, 1),
        (16, 128, 28, 256, 3, 2, 1),
        (16, 256, 14, 256, 3, 1, 1),
        (16, 16, 32, 32, 5, 1, 2),
        (16, 32, 64, 32, 1, 1, 0),
        (16, 3, 32, 16, 3, 1, 1),
        (8, 96, 28, 96, 3, 1, 1),
        (8, 16, 128, 16, 3, 1, 1),
        (32, 8, 28, 8, 3, 1, 1),
        (16, 64, 14, 64, 1, 1, 0),
        (16, 32, 28, 64, 5, 2, 2),
        (4, 3, 96, 12, 7, 2, 3),
        (16, 48, 28, 48, 3, 1, 1),
        (16, 24, 56, 24, 3, 1, 1),
    ];
    for (i, (n, c, hw, o, k, s, p)) in conv_shapes.iter().enumerate() {
        let id = Box::leak(format!("l1_conv2d_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: conv_graph(id, 1, (*c).min(4), 10, (*o).min(4), (*k).min(3), *s, (*p).min(1)),
            perf: conv_graph(id, *n, *c, *hw, *o, *k, *s, *p),
            families: vec!["conv2d"],
        });
    }
    // 3-D conv-transpose analogs: graphs are 2-D stand-ins, but the op
    // family marks them unsupported on MPS (the paper excluded 9 L1).
    for i in 0..3 {
        let id = Box::leak(format!("l1_conv3dT_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: conv_graph(id, 1, 3, 8, 4, 3, 1, 1),
            perf: conv_graph(id, 8, 16, 32, 16, 3, 1, 1),
            families: vec!["conv3d_transpose"],
        });
    }

    // -- depthwise conv: 5 -------------------------------------------------
    let dw_shapes = [
        (16usize, 32usize, 56usize, 3usize, 1usize, 1usize),
        (16, 64, 28, 3, 1, 1),
        (16, 128, 14, 3, 2, 1),
        (16, 96, 28, 5, 1, 2),
        (8, 256, 14, 3, 1, 1),
    ];
    for (i, (n, c, hw, k, s, p)) in dw_shapes.iter().enumerate() {
        let id = Box::leak(format!("l1_dwconv_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: dwconv_graph(id, 1, 4, 10, 3, 1, 1),
            perf: dwconv_graph(id, *n, *c, *hw, *k, *s, *p),
            families: vec!["dwconv2d"],
        });
    }

    // -- reductions: 12 -----------------------------------------------------
    let rkinds = [
        (ReduceKind::Sum, "sum"),
        (ReduceKind::Max, "max"),
        (ReduceKind::Mean, "mean"),
        (ReduceKind::LogSumExp, "lse"),
    ];
    for (kind, kn) in rkinds {
        for (si, (m, n, ax)) in [(16usize, 16384usize, 1usize), (4096, 256, 0), (256, 4096, 1)]
            .iter()
            .enumerate()
        {
            let id = Box::leak(format!("l1_reduce_{kn}_{si}").into_boxed_str());
            defs.push(Def {
                id,
                eval: reduce_graph(id, 6, 32, kind, *ax),
                perf: reduce_graph(id, *m, *n, kind, *ax),
                families: vec!["reduce"],
            });
        }
    }

    // -- softmax: 6 ----------------------------------------------------------
    for (i, (m, n)) in [(16usize, 16384usize), (128, 4096), (4096, 128), (16, 512), (1024, 1024), (64, 50257)]
        .iter()
        .enumerate()
    {
        let id = Box::leak(format!("l1_softmax_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: softmax_graph(id, 5, 40),
            perf: softmax_graph(id, *m, *n),
            families: vec!["softmax"],
        });
    }

    // -- layernorm: 6 ---------------------------------------------------------
    for (i, (m, n)) in [(16usize, 1024usize), (128, 768), (512, 512), (16, 8192), (2048, 256), (64, 64)]
        .iter()
        .enumerate()
    {
        let id = Box::leak(format!("l1_layernorm_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: layernorm_graph(id, 4, 32),
            perf: layernorm_graph(id, *m, *n),
            families: vec!["layernorm"],
        });
    }

    // -- pooling: 2 + 6 "3-D pooling" analogs (metal-unsupported) -------------
    for (i, (is_max, k)) in [(true, 2usize), (false, 2)].iter().enumerate() {
        let id = Box::leak(format!("l1_pool2d_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: pool_graph(id, 1, 4, 8, *k, *k, *is_max),
            perf: pool_graph(id, 16, 64, 56, *k, *k, *is_max),
            families: vec![if *is_max { "maxpool2d" } else { "avgpool2d" }],
        });
    }
    for i in 0..6 {
        let is_max = i % 2 == 0;
        let id = Box::leak(format!("l1_pool3d_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: pool_graph(id, 1, 4, 8, 2, 2, is_max),
            perf: pool_graph(id, 16, 32, 28, 2, 2, is_max),
            families: vec![if is_max { "maxpool3d" } else { "avgpool3d" }],
        });
    }

    // -- binary + transpose: 8 --------------------------------------------------
    let bins = [
        (BinaryKind::Add, "add"),
        (BinaryKind::Mul, "mul"),
        (BinaryKind::Sub, "sub"),
        (BinaryKind::Div, "div"),
        (BinaryKind::Max, "max"),
    ];
    for (kind, kn) in bins {
        let id = Box::leak(format!("l1_binary_{kn}").into_boxed_str());
        defs.push(Def {
            id,
            eval: binary_graph(id, kind, 4, 64),
            perf: binary_graph(id, kind, 128, 16384),
            families: vec!["binary"],
        });
    }
    for (i, (m, n)) in [(4096usize, 4096usize), (16, 65536), (65536, 16)].iter().enumerate() {
        let id = Box::leak(format!("l1_transpose_{i:02}").into_boxed_str());
        defs.push(Def {
            id,
            eval: transpose_graph(id, 8, 16),
            perf: transpose_graph(id, *m, *n),
            families: vec!["transpose"],
        });
    }

    assert_eq!(defs.len(), 100, "level 1 must have exactly 100 problems, got {}", defs.len());
    defs.into_iter()
        .map(|d| Problem {
            id: d.id.to_string(),
            level: Level::L1,
            eval_graph: d.eval,
            perf_graph: d.perf,
            op_families: d.families,
            constant_output: false,
            reducible: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp::eval;
    use crate::kir::validate::validate;
    use crate::platform::{cuda, metal};

    #[test]
    fn exactly_100_problems() {
        assert_eq!(problems().len(), 100);
    }

    #[test]
    fn nine_metal_exclusions() {
        let m = metal::m4_max();
        let c = cuda::h100();
        let ps = problems();
        let excluded = ps.iter().filter(|p| !p.supported_on(&m)).count();
        assert_eq!(excluded, 9);
        assert!(ps.iter().all(|p| p.supported_on(&c)));
    }

    #[test]
    fn all_graphs_validate() {
        for p in problems() {
            validate(&p.eval_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
            validate(&p.perf_graph).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        }
    }

    #[test]
    fn eval_graphs_run() {
        for p in problems() {
            let ins = p.eval_inputs(0);
            eval(&p.eval_graph, &ins).unwrap_or_else(|e| panic!("{}: {e}", p.id));
        }
    }

    #[test]
    fn ids_unique() {
        let ps = problems();
        let mut ids: Vec<&str> = ps.iter().map(|p| p.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), ps.len());
    }
}
