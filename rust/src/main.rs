//! KForge CLI — the leader entrypoint.
//!
//! ```text
//! kforge suite                      # Table 2 + suite census, per platform
//! kforge run --problem <id> --model <persona> [--platform <name>]
//!                                   # one iterative-refinement job, verbose
//! kforge platforms                  # list the registered platforms
//! kforge bench <fig2|fig3|fig4|table2|table4|table5|table6|cases|all>
//!              [--quick N] [--out DIR]
//! kforge conformance [--bless] [--dir DIR] [--quick N] [--out DIR]
//!                                   # check (or regenerate) the golden
//!                                   # paper artifacts for every platform
//! kforge serve [--artifacts DIR]    # PJRT request loop over real artifacts
//! kforge personas                   # the 8 calibrated personas, per platform
//! ```
//!
//! `--platform` accepts any name or alias registered in
//! `kforge::platform::registry()` — adding a platform module makes it
//! addressable here with no CLI changes.

use anyhow::{bail, Context, Result};
use kforge::agents::persona::{by_name, PERSONAS};
use kforge::coordinator::ExperimentConfig;
use kforge::harness::{self, Scale};
use kforge::platform::{registry, PlatformRef};
use kforge::workloads::Suite;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Resolve `--platform` through the registry (default: cuda).  Unknown
/// names produce an error listing everything registered.
fn platform_arg(args: &[String]) -> Result<PlatformRef> {
    match flag_value(args, "--platform") {
        Some(name) => kforge::platform::by_name(name),
        None => kforge::platform::by_name("cuda"),
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("suite") => cmd_suite(),
        Some("personas") => cmd_personas(),
        Some("platforms") => cmd_platforms(),
        Some("run") => cmd_run(args),
        Some("bench") => cmd_bench(args),
        Some("conformance") => cmd_conformance(args),
        Some("serve") => cmd_serve(args),
        Some(other) => {
            bail!(
                "unknown command {other:?}; try: suite, personas, platforms, run, bench, conformance, serve"
            )
        }
        None => {
            println!("kforge — program synthesis for diverse AI hardware accelerators");
            println!("commands: suite | personas | platforms | run | bench | conformance | serve");
            println!("registered platforms: {}", registry().describe());
            Ok(())
        }
    }
}

fn cmd_suite() -> Result<()> {
    let (_, text) = harness::table2::run();
    println!("{text}");
    let suite = Suite::full();
    let constant = suite.problems.iter().filter(|p| p.constant_output).count();
    let reducible = suite.problems.iter().filter(|p| p.reducible).count();
    println!("total problems: {}", suite.len());
    println!("constant-output (§7.3 class): {constant}");
    println!("algebraically reducible (§7.4 class): {reducible}");
    Ok(())
}

fn cmd_platforms() -> Result<()> {
    println!(
        "{:<8} {:<10} {:<28} {:>10} {:>9} {:>8} {:<8}",
        "name", "language", "device", "mem GB/s", "simd", "workers", "profiler"
    );
    for p in registry().platforms() {
        let s = p.spec();
        let frontend = p.profiler_frontend();
        println!(
            "{:<8} {:<10} {:<28} {:>10.0} {:>9} {:>8} {:<8}",
            p.name(),
            p.language(),
            s.name,
            s.mem_bw / 1e9,
            s.simd_width,
            p.default_workers(),
            format!(
                "{}{}",
                frontend.name(),
                if frontend.lossless() { "" } else { " (lossy)" }
            ),
        );
        if !p.aliases().is_empty() {
            println!("         aliases: {}", p.aliases().join(", "));
        }
    }
    Ok(())
}

fn cmd_personas() -> Result<()> {
    // one single-shot column block per registered platform — platforms
    // without dedicated calibration rows (e.g. rocm) show their
    // fallback-derived prior
    let platforms = registry().platforms();
    print!("{:<18} {:>9}", "model", "reasoning");
    for p in platforms {
        // data cells below render at width 24: {:>14.2} + two "/x.xx"
        print!(" {:>24}", format!("{} L1/L2/L3", p.name()));
    }
    println!();
    for persona in PERSONAS {
        print!("{:<18} {:>9}", persona.name, persona.reasoning);
        for p in platforms {
            let row = persona.single_shot(&**p);
            print!(" {:>14.2}/{:.2}/{:.2}", row[0], row[1], row[2]);
        }
        println!();
    }
    println!("\n(platforms without dedicated calibration fall back per their declared prior)");
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let problem_id = flag_value(args, "--problem").context("--problem <id> required")?;
    let model = flag_value(args, "--model").unwrap_or("openai-gpt-5");
    let platform = platform_arg(args)?;
    let persona = by_name(model).with_context(|| format!("unknown persona {model}"))?;
    let suite = Suite::full();
    let problem = suite
        .get(problem_id)
        .with_context(|| format!("unknown problem {problem_id}"))?;
    if !problem.supported_on(platform.spec()) {
        bail!(
            "problem {problem_id} uses ops unsupported on {} ({:?})",
            platform.name(),
            platform.spec().unsupported_ops
        );
    }

    let mut cfg = ExperimentConfig::iterative(platform.clone(), vec![persona]);
    cfg.use_profiling = true;
    let spec = cfg.spec();
    println!("problem: {problem_id} ({})", problem.level.name());
    println!(
        "persona: {} on {} [{}]",
        persona.name,
        spec.name,
        platform.name()
    );
    println!("reference graph:\n{}", problem.eval_graph.render());
    let result = kforge::coordinator::experiment::run_task(&cfg, &spec, persona, problem, None);
    println!("iteration states: {:?}", result.state_history);
    println!("baseline: {:.3} ms", result.baseline_s * 1e3);
    match result.best_candidate_s {
        Some(t) => println!(
            "best candidate: {:.3} ms (speedup {:.2}x, iteration {})",
            t * 1e3,
            result.outcome.speedup,
            result.best_iteration.unwrap()
        ),
        None => println!("no correct candidate produced"),
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let which = args.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = match flag_value(args, "--quick") {
        Some(n) => Scale::Quick(n.parse().context("--quick N")?),
        None => Scale::Full,
    };
    let out_dir = flag_value(args, "--out").map(std::path::PathBuf::from);
    let mut reports: Vec<(&str, String)> = Vec::new();
    let t0 = std::time::Instant::now();
    match which {
        "table2" => reports.push(("table2", harness::table2::run().1)),
        "fig2" => reports.push(("fig2", harness::fig2::run(scale).1)),
        "fig3" => reports.push(("fig3", harness::fig3::run(scale).1)),
        "table4" => reports.push(("table4", harness::table4::run(scale).1)),
        "fig4" => reports.push(("fig4", harness::fig4::run(scale).1)),
        "table5" => reports.push(("table5", harness::table5::run(scale).1)),
        "table6" => reports.push(("table6", harness::table6::run().1)),
        "cases" => reports.push(("cases", harness::casestudy::run().1)),
        "ablation" => reports.push(("ablation", harness::ablation::run(scale).1)),
        "all" => {
            reports.push(("table2", harness::table2::run().1));
            reports.push(("fig2", harness::fig2::run(scale).1));
            reports.push(("fig3", harness::fig3::run(scale).1));
            reports.push(("table4", harness::table4::run(scale).1));
            reports.push(("fig4", harness::fig4::run(scale).1));
            reports.push(("table5", harness::table5::run(scale).1));
            reports.push(("table6", harness::table6::run().1));
            reports.push(("cases", harness::casestudy::run().1));
            reports.push(("ablation", harness::ablation::run(scale).1));
        }
        other => bail!("unknown bench target {other}"),
    }
    for (name, text) in &reports {
        println!("{text}");
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            std::fs::write(dir.join(format!("{name}.txt")), text)?;
        }
    }
    eprintln!("[bench {which} completed in {:.1}s]", t0.elapsed().as_secs_f64());
    Ok(())
}

/// `kforge conformance [--bless] [--dir DIR] [--quick N] [--out DIR]`
///
/// Renders the full golden artifact set (paper tables/figures + one
/// census per registered platform) once, then either blesses it into
/// `--dir` (default `goldens/`) or checks against what is committed
/// there, reporting per-cell drift.  `--out` additionally captures the
/// rendered artifacts (and `DIFF.txt` on failure) for CI upload.
fn cmd_conformance(args: &[String]) -> Result<()> {
    use kforge::conformance::{self, golden};
    let dir = std::path::PathBuf::from(flag_value(args, "--dir").unwrap_or(golden::DEFAULT_DIR));
    let scale = match flag_value(args, "--quick") {
        Some(n) => Scale::Quick(n.parse().context("--quick N")?),
        None => conformance::SCALE,
    };
    let out_dir = flag_value(args, "--out").map(std::path::PathBuf::from);
    let t0 = std::time::Instant::now();
    let arts = conformance::render_all(scale);
    eprintln!(
        "[rendered {} artifacts in {:.1}s]",
        arts.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(out) = &out_dir {
        golden::write_artifacts(out, &arts)?;
    }
    if args.iter().any(|a| a == "--bless") {
        let names = golden::bless_with(&dir, &arts)?;
        println!(
            "blessed {} golden artifacts into {}: {}",
            names.len(),
            dir.display(),
            names.join(", ")
        );
        return Ok(());
    }
    let report = golden::check_against(&dir, &arts)?;
    println!("{}", report.summary());
    if report.passed() {
        return Ok(());
    }
    if let Some(first) = report.drifted.first() {
        println!("\nfirst drift:\n{}", first.report);
    }
    if let Some(out) = &out_dir {
        std::fs::write(out.join("DIFF.txt"), report.full_diff())?;
    }
    bail!("conformance check failed against {}", dir.display());
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let dir = flag_value(args, "--artifacts").unwrap_or("artifacts");
    let requests: usize = flag_value(args, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    let registry = kforge::runtime::Registry::load(dir)
        .with_context(|| format!("loading artifact registry from {dir} (run `make artifacts`)"))?;
    let rt = kforge::runtime::PjrtRuntime::new(registry)?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.registry().entries.len());
    let keys: Vec<String> = rt.registry().entries.iter().map(|e| e.key.clone()).collect();
    let mut latencies = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        let key = &keys[i % keys.len()];
        let inputs = rt.seeded_inputs(key, i as u64)?;
        let t = std::time::Instant::now();
        let out = rt.execute(key, &inputs)?;
        latencies.push(t.elapsed().as_secs_f64());
        if i == 0 {
            println!("first request: {key} -> {} outputs", out.len());
        }
    }
    let total = t0.elapsed().as_secs_f64();
    let s = kforge::util::stats::summarize(&latencies);
    println!(
        "served {requests} requests in {total:.2}s ({:.1} req/s)",
        requests as f64 / total
    );
    println!(
        "latency ms: p50={:.2} p90={:.2} p99={:.2} max={:.2} (compile-once cache: {} executables)",
        s.p50 * 1e3,
        s.p90 * 1e3,
        s.p99 * 1e3,
        s.max * 1e3,
        rt.cache_len()
    );
    Ok(())
}
