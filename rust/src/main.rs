//! KForge CLI — the leader entrypoint.
//!
//! ```text
//! kforge suite                      # Table 2 + suite census, per platform
//! kforge run --model <persona> [--problem <id>] [--platform <name>]
//!            [--baseline <eager|compile|autotuned>] [--level <L1..L4>]
//!            [--sample N] [--cache-dir DIR] [--resume] [--no-cache]
//!            [--shards N --shard-id K]
//!                                   # one verbose job, or (without
//!                                   # --problem) a resumable campaign,
//!                                   # optionally filtered to one level;
//!                                   # with --shards, run one shard of
//!                                   # an N-way campaign over the
//!                                   # shared --cache-dir
//! kforge dist <spawn|merge> --shards N --cache-dir DIR
//!             [--model <persona>] [--platform <name>] [--baseline B]
//!             [--level <L1..L4>] [--sample N] [--verify]
//!                                   # spawn: fork N shard worker
//!                                   # processes, wait, merge their
//!                                   # journals; merge: fold existing
//!                                   # shard journals (--verify proves
//!                                   # the fold bit-identical to a
//!                                   # 1-process run)
//! kforge model <import|gen> [--nnef PATH] [--seed S] [--blocks N]
//!              [--attention] [--global]
//!                                   # whole-model workloads: import an
//!                                   # NNEF-subset file (or stitch a
//!                                   # seeded DAG), validate, evaluate,
//!                                   # and verify pulsed == whole-graph
//! kforge tune [--platform <name>] [--strategy <beam|evolve>]
//!             [--sample N | --synthetic N] [--budget N] [--seed S]
//!             [--workers N] [--no-evidence] [--no-transfer] [--out DIR]
//!             [--cache-dir DIR] [--no-cache]
//!                                   # schedule autotuner: population
//!                                   # search per problem, store-cached;
//!                                   # exits nonzero if any tuned
//!                                   # schedule prices above naive
//! kforge platforms [--names]        # list the registered platforms
//! kforge bench <fig2|fig3|fig4|table2|table4|table5|table6|cases|all>
//!              [--quick N] [--out DIR] [--json PATH]
//!              [--cache-dir DIR] [--resume] [--no-cache]
//! kforge conformance [--bless] [--dir DIR] [--quick N] [--out DIR]
//!                    [--cache-dir DIR] [--resume] [--no-cache]
//!                                   # check (or regenerate) the golden
//!                                   # paper artifacts for every platform
//! kforge cache <stats|clear|gc> [--cache-dir DIR] [--max-bytes N]
//!                                   # inspect / empty / bound the store
//! kforge serve --synthetic [--requests N] [--workers N] [--seed S]
//!              [--queue-cap N] [--shed-depth N] [--deadline-ms MS]
//!              [--warm K] [--gc-max-bytes N] [--json PATH]
//!              [--streaming-fraction F] [--chunk-rows N]
//!              [--chunk-budget-ms MS] [--exec-shards N]
//!              [--cache-dir DIR] [--no-cache]
//!                                   # deterministic bursty load test:
//!                                   # admission control, deadlines and
//!                                   # cache warming over the shared
//!                                   # result store; level-4 requests
//!                                   # may stream in pulsed chunks;
//!                                   # exits nonzero when the p99 /
//!                                   # shed-rate / chunk budgets fail
//! kforge serve [--artifacts DIR] [--requests N] [--warmup N] [--json PATH]
//!                                   # PJRT artifact replay through the
//!                                   # same service front end
//! kforge trace summarize PATH       # per-phase breakdown + rocprof
//!                                   # self-profile of an emitted trace
//! kforge personas                   # the 8 calibrated personas, per platform
//! ```
//!
//! `run`, `tune`, `bench` and `serve` additionally accept
//! `--trace PATH`: the self-profiling tracer (`kforge::obs`) records
//! structured spans and counters across the whole run and exports them
//! as chrome-trace JSON — readable in a trace viewer, by
//! `kforge trace summarize`, and by KForge's own rocprof frontend.
//! Traced runs produce bit-identical results to untraced ones.
//!
//! `--platform` accepts any name or alias registered in
//! `kforge::platform::registry()` — adding a platform module makes it
//! addressable here with no CLI changes.
//!
//! Every campaign-running command shares one process-wide result store
//! (`kforge::store`): in-memory by default, disk-backed under
//! `--cache-dir` (which also enables per-campaign journals and
//! `--resume`), and fully off under `--no-cache`.  Unknown flags are
//! rejected per subcommand, naming the flag and the valid set.

use anyhow::{bail, Context, Result};
use kforge::agents::persona::{by_name, PERSONAS};
use kforge::coordinator::ExperimentConfig;
use kforge::harness::{self, Scale};
use kforge::platform::{registry, PlatformRef};
use kforge::store::{self, Store};
use kforge::util::cliflags::{self, FlagSpec};
use kforge::workloads::Suite;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// First bare (non-flag) token after the subcommand, skipping flag
/// values — so `kforge bench --quick 3 fig2` and `kforge bench fig2
/// --quick 3` both name the same target.  (The flag spec has already
/// validated every token by the time this runs.)
fn first_positional<'a>(args: &'a [String], value_flags: &[&str]) -> Option<&'a str> {
    let mut i = 1;
    while i < args.len() {
        let tok = args[i].as_str();
        if tok.starts_with("--") {
            if value_flags.contains(&tok) {
                i += 1;
            }
        } else {
            return Some(tok);
        }
        i += 1;
    }
    None
}

/// Resolve `--platform` through the registry (default: cuda).  Unknown
/// names produce an error listing everything registered.
fn platform_arg(args: &[String]) -> Result<PlatformRef> {
    match flag_value(args, "--platform") {
        Some(name) => kforge::platform::by_name(name),
        None => kforge::platform::by_name("cuda"),
    }
}

/// Install the process-wide result store from `--cache-dir` /
/// `--no-cache` / `--resume` before any campaign runs.  Default: an
/// in-memory store shared by every campaign in this process.
fn configure_store(args: &[String]) -> Result<()> {
    let no_cache = has_flag(args, "--no-cache");
    let resume = has_flag(args, "--resume");
    let dir = flag_value(args, "--cache-dir");
    let configured = if no_cache {
        if resume {
            bail!("--resume needs the result store; drop --no-cache");
        }
        if dir.is_some() {
            bail!("--no-cache and --cache-dir are mutually exclusive");
        }
        Store::disabled()
    } else if let Some(d) = dir {
        Store::at_dir(std::path::Path::new(d), resume)?
    } else {
        if resume {
            bail!("--resume requires --cache-dir (campaign journals live in the store directory)");
        }
        Store::memory()
    };
    store::configure(configured)?;
    Ok(())
}

fn dispatch(args: &[String]) -> Result<()> {
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest),
        None => {
            println!("kforge — program synthesis for diverse AI hardware accelerators");
            println!("commands: suite | personas | platforms | run | dist | model | tune | bench | conformance | cache | serve | trace");
            println!("registered platforms: {}", registry().describe());
            println!(
                "search strategies: {}",
                kforge::search::strategies()
                    .iter()
                    .map(|s| s.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            return Ok(());
        }
    };
    let none = FlagSpec { value_flags: &[], bool_flags: &[], max_positionals: 0 };
    let spec = match cmd {
        "suite" | "personas" => none,
        "platforms" => FlagSpec {
            value_flags: &[],
            bool_flags: &["--names"],
            max_positionals: 0,
        },
        "run" => FlagSpec {
            value_flags: &[
                "--problem", "--model", "--platform", "--baseline", "--level", "--sample",
                "--cache-dir", "--trace", "--shards", "--shard-id",
            ],
            bool_flags: &["--resume", "--no-cache"],
            max_positionals: 0,
        },
        "dist" => FlagSpec {
            value_flags: &[
                "--shards", "--model", "--platform", "--baseline", "--level", "--sample",
                "--cache-dir",
            ],
            bool_flags: &["--verify", "--resume", "--no-cache"],
            max_positionals: 1,
        },
        "model" => FlagSpec {
            value_flags: &["--nnef", "--seed", "--blocks"],
            bool_flags: &["--attention", "--global"],
            max_positionals: 1,
        },
        "tune" => FlagSpec {
            value_flags: &[
                "--platform", "--strategy", "--sample", "--synthetic", "--budget", "--seed",
                "--workers", "--out", "--cache-dir", "--trace",
            ],
            bool_flags: &["--no-cache", "--no-evidence", "--no-transfer"],
            max_positionals: 0,
        },
        "bench" => FlagSpec {
            value_flags: &["--quick", "--out", "--json", "--cache-dir", "--trace"],
            bool_flags: &["--resume", "--no-cache"],
            max_positionals: 1,
        },
        "conformance" => FlagSpec {
            value_flags: &["--dir", "--quick", "--out", "--cache-dir"],
            bool_flags: &["--bless", "--resume", "--no-cache"],
            max_positionals: 0,
        },
        "cache" => FlagSpec {
            value_flags: &["--cache-dir", "--max-bytes"],
            bool_flags: &[],
            max_positionals: 1,
        },
        "serve" => FlagSpec {
            value_flags: &[
                "--artifacts", "--requests", "--warmup", "--workers", "--seed", "--queue-cap",
                "--shed-depth", "--deadline-ms", "--warm", "--gc-max-bytes", "--json",
                "--streaming-fraction", "--chunk-rows", "--chunk-budget-ms", "--exec-shards",
                "--cache-dir", "--trace",
            ],
            bool_flags: &["--synthetic", "--no-cache"],
            max_positionals: 0,
        },
        "trace" => FlagSpec {
            value_flags: &[],
            bool_flags: &[],
            max_positionals: 2,
        },
        other => bail!(
            "unknown command {other:?}; try: suite, personas, platforms, run, dist, model, tune, bench, conformance, cache, serve, trace"
        ),
    };
    cliflags::validate(cmd, rest, &spec)?;
    if matches!(cmd, "run" | "dist" | "tune" | "bench" | "conformance" | "serve") {
        configure_store(args)?;
    }
    // arm the self-profiling tracer before any work runs; the export
    // happens after the command returns (even a failed budget gate
    // leaves a trace worth reading)
    let trace_out = match cmd {
        "run" | "tune" | "bench" | "serve" => {
            flag_value(args, "--trace").map(std::path::PathBuf::from)
        }
        _ => None,
    };
    if trace_out.is_some() {
        kforge::obs::enable();
    }
    let result = match cmd {
        "suite" => cmd_suite(),
        "personas" => cmd_personas(),
        "platforms" => cmd_platforms(args),
        "run" => cmd_run(args),
        "dist" => cmd_dist(args),
        "model" => cmd_model(args),
        "tune" => cmd_tune(args),
        "bench" => cmd_bench(args),
        "conformance" => cmd_conformance(args),
        "cache" => cmd_cache(args),
        "serve" => cmd_serve(args),
        "trace" => cmd_trace(args),
        _ => unreachable!("validated above"),
    };
    if let Some(path) = &trace_out {
        kforge::obs::disable();
        match kforge::obs::export::write_trace(path, cmd) {
            Ok(()) => println!("wrote chrome-trace to {}", path.display()),
            Err(e) => kforge::kf_error!("trace export failed: {e:#}"),
        }
    }
    result
}

/// `kforge trace summarize PATH` — render the per-phase breakdown and
/// the rocprof self-profile line for an emitted chrome-trace file.
fn cmd_trace(args: &[String]) -> Result<()> {
    let pos: Vec<&str> =
        args[1..].iter().map(|s| s.as_str()).filter(|a| !a.starts_with("--")).collect();
    match pos.as_slice() {
        ["summarize", path] => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            print!("{}", kforge::obs::summary::summarize(&text)?);
            Ok(())
        }
        ["summarize"] => bail!("trace summarize needs a PATH (a file written by --trace)"),
        _ => bail!("usage: kforge trace summarize PATH"),
    }
}

fn cmd_suite() -> Result<()> {
    let (_, text) = harness::table2::run();
    println!("{text}");
    let suite = Suite::full();
    let constant = suite.problems.iter().filter(|p| p.constant_output).count();
    let reducible = suite.problems.iter().filter(|p| p.reducible).count();
    println!("total problems: {}", suite.len());
    println!("constant-output (§7.3 class): {constant}");
    println!("algebraically reducible (§7.4 class): {reducible}");
    Ok(())
}

fn cmd_platforms(args: &[String]) -> Result<()> {
    if has_flag(args, "--names") {
        // one primary name per line — the scriptable form CI's
        // tune-smoke job iterates
        for p in registry().platforms() {
            println!("{}", p.name());
        }
        return Ok(());
    }
    println!(
        "{:<8} {:<10} {:<28} {:>10} {:>9} {:>8} {:<8}",
        "name", "language", "device", "mem GB/s", "simd", "workers", "profiler"
    );
    for p in registry().platforms() {
        let s = p.spec();
        let frontend = p.profiler_frontend();
        println!(
            "{:<8} {:<10} {:<28} {:>10.0} {:>9} {:>8} {:<8}",
            p.name(),
            p.language(),
            s.name,
            s.mem_bw / 1e9,
            s.simd_width,
            p.default_workers(),
            format!(
                "{}{}",
                frontend.name(),
                if frontend.lossless() { "" } else { " (lossy)" }
            ),
        );
        if !p.aliases().is_empty() {
            println!("         aliases: {}", p.aliases().join(", "));
        }
    }
    Ok(())
}

fn cmd_personas() -> Result<()> {
    // one single-shot column block per registered platform — platforms
    // without dedicated calibration rows (e.g. rocm) show their
    // fallback-derived prior
    let platforms = registry().platforms();
    print!("{:<18} {:>9}", "model", "reasoning");
    for p in platforms {
        // data cells below render at width 24: {:>14.2} + two "/x.xx"
        print!(" {:>24}", format!("{} L1/L2/L3", p.name()));
    }
    println!();
    for persona in PERSONAS {
        print!("{:<18} {:>9}", persona.name, persona.reasoning);
        for p in platforms {
            let row = persona.single_shot(&**p);
            print!(" {:>14.2}/{:.2}/{:.2}", row[0], row[1], row[2]);
        }
        println!();
    }
    println!("\n(platforms without dedicated calibration fall back per their declared prior)");
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    use kforge::coordinator::BaselineKind;
    let model = flag_value(args, "--model").unwrap_or("openai-gpt-5");
    let platform = platform_arg(args)?;
    let persona = by_name(model).with_context(|| format!("unknown persona {model}"))?;
    let mut cfg = ExperimentConfig::iterative(platform.clone(), vec![persona]);
    cfg.use_profiling = true;
    // the baseline kind is part of every job key, so arms never share
    // cached results even under one config name
    cfg.baseline = match flag_value(args, "--baseline").unwrap_or("eager") {
        "eager" => BaselineKind::Eager,
        "compile" | "torch-compile" => BaselineKind::TorchCompile,
        "autotuned" => BaselineKind::Autotuned,
        other => bail!("unknown baseline {other:?}; try: eager, compile, autotuned"),
    };

    let Some(problem_id) = flag_value(args, "--problem") else {
        // campaign mode: the whole suite (or --sample N per level),
        // cached and journaled through the process store, resumable
        // with --cache-dir + --resume after a kill
        let mut suite = match flag_value(args, "--sample") {
            Some(n) => Suite::sample(n.parse().context("--sample N")?),
            None => Suite::full(),
        };
        if let Some(tag) = flag_value(args, "--level") {
            let level = kforge::workloads::Level::from_tag(tag)
                .with_context(|| format!("unknown level {tag:?}; try: L1, L2, L3, L4"))?;
            suite = Suite {
                problems: std::sync::Arc::new(
                    suite.by_level(level).into_iter().cloned().collect(),
                ),
            };
        }
        if let Some(n) = flag_value(args, "--shards") {
            // shard mode: execute one slice of the N-way campaign
            // against the shared disk store; `kforge dist spawn` forks
            // one of these per shard and merges afterwards
            let shards: usize = n.parse().context("--shards N")?;
            let shard_id: usize = flag_value(args, "--shard-id")
                .context("--shards needs --shard-id K (or `kforge dist spawn` to drive all K)")?
                .parse()
                .context("--shard-id K")?;
            println!(
                "campaign {}: shard {shard_id}/{shards}, persona {} on {}",
                cfg.name,
                persona.name,
                platform.name()
            );
            let t0 = std::time::Instant::now();
            let report =
                kforge::dist::run_shard(store::global(), &suite, None, &cfg, shards, shard_id)?;
            println!("{}", report.summary());
            eprintln!("[shard completed in {:.1}s]", t0.elapsed().as_secs_f64());
            return Ok(());
        }
        if has_flag(args, "--shard-id") {
            bail!("--shard-id needs --shards N");
        }
        let supported = suite.supported_on(platform.spec()).len();
        println!(
            "campaign {}: persona {} over {supported} of {} problems on {}",
            cfg.name,
            persona.name,
            suite.len(),
            platform.name()
        );
        let t0 = std::time::Instant::now();
        let campaign = kforge::coordinator::run_campaign(&suite, None, &cfg);
        let outcomes: Vec<_> = campaign.results.iter().map(|r| r.outcome).collect();
        println!(
            "jobs: {}  correct: {:.1}%  fast_1: {:.1}%",
            campaign.results.len(),
            kforge::metrics::correctness_rate(&outcomes) * 100.0,
            kforge::metrics::fast_p(&outcomes, 1.0) * 100.0
        );
        let census = campaign.state_census();
        let census: Vec<String> = census.iter().map(|(k, v)| format!("{k}={v}")).collect();
        println!("iteration states: {}", census.join(" "));
        println!("cache: {}", campaign.cache);
        eprintln!("[campaign completed in {:.1}s]", t0.elapsed().as_secs_f64());
        return Ok(());
    };

    if has_flag(args, "--sample") {
        bail!("--sample only applies to campaign mode; drop --problem to run a sampled campaign");
    }
    if has_flag(args, "--level") {
        bail!("--level only applies to campaign mode; drop --problem to run a filtered campaign");
    }
    if has_flag(args, "--shards") || has_flag(args, "--shard-id") {
        bail!("--shards only applies to campaign mode; drop --problem to shard a campaign");
    }
    let suite = Suite::full();
    let problem = suite
        .get(problem_id)
        .with_context(|| format!("unknown problem {problem_id}"))?;
    if !problem.supported_on(platform.spec()) {
        bail!(
            "problem {problem_id} uses ops unsupported on {} ({:?})",
            platform.name(),
            platform.spec().unsupported_ops
        );
    }
    let spec = cfg.spec();
    println!("problem: {problem_id} ({})", problem.level.name());
    println!(
        "persona: {} on {} [{}]",
        persona.name,
        spec.name,
        platform.name()
    );
    println!("reference graph:\n{}", problem.eval_graph.render());
    // run as a one-problem campaign so the job flows through the
    // result store (and its journal) like any other
    let single = Suite {
        problems: std::sync::Arc::new(vec![problem.clone()]),
    };
    let campaign = kforge::coordinator::run_campaign(&single, None, &cfg);
    let result = &campaign.results[0];
    println!("iteration states: {:?}", result.state_history);
    println!("baseline: {:.3} ms", result.baseline_s * 1e3);
    match result.best_candidate_s {
        Some(t) => println!(
            "best candidate: {:.3} ms (speedup {:.2}x, iteration {})",
            t * 1e3,
            result.outcome.speedup,
            result.best_iteration.unwrap()
        ),
        None => println!("no correct candidate produced"),
    }
    println!("cache: {}", campaign.cache);
    Ok(())
}

/// `kforge dist <spawn|merge>` — the multi-process campaign driver.
///
/// `spawn` forks N `kforge run --shards N --shard-id K` workers of
/// this binary against one shared `--cache-dir` (work-stealing chunk
/// claims stop any two from computing the same job), waits for all of
/// them, then folds their shard journals into one campaign result and
/// prints the same `jobs:` / `iteration states:` summary lines a
/// 1-process `kforge run` prints — CI's dist-smoke job diffs exactly
/// those lines between the two paths.  `merge` folds existing shard
/// journals without running anything (e.g. after re-running a crashed
/// shard); `--verify` additionally runs the same campaign 1-process
/// against the same store and proves the merged fold bit-identical.
fn cmd_dist(args: &[String]) -> Result<()> {
    use kforge::coordinator::BaselineKind;
    use kforge::dist;
    let action = first_positional(
        args,
        &["--shards", "--model", "--platform", "--baseline", "--level", "--sample", "--cache-dir"],
    )
    .context(
        "usage: kforge dist <spawn|merge> --shards N --cache-dir DIR [--model P] \
         [--platform NAME] [--baseline B] [--level L] [--sample N] [--verify]",
    )?;
    if !matches!(action, "spawn" | "merge") {
        bail!("unknown dist action {action:?}; try: spawn, merge");
    }
    let shards: usize = flag_value(args, "--shards")
        .context("dist needs --shards N")?
        .parse()
        .context("--shards N")?;
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    let store = store::global();
    if store.shared_dir().is_none() {
        bail!("dist needs a disk-backed store shared across shard processes; pass --cache-dir DIR");
    }
    // build the exact campaign a worker's `run` builds from the same
    // flags: same config name, key list and job order, so the shard
    // journals and the merge fold address one index space
    let model = flag_value(args, "--model").unwrap_or("openai-gpt-5");
    let platform = platform_arg(args)?;
    let persona = by_name(model).with_context(|| format!("unknown persona {model}"))?;
    let mut cfg = ExperimentConfig::iterative(platform.clone(), vec![persona]);
    cfg.use_profiling = true;
    cfg.baseline = match flag_value(args, "--baseline").unwrap_or("eager") {
        "eager" => BaselineKind::Eager,
        "compile" | "torch-compile" => BaselineKind::TorchCompile,
        "autotuned" => BaselineKind::Autotuned,
        other => bail!("unknown baseline {other:?}; try: eager, compile, autotuned"),
    };
    let mut suite = match flag_value(args, "--sample") {
        Some(n) => Suite::sample(n.parse().context("--sample N")?),
        None => Suite::full(),
    };
    if let Some(tag) = flag_value(args, "--level") {
        let level = kforge::workloads::Level::from_tag(tag)
            .with_context(|| format!("unknown level {tag:?}; try: L1, L2, L3, L4"))?;
        suite = Suite {
            problems: std::sync::Arc::new(suite.by_level(level).into_iter().cloned().collect()),
        };
    }
    if action == "spawn" {
        // forward every campaign-shaping flag (plus the store
        // location) to the workers verbatim
        let mut forward: Vec<String> = Vec::new();
        for name in ["--model", "--platform", "--baseline", "--level", "--sample", "--cache-dir"] {
            if let Some(v) = flag_value(args, name) {
                forward.push(name.to_string());
                forward.push(v.to_string());
            }
        }
        println!(
            "dist: spawning {shards} shard(s) of campaign {} (persona {} on {})",
            cfg.name,
            persona.name,
            platform.name()
        );
        let t0 = std::time::Instant::now();
        let ok = dist::spawn_shards(shards, &forward)?;
        let failed = ok.iter().filter(|s| !**s).count();
        if failed > 0 {
            bail!(
                "{failed} of {shards} shard(s) failed; re-run them, then `kforge dist merge --shards {shards}`"
            );
        }
        eprintln!("[{shards} shard(s) completed in {:.1}s]", t0.elapsed().as_secs_f64());
    }
    let campaign = dist::merge_shards(store, &suite, None, &cfg, shards)?;
    let outcomes: Vec<_> = campaign.results.iter().map(|r| r.outcome).collect();
    // byte-for-byte the campaign summary `kforge run` prints, so the
    // two paths diff clean on these lines
    println!(
        "jobs: {}  correct: {:.1}%  fast_1: {:.1}%",
        campaign.results.len(),
        kforge::metrics::correctness_rate(&outcomes) * 100.0,
        kforge::metrics::fast_p(&outcomes, 1.0) * 100.0
    );
    let census = campaign.state_census();
    let census: Vec<String> = census.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("iteration states: {}", census.join(" "));
    println!("cache: {}", campaign.cache);
    if has_flag(args, "--verify") {
        // the proof obligation: a store-answered 1-process run of the
        // same campaign is bit-identical to the merged fold
        let solo = kforge::coordinator::run_campaign_with(store, &suite, None, &cfg);
        dist::assert_bit_identical(&campaign, &solo)?;
        println!(
            "verify: merged result bit-identical to the 1-process run ({} jobs)",
            solo.results.len()
        );
    }
    Ok(())
}

/// `kforge model <import|gen>` — the whole-model workload layer:
/// import an NNEF-subset file (or stitch a seeded multi-kernel DAG),
/// validate it, print its subgraph provenance, evaluate it on seeded
/// inputs, and — when streamable — verify pulsed (chunked) execution
/// bit-identical to whole-graph.  CI's model-smoke job drives both
/// forms.
fn cmd_model(args: &[String]) -> Result<()> {
    use kforge::model;
    let action = first_positional(args, &["--nnef", "--seed", "--blocks"]).context(
        "usage: kforge model <import|gen> [--nnef PATH] [--seed S] [--blocks N] [--attention] [--global]",
    )?;
    let m = match action {
        "import" => {
            let path = flag_value(args, "--nnef").context("model import needs --nnef PATH")?;
            let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let m = model::parse_nnef(&src)?;
            println!("imported {path}");
            m
        }
        "gen" => {
            let seed: u64 = flag_value(args, "--seed")
                .map(|s| s.parse())
                .transpose()
                .context("--seed S")?
                .unwrap_or(0x41);
            let mut cfg = model::ModelConfig::default();
            if let Some(b) = flag_value(args, "--blocks") {
                cfg.blocks = b.parse().context("--blocks N")?;
            }
            cfg.allow_attention = has_flag(args, "--attention");
            cfg.allow_global = has_flag(args, "--global");
            let m = model::generate(seed, &cfg);
            println!("generated seed={seed:#x} blocks={}", cfg.blocks);
            m
        }
        other => bail!("unknown model action {other:?}; try: import, gen"),
    };
    let g = &m.graph;
    println!(
        "model: {} ({} nodes, {} inputs, {} outputs)",
        g.name,
        g.nodes.len(),
        g.input_shapes.len(),
        g.outputs.len()
    );
    for span in &m.provenance {
        println!("  {:<24} nodes {:>3}..{:<3}", span.name, span.start, span.end);
    }
    let streamable = model::is_streamable(g);
    println!("streamable: {streamable}");
    // evaluate on seeded inputs; when streamable, cross-check the
    // pulsed executor against whole-graph evaluation bit for bit
    let mut rng =
        kforge::util::rng::Pcg::new(0xE7A1, kforge::util::rng::fnv1a(g.name.as_bytes()));
    let inputs: Vec<kforge::tensor::Tensor> = g
        .input_shapes
        .iter()
        .map(|s| kforge::tensor::Tensor::randn(s.clone(), &mut rng, 0.4))
        .collect();
    let whole = kforge::kir::interp::eval(g, &inputs)?;
    println!("eval: {} output tensor(s), first shape {:?}", whole.len(), whole[0].shape.0);
    if streamable {
        let pulsed = model::stream_eval(g, &inputs, 2)?;
        let same = whole.len() == pulsed.len()
            && whole.iter().zip(&pulsed).all(|(a, b)| {
                a.shape == b.shape
                    && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
            });
        if !same {
            bail!("pulsed evaluation diverged from whole-graph");
        }
        println!("pulsed(chunk_rows=2): bit-identical to whole-graph");
    }
    Ok(())
}

/// `kforge tune` — the schedule autotuner: population-based search per
/// problem, cached in the result store, printed as a per-problem table
/// plus the golden-pinned acceptance lines.  Exits nonzero if any
/// autotuned schedule prices above naive (CI's tune-smoke gate).
fn cmd_tune(args: &[String]) -> Result<()> {
    use kforge::search::{strategy_by_name, tune_suite, TuneConfig};
    let platform = platform_arg(args)?;
    let mut cfg = TuneConfig::new(platform.clone());
    if let Some(name) = flag_value(args, "--strategy") {
        cfg.strategy = strategy_by_name(name)?;
    }
    if let Some(n) = flag_value(args, "--budget") {
        cfg.budget = n.parse().context("--budget N")?;
    }
    if let Some(s) = flag_value(args, "--seed") {
        cfg.seed = s.parse().context("--seed S")?;
    }
    if let Some(w) = flag_value(args, "--workers") {
        cfg.workers = w.parse().context("--workers N")?;
    }
    if has_flag(args, "--no-evidence") {
        cfg.use_evidence = false;
    }
    if has_flag(args, "--no-transfer") {
        cfg.use_transfer = false;
    }
    let suite = match (flag_value(args, "--sample"), flag_value(args, "--synthetic")) {
        (Some(_), Some(_)) => bail!("--sample and --synthetic are mutually exclusive"),
        (Some(n), None) => Suite::sample(n.parse().context("--sample N")?),
        (None, Some(n)) => Suite::synthetic(cfg.seed, n.parse().context("--synthetic N")?),
        (None, None) => Suite::sample(4),
    };
    println!(
        "tune: strategy {} on {} over {} problems (budget {}/problem, seed {:#x}, evidence {}, transfer {})",
        cfg.strategy.name(),
        platform.name(),
        suite.supported_on(platform.spec()).len(),
        cfg.budget,
        cfg.seed,
        cfg.use_evidence,
        cfg.use_transfer
    );
    let t0 = std::time::Instant::now();
    let report = tune_suite(&cfg, &suite);
    // one renderer shared with the golden-pinned frontier artifacts —
    // the CLI report and the goldens can never diverge column-wise
    let rendered = kforge::search::frontier::render_report(
        &format!("Autotuned schedules: {} / {}", platform.name(), report.strategy),
        &report,
    );
    print!("{rendered}");
    println!("cache: {}", report.cache);
    if let Some(dir) = flag_value(args, "--out") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("tune_{}_{}.txt", platform.name(), report.strategy));
        std::fs::write(&path, &rendered)?;
        println!("wrote frontier report to {}", path.display());
    }
    eprintln!("[tune {} completed in {:.1}s]", platform.name(), t0.elapsed().as_secs_f64());
    let total = report.outcomes.len();
    if report.count_le_naive() < total {
        bail!(
            "autotuned schedule prices above naive on {} of {total} problems — the search arm must never lose to an untuned program",
            total - report.count_le_naive()
        );
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let which = first_positional(args, &["--quick", "--out", "--json", "--cache-dir"]).unwrap_or("all");
    let scale = match flag_value(args, "--quick") {
        Some(n) => Scale::Quick(n.parse().context("--quick N")?),
        None => Scale::Full,
    };
    let out_dir = flag_value(args, "--out").map(std::path::PathBuf::from);
    let mut reports: Vec<(&str, String)> = Vec::new();
    let t0 = std::time::Instant::now();
    match which {
        "table2" => reports.push(("table2", harness::table2::run().1)),
        "fig2" => reports.push(("fig2", harness::fig2::run(scale).1)),
        "fig3" => reports.push(("fig3", harness::fig3::run(scale).1)),
        "table4" => reports.push(("table4", harness::table4::run(scale).1)),
        "fig4" => reports.push(("fig4", harness::fig4::run(scale).1)),
        "table5" => reports.push(("table5", harness::table5::run(scale).1)),
        "table6" => reports.push(("table6", harness::table6::run().1)),
        "cases" => reports.push(("cases", harness::casestudy::run().1)),
        "ablation" => reports.push(("ablation", harness::ablation::run(scale).1)),
        "all" => {
            reports.push(("table2", harness::table2::run().1));
            reports.push(("fig2", harness::fig2::run(scale).1));
            reports.push(("fig3", harness::fig3::run(scale).1));
            reports.push(("table4", harness::table4::run(scale).1));
            reports.push(("fig4", harness::fig4::run(scale).1));
            reports.push(("table5", harness::table5::run(scale).1));
            reports.push(("table6", harness::table6::run().1));
            reports.push(("cases", harness::casestudy::run().1));
            reports.push(("ablation", harness::ablation::run(scale).1));
        }
        other => bail!("unknown bench target {other}"),
    }
    for (name, text) in &reports {
        println!("{text}");
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            std::fs::write(dir.join(format!("{name}.txt")), text)?;
        }
    }
    println!("cache: {}", store::global().snapshot());
    let wall_s = t0.elapsed().as_secs_f64();
    if let Some(path) = flag_value(args, "--json") {
        // machine-readable summary for the BENCH_*.json perf trajectory
        // (schema kforge-bench-v1, documented in ROADMAP.md)
        let json = bench_json(which, scale, &reports, wall_s, measure_trace_overhead());
        std::fs::write(path, json).with_context(|| format!("writing {path}"))?;
        println!("wrote machine-readable summary to {path}");
    }
    eprintln!("[bench {which} completed in {wall_s:.1}s]");
    Ok(())
}

/// Wall-clock ratio (traced / untraced) of one seeded serve virtual
/// scenario — an emission-heavy, store-free, deterministic loop, so the
/// ratio isolates tracer cost from cache state.  Restores the tracer's
/// prior enabled state; when `--trace` is active the traced probe's
/// events stay in the exported buffer (they are part of this bench
/// run).  Wall-clock noise makes this a trend figure, not a gate — the
/// trajectory diff skips it like `wall_s`.
fn measure_trace_overhead() -> f64 {
    use kforge::obs;
    let was_tracing = obs::enabled();
    let run = || {
        let cfg = kforge::serve::ScenarioConfig::new(0x0B5E, 192, 4);
        let t = std::time::Instant::now();
        let _ = kforge::serve::run_virtual(&cfg, false);
        t.elapsed().as_secs_f64()
    };
    obs::disable();
    let untraced = run();
    obs::enable();
    let traced = run();
    if !was_tracing {
        obs::disable();
    }
    if untraced > 0.0 { traced / untraced } else { 1.0 }
}

/// The `kforge bench --json` document: per-report sizes, wall time,
/// process cache counters, a geomean-speedup block per (platform,
/// persona) from a bounded Quick campaign through the shared store —
/// so repeated emissions accumulate a comparable perf trajectory —
/// a `level4` block (per-whole-model geomean speedup plus the
/// deterministic streaming chunk p99 from the virtual scenario phase),
/// and a `transfer` block: evaluations-to-frontier on one schedule-
/// family mate tuned cold vs seeded with its donor's tuned schedule.
fn bench_json(
    target: &str,
    scale: Scale,
    reports: &[(&str, String)],
    wall_s: f64,
    trace_overhead: f64,
) -> String {
    use kforge::util::json::Json;
    use kforge::util::stats;
    // bound the speedup campaigns: Full-scale bench must not imply a
    // second Full campaign per platform just to emit a summary
    let speedup_scale = match scale {
        Scale::Quick(n) => Scale::Quick(n.min(4)),
        Scale::Full => Scale::Quick(4),
    };
    let suite = speedup_scale.suite();
    let mut speedups = Json::obj();
    for platform in registry().platforms() {
        let cfg = ExperimentConfig::iterative(platform.clone(), PERSONAS.iter().collect());
        let campaign = kforge::coordinator::run_campaign(&suite, None, &cfg);
        let mut per_persona = Json::obj();
        for persona in PERSONAS {
            let outcomes: Vec<kforge::metrics::TaskOutcome> = campaign
                .results
                .iter()
                .filter(|r| r.persona == persona.name)
                .map(|r| r.outcome)
                .collect();
            let correct: Vec<f64> = outcomes
                .iter()
                .filter(|o| o.correct)
                .map(|o| o.speedup)
                .collect();
            let geomean = if correct.is_empty() { 0.0 } else { stats::geomean(&correct) };
            per_persona = per_persona.set(
                persona.name,
                Json::obj()
                    .set("geomean_speedup", geomean)
                    .set("correct", correct.len())
                    .set("jobs", outcomes.len()),
            );
        }
        speedups = speedups.set(platform.name(), per_persona);
    }
    // level-4 whole-model block: per-model geomean speedup across the
    // personas from a bounded campaign on the default platform, plus
    // the deterministic streaming price (chunk p99) from the virtual
    // scenario phase alone — no real synthesis behind the chunk figure
    let l4_suite = {
        let full = Suite::full();
        let ps: Vec<_> = full
            .by_level(kforge::workloads::Level::L4)
            .into_iter()
            .cloned()
            .collect();
        Suite { problems: std::sync::Arc::new(ps) }
    };
    let l4_platform = registry().platforms()[0].clone();
    let l4_cfg = ExperimentConfig::iterative(l4_platform, PERSONAS.iter().collect());
    let l4_campaign = kforge::coordinator::run_campaign(&l4_suite, None, &l4_cfg);
    let mut per_model = Json::obj();
    let mut all_correct: Vec<f64> = Vec::new();
    for p in l4_suite.problems.iter() {
        let correct: Vec<f64> = l4_campaign
            .results
            .iter()
            .filter(|r| r.problem_id == p.id && r.outcome.correct)
            .map(|r| r.outcome.speedup)
            .collect();
        let geomean = if correct.is_empty() { 0.0 } else { stats::geomean(&correct) };
        all_correct.extend(&correct);
        per_model = per_model.set(
            p.id.as_str(),
            Json::obj().set("geomean_speedup", geomean).set("correct", correct.len()),
        );
    }
    let mut l4_scenario = kforge::serve::ScenarioConfig::new(0x5EED, 256, 4);
    l4_scenario.load.synthetic_problems = 16; // guarantees L4 traffic in the pool
    let virt = kforge::serve::run_virtual(&l4_scenario, true);
    let chunk_ms: Vec<f64> =
        virt.requests.iter().flat_map(|r| r.chunk_ms.iter().copied()).collect();
    let streaming_requests =
        virt.requests.iter().filter(|r| !r.chunk_ms.is_empty()).count();
    let chunk_p99 = if chunk_ms.is_empty() {
        Json::Null
    } else {
        Json::from(stats::summarize(&chunk_ms).p99)
    };
    let level4 = Json::obj()
        .set("models", l4_suite.len())
        .set(
            "geomean_speedup",
            if all_correct.is_empty() { 0.0 } else { stats::geomean(&all_correct) },
        )
        .set("per_model", per_model)
        .set(
            "streaming",
            Json::obj()
                .set("scenario_seed", l4_scenario.load.seed as i64)
                .set("requests", streaming_requests)
                .set("chunks", chunk_ms.len())
                .set("chunk_p99_ms", chunk_p99)
                .set("chunk_budget_ms", l4_scenario.chunk_budget_ms),
        );
    // cross-problem schedule-transfer block: the first family (see
    // store::key::family_fingerprint) with two supported members on
    // the default platform; the second member is tuned cold and then
    // seeded with the first's tuned schedule.  Store-free and seeded,
    // so the figures are bit-stable across emissions.
    let transfer = {
        use kforge::search::frontier::FRONTIER_BUDGET;
        use kforge::search::{tune_problem, tune_problem_seeded, TuneConfig};
        use kforge::store::key::family_fingerprint;
        let platform = registry().platforms()[0].clone();
        let spec = platform.spec();
        let full = Suite::full();
        let mut first: std::collections::BTreeMap<u64, &kforge::workloads::Problem> =
            std::collections::BTreeMap::new();
        let mut pair = None;
        for p in full.problems.iter().filter(|p| p.supported_on(spec)) {
            let fam = family_fingerprint(&p.perf_graph);
            match first.get(&fam) {
                Some(donor) => {
                    pair = Some((fam, *donor, p));
                    break;
                }
                None => {
                    first.insert(fam, p);
                }
            }
        }
        match pair {
            None => Json::Null,
            Some((fam, donor_p, mate)) => {
                let mut cfg = TuneConfig::new(platform.clone());
                cfg.budget = FRONTIER_BUDGET;
                let donor = tune_problem(&cfg, donor_p);
                let cold = tune_problem(&cfg, mate);
                let seeded =
                    tune_problem_seeded(&cfg, mate, std::slice::from_ref(&donor.schedule));
                Json::obj()
                    .set("platform", platform.name())
                    .set("family", format!("{fam:016x}"))
                    .set("donor", donor_p.id.as_str())
                    .set("mate", mate.id.as_str())
                    .set("cold_evals_to_frontier", cold.evals_to_best as i64)
                    .set("seeded_evals_to_frontier", seeded.evals_to_best as i64)
                    .set(
                        "saved",
                        cold.evals_to_best as i64 - seeded.evals_to_best as i64,
                    )
                    .set("seeded_le_naive", seeded.tuned_s <= cold.naive_s)
            }
        }
    };
    let snap = store::global().snapshot();
    let cache = Json::obj()
        .set("hits", snap.hits as i64)
        .set("misses", snap.misses as i64)
        .set("resumed", snap.resumed as i64)
        .set("bytes_read", snap.bytes_read as i64)
        .set("bytes_written", snap.bytes_written as i64)
        .set("evictions", snap.evictions as i64);
    let report_list: Vec<Json> = reports
        .iter()
        .map(|(name, text)| Json::obj().set("name", *name).set("bytes", text.len()))
        .collect();
    Json::obj()
        .set("schema", "kforge-bench-v1")
        .set("target", target)
        .set("scale", format!("{scale:?}"))
        .set("speedup_scale", format!("{speedup_scale:?}"))
        .set("wall_s", wall_s)
        .set("trace_overhead", trace_overhead)
        .set("reports", Json::Arr(report_list))
        .set("speedups", speedups)
        .set("level4", level4)
        .set("transfer", transfer)
        .set("cache", cache)
        .to_pretty()
}

/// `kforge cache <stats|clear|gc> [--cache-dir DIR] [--max-bytes N]` —
/// operate on an on-disk result store (default `.kforge-cache`).
fn cmd_cache(args: &[String]) -> Result<()> {
    let action = first_positional(args, &["--cache-dir", "--max-bytes"])
        .context("usage: kforge cache <stats|clear|gc> [--cache-dir DIR] [--max-bytes N]")?;
    if !matches!(action, "stats" | "clear" | "gc") {
        bail!("unknown cache action {action:?}; try: stats, clear, gc");
    }
    let dir = std::path::PathBuf::from(flag_value(args, "--cache-dir").unwrap_or(store::DEFAULT_DIR));
    // inspection must not create the directory it inspects (and a
    // typo'd --cache-dir should be visible, not silently materialized)
    if !dir.exists() {
        println!("cache dir {} does not exist; nothing to do", dir.display());
        return Ok(());
    }
    let cache = kforge::store::Cache::at(&dir)?;
    match action {
        "stats" => {
            let entries = cache.disk_entries()?;
            let bytes: u64 = entries.iter().map(|(_, b, _)| *b).sum();
            let journals = match std::fs::read_dir(dir.join("journals")) {
                Ok(rd) => rd.filter_map(|e| e.ok()).filter(|e| e.path().is_file()).count(),
                Err(_) => 0,
            };
            println!("dir: {}", dir.display());
            println!("objects: {}", entries.len());
            println!("bytes: {bytes}");
            println!("journals: {journals}");
            println!(
                "schema: {} pipeline: {:016x}",
                kforge::store::STORE_SCHEMA,
                kforge::store::key::pipeline_fingerprint()
            );
        }
        "clear" => {
            let removed = cache.clear()?;
            let journals = dir.join("journals");
            if journals.exists() {
                std::fs::remove_dir_all(&journals)?;
            }
            println!(
                "cleared {removed} cached results (and campaign journals) from {}",
                dir.display()
            );
        }
        "gc" => {
            let max_bytes: u64 = match flag_value(args, "--max-bytes") {
                Some(n) => n.parse().context("--max-bytes N")?,
                None => 256 * 1024 * 1024,
            };
            let (evicted, kept) = cache.gc(max_bytes)?;
            println!("evicted {evicted} entries; {kept} bytes kept (budget {max_bytes})");
        }
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// `kforge conformance [--bless] [--dir DIR] [--quick N] [--out DIR]`
///
/// Renders the full golden artifact set (paper tables/figures + one
/// census per registered platform) once, then either blesses it into
/// `--dir` (default `goldens/`) or checks against what is committed
/// there, reporting per-cell drift.  `--out` additionally captures the
/// rendered artifacts (and `DIFF.txt` on failure) for CI upload.
fn cmd_conformance(args: &[String]) -> Result<()> {
    use kforge::conformance::{self, golden};
    let dir = std::path::PathBuf::from(flag_value(args, "--dir").unwrap_or(golden::DEFAULT_DIR));
    let scale = match flag_value(args, "--quick") {
        Some(n) => Scale::Quick(n.parse().context("--quick N")?),
        None => conformance::SCALE,
    };
    let out_dir = flag_value(args, "--out").map(std::path::PathBuf::from);
    let t0 = std::time::Instant::now();
    let arts = conformance::render_all(scale);
    eprintln!(
        "[rendered {} artifacts in {:.1}s]",
        arts.len(),
        t0.elapsed().as_secs_f64()
    );
    // process-level store counters: the CI cache-smoke job asserts the
    // second (warm) render reports nonzero hits here
    println!("cache: {}", store::global().snapshot());
    if let Some(out) = &out_dir {
        golden::write_artifacts(out, &arts)?;
    }
    if args.iter().any(|a| a == "--bless") {
        let names = golden::bless_with(&dir, &arts)?;
        println!(
            "blessed {} golden artifacts into {}: {}",
            names.len(),
            dir.display(),
            names.join(", ")
        );
        return Ok(());
    }
    let report = golden::check_against(&dir, &arts)?;
    println!("{}", report.summary());
    if report.passed() {
        return Ok(());
    }
    if let Some(first) = report.drifted.first() {
        println!("\nfirst drift:\n{}", first.report);
    }
    if let Some(out) = &out_dir {
        std::fs::write(out.join("DIFF.txt"), report.full_diff())?;
    }
    bail!("conformance check failed against {}", dir.display());
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let requests: usize = flag_value(args, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    if requests == 0 {
        bail!("--requests must be at least 1");
    }
    if has_flag(args, "--synthetic") {
        cmd_serve_synthetic(args, requests)
    } else {
        cmd_serve_replay(args, requests)
    }
}

/// The load-test harness: seeded bursty traffic through the virtual-time
/// scenario engine, real execution of every admitted distinct job over
/// the shared store.  Exits nonzero when the declared p99 or shed-rate
/// budget fails.
fn cmd_serve_synthetic(args: &[String], requests: usize) -> Result<()> {
    use kforge::serve;
    let workers: usize = flag_value(args, "--workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    let seed: u64 = flag_value(args, "--seed")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0x5EED);
    let mut cfg = serve::ScenarioConfig::new(seed, requests, workers);
    if let Some(v) = flag_value(args, "--queue-cap") {
        cfg.queue_capacity = v.parse()?;
        // follow capacity unless --shed-depth overrides below
        cfg.shed_depth = cfg.queue_capacity;
    }
    if let Some(v) = flag_value(args, "--shed-depth") {
        cfg.shed_depth = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--deadline-ms") {
        cfg.load.deadline_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--warm") {
        cfg.warm_hottest = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--gc-max-bytes") {
        cfg.gc_max_bytes = Some(v.parse()?);
    }
    if let Some(v) = flag_value(args, "--streaming-fraction") {
        cfg.load.streaming_fraction = v.parse()?;
        if !(0.0..=1.0).contains(&cfg.load.streaming_fraction) {
            bail!("--streaming-fraction must be in [0, 1]");
        }
    }
    if let Some(v) = flag_value(args, "--chunk-rows") {
        cfg.load.chunk_rows = v.parse()?;
        if cfg.load.chunk_rows == 0 {
            bail!("--chunk-rows must be at least 1");
        }
    }
    if let Some(v) = flag_value(args, "--chunk-budget-ms") {
        cfg.chunk_budget_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--exec-shards") {
        let shards: usize = v.parse()?;
        if shards == 0 {
            bail!("--exec-shards must be at least 1");
        }
        cfg.exec_shards = Some(shards);
    }
    if cfg.queue_capacity == 0 {
        bail!("--queue-cap must be at least 1");
    }
    cfg.progress_every = 16;
    let store = store::global();
    println!(
        "serve: synthetic load seed={seed} requests={requests} workers={workers} \
         capacity={} shed_depth={} warm={} store={}",
        cfg.queue_capacity,
        cfg.shed_depth,
        cfg.warm_hottest,
        if store.enabled() { "on" } else { "off" }
    );
    let report = serve::run_scenario(store, &cfg);
    let summary = serve::summarize(&cfg, &report);
    print!("{}", summary.render_text());
    if let Some(path) = flag_value(args, "--json") {
        std::fs::write(path, summary.to_json("synthetic").to_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if !summary.within_latency_budget() {
        bail!(
            "virtual p99 {:.2} ms exceeds the {:.1} ms budget",
            summary.latency.map_or(0.0, |s| s.p99),
            summary.p99_budget_ms
        );
    }
    if !summary.within_shed_budget() {
        bail!(
            "shed rate {:.1}% exceeds the {:.1}% budget",
            summary.shed_rate() * 100.0,
            summary.shed_budget * 100.0
        );
    }
    if !summary.within_chunk_budget() {
        bail!(
            "streaming chunk p99 {:.2} ms exceeds the {:.1} ms budget ({} pulsed-vs-whole mismatches)",
            summary.chunk_latency.map_or(0.0, |s| s.p99),
            summary.chunk_budget_ms,
            summary.stream_mismatches
        );
    }
    Ok(())
}

/// Artifact replay: compiled PJRT artifacts cycled through the
/// real-time service front end on the calling thread (the runtime's
/// executable cache is not `Sync`).
fn cmd_serve_replay(args: &[String], requests: usize) -> Result<()> {
    use kforge::serve::{self, Outcome, Priority};
    use kforge::util::{json::Json, stats};
    let dir = flag_value(args, "--artifacts").unwrap_or("artifacts");
    // the first request pays one-time compilation, which used to skew
    // p95/p99 badly at small --requests; warmup requests are measured
    // and reported separately, never in the percentile summary
    let warmup: usize = flag_value(args, "--warmup")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    let registry = kforge::runtime::Registry::load(dir)
        .with_context(|| format!("loading artifact registry from {dir} (run `make artifacts`)"))?;
    let keys = serve::replay_keys(&registry)?;
    let rt = kforge::runtime::PjrtRuntime::new(registry)?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.registry().entries.len());
    let total = warmup + requests;
    let svc: serve::Service<usize, f64> =
        serve::Service::new(serve::AdmissionPolicy::new(total));
    let tickets: Vec<serve::Ticket<f64>> =
        (0..total).map(|i| svc.submit(Priority::Interactive, None, i)).collect();
    svc.close();
    let t0 = std::time::Instant::now();
    svc.drain_inline(|&i| {
        let key = serve::key_for_request(&keys, i);
        let inputs = rt.seeded_inputs(key, i as u64)?;
        let t = std::time::Instant::now();
        let out = rt.execute(key, &inputs)?;
        if i == 0 {
            println!("first request: {key} -> {} outputs", out.len());
        }
        Ok(t.elapsed().as_secs_f64())
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", svc.stats_line());
    let mut warm_latencies = Vec::new();
    let mut latencies = Vec::new();
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            (Outcome::Completed { .. }, Some(s)) => {
                if i < warmup {
                    warm_latencies.push(s);
                } else {
                    latencies.push(s);
                }
            }
            (Outcome::Failed { error }, _) => bail!("request {i} failed: {error}"),
            (other, _) => bail!("request {i} unexpectedly resolved {}", other.label()),
        }
    }
    if !warm_latencies.is_empty() {
        println!(
            "warmup: {} request(s) excluded from percentiles; first={:.2} ms mean={:.2} ms",
            warmup,
            warm_latencies[0] * 1e3,
            stats::mean(&warm_latencies) * 1e3
        );
    }
    let s = stats::summarize(&latencies);
    println!(
        "served {requests} requests in {wall:.2}s ({:.1} req/s)",
        requests as f64 / wall
    );
    println!(
        "latency ms: p50={:.2} p95={:.2} p99={:.2} max={:.2} (compile-once cache: {} executables)",
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.p99 * 1e3,
        s.max * 1e3,
        rt.cache_len()
    );
    if let Some(path) = flag_value(args, "--json") {
        let counts = svc.counts();
        let doc = Json::obj()
            .set("schema", serve::SERVE_SCHEMA)
            .set("mode", "replay")
            .set("artifacts", keys.len())
            .set(
                "requests",
                Json::obj()
                    .set("total", counts.submitted as i64)
                    .set("completed", counts.completed as i64)
                    .set("rejected", counts.rejected as i64)
                    .set("expired", counts.expired as i64)
                    .set("failed", counts.failed as i64),
            )
            .set(
                "latency_ms",
                Json::obj()
                    .set("p50", s.p50 * 1e3)
                    .set("p95", s.p95 * 1e3)
                    .set("p99", s.p99 * 1e3)
                    .set("max", s.max * 1e3)
                    .set("mean", s.mean * 1e3),
            )
            .set("wall_s", wall);
        std::fs::write(path, doc.to_pretty()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}
