//! Run logging: the paper saves detailed logs for each workload after
//! every generation-evaluation iteration (§3.3).  We serialize campaign
//! results as JSON documents the report tooling (and tests) consume.

use super::experiment::CampaignResult;
use crate::util::json::Json;

/// Serialize one campaign to a JSON document.
pub fn to_json(c: &CampaignResult) -> Json {
    let results: Vec<Json> = c
        .results
        .iter()
        .map(|r| {
            Json::obj()
                .set("problem", r.problem_id.as_str())
                .set("level", r.level.name())
                .set("persona", r.persona)
                .set(
                    "states",
                    Json::Arr(r.state_history.iter().map(|s| Json::Str(s.to_string())).collect()),
                )
                .set("correct", r.outcome.correct)
                .set("speedup", r.outcome.speedup)
                .set("baseline_s", r.baseline_s)
                .set(
                    "best_candidate_s",
                    r.best_candidate_s.map(Json::from).unwrap_or(Json::Null),
                )
                .set(
                    "best_iteration",
                    r.best_iteration.map(|i| Json::from(i)).unwrap_or(Json::Null),
                )
        })
        .collect();
    Json::obj()
        .set("config", c.config_name.as_str())
        .set(
            "cache",
            Json::obj()
                .set("hits", c.cache.hits as f64)
                .set("misses", c.cache.misses as f64)
                .set("resumed", c.cache.resumed as f64)
                .set("bytes_read", c.cache.bytes_read as f64)
                .set("bytes_written", c.cache.bytes_written as f64)
                .set("evictions", c.cache.evictions as f64),
        )
        .set("results", Json::Arr(results))
}

/// Write a campaign log under `dir` as `<config>.json`.
pub fn write(c: &CampaignResult, dir: &std::path::Path) -> anyhow::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{}.json", c.config_name));
    std::fs::write(&path, to_json(c).to_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::TaskResult;
    use crate::metrics::TaskOutcome;
    use crate::workloads::Level;

    fn campaign() -> CampaignResult {
        CampaignResult {
            config_name: "unit".into(),
            cache: crate::store::CacheStats { hits: 2, misses: 1, ..Default::default() },
            results: vec![TaskResult {
                problem_id: "p1".into(),
                level: Level::L2,
                persona: "openai-gpt-5",
                state_history: vec!["mismatch", "correct"],
                outcome: TaskOutcome::correct(1.4),
                best_iteration: Some(1),
                baseline_s: 2.0,
                best_candidate_s: Some(1.43),
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let j = to_json(&campaign());
        let parsed = crate::util::json::parse(&j.to_pretty()).unwrap();
        let r = &parsed.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r.get("problem").unwrap().as_str(), Some("p1"));
        assert_eq!(r.get("correct").unwrap().as_bool(), Some(true));
        assert_eq!(
            r.get("states").unwrap().as_arr().unwrap().len(),
            2
        );
        let cache = parsed.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_f64(), Some(2.0));
        assert_eq!(cache.get("misses").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("kforge_runlog_test");
        let path = write(&campaign(), &dir).unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
