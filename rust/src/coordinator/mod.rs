//! The coordinator: KForge's execution engine.
//!
//! Distributes (persona × problem) jobs over a pool of device workers —
//! one kernel at a time per computational unit, exactly the paper's
//! resource policy (§4.3: one kernel per GPU on CUDA, one per Mac
//! Studio node on Metal) — runs the iterative synthesis loop for each
//! job, and aggregates `fast_p` outcomes.  Deterministic regardless of
//! worker interleaving: every job's RNG stream is forked from
//! (seed, persona, problem) — which is also what makes results from
//! the [`crate::store`] result cache safe to substitute for fresh
//! runs: campaigns consult the store before dispatch and write back
//! (cache + journal) as each job completes.

pub mod job;
pub mod worker;
pub mod experiment;
pub mod runlog;

pub use experiment::{
    run_campaign, run_campaign_with, BaselineKind, CampaignResult, ExperimentConfig,
};
pub use job::TaskResult;
