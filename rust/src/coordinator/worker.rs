//! The device-worker pool.
//!
//! The paper evaluates one kernel at a time per computational unit
//! (§4.3).  `run_jobs` fans a job list over `workers` threads; results
//! return in job order regardless of scheduling, and each job's
//! determinism comes from its own forked RNG stream (see
//! `experiment::run_task`), so the pool size never changes results.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` across `workers` threads with `f`, preserving job order
/// in the returned vector.
pub fn run_jobs<J, R, F>(workers: usize, jobs: &[J], f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let n = jobs.len();
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let workers = workers.clamp(1, n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&jobs[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_jobs(8, &jobs, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_matches_many() {
        let jobs: Vec<usize> = (0..50).collect();
        let a = run_jobs(1, &jobs, |&j| j * j);
        let b = run_jobs(16, &jobs, |&j| j * j);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<usize> = run_jobs(4, &[] as &[usize], |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let count = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..200).collect();
        run_jobs(7, &jobs, |_| count.fetch_add(1, Ordering::Relaxed));
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }
}
