//! The device-worker pool.
//!
//! The paper evaluates one kernel at a time per computational unit
//! (§4.3).  `run_jobs` fans a job list over `workers` threads; results
//! return in job order regardless of scheduling, and each job's
//! determinism comes from its own forked RNG stream (see
//! `experiment::run_task`), so the pool size never changes results.
//!
//! Panic behavior: a panicking job no longer takes its worker thread
//! (and the rest of that thread's queue share) down with it, and the
//! panic is re-raised *naming the job index* — by job order, not by
//! nondeterministic thread timing — once every other job has finished.
//! Before this, the panic surfaced either as the scoped-thread join's
//! opaque payload or as the result slot's `expect("job completed")`,
//! with no way to tell which job died.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Best-effort panic payload rendering (panics carry `&str` or
/// `String` in practice; anything else is labeled as such).  Shared
/// with the chunk-claiming pool in `crate::dist`.
pub(crate) fn payload_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `jobs` across `workers` threads with `f`, preserving job order
/// in the returned vector.  If any job panics, the panic is re-raised
/// on the calling thread as `"job <i> panicked: <message>"` for the
/// smallest failing job index.
pub fn run_jobs<J, R, F>(workers: usize, jobs: &[J], f: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let n = jobs.len();
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<std::thread::Result<R>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let workers = workers.clamp(1, n);
    std::thread::scope(|scope| {
        for _w in 0..workers {
            let (next, results, f) = (&next, &results, &f);
            // trace attribution: allocated on the caller so a top-level
            // pool numbers its workers 1..=N in spawn order (tid 0 is
            // the main thread); nested pools draw fresh ids so no two
            // live threads share one.  A no-op unless tracing is on.
            let tid = crate::obs::alloc_tid();
            scope.spawn(move || {
                crate::obs::set_tid(tid);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = catch_unwind(AssertUnwindSafe(|| f(&jobs[i])));
                    *results[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for (i, slot) in results.into_iter().enumerate() {
        match slot.into_inner().unwrap() {
            Some(Ok(r)) => out.push(r),
            Some(Err(payload)) => {
                panic!("job {i} panicked: {}", payload_text(&*payload))
            }
            // every index below n is claimed exactly once and its slot
            // filled before the worker moves on; the scope join means
            // all workers are done
            None => unreachable!("job {i} slot empty after scope join"),
        }
    }
    out
}

/// Run only the jobs at `indices` (a sparse view over a larger job
/// list), returning results in `indices` order.  This is the partial
/// dispatch the result store uses: jobs answered from the cache never
/// reach the pool, and the remainder keeps the same ordering,
/// panic-naming and determinism guarantees as [`run_jobs`].
pub fn run_sparse<R, F>(workers: usize, indices: &[usize], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    run_jobs(workers, indices, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_jobs(8, &jobs, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_matches_many() {
        let jobs: Vec<usize> = (0..50).collect();
        let a = run_jobs(1, &jobs, |&j| j * j);
        let b = run_jobs(16, &jobs, |&j| j * j);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_runs_only_named_indices_in_order() {
        use std::sync::atomic::AtomicUsize;
        let touched = AtomicUsize::new(0);
        let indices = [7usize, 2, 9, 4];
        let out = run_sparse(3, &indices, |i| {
            touched.fetch_add(1, Ordering::Relaxed);
            i * 10
        });
        assert_eq!(out, vec![70, 20, 90, 40]);
        assert_eq!(touched.load(Ordering::Relaxed), 4);
        let none: Vec<usize> = run_sparse(3, &[], |i| i);
        assert!(none.is_empty());
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<usize> = run_jobs(4, &[] as &[usize], |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let count = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..200).collect();
        run_jobs(7, &jobs, |_| count.fetch_add(1, Ordering::Relaxed));
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }

    #[test]
    #[should_panic(expected = "job 5 panicked: boom 5")]
    fn panicking_job_is_reraised_naming_the_job() {
        let jobs: Vec<usize> = (0..8).collect();
        run_jobs(3, &jobs, |&j| {
            if j == 5 {
                panic!("boom {j}");
            }
            j
        });
    }

    #[test]
    fn panicking_job_does_not_take_down_its_worker() {
        // even with one worker the remaining queue still runs: the
        // worker thread survives the caught panic and drains the list
        use std::sync::atomic::AtomicUsize;
        let count = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..10).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_jobs(1, &jobs, |&j| {
                if j == 2 {
                    panic!("dies early");
                }
                count.fetch_add(1, Ordering::Relaxed);
                j
            })
        }));
        let err = result.expect_err("job 2 must re-raise");
        assert!(payload_text(&*err).contains("job 2 panicked"), "{:?}", payload_text(&*err));
        // the other 9 jobs all completed despite the mid-queue panic
        assert_eq!(count.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn smallest_failing_index_wins() {
        // deterministic re-raise: job order, not thread timing
        let jobs: Vec<usize> = (0..20).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_jobs(8, &jobs, |&j| {
                if j % 7 == 3 {
                    panic!("multi");
                }
                j
            })
        }));
        let err = result.expect_err("several jobs panic");
        assert!(
            payload_text(&*err).starts_with("job 3 panicked"),
            "{}",
            payload_text(&*err)
        );
    }
}
