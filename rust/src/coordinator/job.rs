//! Job and result types for the coordinator.

use crate::metrics::TaskOutcome;
use crate::workloads::Level;

/// Result of running the full iterative loop on one (persona, problem).
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub problem_id: String,
    pub level: Level,
    pub persona: &'static str,
    /// Execution-state label per iteration (§3.3 logging).
    pub state_history: Vec<&'static str>,
    /// Best outcome across iterations (the paper scores the best
    /// correct kernel produced during refinement).
    pub outcome: TaskOutcome,
    /// Iteration index that produced the best outcome (if any).
    pub best_iteration: Option<usize>,
    /// Baseline time (seconds) the speedup is computed against.
    pub baseline_s: f64,
    /// Best candidate time (seconds), if any correct iteration.
    pub best_candidate_s: Option<f64>,
}

impl TaskResult {
    /// Fraction of iterations that were correct.
    pub fn correct_fraction(&self) -> f64 {
        if self.state_history.is_empty() {
            return 0.0;
        }
        self.state_history
            .iter()
            .filter(|s| **s == "correct")
            .count() as f64
            / self.state_history.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correct_fraction() {
        let r = TaskResult {
            problem_id: "x".into(),
            level: Level::L1,
            persona: "p",
            state_history: vec!["mismatch", "correct", "correct"],
            outcome: TaskOutcome::correct(1.5),
            best_iteration: Some(2),
            baseline_s: 1.0,
            best_candidate_s: Some(0.66),
        };
        assert!((r.correct_fraction() - 2.0 / 3.0).abs() < 1e-12);
    }
}
